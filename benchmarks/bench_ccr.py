"""Fig. 7: impact of the communication-to-computation ratio — the
relative makespan as a function of cluster bandwidth β ∈ [0.1, 5].
Paper: higher bandwidth lets DagHetPart exploit parallelism better;
fanned-out families react the most."""
from __future__ import annotations

from repro.core import default_cluster

from .common import emit, geomean, relative_makespan_table

BETAS = (0.1, 0.5, 1.0, 2.0, 5.0)


def run(sizes=(200,), seeds=(1, 2)) -> dict:
    out = {}
    fan_out, fan_in = {}, {}
    for beta in BETAS:
        plat = default_cluster(beta=beta)
        table = relative_makespan_table(plat, sizes, seeds)
        ratios = [r.ratio for runs in table.values() for r in runs
                  if r.ratio and r.family != "real"]
        out[beta] = geomean(ratios)
        emit(f"ccr/beta={beta}/relative_makespan", out[beta] * 100,
             "pct;paper_fig7")
        fanned = [r.ratio for f in ("blast", "bwa") for r in table[f]
                  if r.ratio]
        chainy = [r.ratio for f in ("soykb", "epigenomics")
                  for r in table.get(f, []) if r.ratio]
        fan_out[beta] = geomean(fanned)
        fan_in[beta] = geomean(chainy)
    if out[BETAS[-1]] and out[BETAS[0]]:
        emit("ccr/high_bw_improves_over_low",
             bool(out[BETAS[-1]] <= out[BETAS[0]] * 1.02),
             "paper:trend_down_with_bandwidth")
    if fan_out[BETAS[0]] and fan_out[BETAS[-1]]:
        emit("ccr/fanned_families_gain",
             fan_out[BETAS[0]] / fan_out[BETAS[-1]],
             "x;paper=3.14x_small")
    if fan_in[BETAS[0]] and fan_in[BETAS[-1]]:
        emit("ccr/chainy_families_gain",
             fan_in[BETAS[0]] / fan_in[BETAS[-1]],
             "x;paper=1.27x_small")
    return out


if __name__ == "__main__":
    run()
