"""§Roofline: aggregate the dry-run JSONs into the per-(arch × shape ×
mesh) roofline table and nominate hillclimb candidates.

Terms (per chip, TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s
ICI/link):

    compute_s    = HLO_FLOPs / peak_FLOPs
    memory_s     = HLO_bytes(bf16-corrected) / HBM_bw
    collective_s = collective wire bytes / link_bw

roofline_frac = (MODEL_FLOPS/chips/peak) / max(terms): the fraction of
ideal machine throughput the compiled program could reach if the
dominant term ran at its roofline rate.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    want = ("16x16" + (f"_{tag}" if tag else ""),
            "2x16x16" + (f"_{tag}" if tag else ""))
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        parts = p.stem.split("__")
        if len(parts) != 3 or parts[2] not in want:
            continue
        cells.append(json.loads(p.read_text()))
    return cells


def run(mesh: str = "16x16") -> list[dict]:
    cells = [c for c in load_cells() if c.get("mesh") == mesh]
    ok = [c for c in cells if c.get("status") == "ok"]
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"])):
        key = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        emit(f"{key}/compute_s", c["compute_s"], "")
        emit(f"{key}/memory_s", c["memory_s"], "bf16corr")
        emit(f"{key}/collective_s", c["collective_s"], "")
        emit(f"{key}/dominant", c["dominant"], "")
        emit(f"{key}/useful_flop_frac", c["useful_flop_frac"],
             "MODEL_FLOPS/HLO_FLOPS")
        emit(f"{key}/roofline_frac", c["roofline_frac"], "")
        emit(f"{key}/fits_hbm", c["fits_hbm"],
             f"{c.get('per_device_gib_tpu_est', '?')}GiB")
    failed = [c for c in cells if c.get("status") != "ok"]
    for c in failed:
        emit(f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}/status",
             "ERROR", c.get("error", "")[:80])
    if ok:
        worst = min(ok, key=lambda c: c["roofline_frac"])
        coll = max(ok, key=lambda c: c["collective_s"]
                   / max(c["compute_s"], 1e-12))
        emit("roofline/candidates/worst_fraction",
             f"{worst['arch']}/{worst['shape']}",
             f"frac={worst['roofline_frac']:.4f}")
        emit("roofline/candidates/most_collective_bound",
             f"{coll['arch']}/{coll['shape']}",
             f"coll/comp={coll['collective_s']/max(coll['compute_s'],1e-12):.1f}")
    return ok


if __name__ == "__main__":
    run()
