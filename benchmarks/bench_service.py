"""Service-layer throughput and plan-cache effectiveness.

Two sub-benches, both landing under the ``"service"`` tier of
``BENCH_runtime.json`` (``make bench-service``):

* **serial-repeat** — one tenant resubmits the same pipelines with wide
  arrival spacing (the many-users × few-pipelines traffic model).  The
  first submission of each pipeline plans cold, every repeat hits the
  plan cache and replays through the seeded pipeline (no k' sweep).
  Headline numbers: warm-vs-cold planning-latency ratio (the cache's
  pay-off — the acceptance bar is ≥5x) and the seeded-vs-cold makespan
  premium (the bar is ≤1.25x; on an unchanged platform the replayed
  partition re-refines to the same plan, so the premium is ~1.0).

* **burst** — every job arrives at t=0 across three tenants, with a
  mid-burst processor failure.  This exercises co-scheduling (carved
  sub-platforms), weighted fair-share ordering, capacity deferrals and
  event-driven replanning all at once.  Headline numbers: sustained
  planning throughput (jobs per wall-second), virtual admission-wait
  and end-to-end latency p50/p99, utilization, and the replan/deferral
  counter deltas.

CSV rows follow the ``name,value,derived`` contract of
``benchmarks.run``; the JSON tier is rewritten after each sub-bench so
a partial run still leaves usable data.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import default_cluster
from repro.core.scheduler import SchedulerConfig
from repro.scenario import ProcFailure
from repro.service import (
    ServiceConfig,
    Submission,
    run_service,
)

from .bench_runtime import _load_results, _write_results
from .common import KPRIME as FULL_KPRIME
from .common import emit

KPRIME = [2, 4, 6, 9]
FAMILIES = ["montage", "epigenomics", "seismology", "blast"]


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=float), q)) if xs \
        else float("nan")


def _mean(xs):
    return float(np.mean(xs)) if xs else float("nan")


def serial_repeat(n: int = 150, repeats: int = 4, seed: int = 1) -> dict:
    """Each pipeline submitted ``repeats`` times, spaced far apart so
    jobs never overlap: every plan sees the identical full platform and
    the cache hit rate is exactly (repeats-1)/repeats."""
    from repro.core import generate_workflow

    plat = default_cluster()
    # the paper's full k' sweep: what a cold plan costs in production —
    # and exactly what a cache hit skips
    cfg = ServiceConfig(
        scheduler=SchedulerConfig(simulate=True, kprime=FULL_KPRIME),
        name="serial-repeat")
    subs = []
    gap = 1e9  # far larger than any makespan: strictly serial
    t = 0.0
    for fam in FAMILIES:
        wf = generate_workflow(fam, n, seed=seed, platform=plat)
        for r in range(repeats):
            subs.append(Submission(wf, tenant="solo", arrival_t=t,
                                   name=f"{fam}-{r}"))
            t += gap
    rep = run_service(subs, plat, config=cfg)

    cold = rep.plan_wall_s.get("cold", [])
    seeded = rep.plan_wall_s.get("seeded", [])
    by_path: dict[str, list[float]] = {"cold": [], "seeded": []}
    mk_pairs = []
    cold_mk: dict[str, float] = {}
    for j in rep.completed:
        by_path.setdefault(j.planning_path, []).append(j.makespan)
        fam = j.name.rsplit("-", 1)[0]
        if j.planning_path == "cold":
            cold_mk[fam] = j.makespan
        else:
            mk_pairs.append(j.makespan / cold_mk[fam])
    speedup = (_mean(cold) / _mean(seeded)) if seeded else float("nan")
    premium = _mean(mk_pairs) if mk_pairs else float("nan")

    emit("service.serial.jobs", len(rep.completed))
    emit("service.serial.cache_hit_rate", rep.cache_hit_rate,
         f"expected {(repeats - 1) / repeats:.3f}")
    emit("service.serial.cold_plan_ms", _mean(cold) * 1e3,
         f"n={len(cold)}")
    emit("service.serial.seeded_plan_ms", _mean(seeded) * 1e3,
         f"n={len(seeded)}")
    emit("service.serial.plan_speedup", speedup, "target >= 5x")
    emit("service.serial.makespan_premium", premium, "target <= 1.25x")
    return {
        "jobs": len(rep.completed),
        "cache_hit_rate": rep.cache_hit_rate,
        "cold_plan_ms": _mean(cold) * 1e3,
        "seeded_plan_ms": _mean(seeded) * 1e3,
        "plan_speedup": speedup,
        "makespan_premium": premium,
        "cache_stats": {k: v for k, v in rep.cache_stats.items()
                        if k.startswith("service")},
    }


def burst(n: int = 120, jobs_per_tenant: int = 3, seed: int = 1) -> dict:
    """Everything arrives at t=0; a processor failure lands mid-burst."""
    from repro.core import generate_workflow

    plat = default_cluster()
    cfg = ServiceConfig(
        scheduler=SchedulerConfig(simulate=True, kprime=KPRIME),
        name="burst")
    subs = []
    for ti in range(3):
        for ji in range(jobs_per_tenant):
            fam = FAMILIES[(ti + ji) % len(FAMILIES)]
            wf = generate_workflow(fam, n, seed=seed + ji,
                                   platform=plat)
            subs.append(Submission(wf, tenant=f"tenant{ti}",
                                   arrival_t=0.0,
                                   name=f"t{ti}-{fam}-{ji}"))
    # the big-memory C2 processors are the contended ones — failing two
    # of them is what actually displaces running plans
    events = [ProcFailure(time=150.0, procs={plat.k - 6, plat.k - 5})]
    t0 = time.perf_counter()
    rep = run_service(subs, plat, events, cfg)
    wall = time.perf_counter() - t0

    waits = [j.queue_wait for j in rep.completed]
    lats = [j.latency for j in rep.completed]
    stats = {k: v for k, v in rep.cache_stats.items()
             if k.startswith("service")}
    jobs_per_s = len(rep.completed) / wall if wall > 0 else float("nan")

    emit("service.burst.jobs", len(rep.completed),
         f"of {len(subs)} submitted")
    emit("service.burst.jobs_per_s", jobs_per_s, f"wall {wall:.2f}s")
    emit("service.burst.wait_p50", _pct(waits, 50), "virtual time")
    emit("service.burst.wait_p99", _pct(waits, 99))
    emit("service.burst.latency_p50", _pct(lats, 50))
    emit("service.burst.latency_p99", _pct(lats, 99))
    emit("service.burst.utilization", rep.utilization or float("nan"))
    emit("service.burst.replans", stats.get("service_replans", 0))
    emit("service.burst.deferrals", stats.get("service_deferrals", 0))
    return {
        "submitted": len(subs),
        "completed": len(rep.completed),
        "infeasible": len(rep.infeasible),
        "jobs_per_s": jobs_per_s,
        "wall_s": wall,
        "wait_p50": _pct(waits, 50),
        "wait_p99": _pct(waits, 99),
        "latency_p50": _pct(lats, 50),
        "latency_p99": _pct(lats, 99),
        "utilization": rep.utilization,
        "counters": stats,
    }


def run(write_json: bool = True) -> dict:
    results = _load_results()
    tier = results.setdefault("service", {})
    tier["serial_repeat"] = serial_repeat()
    if write_json:
        _write_results(results)
    tier["burst"] = burst()
    if write_json:
        _write_results(results)
    return tier


if __name__ == "__main__":
    out = run()
    sp = out["serial_repeat"]["plan_speedup"]
    pm = out["serial_repeat"]["makespan_premium"]
    ok = sp >= 5.0 and pm <= 1.25
    print(f"# plan cache: {sp:.1f}x faster planning at "
          f"{pm:.3f}x makespan ({'PASS' if ok else 'MISS'})",
          file=sys.stderr)
