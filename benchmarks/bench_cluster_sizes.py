"""Fig. 3 (right): relative makespan vs cluster size (18/36/60 CPUs).
Paper: improvement grows with cluster size (to ~4.96× on big flows)."""
from __future__ import annotations

from repro.core import default_cluster, large_cluster, small_cluster

from .common import emit, geomean, relative_makespan_table

_KP = {
    18: [1, 2, 4, 6, 9, 13, 18],
    36: None,  # default KPRIME
    60: [1, 2, 4, 8, 12, 18, 27, 40, 60],
}


def run(sizes=(200, 1000), seeds=(1,)) -> dict:
    out = {}
    for plat in (small_cluster(), default_cluster(), large_cluster()):
        table = relative_makespan_table(plat, sizes, seeds,
                                        kprime=_KP.get(plat.k))
        ratios = [r.ratio for runs in table.values() for r in runs
                  if r.ratio and runs and r.family != "real"]
        out[plat.k] = geomean(ratios)
        emit(f"cluster_size/{plat.k}cpus/relative_makespan",
             out[plat.k] * 100, "pct;paper_fig3_right")
    if out.get(60) and out.get(18):
        emit("cluster_size/large_beats_small",
             out[60] <= out[18] * 1.05, "paper:improves_with_size")
    return out


if __name__ == "__main__":
    run()
