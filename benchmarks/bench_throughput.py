"""Steady-state throughput: replication pay-off and the saturation knee.

Two sub-benches, both landing under the ``"throughput"`` tier of
``BENCH_runtime.json`` (``make bench-throughput``):

* **replication** — each n=1000 family is planned for sustained
  traffic with a deliberately coarse partition (k'=3: a fine partition
  would consume every big-memory C2 processor and leave nothing to
  replicate onto).  Headline numbers per family: the replicated
  instances/s over the unreplicated steady-state rate (the acceptance
  bar is ≥1.5x with ≥2 replica groups on at least one family) and the
  p50/p99 per-instance latency of a sustained replay at 80% of the
  plan rate — read off the ``sustained_instance_latency`` obs
  histogram, not recomputed.

* **saturation** — one family's plan replayed against an offered-rate
  ladder spanning the analytic sustainable rate, through the plan
  cache (the first rung plans cold, the rest seed).  Headline numbers:
  achieved rate and latency percentiles per rung, and the saturation
  point — the first offered rate the pipeline can no longer keep up
  with (achieved < 95% of offered).

CSV rows follow the ``name,value,derived`` contract of
``benchmarks.run``; the JSON tier is rewritten after each sub-bench so
a partial run still leaves usable data.
"""
from __future__ import annotations

import sys

from repro.core import default_cluster, generate_workflow
from repro.service import PlanCache, run_sustained
from repro.throughput import plan_throughput, replicate_plan

from .bench_runtime import _load_results, _write_results
from .common import emit

#: coarse on purpose — small k' leaves dominating processors free, so
#: replication has room (see the module docstring)
KPRIME = [3]
FAMILIES = ["genome", "blast", "montage", "seismology"]


def replication(n: int = 1000, seed: int = 1) -> dict:
    """Replicated vs. unreplicated sustainable rate, per family."""
    plat = default_cluster()
    out: dict[str, dict] = {}
    for fam in FAMILIES:
        wf = generate_workflow(fam, n, seed=seed, platform=plat)
        tr = plan_throughput(wf, plat, kprime=KPRIME, workers=1)
        if not tr.feasible:
            emit(f"throughput.repl.{fam}.feasible", 0)
            out[fam] = {"feasible": False}
            continue
        unrep = replicate_plan(tr.best, plat, max_replicas=1)
        improvement = tr.plan.rate / unrep.rate
        rep = run_sustained(wf, plat, rate=0.8 * tr.plan.rate,
                            n_instances=24, seed=seed, kprime=KPRIME)
        pct = rep.instance_latency_percentiles or {}
        emit(f"throughput.repl.{fam}.groups", tr.plan.n_replicas)
        emit(f"throughput.repl.{fam}.rate", tr.plan.rate,
             "instances per time unit")
        emit(f"throughput.repl.{fam}.improvement", improvement,
             "vs unreplicated; target >= 1.5x somewhere")
        emit(f"throughput.repl.{fam}.achieved", rep.instances_per_s,
             "sustained replay at 0.8x plan rate")
        emit(f"throughput.repl.{fam}.latency_p50", pct.get("p50"))
        emit(f"throughput.repl.{fam}.latency_p99", pct.get("p99"))
        out[fam] = {
            "feasible": True,
            "k_prime": tr.k_prime,
            "groups": tr.plan.n_replicas,
            "period": tr.plan.period,
            "rate": tr.plan.rate,
            "unreplicated_rate": unrep.rate,
            "improvement": improvement,
            "achieved_rate": rep.instances_per_s,
            "latency_p50": pct.get("p50"),
            "latency_p99": pct.get("p99"),
            "memory_feasible": rep.pipelined.memory.feasible,
        }
    return out


def saturation(family: str = "genome", n: int = 1000,
               seed: int = 1) -> dict:
    """Offered-rate ladder through the plan cache: the latency knee."""
    plat = default_cluster()
    wf = generate_workflow(family, n, seed=seed, platform=plat)
    tr = plan_throughput(wf, plat, kprime=KPRIME, workers=1)
    cache = PlanCache()
    rows = []
    sat_point = None
    for frac in (0.3, 0.6, 0.9, 1.1):
        offered = frac * tr.plan.rate
        rep = run_sustained(wf, plat, rate=offered, n_instances=32,
                            seed=seed, cache=cache, kprime=KPRIME)
        pct = rep.instance_latency_percentiles or {}
        achieved = rep.instances_per_s
        saturated = achieved < 0.95 * offered
        if saturated and sat_point is None:
            sat_point = offered
        rows.append({
            "offered": offered,
            "fraction_of_plan_rate": frac,
            "achieved": achieved,
            "latency_p50": pct.get("p50"),
            "latency_p99": pct.get("p99"),
            "saturated": saturated,
            "planning_path": rep.jobs[0].planning_path,
        })
        emit(f"throughput.sat.{family}.{frac:g}x.achieved", achieved,
             f"offered {offered:.6g}")
        emit(f"throughput.sat.{family}.{frac:g}x.latency_p99",
             pct.get("p99"))
    emit(f"throughput.sat.{family}.plan_rate", tr.plan.rate)
    emit(f"throughput.sat.{family}.saturation_point",
         sat_point if sat_point is not None else float("nan"),
         "first offered rate the pipeline cannot sustain")
    return {
        "family": family,
        "plan_rate": tr.plan.rate,
        "groups": tr.plan.n_replicas,
        "ladder": rows,
        "saturation_point": sat_point,
    }


def run(write_json: bool = True) -> dict:
    results = _load_results()
    tier = results.setdefault("throughput", {})
    tier["replication"] = replication()
    if write_json:
        _write_results(results)
    tier["saturation"] = saturation()
    if write_json:
        _write_results(results)
    return tier


if __name__ == "__main__":
    out = run()
    winners = [(f, r) for f, r in out["replication"].items()
               if r.get("feasible") and r["groups"] >= 2
               and r["improvement"] >= 1.5]
    if winners:
        f, r = max(winners, key=lambda fr: fr[1]["improvement"])
        print(f"# replication: {r['improvement']:.2f}x instances/s "
              f"with {r['groups']} groups on {f} (PASS)",
              file=sys.stderr)
    else:
        print("# replication: no family reached 1.5x with >=2 groups "
              "(MISS)", file=sys.stderr)
