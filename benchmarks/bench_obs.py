"""Observability overhead: the ``repro.obs`` inertness budget.

PR 8's hard contract is that tracing is *provably inert*: makespans are
bit-identical with ``ObsConfig(enabled=True)`` and near-zero overhead
remains when disabled.  This tier measures both on the n=1000 synthetic
suite (seed=1, full k' grid, same instances as the ``quick`` tier):

* ``disabled_vs_pr7`` — the instrumented-but-disabled scheduler against
  the embedded PR-7 wall clocks (budget: ≤2% regression),
* ``enabled_vs_disabled`` — full span tracing (run/sweep-point/stage
  spans + Chrome-trace export) against disabled (budget: ≤10%),
* per-family bit-identity asserts between the two modes.

Timings are best-of-``REPEATS`` to damp scheduler-noise; the budgets
are recorded in the ``obs`` tier of ``BENCH_runtime.json`` (boolean
``within_budget`` flags, not hard asserts — wall clocks on a shared
container drift, the bit-identity asserts are the hard contract).

``python -m benchmarks.bench_obs`` or ``make bench-obs``.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core import default_cluster, schedule
from repro.obs import ObsConfig

from .bench_runtime import _load_results, _write_results
from .common import KPRIME, emit, geomean, workflow_suite

# n=1000 dag_het_part wall clocks measured on this container at the
# PR-7 head (seed=1, full k' grid) — the fixed "before instrumentation"
# anchor for the disabled-overhead budget.
PR7_HET_BASELINE_S = {
    "genome": 0.0872, "blast": 0.0622, "bwa": 0.0767,
    "epigenomics": 0.4135, "montage": 0.2647, "seismology": 0.0535,
    "soykb": 0.1045,
}

DISABLED_BUDGET = 1.02   # ≤2% vs the PR-7 anchor
ENABLED_BUDGET = 1.10    # ≤10% vs disabled
REPEATS = 3


def _best_of(fn, repeats: int = REPEATS):
    best_dt, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        dt = time.perf_counter() - t0
        if dt < best_dt:
            best_dt, out = dt, res
    return best_dt, out


def run(n: int = 1000, seeds=(1,), write_json: bool = True) -> dict:
    plat = default_cluster()
    results = _load_results()
    tier_out = results.setdefault("obs", {})
    rows: list[dict] = []
    tmp = Path(tempfile.mkdtemp(prefix="bench_obs_"))
    for family, _n, seed, wf in workflow_suite(plat, (n,), seeds):
        obs = ObsConfig(enabled=True,
                        trace_path=tmp / f"{family}.trace.json")
        t_off, rep_off = _best_of(lambda: schedule(
            wf, plat, algorithm="dag_het_part", kprime=KPRIME))
        t_on, rep_on = _best_of(lambda: schedule(
            wf, plat, algorithm="dag_het_part", kprime=KPRIME, obs=obs))
        assert rep_on.makespan == rep_off.makespan, (
            f"tracing changed the plan on {family} n={n}: "
            f"{rep_on.makespan} != {rep_off.makespan}"
        )
        row = {
            "family": family, "seed": seed, "makespan": rep_off.makespan,
            "disabled_s": t_off, "enabled_s": t_on,
            "enabled_vs_disabled": t_on / t_off,
            "n_spans": len(rep_on.spans),
        }
        anchor = PR7_HET_BASELINE_S.get(family)
        if anchor:
            row["pr7_baseline_s"] = anchor
            row["disabled_vs_pr7"] = t_off / anchor
        emit(f"obs/n={n}/{family}/enabled_vs_disabled",
             row["enabled_vs_disabled"], "x;identical_makespan")
        emit(f"obs/n={n}/{family}/disabled_vs_pr7",
             row.get("disabled_vs_pr7", float("nan")),
             f"x;budget<={DISABLED_BUDGET}")
        rows.append(row)
        dis = geomean([r.get("disabled_vs_pr7") for r in rows])
        ena = geomean([r["enabled_vs_disabled"] for r in rows])
        tier_out[f"n={n}"] = {
            "kprime": list(KPRIME),
            "repeats": REPEATS,
            "families": rows,
            "disabled_vs_pr7_geomean": dis,
            "enabled_vs_disabled_geomean": ena,
            "budgets": {
                "disabled_vs_pr7": DISABLED_BUDGET,
                "enabled_vs_disabled": ENABLED_BUDGET,
            },
            "within_budget": {
                "disabled": bool(dis <= DISABLED_BUDGET),
                "enabled": bool(ena <= ENABLED_BUDGET),
            },
        }
        if write_json:
            _write_results(results)
    summary = tier_out[f"n={n}"]
    emit(f"obs/n={n}/disabled_vs_pr7_geomean",
         summary["disabled_vs_pr7_geomean"],
         f"x;budget<={DISABLED_BUDGET};ok={summary['within_budget']['disabled']}")
    emit(f"obs/n={n}/enabled_vs_disabled_geomean",
         summary["enabled_vs_disabled_geomean"],
         f"x;budget<={ENABLED_BUDGET};ok={summary['within_budget']['enabled']}")
    return tier_out


if __name__ == "__main__":
    run()
