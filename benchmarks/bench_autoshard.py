"""Beyond-paper: the scheduler as the framework's placement layer.

For each assigned (arch × serving shape) on a mixed TPU fleet, compare
DagHetPart placement against the DagHetMem packer: estimated step
latency (the paper's makespan, seconds), stage counts, and emergent
expert parallelism."""
from __future__ import annotations

from repro.configs import ARCH_IDS, get_config, shape_by_name
from repro.core.autoshard import plan
from repro.core.platform import tpu_fleet_si

from .common import emit

# fleets sized to each model class (chips)
_FLEET = {
    "small": {"v5e": 12, "v4": 4},
    "mid": {"v5e": 48, "v4": 16},
    "big": {"v5e": 96, "v5p": 32},
}


def _fleet_for(cfg):
    p = cfg.total_params()
    if p < 5e9:
        return tpu_fleet_si(_FLEET["small"]), "small"
    if p < 1e11:
        return tpu_fleet_si(_FLEET["mid"]), "mid"
    return tpu_fleet_si(_FLEET["big"]), "big"


def run(archs=None, shapes=("decode_32k",)) -> dict:
    out = {}
    for arch in archs or ARCH_IDS:
        cfg = get_config(arch)
        plat, fleet_name = _fleet_for(cfg)
        for shape_name in shapes:
            shape = shape_by_name(shape_name)
            kp = [1, 4, 8, 16, 24, 32, 48, 64, plat.k]
            kp = sorted({k for k in kp if k <= plat.k})
            het = plan(cfg, shape, plat, kprime=kp)
            base = plan(cfg, shape, plat, algo="dag_het_mem")
            key = f"{arch}/{shape_name}"
            if het is None:
                emit(f"autoshard/{key}/status", "infeasible",
                     f"fleet={fleet_name}")
                continue
            out[key] = (het, base)
            emit(f"autoshard/{key}/est_step_ms", het.est_step_s * 1e3,
                 f"fleet={fleet_name};stages={het.n_stages}")
            if base is not None:
                emit(f"autoshard/{key}/baseline_step_ms",
                     base.est_step_s * 1e3, "dag_het_mem")
                emit(f"autoshard/{key}/speedup_vs_baseline",
                     base.est_step_s / het.est_step_s, "x")
            if het.expert_placement:
                spread = len(set(het.expert_placement.values()))
                emit(f"autoshard/{key}/expert_stage_spread", spread,
                     "emergent_expert_parallelism")
            emit(f"autoshard/{key}/valid", het.valid, "")
    return out


if __name__ == "__main__":
    run()
