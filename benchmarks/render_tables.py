"""Render EXPERIMENTS.md tables from experiments/dryrun*/ JSONs.

Usage: PYTHONPATH=src python -m benchmarks.render_tables [dirname]
Prints markdown to stdout.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1] / "experiments"


def load(dirname: str = "dryrun", tag: str = "") -> list[dict]:
    """Baseline cells only (tagged hillclimb variants excluded unless
    ``tag`` names them)."""
    out = []
    for p in sorted((ROOT / dirname).glob("*.json")):
        parts = p.stem.split("__")
        if len(parts) != 3:
            continue
        want = ("16x16" + (f"_{tag}" if tag else ""),
                "2x16x16" + (f"_{tag}" if tag else ""))
        if parts[2] not in want:
            continue
        out.append(json.loads(p.read_text()))
    return out


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.2f}m"
    return f"{x*1e6:.1f}µ"


def dryrun_table(cells: list[dict], mesh: str) -> str:
    rows = [c for c in cells if c.get("mesh") == mesh]
    lines = [
        f"#### Mesh {mesh}",
        "",
        "| arch | shape | policy | compile_s | GiB/dev (TPU est) | fits "
        "| HLO GFLOPs/dev | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(rows, key=lambda c: (c["arch"], c["shape"])):
        if c.get("status") != "ok":
            lines.append(
                f"| {c['arch']} | {c['shape']} | - | - | - | ERROR | - | - |")
            continue
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['policy']} "
            f"| {c['compile_s']} | {c['per_device_gib_tpu_est']} "
            f"| {'✓' if c['fits_hbm'] else '✗'} "
            f"| {c['hlo_flops_per_device']/1e9:.1f} "
            f"| {c['collective_bytes_per_device']/1e9:.2f} |")
    return "\n".join(lines)


def roofline_table(cells: list[dict], mesh: str) -> str:
    rows = [c for c in cells if c.get("mesh") == mesh
            and c.get("status") == "ok"]
    lines = [
        f"#### Mesh {mesh} (per chip; v5e: 197 TFLOP/s bf16, 819 GB/s "
        "HBM, 50 GB/s/link)",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| useful FLOP frac | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(rows, key=lambda c: (c["arch"], c["shape"])):
        lines.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(c['compute_s'])} "
            f"| {fmt_s(c['memory_s'])} | {fmt_s(c['collective_s'])} "
            f"| {c['dominant']} | {c['useful_flop_frac']:.3f} "
            f"| {c['roofline_frac']:.4f} |")
    return "\n".join(lines)


def main():
    dirname = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    cells = load(dirname)
    print(f"<!-- rendered from experiments/{dirname} -->\n")
    for mesh in ("16x16", "2x16x16"):
        print(dryrun_table(cells, mesh))
        print()
    print("### Roofline terms\n")
    for mesh in ("16x16", "2x16x16"):
        print(roofline_table(cells, mesh))
        print()


if __name__ == "__main__":
    main()
