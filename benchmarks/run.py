"""Benchmark driver — one section per paper table/figure.

``python -m benchmarks.run [--quick|--full] [--only SECTION]``
prints ``name,value,derived`` CSV rows (the harness contract).
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (
    bench_autoshard,
    bench_ccr,
    bench_cluster_sizes,
    bench_compute_demand,
    bench_default_cluster,
    bench_families,
    bench_heterogeneity,
    bench_runtime,
    roofline,
)
from .common import emit

SECTIONS = {
    "default_cluster": lambda full: bench_default_cluster.run(
        sizes=(200, 1000, 4000) if full else (200, 1000)),
    "cluster_sizes": lambda full: bench_cluster_sizes.run(
        sizes=(200, 1000, 4000) if full else (200, 1000)),
    "heterogeneity": lambda full: bench_heterogeneity.run(
        sizes=(200, 1000) if full else (200,)),
    "ccr": lambda full: bench_ccr.run(
        sizes=(200, 1000) if full else (200,)),
    "families": lambda full: bench_families.run(
        sizes=(200, 600, 1000, 2000) if full else (200, 600)),
    "runtime": lambda full: bench_runtime.run(
        sizes=(200, 1000, 4000) if full else (200, 1000)),
    "compute_demand": lambda full: bench_compute_demand.run(),
    "autoshard": lambda full: bench_autoshard.run(),
    "roofline": lambda full: (roofline.run("16x16"),
                              roofline.run("2x16x16")),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help=f"one of {sorted(SECTIONS)}")
    args = ap.parse_args(argv)
    todo = [args.only] if args.only else list(SECTIONS)
    for name in todo:
        t0 = time.perf_counter()
        emit(f"section/{name}/start", 0, "")
        try:
            SECTIONS[name](args.full)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            emit(f"section/{name}/ERROR", repr(e)[:120], "")
        emit(f"section/{name}/elapsed_s", time.perf_counter() - t0, "")
    return 0


if __name__ == "__main__":
    sys.exit(main())
