"""Mid-trace failure sweep: replan latency + makespan degradation
(``make bench-scenario``).

For every n=1000 family, plan once, then inject a processor-failure
event at several points of the simulated execution (fractions of the
no-failure makespan) and replay the scenario under each replan policy:

* **cold** (``full-replan``) — reschedule the residual from scratch
  (full k' sweep): the quality ceiling and the latency worst case;
* **warm** (``pinned-warm-start``) — ``Scheduler.resume`` with the
  inherited partition and pinned in-flight blocks: what warm-starting
  buys is exactly ``replan_cold_s / replan_warm_s`` at what makespan
  premium ``warm_ms / cold_ms``;
* **none** (``no-replan``) — keep the plan; infeasible whenever the
  failed processors were in use (recorded as such).

Failed processors are the fastest ones in use by the initial plan —
the adversarial choice.  Results land under the ``"scenario"`` key of
``BENCH_runtime.json`` with platform context, tracked across PRs.
"""
from __future__ import annotations

import os
import sys
import time

from repro.core import default_cluster, schedule
from repro.core.scheduler import SchedulerConfig
from repro.scenario import ProcFailure, Scenario, run_scenario

from .bench_runtime import _load_results, _write_results
from .common import KPRIME, emit, geomean, workflow_suite

FAIL_FRACS = (0.1, 0.5, 0.9)
N_FAIL = 4


def run(n: int = 1000, seeds=(1,), *, fracs=FAIL_FRACS,
        n_fail: int = N_FAIL, write_json: bool = True) -> dict:
    plat = default_cluster()
    results = _load_results()
    tier_out = results.setdefault("scenario", {})
    rows: list[dict] = []

    def snapshot() -> None:
        """Per-family checkpoint: a partial run leaves usable data."""
        warm_speedups = [r["replan_speedup"] for r in rows
                         if r.get("replan_speedup")]
        warm_premiums = [r["warm_vs_cold_ms"] for r in rows
                         if r.get("warm_vs_cold_ms")]
        tier_out[f"n={n}"] = {
            "platform": plat.name,
            "beta": plat.bandwidth,
            "kprime": list(KPRIME),
            "fail_fracs": list(fracs),
            "n_fail": n_fail,
            "cpus": os.cpu_count(),
            "rows": rows,
            "replan_speedup_geomean": geomean(warm_speedups),
            "warm_vs_cold_ms_geomean": geomean(warm_premiums),
        }
        if write_json:
            _write_results(results)

    cfg = SchedulerConfig(kprime=KPRIME)
    for family, _, seed, wf in workflow_suite(plat, (n,), seeds):
        base = schedule(wf, plat, kprime=KPRIME)
        if not base.feasible:
            rows.append({"family": family, "seed": seed,
                         "infeasible": base.infeasibility.reason})
            snapshot()
            continue
        ms0 = base.makespan
        q = base.best.quotient
        used = sorted({q.proc[v] for v in q.members},
                      key=lambda j: -plat.speed(j))
        failed = frozenset(used[:n_fail])
        for frac in fracs:
            te = frac * ms0
            sc = Scenario(wf, plat, [ProcFailure(te, failed)],
                          name=f"{family}-fail@{frac}")
            row = {"family": family, "seed": seed, "fail_frac": frac,
                   "base_ms": ms0, "failed": sorted(failed)}
            per_policy: dict[str, dict] = {}
            for label, policy in (("cold", "full-replan"),
                                  ("warm", "pinned-warm-start"),
                                  ("none", "no-replan")):
                t0 = time.perf_counter()
                tl = run_scenario(sc, policy, config=cfg,
                                  initial_report=base)
                wall = time.perf_counter() - t0
                per_policy[label] = {
                    "feasible": tl.feasible,
                    "makespan": tl.makespan,
                    "degradation": (tl.makespan / ms0
                                    if tl.makespan else None),
                    "replan_s": (tl.replan_times_s[0]
                                 if tl.replan_times_s else None),
                    "wall_s": wall,
                }
            row["policies"] = per_policy
            cold, warm = per_policy["cold"], per_policy["warm"]
            if cold["replan_s"] and warm["replan_s"]:
                row["replan_speedup"] = cold["replan_s"] / warm["replan_s"]
            if cold["makespan"] and warm["makespan"]:
                row["warm_vs_cold_ms"] = warm["makespan"] / cold["makespan"]
            rows.append(row)
            emit(f"scenario/n={n}/{family}/f={frac}/replan_speedup",
                 row.get("replan_speedup", float("nan")),
                 "cold_s_over_warm_s")
            emit(f"scenario/n={n}/{family}/f={frac}/warm_vs_cold_ms",
                 row.get("warm_vs_cold_ms", float("nan")),
                 "stitched_makespan_ratio")
            snapshot()
    out = tier_out.get(f"n={n}", {})
    emit(f"scenario/n={n}/replan_speedup_geomean",
         out.get("replan_speedup_geomean", float("nan")),
         "warm_start_latency_win")
    emit(f"scenario/n={n}/warm_vs_cold_ms_geomean",
         out.get("warm_vs_cold_ms_geomean", float("nan")),
         "warm_start_quality_cost")
    return out


if __name__ == "__main__":
    n = int(sys.argv[sys.argv.index("--n") + 1]) if "--n" in sys.argv \
        else 1000
    run(n=n)
