"""Table 4 / Figs. 8–9: scheduling runtimes of both algorithms.

Paper (C++): real <1 s; small ≈ seconds (DagHetPart 1.63× slower);
middle ≈ minutes (parity); big: DagHetPart 0.85× (faster).  The
Python-vs-C++ constant differs; the *shape* (relative trend with size)
is the claim under test."""
from __future__ import annotations

from repro.core import default_cluster, real_like_workflows

from .common import emit, geomean, run_pair, workflow_suite


def run(sizes=(200, 1000), seeds=(1,)) -> dict:
    plat = default_cluster()
    out: dict[str, dict] = {}
    groups: dict[int, list] = {}
    for family, n, seed, wf in workflow_suite(plat, sizes, seeds):
        groups.setdefault(n, []).append(run_pair(wf, plat))
    for n, rs in sorted(groups.items()):
        base_t = geomean([r.base_time_s for r in rs])
        het_t = geomean([r.het_time_s for r in rs])
        out[f"n={n}"] = {"base_s": base_t, "het_s": het_t}
        emit(f"runtime/n={n}/dag_het_mem_s", base_t, "paper_table4")
        emit(f"runtime/n={n}/dag_het_part_s", het_t, "paper_table4")
        emit(f"runtime/n={n}/relative", het_t / base_t,
             "x;paper:shrinks_with_size")
    real = [run_pair(wf, plat) for wf in real_like_workflows()]
    emit("runtime/real/dag_het_part_s",
         geomean([r.het_time_s for r in real]), "paper:<1s")
    return out


if __name__ == "__main__":
    run()
