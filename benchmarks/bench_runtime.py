"""Table 4 / Figs. 8–9: scheduling runtimes of both algorithms.

Paper (C++): real <1 s; small ≈ seconds (DagHetPart 1.63× slower);
middle ≈ minutes (parity); big: DagHetPart 0.85× (faster).  The
Python-vs-C++ constant differs; the *shape* (relative trend with size)
is the claim under test.

``python -m benchmarks.bench_runtime`` runs the quick tier (200/1000
tasks).  ``--large`` runs the paper-scale tier (10000/30000 tasks)
followed by the Step-2 before/after comparison (below) at n=1000 and
n=30000.  ``--sweep`` runs the parallel-vs-serial k' sweep comparison
on the n=1000 suite (``make bench-sweep``): per worker count,
wall-clock and the best makespan, asserting the parallel sweep is
bit-identical to serial.  ``--step2`` runs only the scalar-vs-flat
Step-2 comparison on the n=1000 suite (``make bench-step2``): each
family is scheduled once with the scalar Step-2 implementation forced
and once with the flat-array dispatch (the default), makespans are
asserted bit-identical, and per-family assign-stage ("Step-2 share")
plus end-to-end wall clocks land under the ``step2`` tier.  ``--step1``
runs the scalar-vs-flat-vs-multilevel Step-1 partition comparison at
n=30000/100000 (``make bench-step1``), asserting scalar and flat
produce identical block lists and recording edge-cut counters plus
speedups against the embedded PR-5 baseline clocks.  All tiers
append their results to ``BENCH_runtime.json`` so the perf trajectory
is tracked across PRs (the file maps tier -> per-size aggregate plus
per-family rows; it is rewritten after every size group so a partial
run still leaves usable data on disk).
"""
from __future__ import annotations

import json
import os
import platform as _platform
import sys
import time
from pathlib import Path

from repro.core import (
    default_cluster,
    generate_workflow,
    real_like_workflows,
    schedule,
)

from .common import KPRIME, emit, geomean, run_pair, workflow_suite

RESULT_FILE = Path(__file__).resolve().parent.parent / "BENCH_runtime.json"


def _load_results() -> dict:
    if RESULT_FILE.exists():
        try:
            return json.loads(RESULT_FILE.read_text())
        except (ValueError, OSError):
            return {}
    return {}


def _write_results(results: dict) -> None:
    results["meta"] = {
        "python": _platform.python_version(),
        "updated_unix": time.time(),
    }
    RESULT_FILE.write_text(json.dumps(results, indent=2, sort_keys=True))


def run(sizes=(200, 1000), seeds=(1,), tier: str = "quick",
        write_json: bool = True) -> dict:
    plat = default_cluster()
    out: dict[str, dict] = {}
    results = _load_results()
    tier_out = results.setdefault(tier, {})
    groups: dict[int, list] = {}
    rows: dict[int, list[dict]] = {}
    for family, n, seed, wf in workflow_suite(plat, sizes, seeds):
        r = run_pair(wf, plat)
        groups.setdefault(n, []).append(r)
        rows.setdefault(n, []).append({
            "family": family, "seed": seed,
            "base_ms": r.base_ms, "het_ms": r.het_ms,
            "base_s": r.base_time_s, "het_s": r.het_time_s,
        })
        emit(f"runtime/n={n}/{family}/dag_het_part_s", r.het_time_s, "")
        # keep partial results on disk: large instances take minutes
        done = sorted(groups)
        for m in done:
            rs = groups[m]
            tier_out[f"n={m}"] = {
                "base_s": geomean([x.base_time_s for x in rs]),
                "het_s": geomean([x.het_time_s for x in rs]),
                "families": rows[m],
            }
        if write_json:
            _write_results(results)
    for n, rs in sorted(groups.items()):
        base_t = geomean([r.base_time_s for r in rs])
        het_t = geomean([r.het_time_s for r in rs])
        out[f"n={n}"] = {"base_s": base_t, "het_s": het_t}
        emit(f"runtime/n={n}/dag_het_mem_s", base_t, "paper_table4")
        emit(f"runtime/n={n}/dag_het_part_s", het_t, "paper_table4")
        emit(f"runtime/n={n}/relative", het_t / base_t,
             "x;paper:shrinks_with_size")
    if tier == "quick":
        real = [run_pair(wf, plat) for wf in real_like_workflows()]
        emit("runtime/real/dag_het_part_s",
             geomean([r.het_time_s for r in real]), "paper:<1s")
    if write_json:
        _write_results(results)
    return out


def run_sweep(n: int = 1000, seeds=(1,), workers=None,
              write_json: bool = True) -> dict:
    """Parallel-vs-serial k' sweep on the n=1000 suite (``--sweep``).

    For every deterministic family instance, runs the same sweep with
    each worker count, asserts the best makespans are bit-identical to
    serial, and appends the wall-clock timings to the ``sweep`` tier of
    ``BENCH_runtime.json``.
    """
    if workers is None:
        workers = (1, min(4, os.cpu_count() or 1))
    # the serial baseline always runs, exactly once, and first
    workers = tuple(dict.fromkeys((1,) + tuple(workers)))
    plat = default_cluster()
    results = _load_results()
    tier_out = results.setdefault("sweep", {})
    rows: list[dict] = []
    for family, n_, seed, wf in workflow_suite(plat, (n,), seeds):
        row: dict = {"family": family, "seed": seed}
        serial_ms = None
        for w in workers:
            t0 = time.perf_counter()
            rep = schedule(wf, plat, algorithm="dag_het_part",
                           kprime=KPRIME, workers=w)
            dt = time.perf_counter() - t0
            row[f"workers={w}_s"] = dt
            if serial_ms is None:
                serial_ms = rep.makespan
            else:
                assert rep.makespan == serial_ms, (
                    f"parallel sweep diverged on {family}: "
                    f"{rep.makespan} != {serial_ms} (workers={w})"
                )
            emit(f"sweep/n={n}/{family}/workers={w}_s", dt, "")
        row["makespan"] = serial_ms
        w_max = max(workers)
        if w_max > 1 and row.get(f"workers={w_max}_s"):
            row["speedup"] = row["workers=1_s"] / row[f"workers={w_max}_s"]
            emit(f"sweep/n={n}/{family}/speedup_w{w_max}",
                 row["speedup"], "vs_serial;identical_makespan")
        rows.append(row)
        tier_out[f"n={n}"] = {
            "workers": list(workers),
            "kprime": list(KPRIME),
            "cpus": os.cpu_count(),  # speedup ceiling context
            "families": rows,
            "speedup_geomean": geomean(
                [r.get("speedup") for r in rows]),
        }
        if write_json:
            _write_results(results)
    return tier_out


def run_step2(sizes=(1000,), seeds=(1,), write_json: bool = True) -> dict:
    """Scalar-vs-flat Step 2 before/after comparison (``--step2``).

    For every family instance, runs the identical k' sweep once with
    the scalar Step-2 implementation forced ("before") and once with
    the flat-array dispatch ("after", the production default), asserts
    the best makespans are bit-identical, and appends per-family
    assign-stage times (the Step-2 share) and end-to-end wall clocks
    to the ``step2`` tier of ``BENCH_runtime.json``.
    """
    from repro.core.memdag import set_step2_impl, step2_impl

    plat = default_cluster()
    results = _load_results()
    tier_out = results.setdefault("step2", {})
    prev_impl = step2_impl()
    try:
        for n in sizes:
            rows: list[dict] = []
            for family, n_, seed, wf in workflow_suite(plat, (n,), seeds):
                row: dict = {"family": family, "seed": seed}
                for mode, label in (("scalar", "before"),
                                    ("auto", "after")):
                    set_step2_impl(mode)
                    t0 = time.perf_counter()
                    rep = schedule(wf, plat, algorithm="dag_het_part",
                                   kprime=KPRIME)
                    dt = time.perf_counter() - t0
                    row[f"{label}_total_s"] = dt
                    row[f"{label}_assign_s"] = \
                        rep.stage_times.get("assign", 0.0)
                    if "makespan" in row:
                        assert rep.makespan == row["makespan"], (
                            f"flat Step 2 diverged on {family} n={n}: "
                            f"{rep.makespan} != {row['makespan']}"
                        )
                    row["makespan"] = rep.makespan
                if row["after_assign_s"]:
                    row["assign_speedup"] = (row["before_assign_s"]
                                             / row["after_assign_s"])
                row["total_speedup"] = (row["before_total_s"]
                                        / row["after_total_s"])
                emit(f"step2/n={n}/{family}/assign_speedup",
                     row.get("assign_speedup", float("nan")),
                     "x;identical_makespan")
                emit(f"step2/n={n}/{family}/total_speedup",
                     row["total_speedup"], "x")
                rows.append(row)
                tier_out[f"n={n}"] = {
                    "kprime": list(KPRIME),
                    "families": rows,
                    "assign_speedup_geomean": geomean(
                        [r.get("assign_speedup") for r in rows]),
                    "total_speedup_geomean": geomean(
                        [r["total_speedup"] for r in rows]),
                }
                if write_json:
                    _write_results(results)
    finally:
        set_step2_impl(prev_impl)
    return tier_out


# Step-1 wall clocks of the PR-5 code, measured once on this container
# (seed=1, same instances as run_step1) before the flat partitioner
# landed — the fixed "before" anchor for the vs_pr5 columns.
PR5_STEP1_BASELINE_S = {
    30000: {"genome": 0.933, "blast": 0.971, "bwa": 1.079,
            "epigenomics": 0.820, "montage": 0.754,
            "seismology": 0.864, "soykb": 0.736},
    100000: {"blast": 1.161, "epigenomics": 1.182},
}


def run_step1(write_json: bool = True) -> dict:
    """Scalar-vs-flat-vs-multilevel Step 1 comparison (``--step1``).

    Times the raw partition sweep (no downstream stages — Step 1 is
    what this tier isolates) per family with the scalar implementation
    forced, with the flat dispatch (the production default, asserted
    bit-identical block lists), and with the opt-in multilevel mode, at
    n=30000 (full k' grid) and n=100000 (k' subset, two families).
    Cut sizes come from the ``step1_cut_before/after`` counters; the
    ``vs_pr5`` columns compare against the embedded PR-5 wall clocks.
    Results land under the ``step1`` tier of ``BENCH_runtime.json``.
    """
    from repro.core import counters
    from repro.core.partitioner import (
        acyclic_partition,
        set_step1_impl,
        step1_impl,
    )

    plat = default_cluster()
    results = _load_results()
    tier_out = results.setdefault("step1", {})
    prev_impl = step1_impl()
    cases = ((30000, None, KPRIME),
             (100000, ("blast", "epigenomics"), (2, 9, 36)))
    try:
        for n, only, kprime in cases:
            rows: list[dict] = []
            instances = (
                workflow_suite(plat, (n,), (1,)) if only is None
                else ((f, n, 1, generate_workflow(f, n, seed=1,
                                                  platform=plat))
                      for f in only))
            for family, _n, seed, wf in instances:
                row: dict = {"family": family, "seed": seed}
                set_step1_impl("scalar")
                t0 = time.perf_counter()
                ref = [acyclic_partition(wf, k) for k in kprime]
                row["scalar_s"] = time.perf_counter() - t0
                set_step1_impl("auto")
                snap = counters.snapshot()
                t0 = time.perf_counter()
                flat = [acyclic_partition(wf, k) for k in kprime]
                row["flat_s"] = time.perf_counter() - t0
                d = counters.delta(snap)
                assert flat == ref, (
                    f"flat Step 1 diverged on {family} n={n}"
                )
                row["cut_before"] = d.get("step1_cut_before", 0)
                row["cut_after"] = d.get("step1_cut_after", 0)
                snap = counters.snapshot()
                t0 = time.perf_counter()
                acyclic_partition(wf, kprime[-1], multilevel=True)
                row["multilevel_s"] = time.perf_counter() - t0
                d = counters.delta(snap)
                row["ml_coarsen_levels"] = d.get("step1_coarsen_levels", 0)
                row["flat_speedup"] = row["scalar_s"] / row["flat_s"]
                base = PR5_STEP1_BASELINE_S.get(n, {}).get(family)
                if base:
                    row["pr5_baseline_s"] = base
                    row["vs_pr5_speedup"] = base / row["flat_s"]
                emit(f"step1/n={n}/{family}/flat_speedup",
                     row["flat_speedup"], "x;identical_blocks")
                emit(f"step1/n={n}/{family}/vs_pr5_speedup",
                     row.get("vs_pr5_speedup", float("nan")), "x")
                rows.append(row)
                tier_out[f"n={n}"] = {
                    "kprime": list(kprime),
                    "families": rows,
                    "flat_speedup_geomean": geomean(
                        [r["flat_speedup"] for r in rows]),
                    "vs_pr5_speedup_geomean": geomean(
                        [r.get("vs_pr5_speedup") for r in rows]),
                }
                if write_json:
                    _write_results(results)
    finally:
        set_step1_impl(prev_impl)
    return tier_out


if __name__ == "__main__":
    if "--large" in sys.argv:
        run(sizes=(10000, 30000), seeds=(1,), tier="large")
        # ROADMAP hot-spot closure evidence: Step-2 share at n=1000,
        # end-to-end before/after at paper scale
        run_step2(sizes=(1000, 30000), seeds=(1,))
    elif "--step2" in sys.argv:
        run_step2()
    elif "--step1" in sys.argv:
        run_step1()
    elif "--sweep" in sys.argv:
        run_sweep()
    else:
        run()
