"""§5.2.4: workflows with 4× bigger task workloads w_u.  Paper: the
relative makespan is virtually identical to the 1× case."""
from __future__ import annotations

from repro.core import default_cluster

from .common import emit, geomean, relative_makespan_table


def run(sizes=(200,), seeds=(1, 2)) -> dict:
    plat = default_cluster()
    out = {}
    for mult in (1.0, 4.0):
        table = relative_makespan_table(plat, sizes, seeds,
                                        work_multiplier=mult)
        ratios = [r.ratio for runs in table.values() for r in runs
                  if r.ratio and r.family != "real"]
        out[mult] = geomean(ratios)
        emit(f"compute_demand/{mult}x/relative_makespan",
             out[mult] * 100, "pct;paper_5.2.4")
    drift = abs(out[4.0] - out[1.0]) / out[1.0]
    emit("compute_demand/drift", drift,
         "frac;paper:virtually_identical(<0.15)")
    return out


if __name__ == "__main__":
    run()
