"""Analytic-vs-simulated gap sweep (``make bench-sim``).

The optimizer prices mappings with the paper's contention-free
bottom-weight formula; :mod:`repro.sim` executes them.  This benchmark
quantifies how far reality (richer communication, duration jitter)
drifts from the proxy on the n=1000 suite:

* **paper model** — asserts the simulated makespan is *bit-identical*
  to the analytic value (the subsystem's correctness anchor; a gap
  here is a bug, not a finding);
* **fair-share contention** — egress/ingress/link max-min sharing; the
  ``contention_gap`` column is simulated/analytic (≥ 1);
* **jitter envelope** — N seeded lognormal perturbations of the block
  durations; ``jitter_lo``/``jitter_hi`` bracket the makespan relative
  to the deterministic value.

Results land under the ``"sim"`` key of ``BENCH_runtime.json`` with
platform context, so the fidelity trajectory of the analytic proxy is
tracked across PRs alongside the runtime tiers.
"""
from __future__ import annotations

import os
import sys
import time

from repro.core import default_cluster, schedule
from repro.sim import FairShareComm, simulate

from .bench_runtime import _load_results, _write_results
from .common import KPRIME, emit, geomean, workflow_suite

JITTER = 0.2
REPLICAS = 20


def run(n: int = 1000, seeds=(1,), *, jitter: float = JITTER,
        replicas: int = REPLICAS, write_json: bool = True) -> dict:
    plat = default_cluster()
    results = _load_results()
    tier_out = results.setdefault("sim", {})
    rows: list[dict] = []
    comm_name = FairShareComm().name

    def snapshot() -> None:
        """Per-family checkpoint: a partial run leaves usable data."""
        tier_out[f"n={n}"] = {
            "platform": plat.name,
            "beta": plat.bandwidth,
            "comm": comm_name,
            "jitter": jitter,
            "replicas": replicas,
            "kprime": list(KPRIME),
            "cpus": os.cpu_count(),
            "families": rows,
            "contention_gap_geomean": geomean(
                [r.get("contention_gap") for r in rows]),
            "jitter_hi_geomean": geomean(
                [r.get("jitter_hi") for r in rows]),
        }
        if write_json:
            _write_results(results)

    for family, _, seed, wf in workflow_suite(plat, (n,), seeds):
        rep = schedule(wf, plat, algorithm="dag_het_part", kprime=KPRIME)
        if not rep.feasible:
            rows.append({"family": family, "seed": seed,
                         "infeasible": rep.infeasibility.reason})
            snapshot()
            continue
        res = rep.best
        t0 = time.perf_counter()
        paper = simulate(res, memory=False, record_events=False)
        assert paper.makespan == res.makespan, (
            f"bit-exactness anchor broken on {family}: "
            f"{paper.makespan} != {res.makespan}"
        )
        cont = simulate(res, comm="fair-share", memory=False,
                        record_events=False)
        env = simulate(res, jitter=jitter, replicas=replicas,
                       memory=False, record_events=False).envelope
        sim_s = time.perf_counter() - t0
        gap = cont.makespan / res.makespan
        row = {
            "family": family, "seed": seed,
            "analytic_ms": res.makespan,
            "paper_sim_ms": paper.makespan,
            "contention_ms": cont.makespan,
            "contention_gap": gap,
            "jitter_lo": env.lo / res.makespan,
            "jitter_mean": env.mean / res.makespan,
            "jitter_hi": env.hi / res.makespan,
            "sim_s": sim_s,
        }
        rows.append(row)
        emit(f"sim/n={n}/{family}/contention_gap", gap, "sim_vs_analytic")
        emit(f"sim/n={n}/{family}/jitter_hi", row["jitter_hi"],
             f"lognormal({jitter});replicas={replicas}")
        snapshot()
    out = tier_out.get(f"n={n}", {})
    emit(f"sim/n={n}/contention_gap_geomean",
         out.get("contention_gap_geomean", float("nan")),
         "paper_model_is_bit_exact")
    return out


if __name__ == "__main__":
    n = int(sys.argv[sys.argv.index("--n") + 1]) if "--n" in sys.argv \
        else 1000
    run(n=n)
