"""Figs. 5–6: per-family relative and absolute makespan vs workflow
size.  Paper: blast/bwa/seismology (high fan-out) are consistently easy;
soykb/epigenomics gain less; absolute makespans grow ~linearly."""
from __future__ import annotations

from repro.core import FAMILIES, default_cluster, generate_workflow

from .common import emit, run_pair


def run(sizes=(200, 600, 1000), seeds=(1,)) -> dict:
    plat = default_cluster()
    out = {}
    for family in FAMILIES:
        per_size = {}
        for n in sizes:
            rs = []
            for seed in seeds:
                wf = generate_workflow(family, n, seed=seed, platform=plat)
                rs.append(run_pair(wf, plat))
            ratios = [r.ratio for r in rs if r.ratio]
            abs_ms = [r.het_ms for r in rs if r.het_ms]
            rel = sum(ratios) / len(ratios) if ratios else float("nan")
            ab = sum(abs_ms) / len(abs_ms) if abs_ms else float("nan")
            per_size[n] = (rel, ab)
            emit(f"families/{family}/n={n}/relative_makespan",
                 rel * 100, "pct;paper_fig5")
            emit(f"families/{family}/n={n}/absolute_makespan", ab,
                 "units;paper_fig6")
        out[family] = per_size
    return out


if __name__ == "__main__":
    run()
