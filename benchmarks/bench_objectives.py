"""Objective trade-offs + fuzz-corpus pass rate.

Two sub-benches, both landing under the ``"objectives"`` tier of
``BENCH_runtime.json`` (``make bench-objectives``):

* **tradeoff** — three n=1000 families on the default cluster with a
  *calibrated* failure/power model (uniformly scaled so the baseline
  makespan plan lands at ~0.95 success probability — the regime where
  reliability-weighting can actually move the winner).  Per family,
  three plans over the same k' sweep: the plain makespan winner
  (priced post-hoc), the reliability-weighted winner
  (:func:`plan_reliability`), and the energy minimizer under a
  reliability floor just below the baseline's own success probability
  (:func:`plan_energy` with a 3-level DVFS ladder).  Headline numbers:
  the weighted-makespan gain of the reliability winner and the energy
  saved by DVFS at the floor.

* **fuzz** — pass rate of a 50-case :func:`fuzz_scenarios` corpus
  (checks, violations, per-policy counts) so the harness's health is a
  tracked number, not just a test verdict.

CSV rows follow the ``name,value,derived`` contract of
``benchmarks.run``; the JSON tier is rewritten after each sub-bench so
a partial run still leaves usable data.
"""
from __future__ import annotations

import sys

from repro.core import default_cluster, generate_workflow, schedule
from repro.core.platform import ProcPower
from repro.objectives import (
    energy_plan,
    plan_energy,
    plan_reliability,
    schedule_energy,
    schedule_reliability,
)
from repro.scenario import fuzz_scenarios

from .bench_runtime import _load_results, _write_results
from .common import emit

KPRIME = [4, 8, 16, 33]
FAMILIES = ["genome", "montage", "blast"]
TARGET_HAZARD = 0.1  # baseline success_prob ~ exp(-0.1) ~ 0.905
SPEED_LEVELS = (0.6, 0.8, 1.0)


def _modeled_cluster(wf, plat):
    """Attach speed-cubed failure rates (faster processors run hotter
    and fail more — the classic DVFS/reliability coupling) scaled so
    the *baseline* makespan plan sits at ``exp(-TARGET_HAZARD)``
    success, plus a mildly heterogeneous power model."""
    base = schedule(wf, plat, kprime=KPRIME, workers=1)
    probe = plat.with_failure_rates(
        {j: plat.procs[j].speed ** 3 * 1e-9 for j in range(plat.k)})
    h1 = schedule_reliability(base.best, probe).hazard
    s = TARGET_HAZARD / h1 * 1e-9 if h1 > 0 else 0.0
    modeled = plat.with_failure_rates(
        {j: plat.procs[j].speed ** 3 * s for j in range(plat.k)})
    modeled = modeled.with_power(
        {j: ProcPower(0.5, 1.0 + 0.1 * j, 2.0) for j in range(plat.k)})
    return base, modeled


def tradeoff(n: int = 1000, seed: int = 1) -> dict:
    """Makespan vs reliability-weighted vs energy-under-floor."""
    plat = default_cluster()
    out: dict[str, dict] = {}
    for fam in FAMILIES:
        wf = generate_workflow(fam, n, seed=seed, platform=plat)
        base, modeled = _modeled_cluster(wf, plat)
        base_rel = schedule_reliability(base.best, modeled)
        base_en = schedule_energy(base.best, modeled)

        rr = plan_reliability(wf, modeled, kprime=KPRIME, workers=1)
        gain = (base_rel.weighted_makespan / rr.reliability.weighted_makespan
                if rr.feasible else float("nan"))

        floor = 0.995 * base_rel.success_prob
        er = plan_energy(wf, modeled, reliability_floor=floor,
                         speed_levels=SPEED_LEVELS,
                         kprime=KPRIME, workers=1)
        # energy saved vs running the *same* winning mapping all-nominal
        nominal = (schedule_energy(er.best, modeled)
                   if er.feasible else None)
        saved = (1.0 - er.energy.total / nominal.total
                 if nominal is not None else float("nan"))

        emit(f"objectives.{fam}.base.makespan", base.makespan)
        emit(f"objectives.{fam}.base.success_prob",
             base_rel.success_prob)
        emit(f"objectives.{fam}.rel.weighted_gain", gain,
             "baseline weighted-ms over reliability winner's")
        emit(f"objectives.{fam}.rel.success_prob",
             rr.reliability.success_prob if rr.feasible else None)
        emit(f"objectives.{fam}.energy.saved_frac", saved,
             f"DVFS vs nominal at floor {floor:.4f}")
        emit(f"objectives.{fam}.energy.total",
             er.energy.total if er.feasible else None)
        out[fam] = {
            "base_makespan": base.makespan,
            "base_success_prob": base_rel.success_prob,
            "base_energy": base_en.total,
            "rel_k_prime": rr.k_prime,
            "rel_makespan": rr.best.makespan if rr.feasible else None,
            "rel_success_prob": (rr.reliability.success_prob
                                 if rr.feasible else None),
            "rel_weighted_gain": gain,
            "energy_floor": floor,
            "energy_k_prime": er.k_prime,
            "energy_total": er.energy.total if er.feasible else None,
            "energy_saved_frac": saved,
            "energy_reliability": (er.energy.reliability
                                   if er.feasible else None),
        }
    return out


def fuzz(n: int = 50, seed: int = 0) -> dict:
    """Corpus pass rate across every policy + the service loop."""
    rep = fuzz_scenarios(seed=seed, n=n)
    emit("objectives.fuzz.cases", rep.n_cases)
    emit("objectives.fuzz.checks", rep.checks)
    emit("objectives.fuzz.violations", len(rep.violations),
         "target: 0")
    return {
        "seed": rep.seed,
        "cases": rep.n_cases,
        "checks": rep.checks,
        "violations": len(rep.violations),
        "per_policy": dict(rep.per_policy),
        "passed": rep.passed,
    }


def run(write_json: bool = True) -> dict:
    results = _load_results()
    tier = results.setdefault("objectives", {})
    tier["tradeoff"] = tradeoff()
    if write_json:
        _write_results(results)
    tier["fuzz"] = fuzz()
    if write_json:
        _write_results(results)
    return tier


if __name__ == "__main__":
    out = run()
    gains = [(f, r["rel_weighted_gain"]) for f, r in
             out["tradeoff"].items()]
    saves = [(f, r["energy_saved_frac"]) for f, r in
             out["tradeoff"].items()]
    bf, bg = max(gains, key=lambda x: x[1])
    sf, sv = max(saves, key=lambda x: x[1])
    fz = out["fuzz"]
    print(f"# reliability: best weighted gain {bg:.3f}x on {bf}; "
          f"energy: best DVFS saving {sv:.1%} on {sf}",
          file=sys.stderr)
    print(f"# fuzz: {fz['checks']} checks, {fz['violations']} "
          f"violation(s) over {fz['cases']} cases "
          f"({'PASS' if fz['passed'] else 'FAIL'})", file=sys.stderr)

    # the unconstrained-floor sanity anchor: with no floor the plan is
    # all-lowest-level, so it can never cost more than nominal
    plat = default_cluster()
    wf = generate_workflow("genome", 300, seed=1, platform=plat)
    base, modeled = _modeled_cluster(wf, plat)
    free = energy_plan(base.best, modeled, speed_levels=SPEED_LEVELS)
    nominal = schedule_energy(base.best, modeled)
    assert free.total <= nominal.total + 1e-9
