"""Fig. 4: impact of platform heterogeneity (NoHet / LessHet / default /
MoreHet) on relative and absolute makespan.  Paper: relative makespan
grows with heterogeneity (baseline benefits from the big-first
strategy), but DagHetPart always improves."""
from __future__ import annotations

from repro.core import (
    default_cluster,
    less_het_cluster,
    more_het_cluster,
    no_het_cluster,
)

from .common import emit, geomean, relative_makespan_table


def run(sizes=(200, 1000), seeds=(1,)) -> dict:
    out = {}
    for name, plat in (
        ("NoHet", no_het_cluster()),
        ("LessHet", less_het_cluster()),
        ("default", default_cluster()),
        ("MoreHet", more_het_cluster()),
    ):
        table = relative_makespan_table(plat, sizes, seeds)
        ratios, abs_ms = [], []
        for runs in table.values():
            for r in runs:
                if r.ratio and r.family != "real":
                    ratios.append(r.ratio)
                    abs_ms.append(r.het_ms)
        rel = geomean(ratios)
        out[name] = rel
        emit(f"heterogeneity/{name}/relative_makespan", rel * 100,
             "pct;paper_fig4_left")
        emit(f"heterogeneity/{name}/absolute_makespan",
             geomean(abs_ms), "units;paper_fig4_right")
        emit(f"heterogeneity/{name}/always_improves",
             bool(rel <= 1.0 + 1e-9), "paper:improves_in_all_cases")
    return out


if __name__ == "__main__":
    run()
