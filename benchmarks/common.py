"""Shared benchmark infrastructure.

Benchmarks mirror the paper's experimental setup (§5.1) at CPU-budget
sizes: the paper's size groups are real (11–58 tasks), small (≤8k),
middle (10k–18k), big (20k–30k); quick mode uses {200, 1000} tasks and
2 seeds, ``--full`` grows to {200, 1000, 4000, 10000} (hour-scale).

Output contract: ``name,value,derived`` CSV rows on stdout.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core import (
    FAMILIES,
    generate_workflow,
    real_like_workflows,
    schedule,
    validate_mapping,
)

KPRIME = [1, 2, 4, 6, 9, 13, 19, 28, 36]


@dataclass
class RunResult:
    family: str
    n_tasks: int
    seed: int
    base_ms: float | None
    het_ms: float | None
    base_time_s: float
    het_time_s: float

    @property
    def ratio(self) -> float | None:
        if self.base_ms and self.het_ms:
            return self.het_ms / self.base_ms
        return None


def run_pair(wf, platform, kprime=None, validate: bool = False,
             workers: int = 1):
    """Run baseline + heuristic on one workflow; returns RunResult.

    Both runs go through the unified Scheduler API; ``workers > 1``
    parallelizes the heuristic's k' sweep (bit-identical makespans).
    """
    t0 = time.perf_counter()
    base = schedule(wf, platform, algorithm="dag_het_mem")
    t1 = time.perf_counter()
    het = schedule(wf, platform, algorithm="dag_het_part",
                   kprime=kprime or KPRIME, workers=workers)
    t2 = time.perf_counter()
    if validate:
        if base.feasible:
            assert validate_mapping(wf, base.best) == [], wf.name
        if het.feasible:
            assert validate_mapping(wf, het.best) == [], wf.name
    return RunResult(
        family=wf.name.split("_")[0] if wf.name else "?",
        n_tasks=wf.n,
        seed=0,
        base_ms=base.makespan,
        het_ms=het.makespan,
        base_time_s=t1 - t0,
        het_time_s=t2 - t1,
    )


def workflow_suite(platform, sizes=(200, 1000), seeds=(1, 2),
                   work_multiplier: float = 1.0):
    """(family, size, seed, workflow) tuples for the synthetic suite."""
    for family in FAMILIES:
        for n in sizes:
            for seed in seeds:
                wf = generate_workflow(family, n, seed=seed,
                                       platform=platform,
                                       work_multiplier=work_multiplier)
                yield family, n, seed, wf


def geomean(vals) -> float:
    vals = [v for v in vals if v is not None and v > 0]
    if not vals:
        return float("nan")
    return float(np.exp(np.mean(np.log(vals))))


def emit(name: str, value, derived: str = "") -> None:
    """The ``name,value,derived`` CSV contract of benchmarks.run."""
    if isinstance(value, float):
        value = f"{value:.6g}"
    print(f"{name},{value},{derived}", flush=True)


def relative_makespan_table(platform, sizes, seeds, kprime=None,
                            work_multiplier: float = 1.0):
    """{family: [RunResult...]} over the synthetic suite + real-like."""
    out: dict[str, list[RunResult]] = {}
    for family, n, seed, wf in workflow_suite(
            platform, sizes, seeds, work_multiplier):
        r = run_pair(wf, platform, kprime)
        r = RunResult(family, n, seed, r.base_ms, r.het_ms,
                      r.base_time_s, r.het_time_s)
        out.setdefault(family, []).append(r)
    real = []
    for wf in real_like_workflows():
        from repro.core.workflows import scale_memory_to_platform
        scale_memory_to_platform(wf, platform)
        r = run_pair(wf, platform, kprime)
        real.append(RunResult("real", wf.n, 0, r.base_ms, r.het_ms,
                              r.base_time_s, r.het_time_s))
    out["real"] = real
    return out
