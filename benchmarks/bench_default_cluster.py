"""Fig. 3 (left): relative makespan of DagHetPart vs DagHetMem on the
default cluster, by workflow group.  Paper: 41% average (2.44×)."""
from __future__ import annotations

from repro.core import default_cluster

from .common import emit, geomean, relative_makespan_table


def run(sizes=(200, 1000), seeds=(1, 2)) -> dict:
    plat = default_cluster()
    table = relative_makespan_table(plat, sizes, seeds)
    ratios_all = []
    for family, runs in sorted(table.items()):
        ratios = [r.ratio for r in runs if r.ratio]
        if family != "real":
            ratios_all.extend(ratios)
        emit(f"default_cluster/relative_makespan/{family}",
             geomean(ratios) * 100 if ratios else float("nan"),
             f"pct;n={len(ratios)};paper_fig3_left")
    overall = geomean(ratios_all)
    emit("default_cluster/relative_makespan/synthetic_geomean",
         overall * 100, "pct;paper=41pct")
    emit("default_cluster/improvement_factor", 1.0 / overall,
         "x;paper=2.44x")
    scheduled = sum(
        1 for runs in table.values() for r in runs if r.het_ms)
    total = sum(len(runs) for runs in table.values())
    emit("default_cluster/schedulable", f"{scheduled}/{total}",
         "paper:(almost all)")
    return table


if __name__ == "__main__":
    run()
