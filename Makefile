PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)
export PYTHONPATH

.PHONY: verify test fast bench bench-large bench-sweep bench-sim \
	bench-scenario bench-service bench-step1 bench-step2 bench-obs \
	bench-throughput bench-objectives fuzz docs-check

# tier-1 verification (ROADMAP.md) + executable-docs check
verify:
	python -m pytest -x -q
	python tools/docs_check.py

# run the code fences in README.md, docs/*.md and examples/README.md
# (doctest fences verbatim, plain python fences executed)
docs-check:
	python tools/docs_check.py

# full test suite without -x (see every failure)
test:
	python -m pytest -q

# core scheduling tests only (seconds, not minutes)
fast:
	python -m pytest -q -m "not slow" \
		tests/test_dag.py tests/test_makespan.py tests/test_memdag.py \
		tests/test_partitioner.py tests/test_heuristics.py \
		tests/test_incremental.py tests/test_system.py

bench:
	python -m benchmarks.bench_runtime

# paper-scale runtime tier (n = 10000 / 30000) plus the scalar-vs-flat
# Step-2 before/after comparison (n = 1000 / 30000) -> BENCH_runtime.json
bench-large:
	python -m benchmarks.bench_runtime --large

# scalar-vs-flat Step-2 comparison on the n=1000 suite only
# -> BENCH_runtime.json ("step2")
bench-step2:
	python -m benchmarks.bench_runtime --step2

# scalar-vs-flat-vs-multilevel Step-1 partition comparison at
# n = 30000 / 100000 -> BENCH_runtime.json ("step1")
bench-step1:
	python -m benchmarks.bench_runtime --step1

# parallel-vs-serial k' sweep on the n=1000 suite -> BENCH_runtime.json
bench-sweep:
	python -m benchmarks.bench_runtime --sweep

# analytic-vs-simulated gap (contention + jitter) -> BENCH_runtime.json
bench-sim:
	python -m benchmarks.bench_sim

# mid-trace failure sweep: cold-vs-warm replan latency + makespan
# degradation vs failure time -> BENCH_runtime.json ("scenario")
bench-scenario:
	python -m benchmarks.bench_scenario

# multi-tenant service: plan-cache speedup + makespan premium, burst
# throughput/latency/replan counters -> BENCH_runtime.json ("service")
bench-service:
	python -m benchmarks.bench_service

# repro.obs inertness budget: disabled-vs-PR-7 (<=2%) and
# enabled-vs-disabled (<=10%) overhead on the n=1000 suite, makespans
# asserted bit-identical -> BENCH_runtime.json ("obs")
bench-obs:
	python -m benchmarks.bench_obs

# steady-state throughput: replicated-vs-unreplicated instances/s per
# n=1000 family, sustained-replay latency p50/p99, offered-rate ladder
# with the saturation point -> BENCH_runtime.json ("throughput")
bench-throughput:
	python -m benchmarks.bench_throughput

# objective trade-offs: makespan vs reliability-weighted vs
# energy-under-floor on 3 families + 50-case fuzz pass rate
# -> BENCH_runtime.json ("objectives")
bench-objectives:
	python -m benchmarks.bench_objectives

# large seeded fuzz corpus (150 cases x 3 policies + service), prints
# the per-policy violation breakdown; seed via REPRO_FUZZ_SEED
fuzz:
	python -c "from repro.scenario.fuzz import main; raise SystemExit(main())"
