#!/usr/bin/env python
"""Execute the code fences of the Markdown docs (``make docs-check``).

Documentation that cannot run rots silently; this checker keeps the
README quickstart and the docs/ guides executable:

* ```` ```python ```` fences are executed top to bottom in a fresh
  namespace per *file* (so a fence may build on earlier fences of the
  same file, like a reader following along),
* fences whose body contains ``>>>`` prompts run through :mod:`doctest`
  (expected output is checked),
* any other info string (```` ```bash ````, ```` ```text ````, ...) or
  the explicit ``python no-run`` marker is skipped.

Exit status is non-zero on the first broken snippet, with the file and
fence line number.  Checked by default: ``README.md``, ``docs/*.md``,
``examples/README.md``; pass explicit paths to override.

Run as ``make docs-check`` (standalone) or via ``make verify`` — the
repo root and ``src/`` on ``PYTHONPATH`` are assumed, as everywhere
else in the Makefile.
"""
from __future__ import annotations

import doctest
import sys
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_TARGETS = ["README.md", "docs", "examples/README.md"]

# the snippets import repro.* exactly like the Makefile targets do;
# make standalone invocation work without an exported PYTHONPATH
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))


def iter_fences(path: Path):
    """Yield ``(line_number, info_string, body)`` per fenced block."""
    lines = path.read_text().splitlines()
    i = 0
    while i < len(lines):
        line = lines[i]
        stripped = line.lstrip()
        if stripped.startswith("```") and stripped != "```":
            info = stripped[3:].strip().lower()
            fence_indent = line[: len(line) - len(stripped)]
            body: list[str] = []
            start = i + 1
            i += 1
            while i < len(lines) and lines[i].strip() != "```":
                text = lines[i]
                if fence_indent and text.startswith(fence_indent):
                    text = text[len(fence_indent):]
                body.append(text)
                i += 1
            yield start, info, "\n".join(body)
        i += 1


def run_file(path: Path) -> tuple[int, int]:
    """Execute ``path``'s python fences; returns (ran, failed)."""
    ran = failed = 0
    namespace: dict = {"__name__": f"docs_check::{path.name}"}
    for lineno, info, body in iter_fences(path):
        if info not in ("python", "pycon"):
            continue
        ran += 1
        rel = path.relative_to(REPO)
        if ">>>" in body:
            runner = doctest.DocTestRunner(
                optionflags=doctest.ELLIPSIS
                | doctest.NORMALIZE_WHITESPACE)
            test = doctest.DocTestParser().get_doctest(
                body, namespace, f"{rel}:{lineno}", str(rel), lineno)
            result = runner.run(test)
            if result.failed:
                failed += 1
                print(f"FAIL {rel}:{lineno} ({result.failed} doctest "
                      f"failure(s))")
        else:
            try:
                exec(compile(body, f"{rel}:{lineno}", "exec"), namespace)
            except Exception:
                failed += 1
                print(f"FAIL {rel}:{lineno}")
                traceback.print_exc()
    return ran, failed


def main(argv: list[str]) -> int:
    targets = argv or DEFAULT_TARGETS
    files: list[Path] = []
    for t in targets:
        p = (REPO / t) if not Path(t).is_absolute() else Path(t)
        if p.is_dir():
            files.extend(sorted(p.glob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"docs-check: missing target {t}")
            return 1
    total_ran = total_failed = 0
    for f in files:
        ran, failed = run_file(f)
        total_ran += ran
        total_failed += failed
        status = "FAIL" if failed else "ok"
        print(f"{status:4s} {f.relative_to(REPO)}: {ran} snippet(s), "
              f"{failed} failure(s)")
    if total_ran == 0:
        print("docs-check: no executable snippets found")
        return 1
    return 1 if total_failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
