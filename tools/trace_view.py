#!/usr/bin/env python
"""Top-N slowest spans of a Chrome trace or span JSONL, as a table.

Reads either output shape of :mod:`repro.obs.export`:

* a Chrome trace JSON (``ObsConfig.trace_path``) — matched ``B``/``E``
  pairs are re-joined into spans per ``(pid, tid)`` track, ``X``
  complete events count as-is;
* a JSONL sink file (``ObsConfig.sink``) — lines with
  ``"event": "span"`` carry ``ts``/``dur`` directly.

Usage::

    python tools/trace_view.py trace.json [-n 20] [--self]

``--self`` ranks by *self time* (duration minus the time covered by
child spans on the same track) instead of total duration — the number
that answers "where did the time actually go" for nested spans.

``--per-instance`` splits tracks by workflow instance for pipelined
multi-instance traces (``repro.obs.export.sim_proc_events`` with
``stride=``): a slice carrying ``instance`` in its args shows its
track as ``proc:3#i7``, so one processor's interleaved instances read
apart.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

_US = 1e6


def _spans_from_chrome(doc: dict) -> list[dict]:
    """Re-join B/E pairs (and take X events verbatim) into span dicts
    with seconds-domain ``ts``/``dur``."""
    spans: list[dict] = []
    stacks: dict[tuple, list[dict]] = {}
    for e in doc.get("traceEvents", []):
        key = (e.get("pid", ""), e.get("tid", ""))
        ph = e.get("ph")
        if ph == "B":
            stacks.setdefault(key, []).append({
                "name": e["name"], "ts": e["ts"] / _US,
                "pid": key[0], "tid": key[1],
                "depth": len(stacks.get(key, ())) - 1
                if key in stacks else 0,
                "attrs": e.get("args", {}),
            })
        elif ph == "E":
            stack = stacks.get(key)
            if stack:
                sp = stack.pop()
                sp["depth"] = len(stack)
                sp["dur"] = e["ts"] / _US - sp["ts"]
                spans.append(sp)
        elif ph == "X":
            spans.append({
                "name": e["name"], "ts": e["ts"] / _US,
                "dur": e.get("dur", 0.0) / _US,
                "pid": key[0], "tid": key[1], "depth": 0,
                "attrs": e.get("args", {}),
            })
    return spans


def _spans_from_jsonl(path: Path) -> list[dict]:
    spans = []
    for line in path.read_text().splitlines():
        if not line.strip():
            continue
        rec = json.loads(line)
        if rec.get("event") == "span":
            rec.setdefault("pid", "wall")
            rec.setdefault("attrs", {})
            spans.append(rec)
    return spans


def load_spans(path: Path) -> list[dict]:
    if path.suffix == ".jsonl":
        return _spans_from_jsonl(path)
    return _spans_from_chrome(json.loads(path.read_text()))


def add_self_time(spans: list[dict]) -> None:
    """``self_s`` = duration minus time covered by direct children on
    the same track (overlap-clipped, so malformed input can't go
    negative)."""
    by_track: dict[tuple, list[dict]] = {}
    for s in spans:
        by_track.setdefault((s.get("pid"), s.get("tid")), []).append(s)
    for track in by_track.values():
        track.sort(key=lambda s: (s["ts"], -s["dur"]))
        for s in track:
            child_time = 0.0
            t_end = s["ts"] + s["dur"]
            depth = s.get("depth", 0)
            for c in track:
                if c is s or c.get("depth", 0) != depth + 1:
                    continue
                lo = max(s["ts"], c["ts"])
                hi = min(t_end, c["ts"] + c["dur"])
                if hi > lo:
                    child_time += hi - lo
            s["self_s"] = max(0.0, s["dur"] - child_time)


def split_per_instance(spans: list[dict]) -> None:
    """Suffix each span's track with ``#i{instance}`` when its attrs
    carry one (pipelined multi-instance traces)."""
    for s in spans:
        inst = (s.get("attrs") or {}).get("instance")
        if inst is not None:
            s["tid"] = f"{s.get('tid', '')}#i{inst}"


def format_table(spans: list[dict], n: int, by_self: bool) -> str:
    key = "self_s" if by_self else "dur"
    top = sorted(spans, key=lambda s: s.get(key, 0.0), reverse=True)[:n]
    total = sum(s.get(key, 0.0) for s in spans) or 1.0
    header = (f"{'dur_ms':>10}  {'self_ms':>10}  {'%':>5}  "
              f"{'track':<24} span")
    lines = [header, "-" * len(header)]
    for s in top:
        attrs = s.get("attrs") or {}
        detail = " ".join(f"{k}={v}" for k, v in list(attrs.items())[:4])
        name = s["name"] + (f"  [{detail}]" if detail else "")
        lines.append(
            f"{s['dur'] * 1e3:>10.3f}  "
            f"{s.get('self_s', s['dur']) * 1e3:>10.3f}  "
            f"{100 * s.get(key, 0.0) / total:>5.1f}  "
            f"{str(s.get('tid', '')):<24} "
            f"{'  ' * s.get('depth', 0)}{name}")
    lines.append(f"({len(spans)} spans total; "
                 f"ranked by {'self' if by_self else 'total'} time)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Top-N slowest spans of a repro.obs trace")
    ap.add_argument("trace", type=Path,
                    help="Chrome trace .json or sink .jsonl")
    ap.add_argument("-n", type=int, default=15, help="rows to show")
    ap.add_argument("--self", dest="by_self", action="store_true",
                    help="rank by self time (minus child spans)")
    ap.add_argument("--per-instance", dest="per_instance",
                    action="store_true",
                    help="split tracks per workflow instance "
                         "(pipelined traces)")
    args = ap.parse_args(argv)
    spans = load_spans(args.trace)
    if not spans:
        print(f"no spans in {args.trace}", file=sys.stderr)
        return 1
    if args.per_instance:
        split_per_instance(spans)
    add_self_time(spans)
    print(format_table(spans, args.n, args.by_self))
    return 0


if __name__ == "__main__":
    sys.exit(main())
