"""End-to-end tests of DagHetMem and DagHetPart on paper-style
instances: validity (memory, acyclicity, injectivity) and the paper's
qualitative claims (heuristic beats baseline; big fans gain most).

All runs go through the unified Scheduler API (`repro.core.scheduler`);
the deprecated `dag_het_part`/`dag_het_mem` wrappers have their own
coverage in tests/test_scheduler.py.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    FAMILIES,
    Platform,
    Processor,
    default_cluster,
    generate_workflow,
    no_het_cluster,
    random_layered_dag,
    real_like_workflows,
    schedule,
    small_cluster,
    validate_mapping,
)

SWEEP = [1, 2, 4, 6, 9, 13, 19, 28, 36]


def baseline(wf, plat):
    return schedule(wf, plat, algorithm="dag_het_mem")


class TestBaselineValidity:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_valid_mapping_per_family(self, family):
        plat = default_cluster()
        wf = generate_workflow(family, 200, seed=1, platform=plat)
        rep = baseline(wf, plat)
        assert rep.feasible, f"baseline failed on {family}"
        assert validate_mapping(wf, rep.best) == []

    def test_fits_single_processor_when_possible(self):
        wf = random_layered_dag(50, seed=0)
        huge = Platform([Processor("big", 1.0, 1e9),
                         Processor("small", 1.0, 1.0)], 1.0)
        rep = baseline(wf, huge)
        assert rep.feasible
        assert rep.summary.k_used == 1

    def test_reports_infeasibility_when_impossible(self):
        wf = random_layered_dag(100, seed=1)
        tiny = Platform([Processor("p", 1.0, 0.5)], 1.0)
        rep = baseline(wf, tiny)
        assert not rep.feasible
        assert rep.best is None
        assert rep.infeasibility is not None
        assert rep.infeasibility.stage == "pack"

    def test_real_like_workflows_schedulable(self):
        plat = default_cluster()
        for wf in real_like_workflows():
            rep = baseline(wf, plat)
            assert rep.feasible
            assert validate_mapping(wf, rep.best) == []


class TestHeuristicValidity:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_valid_mapping_per_family(self, family):
        plat = default_cluster()
        wf = generate_workflow(family, 200, seed=1, platform=plat)
        rep = schedule(wf, plat, kprime=SWEEP)
        assert rep.feasible, f"heuristic failed on {family}"
        assert validate_mapping(wf, rep.best) == []

    def test_improves_on_baseline_geomean(self):
        """Paper headline: DagHetPart clearly beats DagHetMem on average."""
        plat = default_cluster()
        ratios = []
        for family in ("blast", "bwa", "seismology", "genome"):
            wf = generate_workflow(family, 200, seed=2, platform=plat)
            base = baseline(wf, plat)
            het = schedule(wf, plat, kprime=SWEEP)
            assert base.feasible and het.feasible
            ratios.append(base.makespan / het.makespan)
        geo = float(np.exp(np.mean(np.log(ratios))))
        assert geo > 1.5, f"expected clear improvement, got {geo:.2f}x"

    def test_fanned_out_families_gain_most(self):
        """Paper §5.2.5: blast/bwa/seismology improve more than soykb."""
        plat = default_cluster()

        def ratio(family):
            wf = generate_workflow(family, 300, seed=3, platform=plat)
            base = baseline(wf, plat)
            het = schedule(wf, plat, kprime=SWEEP)
            return base.makespan / het.makespan

        assert ratio("blast") > ratio("soykb")

    def test_homogeneous_cluster_still_improves(self):
        """Paper §5.2.3: improvement persists even on NoHet."""
        plat = no_het_cluster()
        wf = generate_workflow("seismology", 200, seed=1, platform=plat)
        base = baseline(wf, plat)
        het = schedule(wf, plat, kprime=SWEEP)
        assert het.makespan <= base.makespan

    def test_small_cluster(self):
        plat = small_cluster()
        wf = generate_workflow("bwa", 200, seed=1, platform=plat)
        rep = schedule(wf, plat, kprime=[1, 2, 4, 8, 12, 18])
        assert rep.feasible
        assert validate_mapping(wf, rep.best) == []

    def test_distinct_processors(self):
        plat = default_cluster()
        wf = generate_workflow("montage", 150, seed=4, platform=plat)
        rep = schedule(wf, plat, kprime=[6, 12])
        q = rep.best.quotient
        procs = [q.proc[v] for v in q.vertices()]
        assert len(procs) == len(set(procs))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), n=st.integers(20, 80))
    def test_property_valid_on_random_dags(self, seed, n):
        plat = small_cluster()
        wf = random_layered_dag(n, seed=seed)
        from repro.core.workflows import scale_memory_to_platform
        scale_memory_to_platform(wf, plat)
        rep = schedule(wf, plat, kprime=[1, 3, 8, 18])
        if rep.feasible:  # instances may legitimately be infeasible
            assert validate_mapping(wf, rep.best) == []
        else:
            assert rep.infeasibility is not None


class TestStepBehaviour:
    def test_k_prime_sweep_picks_best(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 150, seed=5, platform=plat)
        best = schedule(wf, plat, kprime=SWEEP)
        single = schedule(wf, plat, kprime=[36])
        if single.feasible:
            assert best.makespan <= single.makespan + 1e-9

    def test_sweep_trace_covers_every_kprime(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 150, seed=5, platform=plat)
        rep = schedule(wf, plat, kprime=SWEEP)
        assert [p.k_prime for p in rep.sweep] == SWEEP
        feasible_ms = [p.makespan for p in rep.sweep if p.feasible]
        assert rep.makespan == min(feasible_ms)

    def test_bandwidth_affects_makespan(self):
        wf = generate_workflow("blast", 200, seed=1,
                               platform=default_cluster())
        slow = schedule(wf, default_cluster(beta=0.1), kprime=[13])
        fast = schedule(wf, default_cluster(beta=5.0), kprime=[13])
        assert fast.makespan < slow.makespan
