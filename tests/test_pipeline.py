"""Pipeline-parallel runner tests.

The GPipe schedule needs multiple devices, so the numerical checks run
in a subprocess with 4 host-platform devices (the main test process
keeps its single real device, per the dry-run isolation rule)."""
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

_PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np
from repro.runtime.pipeline import pipeline_apply, stack_stage_params

mesh = jax.make_mesh((4,), ("stage",))
rng = np.random.default_rng(0)
D, B, S_STAGES = 16, 8, 4

stages = [
    {"w": jnp.asarray(rng.normal(size=(D, D)) / np.sqrt(D), jnp.float32),
     "b": jnp.asarray(rng.normal(size=(D,)) * 0.1, jnp.float32)}
    for _ in range(S_STAGES)
]
params = stack_stage_params(stages)
x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

# sequential reference
ref = x
for st in stages:
    ref = stage_fn(st, ref)

with mesh:
    out = jax.jit(
        lambda p, x: pipeline_apply(stage_fn, p, x, mesh=mesh,
                                    microbatches=4))(params, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=1e-5, rtol=1e-5)
print("FWD_OK")

# gradients through the pipeline == gradients through the sequential net
def loss_pipe(p, x):
    return (pipeline_apply(stage_fn, p, x, mesh=mesh,
                           microbatches=4) ** 2).mean()

def loss_seq(stages, x):
    y = x
    for st in stages:
        y = stage_fn(st, y)
    return (y ** 2).mean()

with mesh:
    g_pipe = jax.jit(jax.grad(loss_pipe))(params, x)
g_seq = jax.grad(loss_seq)(stages, x)
g_seq = stack_stage_params(g_seq)
for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_seq)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-4)
print("GRAD_OK")

# uneven microbatches (fill/drain correctness): mu != n_stages
with mesh:
    out2 = jax.jit(
        lambda p, x: pipeline_apply(stage_fn, p, x, mesh=mesh,
                                    microbatches=8))(params, x)
np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                           atol=1e-5, rtol=1e-5)
print("MB_OK")
"""


def test_pipeline_forward_backward_multi_device():
    proc = subprocess.run(
        [sys.executable, "-c", _PROGRAM],
        capture_output=True, text=True, timeout=480,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "FWD_OK" in proc.stdout
    assert "GRAD_OK" in proc.stdout
    assert "MB_OK" in proc.stdout
