"""Round-trip tests for the WfCommons-flavored Workflow serialization."""
import json

import pytest

from repro.core import FAMILIES, generate_workflow, real_like_workflows
from repro.core.workflows import SCHEMA_VERSION, from_json, to_json


def assert_same_workflow(a, b):
    assert b.name == a.name
    assert b.n == a.n
    assert b.labels == a.labels
    assert b.work == a.work
    assert b.mem == a.mem
    assert b.persistent == a.persistent
    assert b.succ == a.succ
    assert b.pred == a.pred


@pytest.mark.parametrize("family", FAMILIES)
def test_family_round_trip(family):
    wf = generate_workflow(family, 120, seed=3)
    assert_same_workflow(wf, from_json(to_json(wf)))


def test_real_like_round_trip_is_fixed_point():
    for wf in real_like_workflows():
        s = to_json(wf)
        assert to_json(from_json(s)) == s  # byte-identical fixed point


def test_persistent_weights_survive():
    wf = generate_workflow("montage", 40, seed=1)
    wf.persistent[3] = 123.5
    back = from_json(to_json(wf))
    assert back.persistent[3] == 123.5
    assert_same_workflow(wf, back)


def test_schema_shape():
    wf = generate_workflow("blast", 20, seed=0)
    doc = json.loads(to_json(wf, indent=2))
    assert doc["schemaVersion"] == SCHEMA_VERSION
    spec = doc["workflow"]["specification"]
    assert len(spec["tasks"]) == wf.n
    assert len(spec["files"]) == wf.n_edges
    t0 = spec["tasks"][0]
    assert set(t0) == {"id", "name", "parents", "children"}
    f0 = spec["files"][0]
    assert set(f0) == {"id", "size", "source", "target"}
    # edges carry their weights through files, parents/children agree
    by_id = {t["id"]: t for t in spec["tasks"]}
    for f in spec["files"]:
        assert f["target"] in by_id[f["source"]]["children"]
        assert f["source"] in by_id[f["target"]]["parents"]


def test_execution_entries_optional():
    doc = {
        "name": "tiny",
        "schemaVersion": SCHEMA_VERSION,
        "workflow": {
            "specification": {
                "tasks": [
                    {"id": "a", "name": "first", "parents": [],
                     "children": ["b"]},
                    {"id": "b", "name": "second", "parents": ["a"],
                     "children": []},
                ],
                "files": [{"id": "a->b", "size": 3.5, "source": "a",
                           "target": "b"}],
            },
            "execution": {"tasks": [{"id": "b", "work": 7.0}]},
        },
    }
    wf = from_json(json.dumps(doc))
    assert wf.n == 2
    assert wf.labels == ["first", "second"]
    assert wf.succ[0] == {1: 3.5}
    assert wf.work == [1.0, 7.0]     # add_task default, then override
    assert wf.mem == [1.0, 1.0]
    assert wf.persistent == [0.0, 0.0]


# ---------------------------------------------------------------------- #
# structured validation (service admission path)
# ---------------------------------------------------------------------- #
class TestValidation:
    """Malformed payloads raise WorkflowValidationError with a stable
    code — the service turns these into Rejections, so the code set is
    API surface."""

    def _doc(self, tasks=None, files=None, execution=None):
        doc = {"name": "t", "workflow": {"specification": {
            "tasks": tasks if tasks is not None else [{"id": "a"},
                                                      {"id": "b"}],
        }}}
        if files is not None:
            doc["workflow"]["specification"]["files"] = files
        if execution is not None:
            doc["workflow"]["execution"] = {"tasks": execution}
        return json.dumps(doc)

    def _code(self, text):
        from repro.core.workflows import WorkflowValidationError
        with pytest.raises(WorkflowValidationError) as ei:
            from_json(text)
        return ei.value.code

    def test_bad_json(self):
        assert self._code("{not json") == "bad-json"

    def test_bad_schema(self):
        assert self._code('{"no": "workflow"}') == "bad-schema"
        assert self._code(json.dumps(
            {"workflow": {"specification": {"tasks": "nope"}}}
        )) == "bad-schema"

    def test_empty(self):
        assert self._code(json.dumps(
            {"workflow": {"specification": {"tasks": []}}})) == "empty"

    def test_duplicate_task_id(self):
        assert self._code(self._doc(
            tasks=[{"id": "a"}, {"id": "a"}])) == "duplicate-task-id"

    def test_dangling_edge(self):
        assert self._code(self._doc(
            files=[{"source": "a", "target": "ghost",
                    "size": 1.0}])) == "dangling-edge"
        assert self._code(self._doc(
            execution=[{"id": "ghost", "work": 1.0}])) == "dangling-edge"

    def test_self_loop(self):
        assert self._code(self._doc(
            files=[{"source": "a", "target": "a",
                    "size": 1.0}])) == "self-loop"

    def test_bad_weights(self):
        for field, value in (("work", -1.0), ("memory", float("nan")),
                             ("persistent", float("inf"))):
            text = self._doc(execution=[{"id": "a", field: value}])
            # json.dumps writes NaN/Infinity literals; Python's loads
            # accepts them, so the weight check (not bad-json) fires
            assert self._code(text) == "bad-weight"
        assert self._code(self._doc(
            files=[{"source": "a", "target": "b",
                    "size": -3.0}])) == "bad-weight"

    def test_cycle(self):
        assert self._code(self._doc(
            files=[{"source": "a", "target": "b", "size": 1.0},
                   {"source": "b", "target": "a", "size": 1.0}],
        )) == "cycle"

    def test_error_carries_where(self):
        from repro.core.workflows import WorkflowValidationError
        with pytest.raises(WorkflowValidationError) as ei:
            from_json(self._doc(
                execution=[{"id": "a", "work": -1.0}]))
        assert ei.value.where == "a"
        assert "[bad-weight]" in str(ei.value)
