"""Makespan / bottom-weight tests, incl. the paper's Fig. 1 example."""
import pytest

from repro.core import (
    Platform,
    Processor,
    Workflow,
    bottom_weights,
    critical_path,
    makespan,
)
from repro.core.dag import QuotientGraph


def fig1_quotient():
    """The quotient graph of the paper's Fig. 1 (right), unitary tasks."""
    wf = Workflow(9)
    for u in range(9):
        wf.work[u] = 1.0
    q = QuotientGraph(wf)
    v1 = q.new_vertex({0, 1, 2, 3})
    v2 = q.new_vertex({4})
    v3 = q.new_vertex({5, 6, 7})
    v4 = q.new_vertex({8})
    q.add_edge(v1, v2, 1.0)
    q.add_edge(v1, v3, 2.0)   # c_{v1,v3} = 2 (two unit edges)
    q.add_edge(v2, v3, 1.0)
    q.add_edge(v2, v4, 1.0)
    q.add_edge(v3, v4, 1.0)
    return q, (v1, v2, v3, v4)


def test_fig1_bottom_weights():
    """Paper §3.3: l_v4 = 1, l_v3 = 5, l_v2 = 7, l_v1 = 12."""
    q, (v1, v2, v3, v4) = fig1_quotient()
    plat = Platform([Processor(f"p{i}", 1.0, 100.0) for i in range(4)], 1.0)
    l = bottom_weights(q, plat)
    assert l[v4] == pytest.approx(1.0)
    assert l[v3] == pytest.approx(5.0)
    assert l[v2] == pytest.approx(7.0)
    assert l[v1] == pytest.approx(12.0)
    assert makespan(q, plat) == pytest.approx(12.0)


def test_fig1_critical_path():
    q, (v1, v2, v3, v4) = fig1_quotient()
    plat = Platform([Processor(f"p{i}", 1.0, 100.0) for i in range(4)], 1.0)
    # l_v1 = 4 + max(1 + 7, 2 + 5) = 12 via v2; then v2 -> v3 (1+5 > 1+1)
    assert critical_path(q, plat) == [v1, v2, v3, v4]


def test_unassigned_speed_is_one():
    """Estimated makespan: unassigned vertices compute at speed 1."""
    q, (v1, v2, v3, v4) = fig1_quotient()
    fast = Platform([Processor(f"p{i}", 10.0, 100.0) for i in range(4)], 1.0)
    # nothing assigned -> speeds are 1 regardless of the platform
    assert makespan(q, fast) == pytest.approx(12.0)
    # assigning v1 to a 10x processor shaves 90% off its compute part
    q.proc[v1] = 0
    l = bottom_weights(q, fast)
    assert l[v1] == pytest.approx(0.4 + 8.0)


def test_speed_and_bandwidth_scaling():
    q, (v1, v2, v3, v4) = fig1_quotient()
    plat = Platform([Processor(f"p{i}", 2.0, 100.0) for i in range(4)], 0.5)
    for i, v in enumerate((v1, v2, v3, v4)):
        q.proc[v] = i
    # compute halves, communication doubles:
    # l_v4 = .5, l_v3 = 1.5 + 2 + .5 = 4, l_v2 = .5 + max(2+4, 2+.5) = 6.5,
    # l_v1 = 2 + max(2+6.5, 4+4) = 10.5
    assert makespan(q, plat) == pytest.approx(10.5)


def test_single_block_no_communication():
    """An unpartitioned DAG executes at w_total / s with no comms."""
    wf = Workflow(3)
    wf.work[:] = [1.0, 2.0, 3.0]
    wf.add_edge(0, 1, 100.0)
    wf.add_edge(1, 2, 100.0)
    q = QuotientGraph(wf)
    v = q.new_vertex({0, 1, 2})
    plat = Platform([Processor("p", 4.0, 1e9)], 0.001)
    q.proc[v] = 0
    assert makespan(q, plat) == pytest.approx(6.0 / 4.0)


def test_cyclic_quotient_has_no_makespan():
    wf = Workflow(2)
    wf.add_edge(0, 1)
    q = QuotientGraph(wf)
    a = q.new_vertex({0})
    b = q.new_vertex({1})
    q.add_edge(a, b, 1.0)
    q.add_edge(b, a, 1.0)
    plat = Platform([Processor("p", 1.0, 1.0)], 1.0)
    with pytest.raises(ValueError):
        makespan(q, plat)
