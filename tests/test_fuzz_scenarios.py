"""Scenario fuzzing: the smoke corpus, tie ordering, reproducibility.

Tier-1 runs a 25-seed deterministic corpus across every replanning
policy plus the service loop (`fuzz_scenarios`); the large corpus is
behind the ``slow`` marker and reproducible via ``REPRO_FUZZ_SEED``
(also what ``make fuzz`` runs).
"""
import os

import pytest

from repro.core import SchedulerConfig
from repro.scenario import (
    EventTimelineError,
    LinkDegrade,
    ProcFailure,
    Scenario,
    SpeedChange,
    canonical_event_order,
    event_from_dict,
    event_sort_key,
    fuzz_scenarios,
    generate_case,
    run_scenario,
    validate_event_timeline,
)
from repro.scenario.fuzz import FUZZ_POLICIES

SMOKE_SEED = 2026


# ---------------------------------------------------------------------- #
# the deterministic smoke corpus (tier-1)
# ---------------------------------------------------------------------- #
class TestSmokeCorpus:
    def test_25_seed_corpus_clean(self):
        """Acceptance gate: 25 cases × all policies + service, zero
        uncaught exceptions, every invariant holds."""
        rep = fuzz_scenarios(seed=SMOKE_SEED, n=25)
        assert rep.passed, rep.summary()
        assert rep.n_cases == 25
        # the corpus exercises every policy
        assert set(FUZZ_POLICIES) == {"pinned-warm-start",
                                      "full-replan", "no-replan"}

    def test_pricing_corpus_clean(self):
        """The checkpoint-pricing path upholds the same invariants."""
        rep = fuzz_scenarios(seed=SMOKE_SEED + 1, n=10,
                             price_migration=True)
        assert rep.passed, rep.summary()

    def test_corpus_is_deterministic(self):
        a = fuzz_scenarios(seed=SMOKE_SEED, n=5)
        b = fuzz_scenarios(seed=SMOKE_SEED, n=5)
        assert a.checks == b.checks
        assert a.violations == b.violations

    def test_cases_are_reproducible(self):
        for i in range(5):
            c1 = generate_case(SMOKE_SEED, i)
            c2 = generate_case(SMOKE_SEED, i)
            assert c1.family == c2.family
            assert c1.n_tasks == c2.n_tasks
            assert list(c1.events) == list(c2.events)
            assert [p.name for p in c1.platform.procs] == \
                [p.name for p in c2.platform.procs]
            assert c1.platform.failure_rates == c2.platform.failure_rates

    def test_corpus_covers_the_interesting_shapes(self):
        """Not vacuous: some cases have empty timelines (the bit-exact
        anchor), some multi-event, some with failure models."""
        cases = [generate_case(SMOKE_SEED, i) for i in range(25)]
        assert any(not c.events for c in cases)
        assert any(len(c.events) >= 2 for c in cases)
        assert any(c.platform.failure_rates for c in cases)
        assert any(
            isinstance(e, ProcFailure) for c in cases for e in c.events)


@pytest.mark.slow
class TestLargeCorpus:
    def test_large_corpus_clean(self):
        seed = int(os.environ.get("REPRO_FUZZ_SEED", "0"))
        rep = fuzz_scenarios(seed=seed, n=150)
        assert rep.passed, rep.summary()

    def test_large_pricing_corpus_clean(self):
        seed = int(os.environ.get("REPRO_FUZZ_SEED", "0"))
        rep = fuzz_scenarios(seed=seed + 7, n=75, price_migration=True)
        assert rep.passed, rep.summary()


# ---------------------------------------------------------------------- #
# intra-timestamp event ordering (the fix the fuzzer depends on)
# ---------------------------------------------------------------------- #
class TestTieOrdering:
    def test_canonical_order_accepted(self):
        evs = [ProcFailure(time=5.0, procs={1}),
               SpeedChange(time=5.0, proc=0, factor=0.5),
               LinkDegrade(time=5.0, src=0, dst=1, bandwidth=0.5)]
        validate_event_timeline(evs)  # does not raise

    def test_non_canonical_tie_rejected(self):
        evs = [SpeedChange(time=5.0, proc=0, factor=0.5),
               ProcFailure(time=5.0, procs={1})]
        with pytest.raises(EventTimelineError) as ei:
            validate_event_timeline(evs)
        assert ei.value.code == "unsorted-tie"
        assert ei.value.index == 1

    def test_same_kind_tiebreak(self):
        a = SpeedChange(time=5.0, proc=0, factor=0.5)
        b = SpeedChange(time=5.0, proc=1, factor=0.5)
        assert event_sort_key(a) < event_sort_key(b)
        validate_event_timeline([a, b])
        with pytest.raises(EventTimelineError):
            validate_event_timeline([b, a])

    def test_equal_events_allowed(self):
        a = SpeedChange(time=5.0, proc=0, factor=0.5)
        validate_event_timeline([a, a])

    def test_canonical_event_order_sorts_into_accepted(self):
        evs = [LinkDegrade(time=5.0, src=0, dst=1, bandwidth=0.5),
               SpeedChange(time=5.0, proc=2, factor=2.0),
               SpeedChange(time=1.0, proc=0, factor=0.5),
               ProcFailure(time=5.0, procs={3})]
        fixed = canonical_event_order(evs)
        validate_event_timeline(fixed)
        assert [e.time for e in fixed] == [1.0, 5.0, 5.0, 5.0]
        assert fixed[1].kind == "proc_failure"

    def test_scenario_rejects_non_canonical_tie(self):
        c = generate_case(SMOKE_SEED, 0)
        evs = [SpeedChange(time=1.0, proc=0, factor=0.5),
               ProcFailure(time=1.0, procs={1})]
        with pytest.raises(EventTimelineError):
            Scenario(c.workflow, c.platform, evs)

    def test_tied_events_replay_identically_from_json(self):
        """The satellite's point: a JSON round-trip of simultaneous
        events cannot reorder them — the canonical order pins the
        replay bit-exactly."""
        c = generate_case(SMOKE_SEED, 3)
        plat = c.platform
        evs = canonical_event_order([
            SpeedChange(time=8.0, proc=0, factor=0.5),
            SpeedChange(time=8.0, proc=1, factor=2.0),
            LinkDegrade(time=8.0, src=0, dst=1, bandwidth=0.25),
        ])
        rebuilt = [event_from_dict(e.to_dict()) for e in evs]
        assert rebuilt == evs
        cfg = SchedulerConfig(simulate=True)
        tl1 = run_scenario(Scenario(c.workflow, plat, evs), config=cfg)
        tl2 = run_scenario(Scenario(c.workflow, plat, rebuilt),
                           config=cfg)
        assert tl1.makespan == tl2.makespan
        assert len(tl1.segments) == len(tl2.segments)
