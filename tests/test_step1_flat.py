"""Flat-array Step 1 vs the scalar reference — bit-identity properties.

The flat partitioner (:mod:`repro.core.partitioner`) replays the scalar
FM move sequence over the shared CSR view behind a vectorized
gain/legality prefilter, so the single-level result must match the
scalar path with ``==`` — identical block lists, decision for decision.
The multilevel path deliberately changes cuts (it is opt-in), so it is
tested against the partition *invariants* instead: acyclic quotient,
topologically ordered block ids, coverage, compact ids, determinism.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    build_quotient,
    default_cluster,
    generate_workflow,
    schedule,
)
from repro.core import counters
from repro.core.partitioner import (
    _acyclic_partition_flat,
    _acyclic_partition_scalar,
    _locality_topo_order,
    acyclic_partition,
    edge_cut,
    partition_block,
    set_step1_impl,
    step1_impl,
)
from conftest import make_random_dag

FAMILIES = ["genome", "blast", "bwa", "epigenomics",
            "montage", "seismology", "soykb"]


@pytest.fixture(autouse=True)
def _restore_impl():
    prev = step1_impl()
    yield
    set_step1_impl(prev)


def assert_partition_invariants(wf, block_of, k):
    """The contract of acyclic_partition, mode-independent."""
    assert len(block_of) == wf.n
    k_eff = max(block_of) + 1
    assert k_eff <= k
    assert sorted(set(block_of)) == list(range(k_eff))  # compact ids
    for u in range(wf.n):
        for v in wf.succ[u]:
            assert block_of[u] <= block_of[v]
    assert build_quotient(wf, block_of).is_acyclic()


class TestBitIdentity:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_families_scalar_equals_flat(self, family):
        wf = generate_workflow(family, 1000, seed=7)
        for k in (1, 2, 7, 36):
            a = _acyclic_partition_scalar(wf, k, 0.2, 4)
            b = _acyclic_partition_flat(wf, k, 0.2, 4)
            assert a == b  # exact list equality, never approx
            assert_partition_invariants(wf, a, k)

    @settings(max_examples=60, deadline=None)
    @given(st.integers(2, 150), st.integers(0, 10_000),
           st.sampled_from([0.05, 0.15, 0.35]),
           st.sampled_from([2, 3, 5, 9]))
    def test_random_dags_scalar_equals_flat(self, n, seed, p, k):
        wf = make_random_dag(n, seed, p=p)
        a = _acyclic_partition_scalar(wf, k, 0.2, 4)
        b = _acyclic_partition_flat(wf, k, 0.2, 4)
        assert a == b
        assert_partition_invariants(wf, a, k)

    def test_dispatch_modes_agree(self):
        wf = make_random_dag(600, 11, p=0.02)
        out = {}
        for mode in ("scalar", "flat", "auto"):
            set_step1_impl(mode)
            out[mode] = acyclic_partition(wf, 5)
        assert out["scalar"] == out["flat"] == out["auto"]

    def test_partition_block_modes_agree(self):
        wf = generate_workflow("montage", 800, seed=3)
        rng = random.Random(5)
        nodes = sorted(rng.sample(range(wf.n), wf.n - 50))
        out = {}
        for mode in ("scalar", "flat"):
            set_step1_impl(mode)
            out[mode] = partition_block(wf, nodes, 4)
        assert out["scalar"] == out["flat"]

    def test_set_step1_impl_rejects_unknown_and_returns_prev(self):
        with pytest.raises(ValueError):
            set_step1_impl("simd")
        assert set_step1_impl("scalar") == "auto"
        assert set_step1_impl("flat") == "scalar"
        assert step1_impl() == "flat"


class TestMultilevel:
    @pytest.mark.parametrize("family", ["blast", "montage", "epigenomics"])
    @pytest.mark.parametrize("k", [4, 9])
    def test_invariants_and_determinism(self, family, k):
        wf = generate_workflow(family, 1500, seed=2)
        a = acyclic_partition(wf, k, multilevel=True)
        assert_partition_invariants(wf, a, k)
        assert a == acyclic_partition(wf, k, multilevel=True)

    def test_balance_within_split_slack(self):
        # clusters are weight-capped at total/k, so no *non-final*
        # block can exceed the split threshold by more than one cluster
        wf = generate_workflow("bwa", 1500, seed=4)
        k = 6
        block_of = acyclic_partition(wf, k, multilevel=True)
        total = sum(wf.work) or float(wf.n)
        k_eff = max(block_of) + 1
        weights = [0.0] * k_eff
        for u, b in enumerate(block_of):
            weights[b] += wf.work[u] or 1.0
        bound = 1.2 * total / k_eff + total / k + 1e-9
        assert all(w <= bound for w in weights[:-1])

    def test_small_graphs_fall_through_to_single_level(self):
        wf = make_random_dag(100, 3, p=0.2)
        assert acyclic_partition(wf, 4, multilevel=True) \
            == acyclic_partition(wf, 4)

    def test_counters_track_coarsening(self):
        # chain-rich family: heavy-edge matching actually contracts
        # (star-shaped families like blast stall — one pair per hub)
        wf = generate_workflow("bwa", 1500, seed=2)
        counters.reset()
        acyclic_partition(wf, 4, multilevel=True)
        snap = counters.snapshot()
        assert snap.get("step1_multilevel_calls") == 1
        assert snap.get("step1_coarsen_levels", 0) >= 1
        assert "step1_cut_before" in snap and "step1_cut_after" in snap
        assert snap["step1_cut_after"] <= snap["step1_cut_before"]


class TestFullPipeline:
    @pytest.mark.parametrize("family", ["epigenomics", "blast", "soykb"])
    def test_schedule_bit_identical_across_modes(self, family):
        plat = default_cluster()
        wf = generate_workflow(family, 1000, seed=3, platform=plat)
        out = {}
        for mode in ("scalar", "flat"):
            set_step1_impl(mode)
            rep = schedule(wf, plat, algorithm="dag_het_part",
                           kprime=[1, 3, 7])
            out[mode] = (rep.makespan,
                         rep.summary.block_of_task,
                         sorted(rep.summary.proc_of_block.items()))
        assert out["scalar"] == out["flat"]

    def test_multilevel_config_produces_valid_schedule(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 1500, seed=1, platform=plat)
        rep = schedule(wf, plat, algorithm="dag_het_part",
                       kprime=[4], step1_multilevel=True)
        assert rep.feasible
        assert rep.makespan > 0
        block_of = rep.summary.block_of_task
        assert build_quotient(wf, block_of).is_acyclic()
        assert rep.cache_stats.get("step1_multilevel_calls", 0) >= 1

    def test_step1_counters_in_cache_stats(self):
        plat = default_cluster()
        wf = generate_workflow("seismology", 1000, seed=5, platform=plat)
        rep = schedule(wf, plat, algorithm="dag_het_part", kprime=[4])
        stats = rep.cache_stats
        assert stats.get("step1_flat_calls", 0) >= 1  # auto → flat at n=1000
        assert "step1_cut_before" in stats and "step1_cut_after" in stats


class TestEdgeCutAndCaches:
    def test_edge_cut_vectorized_matches_scalar_sum(self):
        wf = make_random_dag(200, 9, p=0.3)   # ~6000 edges → CSR path
        assert wf.n_edges >= 2048
        block_of = acyclic_partition(wf, 5)
        expected = 0.0
        for u in range(wf.n):
            for v, c in wf.succ[u].items():
                if block_of[u] != block_of[v]:
                    expected += c
        assert edge_cut(wf, block_of) == pytest.approx(expected, rel=1e-12)

    def test_locality_cache_invalidated_by_version_bump(self):
        wf = make_random_dag(80, 1, p=0.2)
        order = _locality_topo_order(wf)
        cached = wf._locality_order_cache
        # accumulate onto an existing edge: (n, n_edges) both unchanged,
        # only the _version component of the key notices the mutation
        u = next(u for u in range(80) if wf.succ[u])
        v = next(iter(wf.succ[u]))
        wf.add_edge(u, v, 42.0)
        order2 = _locality_topo_order(wf)
        assert wf._locality_order_cache is not cached
        assert order2 == order  # same topology → same order, recomputed
        pos = {t: i for i, t in enumerate(order2)}
        for a in range(wf.n):
            for b in wf.succ[a]:
                assert pos[a] < pos[b]

    def test_flat_partition_reuses_csr_lists_cache(self):
        wf = generate_workflow("genome", 1000, seed=1)
        set_step1_impl("flat")
        acyclic_partition(wf, 4)
        cached = wf._step1_lists_cache
        acyclic_partition(wf, 7)
        assert wf._step1_lists_cache is cached  # same fv → same lists
        wf.add_edge(0, wf.add_task(work=1.0, mem=1.0), 2.0)
        acyclic_partition(wf, 4)
        assert wf._step1_lists_cache is not cached
