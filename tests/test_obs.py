"""repro.obs: spans, typed metrics, exporters — and the inertness
contract.

The load-bearing guarantees:

* **bit-identity** — makespans (all seven n=1000 families) and
  ``ServiceTrace``s are bit-identical with tracing on or off;
* **picklability** — histogram deltas ship through ``SweepPoint``
  across the ``workers=2`` process pool and merge in the parent;
* **Chrome-trace schema** — valid JSON, globally monotone ``ts``,
  matched B/E pairs per track (Perfetto's stack discipline).
"""
import json
import pickle

import pytest

from repro.core import (
    FAMILIES,
    ScheduleReport,
    default_cluster,
    generate_workflow,
    schedule,
)
from repro.obs import (
    METRICS,
    Histogram,
    JsonlSink,
    MetricsRegistry,
    ObsConfig,
    RATIO_BOUNDARIES,
    Span,
    Tracer,
    activate,
    percentile,
    percentiles,
    span_events,
    trace_span,
    tracing_active,
    write_chrome_trace,
)
from repro.service import ServiceConfig, Submission, run_service
from repro.service.report import ServiceReport


# ---------------------------------------------------------------------- #
# metrics registry
# ---------------------------------------------------------------------- #
class TestMetrics:
    def test_histogram_buckets_and_stats(self):
        h = Histogram(boundaries=(1.0, 10.0, 100.0))
        for v in (0.5, 1.0, 5.0, 50.0, 500.0):
            h.observe(v)
        # upper-edge inclusive: 1.0 lands in the first bucket
        assert h.counts == [2, 1, 1, 1]
        assert h.count == 5
        assert h.sum == pytest.approx(556.5)
        assert h.min == 0.5 and h.max == 500.0

    def test_histogram_dict_round_trip_and_merge(self):
        h = Histogram(boundaries=(1.0, 10.0))
        h.observe(0.3)
        h.observe(30.0)
        d = h.to_dict()
        assert Histogram.from_dict(d).to_dict() == d
        h2 = Histogram(boundaries=(1.0, 10.0))
        h2.observe(5.0)
        h2.merge_dict(d)
        assert h2.count == 3
        assert h2.min == 0.3 and h2.max == 30.0

    def test_percentiles_clamped_to_observed_range(self):
        h = Histogram(boundaries=(1.0, 10.0, 100.0))
        for v in (2.0, 3.0, 4.0, 5.0):
            h.observe(v)
        p = percentiles(h.to_dict())
        assert set(p) == {"p50", "p95", "p99"}
        for v in p.values():
            assert 2.0 <= v <= 5.0  # clamped to [min, max]
        assert p["p50"] <= p["p95"] <= p["p99"]
        assert percentile(h.to_dict(), 0) == pytest.approx(2.0)
        assert percentiles({}) is None

    def test_registry_snapshot_delta_merge(self):
        reg = MetricsRegistry()
        reg.counter("c", 2)
        reg.gauge("g", 1.5)
        reg.observe("h", 0.25)
        snap = reg.snapshot()
        reg.counter("c", 3)
        reg.gauge("g", 2.5)
        reg.observe("h", 0.75)
        d = reg.delta(snap)
        assert d["counters"] == {"c": 3}
        assert d["gauges"] == {"g": 2.5}
        assert d["histograms"]["h"]["count"] == 1
        # merging the delta into a snapshot-restored registry lands on
        # the current state (count/sum; min/max keep current values)
        reg2 = MetricsRegistry()
        reg2.restore(snap)
        reg2.merge(d)
        assert reg2.counters["c"] == 5
        assert reg2.histograms["h"].count == 2

    def test_delta_is_sparse_and_picklable(self):
        reg = MetricsRegistry()
        reg.observe("ratio", 1.02, boundaries=RATIO_BOUNDARIES)
        snap = reg.snapshot()
        reg.observe("ratio", 1.05, boundaries=RATIO_BOUNDARIES)
        d = reg.delta(snap)
        assert list(d) == ["histograms"]  # nothing else moved
        rt = pickle.loads(pickle.dumps(d))
        assert rt == d
        json.loads(json.dumps(d))  # JSON-clean too

    def test_counters_alias_feeds_registry(self):
        from repro.core import counters

        assert counters.COUNTERS is METRICS.counters
        snap = METRICS.snapshot()
        counters.bump("obs_test_counter", 7)
        assert METRICS.delta(snap)["counters"]["obs_test_counter"] == 7


# ---------------------------------------------------------------------- #
# tracer
# ---------------------------------------------------------------------- #
class TestTracer:
    def test_nesting_depth_and_attrs(self):
        tr = Tracer()
        with activate(tr):
            assert tracing_active()
            with trace_span("outer", a=1):
                with trace_span("inner") as sp:
                    sp.attrs["b"] = 2
        assert not tracing_active()
        by_name = {s.name: s for s in tr.spans}
        assert by_name["outer"].depth == 0
        assert by_name["inner"].depth == 1
        assert by_name["outer"].attrs == {"a": 1}
        assert by_name["inner"].attrs == {"b": 2}
        # inner closed first
        assert tr.spans[0].name == "inner"

    def test_disabled_fast_path_discards_attrs(self):
        with trace_span("nope", x=1) as sp:
            sp.attrs["y"] = 2
            sp.attrs.update(z=3)
        assert dict(sp.attrs) == {}  # shared null span never grows

    def test_activate_none_is_passthrough(self):
        tr = Tracer()
        with activate(tr):
            with activate(None):
                with trace_span("still-traced"):
                    pass
        assert [s.name for s in tr.spans] == ["still-traced"]

    def test_by_duration(self):
        tr = Tracer()
        tr.extend([Span("a", 0.0, 0.1, "t"), Span("b", 0.0, 0.5, "t"),
                   Span("c", 0.0, 0.3, "t")])
        assert [s.name for s in tr.by_duration(2)] == ["b", "c"]


# ---------------------------------------------------------------------- #
# exporters
# ---------------------------------------------------------------------- #
def _check_chrome_schema(path):
    """Valid JSON, globally monotone ts, matched B/E pairs per tid."""
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events, "empty trace"
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts), "ts not monotone"
    stacks: dict = {}
    for e in events:
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            stacks.setdefault(key, []).append(e["name"])
        elif e["ph"] == "E":
            assert stacks.get(key), f"E without B on {key}"
            assert stacks[key].pop() == e["name"]
    leftovers = {k: v for k, v in stacks.items() if v}
    assert not leftovers, f"unclosed B events: {leftovers}"
    return events


class TestExport:
    def test_span_events_and_chrome_trace(self, tmp_path):
        spans = [
            Span("run", ts=0.0, dur=1.0, tid="main", depth=0),
            Span("stage", ts=0.2, dur=0.3, tid="main", depth=1,
                 attrs={"k": 4}),
            Span("stage", ts=0.6, dur=0.0, tid="main", depth=1),
        ]
        path = tmp_path / "trace.json"
        write_chrome_trace(path, span_events(spans))
        events = _check_chrome_schema(path)
        assert len(events) == 6  # one B + one E per span
        args = [e.get("args") for e in events if e["ph"] == "B"]
        assert {"k": 4} in args

    def test_jsonl_sink(self, tmp_path):
        path = tmp_path / "events.jsonl"
        with JsonlSink(path) as sink:
            assert sink.enabled
            sink.emit({"a": 1})
            sink.emit({"b": [1, 2]})
        lines = path.read_text().splitlines()
        assert [json.loads(ln) for ln in lines] == [{"a": 1},
                                                    {"b": [1, 2]}]
        disabled = JsonlSink(None)
        disabled.emit({"x": 1})  # no-op, no error
        assert not disabled.enabled


# ---------------------------------------------------------------------- #
# inertness: bit-identical results with tracing on/off
# ---------------------------------------------------------------------- #
def _plan_fingerprint(rep: ScheduleReport):
    s = rep.summary
    return (s.makespan, s.k_used, s.k_prime, tuple(s.block_of_task),
            tuple(sorted(s.proc_of_block.items())))


class TestInertness:
    @pytest.mark.parametrize("family", sorted(FAMILIES))
    def test_scheduler_bit_identical_all_families(self, family,
                                                  tmp_path):
        plat = default_cluster()
        wf = generate_workflow(family, 1000, seed=11, platform=plat)
        off = schedule(wf, plat, kprime=[4, 9])
        on = schedule(wf, plat, kprime=[4, 9],
                      obs=ObsConfig(enabled=True,
                                    trace_path=tmp_path / "t.json"))
        assert off.feasible and on.feasible
        assert _plan_fingerprint(off) == _plan_fingerprint(on)
        assert on.spans and not off.spans
        _check_chrome_schema(tmp_path / "t.json")

    def test_probe_spans_inert_too(self):
        plat = default_cluster()
        wf = generate_workflow("montage", 300, seed=3, platform=plat)
        off = schedule(wf, plat, kprime=[6])
        on = schedule(wf, plat, kprime=[6],
                      obs=ObsConfig(enabled=True, probe_spans=True))
        assert _plan_fingerprint(off) == _plan_fingerprint(on)
        assert any(s.name.startswith("probe.") for s in on.spans)

    def test_service_trace_bit_identical(self, tmp_path):
        plat = default_cluster()
        subs = [
            Submission(generate_workflow("blast", 120, seed=5,
                                         platform=plat),
                       tenant="a", arrival_t=0.0, name="j0"),
            Submission(generate_workflow("blast", 120, seed=5,
                                         platform=plat),
                       tenant="b", arrival_t=1.0, name="j1"),
            Submission(generate_workflow("genome", 150, seed=6,
                                         platform=plat),
                       tenant="a", arrival_t=2.0, name="j2"),
        ]
        off = run_service(subs, plat)
        trace_path = tmp_path / "svc.json"
        sink_path = tmp_path / "svc.jsonl"
        on = run_service(subs, plat,
                         obs=ObsConfig(enabled=True,
                                       trace_path=trace_path,
                                       sink=sink_path))
        # the virtual-time trace is the determinism contract
        assert on.trace.to_dict() == off.trace.to_dict()
        assert on.spans and not off.spans
        names = {s.name for s in on.spans}
        assert {"service.admit", "service.dispatch", "service.plan",
                "service.complete"} <= names
        events = _check_chrome_schema(trace_path)
        # both clock domains present in one file
        assert {"wall", "virtual"} <= {e["pid"] for e in events}
        # the sink streamed the service log and the spans
        records = [json.loads(ln)
                   for ln in sink_path.read_text().splitlines()]
        kinds = {r["event"] for r in records}
        assert kinds == {"service", "span"}
        assert sum(r["event"] == "service" for r in records) == len(
            on.trace.log)

    def test_service_percentiles_from_histograms(self):
        plat = default_cluster()
        subs = [Submission(generate_workflow("blast", 120, seed=5,
                                             platform=plat),
                           arrival_t=float(i), name=f"j{i}")
                for i in range(3)]
        rep = run_service(subs, plat)
        p = rep.plan_latency_percentiles
        assert p is not None and p["p50"] <= p["p95"] <= p["p99"]
        assert rep.queue_wait_percentiles is not None
        # identical DAGs: second+ submissions hit the plan cache, so
        # the premium histogram has samples near 1.0
        prem = rep.makespan_premium_percentiles
        assert prem is not None and prem["p50"] >= 0.5


# ---------------------------------------------------------------------- #
# worker shipping: pickled histogram deltas under the process pool
# ---------------------------------------------------------------------- #
class TestWorkerShipping:
    def test_histogram_deltas_cross_the_pool(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 300, seed=7, platform=plat)
        snap = METRICS.snapshot()
        rep = schedule(wf, plat, kprime=[1, 4, 9], workers=2)
        # every sweep point shipped its non-counter metrics delta back
        for p in rep.sweep:
            hist = p.metrics["histograms"]["sched_sweep_point_s"]
            assert hist["count"] == 1
        # and the parent registry merged them (plus any pre-sweep
        # parent-side observations)
        d = METRICS.delta(snap)
        assert (d["histograms"]["sched_sweep_point_s"]["count"]
                >= len(rep.sweep))
        # aggregated run metrics on the report
        agg = rep.metrics["histograms"]["sched_sweep_point_s"]
        assert agg["count"] == len(rep.sweep)

    def test_parallel_spans_carry_worker_tracks(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 300, seed=7, platform=plat)
        rep = schedule(wf, plat, kprime=[1, 4, 9], workers=2,
                       obs=ObsConfig(enabled=True))
        tids = {s.tid for s in rep.spans}
        assert len(tids) >= 2  # parent + at least one worker pid


# ---------------------------------------------------------------------- #
# serialization compatibility
# ---------------------------------------------------------------------- #
class TestSerialization:
    def test_schedule_report_metrics_round_trip(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 120, seed=4, platform=plat)
        rep = schedule(wf, plat, kprime=[1, 4])
        rt = ScheduleReport.from_json(rep.to_json())
        assert rt.metrics == rep.metrics
        assert rt.metrics["histograms"]["sched_sweep_point_s"][
            "count"] == 2

    def test_pre_pr8_payloads_still_load(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 120, seed=4, platform=plat)
        rep = schedule(wf, plat, kprime=[1])
        d = rep.to_dict()
        del d["metrics"]                       # pre-PR-8 shape
        for p in d["sweep"]:
            del p["metrics"]
        old = ScheduleReport.from_dict(d)
        assert old.metrics == {} and old.sweep[0].metrics == {}

        svc = run_service(
            [Submission(wf, name="j0")], plat)
        sd = svc.to_dict()
        del sd["metrics"]                      # pre-PR-8 shape
        assert ServiceReport.from_dict(sd).metrics == {}
        assert ServiceReport.from_dict(sd).plan_latency_percentiles \
            is None
