"""Tests for repro.sim — the discrete-event schedule execution engine.

The load-bearing anchor: under the paper's model (contention-free
links, deterministic durations) the simulated makespan is
**bit-identical** to the analytic bottom-weight :func:`makespan` for
every valid mapping — asserted for the outputs of *both* pipelines on
all seven n=1000 families, and property-tested over random valid
mappings of those same instances.  Around it: contention ordering,
jitter-seeding determinism, the transient-memory tracker (including
the "block sums pass, trace violates" case), SimReport JSON round
trips, per-link platform overrides, and the scheduler's ``simulate``
stage.
"""
import math
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    FAMILIES,
    Platform,
    Processor,
    Workflow,
    default_cluster,
    generate_workflow,
    makespan,
    schedule,
    simulate_peak_members,
    validate_mapping,
)
from repro.core.baseline import MappingResult
from repro.core.dag import build_quotient
from repro.sim import (
    BlockSpec,
    ContentionFreeComm,
    EdgeSpec,
    FairShareComm,
    SimReport,
    run_engine,
    simulate,
)

ANCHOR_N = 1000


@pytest.fixture(scope="module")
def plat() -> Platform:
    return default_cluster()


@pytest.fixture(scope="module")
def family_wfs(plat):
    """The seven n=1000 instances, generated once per module."""
    return {f: generate_workflow(f, ANCHOR_N, seed=1, platform=plat)
            for f in FAMILIES}


def unit_procs(k: int, mem: float = 1e9) -> Platform:
    return Platform([Processor(f"p{i}", 1.0, mem) for i in range(k)], 1.0)


def make_result(wf, q, platform, orders=None) -> MappingResult:
    extras = {} if orders is None else {"orders": orders}
    return MappingResult(algo="test", quotient=q, platform=platform,
                         makespan=makespan(q, platform), runtime_s=0.0,
                         k_used=q.n_vertices, extras=extras)


# ---------------------------------------------------------------------- #
# the correctness anchor (ISSUE acceptance criterion)
# ---------------------------------------------------------------------- #
class TestAnalyticAnchor:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_bit_exact_both_pipelines_n1000(self, family, family_wfs, plat):
        wf = family_wfs[family]
        for algo in ("dag_het_part", "dag_het_mem"):
            rep = schedule(wf, plat, algorithm=algo)
            assert rep.feasible, (family, algo)
            sim = simulate(rep.best, memory=False, record_events=False)
            assert sim.exact_anchor
            assert sim.makespan == rep.makespan, (family, algo)
            # the analytic value the report carries agrees too
            assert sim.analytic_makespan == rep.makespan
            # forward trace agrees to round-off (it folds the same
            # terms from the other end)
            assert sim.horizon == pytest.approx(sim.makespan, rel=1e-12)

    @settings(max_examples=15, deadline=None)
    @given(
        family=st.sampled_from(FAMILIES),
        n_blocks=st.integers(min_value=1, max_value=36),
        seed=st.integers(min_value=0, max_value=10**6),
    )
    def test_random_valid_mappings_bit_exact(
            self, family_wfs, plat, family, n_blocks, seed):
        """Contiguous cuts of a topological order give an acyclic
        quotient; with distinct processors that is a valid mapping
        shape — the simulated makespan must match Eq. (2) bit-exactly
        on every one of them."""
        wf = family_wfs[family]
        rng = random.Random(seed)
        order = wf.topological_order()
        cuts = sorted(rng.sample(range(1, wf.n), n_blocks - 1)) \
            if n_blocks > 1 else []
        block_of = [0] * wf.n
        b = 0
        bounds = cuts + [wf.n]
        lo = 0
        for b, hi in enumerate(bounds):
            for i in range(lo, hi):
                block_of[order[i]] = b
            lo = hi
        q = build_quotient(wf, block_of)
        procs = rng.sample(range(plat.k), len(q.members))
        for pj, vid in zip(procs, sorted(q.members)):
            q.proc[vid] = pj
        sim = simulate(make_result(wf, q, plat), memory=False,
                       record_events=False)
        assert sim.makespan == makespan(q, plat)


# ---------------------------------------------------------------------- #
# contention model
# ---------------------------------------------------------------------- #
def fan_out_workflow():
    """0 → 1 (c=2), 0 → 2 (c=4); singleton blocks on three procs."""
    wf = Workflow(3)
    wf.work[:] = [1.0, 1.0, 1.0]
    wf.mem[:] = [1.0, 1.0, 1.0]
    wf.add_edge(0, 1, 2.0)
    wf.add_edge(0, 2, 4.0)
    q = build_quotient(wf, [0, 1, 2])
    for vid in q.members:
        q.proc[vid] = vid
    return wf, q


class TestContention:
    def test_contention_free_reference(self):
        wf, q = fan_out_workflow()
        plat = unit_procs(3)
        sim = simulate(make_result(wf, q, plat))
        assert sim.makespan == makespan(q, plat) == 6.0
        xf = {(t.src, t.dst): (t.start, t.finish) for t in sim.transfers}
        assert xf == {(0, 1): (1.0, 3.0), (0, 2): (1.0, 5.0)}

    def test_fair_share_egress_serializes_fan_out(self):
        wf, q = fan_out_workflow()
        plat = unit_procs(3)
        sim = simulate(make_result(wf, q, plat), comm="fair-share")
        # both transfers share block 0's egress port at rate 1/2 until
        # the smaller one drains: (0,1) lands at 1 + 2/(1/2) = 5, then
        # (0,2) finishes its remaining 2 units at full rate at t = 7
        xf = {(t.src, t.dst): (t.start, t.finish) for t in sim.transfers}
        assert xf[(0, 1)] == (1.0, 5.0)
        assert xf[(0, 2)] == (1.0, 7.0)
        assert sim.makespan == sim.horizon == 8.0
        assert not sim.exact_anchor
        # event ordering: (0,1) completes strictly before (0,2)
        done = [e.edge for e in sim.events if e.kind == "transfer_finish"]
        assert done == [(0, 1), (0, 2)]

    def test_link_only_model_has_no_fan_out_contention(self):
        wf, q = fan_out_workflow()
        plat = unit_procs(3)
        sim = simulate(make_result(wf, q, plat),
                       comm=FairShareComm(egress=False, ingress=False))
        # distinct destination links: degenerates to contention-free
        assert sim.horizon == 6.0

    def test_ingress_contention_on_join(self):
        # 0 → 2 (c=2), 1 → 2 (c=2): both land on proc of block 2
        wf = Workflow(3)
        wf.work[:] = [1.0, 1.0, 1.0]
        wf.add_edge(0, 2, 2.0)
        wf.add_edge(1, 2, 2.0)
        q = build_quotient(wf, [0, 1, 2])
        for vid in q.members:
            q.proc[vid] = vid
        plat = unit_procs(3)
        sim = simulate(make_result(wf, q, plat), comm="fair-share")
        # both start at t=1 sharing the ingress port: both land at 5
        xf = {(t.src, t.dst): t.finish for t in sim.transfers}
        assert xf == {(0, 2): 5.0, (1, 2): 5.0}
        assert sim.horizon == 6.0

    def test_per_link_override_respected(self):
        # chain 0 → 1 (c=2) with the 0→1 link halved
        wf = Workflow(2)
        wf.work[:] = [1.0, 1.0]
        wf.add_edge(0, 1, 2.0)
        q = build_quotient(wf, [0, 1])
        q.proc[0], q.proc[1] = 0, 1
        plat = unit_procs(2).with_link_bandwidth(0, 1, 0.5)
        sim = simulate(make_result(wf, q, plat))
        assert sim.makespan == 1.0 + 2.0 / 0.5 + 1.0
        assert not sim.exact_anchor  # analytic uses the uniform beta

    def test_asymmetric_override_consistent_with_trace(self):
        # the backward (canonical-makespan) pass must price the 0→1
        # link, not the unused 1→0 direction it traverses transposed
        wf = Workflow(2)
        wf.work[:] = [1.0, 1.0]
        wf.add_edge(0, 1, 2.0)
        q = build_quotient(wf, [0, 1])
        q.proc[0], q.proc[1] = 0, 1
        plat = unit_procs(2).with_link_bandwidth(0, 1, 0.5,
                                                 symmetric=False)
        sim = simulate(make_result(wf, q, plat))
        assert sim.makespan == sim.horizon == 6.0
        assert sim.block_finish[1] == 6.0

    def test_fair_share_same_proc_transfer_is_free(self):
        # data between two blocks pinned to one processor never touches
        # the network: no egress/ingress/link consumption
        wf = Workflow(2)
        wf.work[:] = [1.0, 1.0]
        wf.add_edge(0, 1, 4.0)
        q = build_quotient(wf, [0, 1])
        q.proc[0] = q.proc[1] = 0
        plat = unit_procs(1)
        sim = simulate(make_result(wf, q, plat), comm="fair-share")
        assert sim.horizon == 2.0  # matches the contention-free model

    def test_non_injective_mapping_serializes_on_processor(self):
        # two independent blocks pinned to the same processor
        wf = Workflow(2)
        wf.work[:] = [2.0, 3.0]
        q = build_quotient(wf, [0, 1])
        q.proc[0] = q.proc[1] = 0
        plat = unit_procs(1)
        sim = simulate(make_result(wf, q, plat))
        assert sim.horizon == 5.0
        assert sim.makespan == 5.0  # backward anchor disabled
        assert not sim.exact_anchor
        # the analytic proxy ignores the sharing
        assert sim.analytic_makespan == 3.0


# ---------------------------------------------------------------------- #
# stochastic durations
# ---------------------------------------------------------------------- #
class TestJitter:
    def setup_method(self):
        self.plat = default_cluster()
        self.wf = generate_workflow("montage", 120, seed=3,
                                    platform=self.plat)
        self.res = schedule(self.wf, self.plat, kprime=[4]).best

    def test_seeding_is_deterministic(self):
        a = simulate(self.res, jitter=0.2, replicas=6, seed=7,
                     memory=False, record_events=False)
        b = simulate(self.res, jitter=0.2, replicas=6, seed=7,
                     memory=False, record_events=False)
        assert a.envelope.makespans == b.envelope.makespans
        c = simulate(self.res, jitter=0.2, replicas=6, seed=8,
                     memory=False, record_events=False)
        assert a.envelope.makespans != c.envelope.makespans

    def test_envelope_brackets_and_headline_stays_deterministic(self):
        sim = simulate(self.res, jitter=0.2, replicas=12, seed=1,
                       memory=False, record_events=False)
        assert sim.makespan == self.res.makespan  # headline unjittered
        env = sim.envelope
        assert len(env.makespans) == 12
        assert env.lo <= env.mean <= env.hi
        assert env.std >= 0.0
        spread = {round(m, 6) for m in env.makespans}
        assert len(spread) > 1  # jitter actually moved the makespan

    def test_uniform_kind_and_zero_replicas_default(self):
        sim = simulate(self.res, jitter=0.1, jitter_kind="uniform",
                       memory=False, record_events=False)
        assert len(sim.envelope.makespans) == 16  # default replicas
        sim0 = simulate(self.res, memory=False, record_events=False)
        assert sim0.envelope is None


# ---------------------------------------------------------------------- #
# memory-occupancy tracking
# ---------------------------------------------------------------------- #
class TestMemoryTrace:
    def test_valid_mappings_have_violation_free_traces(self, plat):
        wf = generate_workflow("bwa", 300, seed=2, platform=plat)
        for algo in ("dag_het_part", "dag_het_mem"):
            rep = schedule(wf, plat, algorithm=algo)
            sim = simulate(rep.best)
            assert sim.memory.feasible
            assert validate_mapping(wf, rep.best, memory_trace=True) == []

    def test_peak_matches_witness_simulation(self, plat):
        wf = generate_workflow("blast", 200, seed=4, platform=plat)
        res = schedule(wf, plat, algorithm="dag_het_mem").best
        sim = simulate(res)
        orders = res.extras["orders"]
        q = res.quotient
        for vid, members in q.members.items():
            p = q.proc[vid]
            base = sum(wf.persistent[u] for u in members)
            expected = base + simulate_peak_members(wf, members,
                                                    orders[vid])
            assert sim.memory.peak[p] >= expected or \
                math.isclose(sim.memory.peak[p], expected)
        # single block per proc here -> equality for each proc's block
        for vid, members in q.members.items():
            base = sum(wf.persistent[u] for u in members)
            assert sim.memory.peak[q.proc[vid]] == \
                base + simulate_peak_members(wf, members, orders[vid])

    def test_trace_catches_witness_only_violation(self):
        """Block sums pass (a better traversal exists) but the planned
        witness order transiently overflows — the tracker reports the
        exact time-point, processor and task."""
        wf = Workflow(3)
        wf.work[:] = [1.0, 3.0, 2.0]   # a, b, c
        wf.mem[:] = [1.0, 1.0, 50.0]
        wf.add_edge(0, 1, 10.0)        # a -> b internal file
        q = build_quotient(wf, [0, 0, 0])
        (vid,) = q.members
        q.proc[vid] = 0
        cap = 55.0
        plat = Platform([Processor("p0", 1.0, cap)], 1.0)
        # witness holds a->b live while c runs: peak 60 > 55;
        # the traversal [a, b, c] peaks at 50 and certifies the sum
        res = make_result(wf, q, plat, orders={vid: [0, 2, 1]})
        assert validate_mapping(wf, res) == []  # block sums fine
        errors = validate_mapping(wf, res, memory_trace=True)
        assert len(errors) == 1
        msg = errors[0]
        assert "transient memory violation" in msg
        assert "t=1" in msg and "task 2" in msg and "processor 0" in msg
        sim = simulate(res)
        v = sim.memory.violations[0]
        assert (v.time, v.proc, v.task, v.occupancy) == (1.0, 0, 2, 60.0)
        # the same mapping with the good witness is trace-clean
        ok = make_result(wf, q, plat, orders={vid: [0, 1, 2]})
        assert validate_mapping(wf, ok, memory_trace=True) == []

    def test_invalid_witness_falls_back_to_greedy(self):
        wf = Workflow(2)
        wf.work[:] = [1.0, 1.0]
        wf.add_edge(0, 1, 2.0)
        q = build_quotient(wf, [0, 0])
        (vid,) = q.members
        q.proc[vid] = 0
        plat = unit_procs(1)
        # precedence-violating witness is ignored, not replayed
        res = make_result(wf, q, plat, orders={vid: [1, 0]})
        sim = simulate(res)
        assert sim.memory.feasible


# ---------------------------------------------------------------------- #
# report plumbing
# ---------------------------------------------------------------------- #
class TestSimReport:
    def test_json_round_trip_full(self, plat):
        wf = generate_workflow("montage", 150, seed=5, platform=plat)
        res = schedule(wf, plat, kprime=[4]).best
        sim = simulate(res, jitter=0.1, replicas=4)
        back = SimReport.from_json(sim.to_json())
        assert back.makespan == sim.makespan
        assert back.horizon == sim.horizon
        assert back.exact_anchor == sim.exact_anchor
        assert back.block_start == sim.block_start
        assert back.block_finish == sim.block_finish
        assert back.block_proc == sim.block_proc
        assert back.transfers == sim.transfers
        assert back.procs == sim.procs
        assert back.events == sim.events
        assert back.memory.per_proc == sim.memory.per_proc
        assert back.memory.peak == sim.memory.peak
        assert back.envelope.makespans == sim.envelope.makespans
        assert back.to_json() == sim.to_json()

    def test_utilization_and_gantt(self):
        wf, q = fan_out_workflow()
        plat = unit_procs(3)
        sim = simulate(make_result(wf, q, plat))
        by_proc = {p.proc: p for p in sim.procs}
        assert by_proc[0].busy_s == 1.0
        assert by_proc[0].utilization == pytest.approx(1.0 / 6.0)
        assert by_proc[0].idle_s == pytest.approx(5.0)
        g = sim.gantt(width=30)
        assert len(g.splitlines()) == 4  # header + 3 proc rows
        assert "█" in g and "busy" in g

    def test_infeasible_report_raises(self, plat):
        wf = generate_workflow("blast", 50, seed=1)
        for u in range(wf.n):
            wf.mem[u] = 1e9  # nothing fits anywhere
        rep = schedule(wf, plat, kprime=[2])
        assert not rep.feasible
        with pytest.raises(ValueError, match="no feasible mapping"):
            simulate(rep)


# ---------------------------------------------------------------------- #
# scheduler integration
# ---------------------------------------------------------------------- #
class TestSimulateStage:
    def test_stage_attaches_report(self, plat):
        wf = generate_workflow("seismology", 120, seed=2, platform=plat)
        rep = schedule(wf, plat, kprime=[4], simulate=True)
        assert isinstance(rep.sim, SimReport)
        assert rep.sim.makespan == rep.makespan
        assert rep.sim.exact_anchor

    def test_stage_options_and_default_off(self, plat):
        wf = generate_workflow("seismology", 120, seed=2, platform=plat)
        rep = schedule(wf, plat, kprime=[4])
        assert rep.sim is None
        rep = schedule(wf, plat, kprime=[4], simulate=True,
                       sim_options={"comm": "fair-share",
                                    "memory": False})
        assert rep.sim.comm.startswith("fair-share")
        assert rep.sim.memory is None
        assert rep.sim.makespan >= rep.makespan

    def test_stage_in_parallel_sweep(self, plat):
        wf = generate_workflow("bwa", 150, seed=2, platform=plat)
        rep = schedule(wf, plat, kprime=[2, 4, 6], workers=2,
                       simulate=True,
                       sim_options={"record_events": False})
        serial = schedule(wf, plat, kprime=[2, 4, 6], simulate=True,
                          sim_options={"record_events": False})
        assert rep.sim is not None
        assert rep.sim.makespan == serial.sim.makespan == rep.makespan

    def test_pack_pipeline_has_simulate_stage_too(self, plat):
        wf = generate_workflow("genome", 120, seed=2, platform=plat)
        rep = schedule(wf, plat, algorithm="dag_het_mem", simulate=True)
        assert rep.sim is not None
        assert rep.sim.makespan == rep.makespan


# ---------------------------------------------------------------------- #
# per-link platform overrides (satellite fix)
# ---------------------------------------------------------------------- #
class TestPlatformLinks:
    def test_override_and_uniform_default(self):
        p = unit_procs(6).with_link_bandwidth(0, 5, 9.0)
        assert p.bandwidth_between(0, 5) == 9.0
        assert p.bandwidth_between(5, 0) == 9.0  # symmetric default
        assert p.bandwidth_between(0, 1) == 1.0
        assert math.isinf(p.bandwidth_between(3, 3))
        q = unit_procs(6).with_link_bandwidth(0, 5, 9.0, symmetric=False)
        assert q.bandwidth_between(5, 0) == 1.0

    def test_without_reindexes_links(self):
        p = unit_procs(6).with_link_bandwidth(0, 5, 9.0)
        d = p.without({1, 2})
        # old 5 is new 3; the override survives the renumbering
        assert d.k == 4
        assert d.bandwidth_between(0, 3) == 9.0
        assert d.bandwidth_between(3, 0) == 9.0
        assert d.bandwidth_between(0, 1) == 1.0

    def test_zero_or_negative_link_bandwidth_rejected(self):
        p = unit_procs(3)
        with pytest.raises(ValueError, match="positive"):
            p.with_link_bandwidth(0, 1, 0.0)
        with pytest.raises(ValueError, match="positive"):
            p.with_link_bandwidth(0, 1, -2.0)
        assert p.with_link_bandwidth(0, 1, math.inf) \
            .bandwidth_between(0, 1) == math.inf

    def test_without_drops_links_of_failed_procs(self):
        p = unit_procs(6).with_link_bandwidth(0, 5, 9.0)
        d = p.without({5})
        assert d.link_bandwidth == {}

    def test_with_bandwidth_keeps_overrides(self):
        p = unit_procs(6).with_link_bandwidth(0, 5, 9.0)
        r = p.with_bandwidth(2.0)
        assert r.bandwidth == 2.0
        assert r.bandwidth_between(0, 5) == 9.0
        assert r.bandwidth_between(0, 1) == 2.0

    def test_composition_failure_scenario(self):
        # configure links, fail a node, rescale beta: config survives
        p = (unit_procs(5)
             .with_link_bandwidth(1, 4, 0.25)
             .with_link_bandwidth(0, 2, 8.0))
        d = p.without({3}).with_bandwidth(0.5)
        assert d.bandwidth_between(1, 3) == 0.25   # old 4 -> new 3
        assert d.bandwidth_between(0, 2) == 8.0
        assert d.bandwidth_between(2, 1) == 0.5


# ---------------------------------------------------------------------- #
# raw engine edge cases
# ---------------------------------------------------------------------- #
class TestEngine:
    def test_cycle_detection(self):
        plat = unit_procs(2)
        blocks = [BlockSpec(0, 0, 1.0), BlockSpec(1, 1, 1.0)]
        edges = [EdgeSpec(0, 1, 1.0), EdgeSpec(1, 0, 1.0)]
        with pytest.raises(ValueError, match="cyclic"):
            run_engine(blocks, edges, ContentionFreeComm(), plat)

    def test_empty_and_single(self):
        plat = unit_procs(1)
        t = run_engine([], [], ContentionFreeComm(), plat)
        assert t.horizon == 0.0
        t = run_engine([BlockSpec(7, 0, 2.5)], [], ContentionFreeComm(),
                       plat)
        assert t.start[7] == 0.0 and t.finish[7] == 2.5
