"""MoE layer property tests: capacity routing semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import Initializer, swiglu
from repro.models.moe import init_moe, moe_capacity, moe_ffn


def make_params(d=16, f=32, e=4, seed=0):
    init = Initializer(seed, jnp.float32)
    return init_moe(init, d, f, e)


class TestCapacity:
    def test_formula(self):
        assert moe_capacity(128, 8, 2, 1.25) == 40
        assert moe_capacity(4, 64, 8, 1.0) == 1     # floor at 1
        assert moe_capacity(16, 2, 2, 100.0) == 16  # cap at tokens


class TestRouting:
    def test_no_drop_regime_matches_manual_mixture(self):
        """With capacity >= tokens, expert-choice == token-choice: the
        output equals the gate-weighted mixture of expert FFNs."""
        rng = np.random.default_rng(0)
        p = make_params()
        x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
        y, _ = moe_ffn(p, x, top_k=2, capacity_factor=100.0)

        logits = jnp.einsum("gtd,de->gte", x, p["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_vals, top_idx = jax.lax.top_k(probs, 2)
        top_vals = top_vals / top_vals.sum(-1, keepdims=True)
        expert_out = jnp.stack([
            swiglu(x, p["w_gate"][e], p["w_up"][e], p["w_down"][e])
            for e in range(4)
        ], axis=2)                                    # [G, T, E, d]
        manual = jnp.zeros_like(x)
        for k in range(2):
            sel = jnp.take_along_axis(
                expert_out, top_idx[..., k][..., None, None], axis=2
            )[..., 0, :]
            manual = manual + top_vals[..., k][..., None] * sel
        np.testing.assert_allclose(y, manual, atol=1e-5, rtol=1e-5)

    def test_tight_capacity_drops_tokens(self):
        rng = np.random.default_rng(1)
        p = make_params()
        x = jnp.asarray(rng.normal(size=(1, 32, 16)), jnp.float32)
        y_tight, _ = moe_ffn(p, x, top_k=2, capacity_factor=0.25)
        y_loose, _ = moe_ffn(p, x, top_k=2, capacity_factor=100.0)
        # tight capacity zeroes some tokens' updates
        tight_norms = jnp.linalg.norm(y_tight[0], axis=-1)
        assert float((tight_norms == 0.0).sum()) > 0
        assert float(jnp.linalg.norm(y_tight - y_loose)) > 0

    def test_aux_loss_equals_topk_when_balanced(self):
        """Switch-style aux: E·Σ f_e·P_e = k at perfect balance (each
        expert dispatched a k/E fraction at probability 1/E)."""
        p = make_params(seed=2)
        # zero router -> uniform probabilities
        p = dict(p, router=jnp.zeros_like(p["router"]))
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(2, 64, 16)), jnp.float32)
        _, aux = moe_ffn(p, x, top_k=2)
        assert float(aux) == pytest.approx(2.0, abs=0.05)

    def test_gradients_reach_all_used_experts(self):
        rng = np.random.default_rng(3)
        p = make_params(seed=3)
        x = jnp.asarray(rng.normal(size=(1, 16, 16)), jnp.float32)

        def loss(p):
            y, aux = moe_ffn(p, x, top_k=2, capacity_factor=2.0)
            return (y ** 2).mean() + 0.01 * aux

        g = jax.grad(loss)(p)
        assert bool(jnp.all(jnp.isfinite(g["w_gate"])))
        assert float(jnp.abs(g["router"]).max()) > 0

    def test_shard_hook_is_called(self):
        calls = []
        p = make_params()
        x = jnp.zeros((1, 8, 16), jnp.float32)
        moe_ffn(p, x, top_k=2, shard=lambda v, kind: calls.append(kind) or v)
        assert "moe_tokens" in calls and "moe_hidden" in calls
