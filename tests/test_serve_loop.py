"""Continuous-batching correctness: requests served concurrently in a
shared slot pool must produce exactly what they produce when served
alone (per-slot cache cursors keep requests isolated)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import LM
from repro.runtime.serve_loop import Request, ServeLoop


def make_model():
    cfg = get_smoke_config("llama3_8b")
    model = LM(cfg, param_dtype=jnp.float32, attn_chunk=8, max_seq=64)
    return cfg, model, model.init(0)


def serve(model, params, requests, slots):
    loop = ServeLoop(model, params, slots=slots, max_len=48)
    for r in requests:
        loop.submit(r)
    done = loop.run()
    return {r.rid: list(r.out) for r in done}


class TestServeLoop:
    def test_concurrent_equals_solo(self):
        cfg, model, params = make_model()
        rng = np.random.default_rng(0)
        prompts = [
            rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in (3, 7, 5, 4, 6)
        ]

        def reqs():
            return [Request(i, p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]

        solo = {}
        for r in reqs():
            solo.update(serve(model, params, [r], slots=2))
        together = serve(model, params, reqs(), slots=2)

        assert together.keys() == solo.keys()
        for rid in solo:
            assert together[rid] == solo[rid], rid

    def test_more_requests_than_slots_all_finish(self):
        cfg, model, params = make_model()
        rng = np.random.default_rng(1)
        requests = [
            Request(i, rng.integers(0, cfg.vocab_size, size=4).astype(
                np.int32), max_new_tokens=4)
            for i in range(7)
        ]
        done = serve(model, params, requests, slots=3)
        assert len(done) == 7
        assert all(len(v) == 4 for v in done.values())

    def test_eos_stops_early(self):
        cfg, model, params = make_model()
        rng = np.random.default_rng(2)
        prompt = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
        # find which token greedy decode emits first, then use it as eos
        probe = serve(model, params,
                      [Request(0, prompt, max_new_tokens=3)], slots=1)
        first = probe[0][0]
        loop = ServeLoop(model, params, slots=1, max_len=48)
        loop.submit(Request(1, prompt, max_new_tokens=8, eos_id=first))
        done = loop.run()
        assert len(done) == 1 and done[0].out[-1] == first
        assert len(done[0].out) <= 8

    def test_stateful_arch_rejected(self):
        cfg = get_smoke_config("rwkv6_1b6")
        model = LM(cfg, param_dtype=jnp.float32, max_seq=32)
        with pytest.raises(ValueError):
            ServeLoop(model, model.init(0))
