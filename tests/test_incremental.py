"""Property tests for the incremental scheduling evaluator.

The load-bearing invariant: after ANY sequence of engine mutations
(merges, triple merges, processor reassignments, swaps, rollbacks) the
maintained bottom weights are *bit-identical* to a from-scratch
:func:`repro.core.makespan.bottom_weights` sweep, and the makespan /
critical path follow.  The randomized suite below drives well over 200
mutation sequences; it runs with the real ``hypothesis`` when present
and with the seeded fallback otherwise (the deterministic loops below
do not depend on either).
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import Platform, Processor
from repro.core.dag import QuotientGraph, Workflow, build_quotient
from repro.core.incremental import IncrementalEvaluator
from repro.core.makespan import bottom_weights, critical_path, makespan
from repro.core.workflows import random_layered_dag


def make_platform(k: int = 8, seed: int = 0) -> Platform:
    rng = random.Random(seed)
    procs = [
        Processor(f"p{i}", rng.choice([1.0, 2.0, 4.0, 8.0]),
                  rng.choice([8.0, 32.0, 192.0]))
        for i in range(k)
    ]
    return Platform(procs, bandwidth=rng.choice([0.5, 1.0, 2.0]))


def make_quotient(n: int, blocks: int, seed: int) -> QuotientGraph:
    wf = random_layered_dag(n, seed=seed)
    rng = random.Random(seed + 1)
    block_of = [rng.randrange(blocks) for _ in range(n)]
    # contiguity not required: random groupings may be cyclic, retry a
    # few relabelings biased toward topological position
    order = wf.topological_order()
    for attempt in range(10):
        q = build_quotient(wf, block_of)
        if q.is_acyclic():
            return q
        pos = {u: i for i, u in enumerate(order)}
        block_of = [min(blocks - 1, pos[u] * blocks // n)
                    for u in range(n)]
    q = build_quotient(wf, block_of)
    assert q.is_acyclic()
    return q


def mutate_once(ev: IncrementalEvaluator, platform: Platform,
                rng: random.Random) -> None:
    """One random committed mutation through the engine."""
    q = ev.q
    verts = sorted(q.members)
    op = rng.random()
    if op < 0.45 and len(verts) >= 2:
        # merge a random adjacent pair (with 2-cycle escalation)
        v = rng.choice(verts)
        nbrs = sorted(set(q.pred[v]) | set(q.succ[v]))
        if not nbrs:
            return
        vp = rng.choice(nbrs)
        ev.begin()
        vm, cycle = ev.merge(v, vp)
        if cycle is not None and len(cycle) == 2:
            other = cycle[0] if cycle[0] != vm else cycle[1]
            vm, cycle = ev.merge(vm, other)
        if cycle is not None:
            ev.rollback()
            return
        ev.commit()
        if rng.random() < 0.7:
            ev.set_proc(vm, rng.randrange(platform.k))
    elif op < 0.75:
        v = rng.choice(verts)
        ev.set_proc(v, rng.choice([None] + list(range(platform.k))))
    elif len(verts) >= 2:
        v, w = rng.sample(verts, 2)
        ev.swap(v, w)


class TestEquivalence:
    def test_randomized_mutation_sequences(self):
        """>= 200 randomized sequences: engine == from-scratch sweep."""
        sequences = 0
        for seed in range(70):
            platform = make_platform(k=6, seed=seed)
            q = make_quotient(30 + seed % 17, 6 + seed % 5, seed)
            ev = IncrementalEvaluator(q, platform)
            ev.assert_consistent()
            rng = random.Random(1000 + seed)
            for step in range(3):
                mutate_once(ev, platform, rng)
                sequences += 1
                ev.assert_consistent()
                assert ev.makespan() == makespan(q, platform)
        assert sequences >= 200

    def test_rollback_restores_exact_state(self):
        platform = make_platform(k=5, seed=3)
        q = make_quotient(40, 8, 3)
        ev = IncrementalEvaluator(q, platform)
        before_l = dict(ev.l)
        before_succ = {v: dict(q.succ[v]) for v in q.members}
        before_proc = dict(q.proc)
        rng = random.Random(7)
        verts = sorted(q.members)
        for _ in range(20):
            v = rng.choice(verts)
            nbrs = sorted(set(q.pred[v]) | set(q.succ[v]))
            ev.begin()
            ev.set_proc(v, rng.randrange(platform.k))
            if nbrs:
                ev.merge(v, rng.choice(nbrs))
            ev.rollback()
            assert ev.l == before_l
            assert {x: dict(q.succ[x]) for x in q.members} == before_succ
            assert dict(q.proc) == before_proc
        ev.assert_consistent()

    def test_critical_path_matches_reference(self):
        for seed in range(10):
            platform = make_platform(k=6, seed=seed)
            q = make_quotient(35, 7, seed)
            rng = random.Random(seed)
            for v in sorted(q.members):
                if rng.random() < 0.8:
                    q.proc[v] = rng.randrange(platform.k)
            ev = IncrementalEvaluator(q, platform)
            ref = critical_path(q, platform)
            got = ev.critical_path()
            # both must realize the makespan; tie-breaks may differ
            l = bottom_weights(q, platform)
            assert l[got[0]] == makespan(q, platform)
            assert got[0] == ref[0] or l[got[0]] == l[ref[0]]
            beta = platform.bandwidth
            for a, b in zip(got, got[1:]):
                assert b in q.succ[a]
                assert l[a] == pytest.approx(
                    q.weight[a] / (platform.procs[q.proc[a]].speed
                                   if q.proc[a] is not None else 1.0)
                    + q.succ[a][b] / beta + l[b])


class TestProbes:
    def _setup(self, seed):
        platform = make_platform(k=6, seed=seed)
        q = make_quotient(40, 8, seed)
        rng = random.Random(seed + 5)
        for v in sorted(q.members):
            q.proc[v] = rng.randrange(platform.k)
        return platform, q, rng

    def test_probe_swap_exact(self):
        """probe_swap == makespan of actually applying the swap."""
        checked = 0
        for seed in range(12):
            platform, q, rng = self._setup(seed)
            ev = IncrementalEvaluator(q, platform)
            verts = sorted(q.members)
            for _ in range(12):
                v, w = rng.sample(verts, 2)
                got = ev.probe_swap(v, w, float("inf"))
                q.proc[v], q.proc[w] = q.proc[w], q.proc[v]
                ref = makespan(q, platform)
                q.proc[v], q.proc[w] = q.proc[w], q.proc[v]
                assert got == ref
                ev.assert_consistent()  # probe left no trace
                checked += 1
        assert checked >= 100

    def test_probe_swap_bound_rejections_sound(self):
        """None from a bounded probe really means ms >= bound."""
        for seed in range(8):
            platform, q, rng = self._setup(seed)
            ev = IncrementalEvaluator(q, platform)
            ms0 = ev.makespan()
            verts = sorted(q.members)
            for _ in range(10):
                v, w = rng.sample(verts, 2)
                got = ev.probe_swap(v, w, ms0)
                q.proc[v], q.proc[w] = q.proc[w], q.proc[v]
                ref = makespan(q, platform)
                q.proc[v], q.proc[w] = q.proc[w], q.proc[v]
                if got is None:
                    assert ref >= ms0
                else:
                    assert got == ref and ref < ms0

    def test_probe_merge_exact(self):
        for seed in range(10):
            platform, q, rng = self._setup(seed + 100)
            ev = IncrementalEvaluator(q, platform)
            verts = sorted(q.members)
            for v in verts:
                nbrs = sorted(set(q.pred[v]) | set(q.succ[v]))
                if not nbrs:
                    continue
                vp = nbrs[0]
                # probes cannot escalate 2-cycles; skip those pairs
                down, up = (vp, v) if vp in q.succ[v] else (v, vp)
                if q.succ[up].keys() & q.pred[down].keys():
                    continue
                proc = q.proc[vp]
                got = ev.probe_merge(v, vp, proc, float("inf"))
                vm, undo = q.merge(v, vp)
                cyclic = not q.is_acyclic()
                if not cyclic:
                    q.proc[vm] = proc
                    ref = makespan(q, platform)
                q.unmerge(undo)
                if cyclic:
                    assert got is None
                else:
                    assert got == ref
                ev.assert_consistent()


class TestSwapPassPruning:
    def test_pruned_equals_exhaustive(self):
        """Critical-path pruning must not change Step 4's outcome."""
        from repro.core.heuristic import _Requirements, _swap_pass

        for seed in range(8):
            platform = make_platform(k=10, seed=seed)
            results = []
            for exhaustive in (False, True):
                q = make_quotient(36, 8, seed)
                wf = q.wf
                procs = random.Random(seed).sample(
                    range(platform.k), q.n_vertices)
                for v, p in zip(sorted(q.members), procs):
                    q.proc[v] = p
                ev = IncrementalEvaluator(q, platform)
                reqs = _Requirements(wf, 0)
                _swap_pass(wf, platform, q, reqs, ev,
                           exhaustive=exhaustive)
                results.append(ev.makespan())
            assert results[0] == pytest.approx(results[1])


def _assert_ranks_exact_topological(ev: IncrementalEvaluator) -> None:
    """The maintained ranks are a strict topological order of Γ."""
    q = ev.q
    ranks = {v: ev._rank[v] for v in q.members}
    assert len(set(ranks.values())) == len(ranks), "duplicate ranks"
    for u in q.members:
        for w in q.succ[u]:
            assert ranks[u] < ranks[w], f"rank order violated on {u}->{w}"


class TestDynamicRanks:
    """Pearce–Kelly localized rank maintenance (ROADMAP hot spot #3).

    *Rank equivalence*: PK repairs and a full refresh may assign
    different rank values, but both must (a) be strict topological
    orders of Γ and (b) make bounded probes return identical verdicts
    — rank values are consumed only as a processing order, never
    compared across runs.
    """

    def test_pk_keeps_ranks_exact_across_random_merges(self):
        checked = 0
        for seed in range(25):
            platform = make_platform(k=6, seed=seed)
            q = make_quotient(36 + seed % 13, 7 + seed % 4, seed)
            ev = IncrementalEvaluator(q, platform)
            rng = random.Random(seed * 13 + 5)
            for _ in range(12):
                mutate_once(ev, platform, rng)
                if ev._ranks_exact:  # triple merges may drop exactness
                    _assert_ranks_exact_topological(ev)
                    checked += 1
                ev.assert_consistent()
        assert checked >= 150

    def test_pk_equivalent_to_full_refresh(self):
        """Probe verdicts under PK ranks == after a forced refresh."""
        for seed in range(12):
            platform = make_platform(k=5, seed=seed)
            q = make_quotient(30, 6, seed)
            ev = IncrementalEvaluator(q, platform)
            rng = random.Random(seed + 77)
            for _ in range(8):
                mutate_once(ev, platform, rng)
            ev.ensure_exact_ranks()
            _assert_ranks_exact_topological(ev)
            verts = sorted(q.members)
            bound = ev.makespan() + 1.0
            pk_probes = [ev.probe_swap(v, w, bound)
                         for v in verts[:6] for w in verts[-6:] if v != w]
            ev.refresh_ranks()  # discard PK ranks for fresh exact ones
            _assert_ranks_exact_topological(ev)
            fresh = [ev.probe_swap(v, w, bound)
                     for v in verts[:6] for w in verts[-6:] if v != w]
            assert pk_probes == fresh

    def test_pk_rollback_restores_ranks_exactly(self):
        for seed in range(15):
            platform = make_platform(k=4, seed=seed)
            q = make_quotient(28, 6, seed)
            ev = IncrementalEvaluator(q, platform)
            rng = random.Random(seed * 3 + 1)
            for _ in range(25):
                verts = sorted(q.members)
                if len(verts) < 3:
                    break
                before_ranks = dict(ev._rank)
                before_exact = ev._ranks_exact
                a, b = rng.sample(verts, 2)
                ev.begin()
                ev.merge(a, b)  # may run an in-frame PK repair
                ev.rollback()
                assert ev._rank == before_ranks
                assert ev._ranks_exact == before_exact
                ev.assert_consistent()

    def test_localized_cycle_probe_matches_generic(self):
        """_cycle_after_merge's verdict == QuotientGraph.cycle_through
        (and the 2-cycle representative is identical)."""
        agree = cycles = 0
        for seed in range(20):
            platform = make_platform(k=4, seed=seed)
            q = make_quotient(30, 7, seed)
            ev = IncrementalEvaluator(q, platform)
            rng = random.Random(seed + 11)
            verts = sorted(q.members)
            for _ in range(20):
                a, b = rng.sample(verts, 2)
                rv = max(ev._rank[a], ev._rank[b])
                vm, undo = q.merge(a, b)
                ev._rank[vm] = rv
                ranked = ev._cycle_after_merge(vm, rv)
                generic = q.cycle_through(vm)
                assert (ranked is None) == (generic is None)
                if ranked is not None:
                    cycles += 1
                    if len(generic) == 2:
                        assert ranked == generic
                del ev._rank[vm]
                q.unmerge(undo)
                agree += 1
        assert agree >= 300 and cycles >= 5


class TestSwapProbeCache:
    """Step-4 dependency-region verdict caching (ROADMAP hot spot #4):
    the cached pass must make bit-identical swap decisions."""

    def test_cache_on_off_bit_identical(self):
        from repro.core.heuristic import _Requirements, _swap_pass

        for seed in range(40):
            outcomes = []
            for use_cache in (False, True):
                platform = make_platform(k=6, seed=seed)
                q = make_quotient(30 + seed % 11, 6 + seed % 4, seed)
                rng = random.Random(seed)
                for v in sorted(q.members):
                    q.proc[v] = rng.randrange(platform.k)
                wf = q.wf
                reqs = _Requirements(wf, 0)
                ev = IncrementalEvaluator(q, platform)
                _swap_pass(wf, platform, q, reqs, ev,
                           probe_cache=use_cache)
                outcomes.append((ev.makespan(), dict(q.proc)))
            assert outcomes[0] == outcomes[1]

    def test_cache_hits_recorded_on_real_instance(self):
        from repro.core import counters, default_cluster, \
            generate_workflow, schedule

        plat = default_cluster()
        wf = generate_workflow("epigenomics", 600, seed=2, platform=plat)
        snap = counters.snapshot()
        rep = schedule(wf, plat, kprime=[9, 19])
        assert rep.feasible
        moved = counters.delta(snap)
        assert rep.cache_stats.get("swap_probes", 0) \
            == moved.get("swap_probes", 0)
        # Step 3 merged on this instance and PK kept every committed
        # merge on the localized path (no full refresh)
        assert moved.get("rank_pk_noops", 0) \
            + moved.get("rank_pk_repairs", 0) > 0
        assert moved.get("rank_full_refreshes", 0) == 0
        assert moved.get("swap_probe_cache_hits", 0) > 0


@pytest.mark.slow
def test_end_to_end_large_instance():
    """The scheduler completes and validates on a mid-size instance."""
    from repro.core import (
        default_cluster, generate_workflow, schedule, validate_mapping,
    )

    plat = default_cluster()
    wf = generate_workflow("blast", 4000, seed=1, platform=plat)
    rep = schedule(wf, plat, kprime=[4, 13, 36])
    assert rep.feasible
    assert validate_mapping(wf, rep.best) == []
