"""repro.scenario: engine pause/resume, warm-start replanning,
timeline stitching, migration accounting, and the elastic consumers."""
import json

import pytest

from repro.core import (
    Platform,
    Processor,
    ResumeState,
    Scheduler,
    SchedulerConfig,
    Workflow,
    default_cluster,
    generate_workflow,
    residual_workflow,
    schedule,
    validate_mapping,
)
from repro.runtime.fault import StragglerMonitor
from repro.scenario import (
    LinkDegrade,
    ProcArrival,
    ProcFailure,
    Scenario,
    SpeedChange,
    TimelineReport,
    event_from_dict,
    run_scenario,
)
from repro.sim import build_specs, resolve_comm, resume_engine, run_engine

KPRIME = [2, 4, 9]


def _wf(family="montage", n=200, seed=1, plat=None):
    return generate_workflow(family, n, seed=seed,
                             platform=plat or default_cluster())


# ---------------------------------------------------------------------- #
# engine pause / resume
# ---------------------------------------------------------------------- #
class TestEnginePause:
    @pytest.fixture(scope="class")
    def specs(self):
        plat = default_cluster()
        wf = _wf("epigenomics", 300, 2, plat)
        res = schedule(wf, plat, kprime=[6]).best
        blocks, edges = build_specs(res.quotient, plat)
        return plat, blocks, edges

    @pytest.mark.parametrize("comm", ["contention-free", "fair-share"])
    def test_pause_resume_bit_identical(self, specs, comm):
        plat, blocks, edges = specs
        full = run_engine(blocks, edges, resolve_comm(comm), plat)
        tr = run_engine(blocks, edges, resolve_comm(comm), plat,
                        stop_time=full.horizon * 0.3)
        assert tr.paused
        # pause freezes exactly the <= stop_time prefix
        cut = full.horizon * 0.3
        assert set(tr.finish) == {v for v, t in full.finish.items()
                                  if t <= cut}
        tr = resume_engine(tr.checkpoint, stop_time=full.horizon * 0.7)
        assert tr.paused
        tr = resume_engine(tr.checkpoint)
        assert not tr.paused
        assert tr.start == full.start
        assert tr.finish == full.finish
        assert tr.xfer_start == full.xfer_start
        assert tr.xfer_finish == full.xfer_finish
        assert tr.horizon == full.horizon

    def test_in_flight_classification(self, specs):
        plat, blocks, edges = specs
        full = run_engine(blocks, edges, resolve_comm("contention-free"),
                          plat)
        cut = full.horizon * 0.5
        tr = run_engine(blocks, edges, resolve_comm("contention-free"),
                        plat, stop_time=cut)
        for v in tr.in_flight():
            assert full.start[v] <= cut < full.finish[v]

    def test_stop_past_horizon_completes(self, specs):
        plat, blocks, edges = specs
        full = run_engine(blocks, edges, resolve_comm("contention-free"),
                          plat)
        tr = run_engine(blocks, edges, resolve_comm("contention-free"),
                        plat, stop_time=full.horizon * 2)
        assert not tr.paused and tr.finish == full.finish

    def test_resume_rejects_earlier_stop(self, specs):
        plat, blocks, edges = specs
        tr = run_engine(blocks, edges, resolve_comm("contention-free"),
                        plat, stop_time=10.0)
        if tr.paused:
            with pytest.raises(ValueError, match="precedes"):
                resume_engine(tr.checkpoint, stop_time=1.0)


# ---------------------------------------------------------------------- #
# residual extraction
# ---------------------------------------------------------------------- #
class TestResidualWorkflow:
    def test_requirement_preserved_on_frontier(self, diamond):
        sub, mapping = residual_workflow(diamond, {0})
        assert mapping == [1, 2, 3]
        # frontier tasks keep their full requirement: the boundary
        # input volume is folded into task memory
        for i, u in enumerate(mapping):
            assert sub.task_requirement(i) == pytest.approx(
                diamond.task_requirement(u))
        assert sorted(sub.sources()) == [0, 1]  # old tasks 1 and 2

    def test_rejects_non_closed_prefix(self, diamond):
        with pytest.raises(ValueError, match="closed under predecessors"):
            residual_workflow(diamond, {3})

    def test_empty_completed_is_identity_shape(self, diamond):
        sub, mapping = residual_workflow(diamond, set())
        assert mapping == [0, 1, 2, 3]
        assert sub.n_edges == diamond.n_edges


# ---------------------------------------------------------------------- #
# the identity anchor
# ---------------------------------------------------------------------- #
class TestIdentityAnchor:
    def test_empty_timeline_matches_schedule(self):
        plat = default_cluster()
        wf = _wf()
        cfg = SchedulerConfig(kprime=KPRIME, simulate=True)
        plain = Scheduler(cfg).schedule(wf, plat)
        tl = run_scenario(Scenario(wf, plat, []), config=cfg)
        assert tl.feasible and len(tl.segments) == 1
        # bit-exact: same best makespan, same simulated makespan
        assert tl.segments[0].report.makespan == plain.makespan
        assert tl.makespan == plain.sim.makespan
        assert tl.migrations == [] and tl.replan_times_s == []

    def test_event_after_completion_is_noop(self):
        plat = default_cluster()
        wf = _wf()
        cfg = SchedulerConfig(kprime=KPRIME)
        plain = Scheduler(cfg).schedule(wf, plat)
        tl = run_scenario(
            Scenario(wf, plat, [ProcFailure(plain.makespan * 10, {0})]),
            config=cfg)
        assert tl.feasible and len(tl.segments) == 1
        assert tl.makespan == pytest.approx(plain.makespan)


# ---------------------------------------------------------------------- #
# failure scenarios + policies
# ---------------------------------------------------------------------- #
class TestFailureScenarios:
    @pytest.fixture(scope="class")
    def setting(self):
        plat = default_cluster()
        wf = _wf("montage", 200, 1, plat)
        cfg = SchedulerConfig(kprime=KPRIME)
        base = Scheduler(cfg).schedule(wf, plat)
        q = base.best.quotient
        used = sorted({q.proc[v] for v in q.members})
        te = 0.4 * base.makespan
        return plat, wf, cfg, base, used, te

    def test_warm_start_freezes_completed_and_pins_inflight(self, setting):
        plat, wf, cfg, base, used, te = setting
        sc = Scenario(wf, plat, [ProcFailure(te, frozenset(used[:2]))])
        tl = run_scenario(sc, "pinned-warm-start", config=cfg)
        assert tl.feasible and len(tl.segments) == 2
        assert tl.validate() == []  # memory_trace=True per segment

        seg0, seg1 = tl.segments
        cut = seg0.executed_until
        sim0 = seg0.sim
        q0 = seg0.mapping.quotient
        completed = {v for v, f in sim0.block_finish.items() if f <= cut}
        inflight = {v for v, s in sim0.block_start.items()
                    if s < cut and v not in completed}
        done_tasks = set()
        for v in completed:
            done_tasks |= {seg0.task_ids[u] for u in q0.members[v]}
        # completed tasks left the workflow for good
        assert done_tasks.isdisjoint(seg1.task_ids)
        assert seg1.completed_before == len(done_tasks)

        # in-flight blocks on surviving processors stay put (by name)
        q1 = seg1.mapping.quotient
        inv1 = {g: i for i, g in enumerate(seg1.task_ids)}
        proc_name1 = {}
        for vid, members in q1.members.items():
            nm = seg1.platform.procs[q1.proc[vid]].name
            for u in members:
                proc_name1[u] = nm
        failed_names = {plat.procs[j].name for j in used[:2]}
        for v in inflight:
            old_name = plat.procs[q0.proc[v]].name
            if old_name in failed_names:
                continue  # displaced, not pinned
            for u in q0.members[v]:
                assert proc_name1[inv1[seg0.task_ids[u]]] == old_name

        # migration log agrees on the restart accounting (moved_tasks
        # may be > 0: Step 4 is free to improve *unstarted* blocks)
        m = tl.migrations[0]
        assert m.restarted_blocks == len(inflight)
        assert m.restarted_tasks == sum(len(q0.members[v])
                                        for v in inflight)
        assert m.lost_work > 0

    def test_full_replan_feasible_and_valid(self, setting):
        plat, wf, cfg, base, used, te = setting
        sc = Scenario(wf, plat, [ProcFailure(te, frozenset(used[:2]))])
        tl = run_scenario(sc, "full-replan", config=cfg)
        assert tl.feasible
        assert tl.validate() == []
        assert tl.segments[-1].report.algorithm == "dag_het_part"

    def test_no_replan_structured_infeasibility_on_failure(self, setting):
        plat, wf, cfg, base, used, te = setting
        sc = Scenario(wf, plat, [ProcFailure(te, frozenset(used[:2]))])
        tl = run_scenario(sc, "no-replan", config=cfg)
        assert not tl.feasible
        assert tl.makespan is None
        assert tl.failed_at == pytest.approx(te)
        assert tl.infeasibility is not None

    def test_no_replan_survives_untouched_failure(self, setting):
        plat, wf, cfg, base, used, te = setting
        idle = [j for j in range(plat.k) if j not in used]
        sc = Scenario(wf, plat, [ProcFailure(te, frozenset(idle[:1]))])
        tl = run_scenario(sc, "no-replan", config=cfg)
        assert tl.feasible
        assert tl.migrations[0].moved_tasks == 0
        assert tl.migrations[0].displaced_tasks == 0

    def test_speed_change_replans_feasibly(self, setting):
        plat, wf, cfg, base, used, te = setting
        events = [SpeedChange(te, proc=used[0], factor=0.25)]
        tl = run_scenario(Scenario(wf, plat, events),
                          "pinned-warm-start", config=cfg)
        assert tl.feasible and tl.validate() == []
        assert tl.segments[1].platform.speed(used[0]) == pytest.approx(
            plat.speed(used[0]) * 0.25)

    def test_link_degrade_and_arrival_chain(self, setting):
        plat, wf, cfg, base, used, te = setting
        events = [
            LinkDegrade(te, src=used[0], dst=used[1], bandwidth=0.05),
            ProcArrival(te * 1.5,
                        procs=(Processor("fresh-0", 64.0, 256.0),)),
        ]
        tl = run_scenario(Scenario(wf, plat, events),
                          "pinned-warm-start", config=cfg)
        assert tl.feasible and tl.validate() == []
        assert tl.segments[-1].platform.k == plat.k + 1

    def test_inflight_transfer_never_silently_dropped(self):
        # A(20) --100--> B(10) on two unit-speed procs: makespan 130.
        # A no-op event at t=50 lands mid-transfer; A's output is not
        # durable yet, so A restarts — the stitched makespan must never
        # undercut the no-event one (a dropped transfer once made it 60)
        wf = Workflow(2)
        wf.work[:] = [20.0, 10.0]
        wf.mem[:] = [1.0, 1.0]
        wf.add_edge(0, 1, 100.0)
        plat = Platform([Processor("a", 1.0, 1e6),
                         Processor("b", 1.0, 1e6)], 1.0)
        cfg = SchedulerConfig(kprime=[2])
        base = Scheduler(cfg).schedule(wf, plat)
        assert base.makespan == pytest.approx(130.0)
        sc = Scenario(wf, plat, [SpeedChange(50.0, proc=0, factor=1.0)])
        tl = run_scenario(sc, "no-replan", config=cfg,
                          initial_report=base)
        assert tl.feasible
        assert tl.makespan >= base.makespan  # no silent transfer drop
        assert tl.makespan == pytest.approx(50.0 + 130.0)  # restart
        m = tl.migrations[0]
        assert m.restarted_blocks == 1  # A: delivered nothing durable
        assert m.lost_work == pytest.approx(20.0)  # its full compute
        # whereas an event after the transfer landed freezes A for
        # good: only B (mid-compute at t=125) restarts -> 125 + 10
        sc2 = Scenario(wf, plat, [SpeedChange(125.0, proc=0, factor=1.0)])
        tl2 = run_scenario(sc2, "no-replan", config=cfg,
                           initial_report=base)
        assert tl2.makespan == pytest.approx(125.0 + 10.0)
        assert tl2.migrations[0].restarted_blocks == 1  # B mid-compute
        assert tl2.segments[1].completed_before == 1    # A frozen

    def test_pipeline_sim_options_govern_pause_model(self, setting):
        # cfg.simulate reuses the pipeline SimReport; a conflicting
        # caller-side sim_options must not leak into the pause engine
        plat, wf, cfg, base, used, te = setting
        from dataclasses import replace
        cfg_sim = replace(cfg, simulate=True)
        sc = Scenario(wf, plat, [ProcFailure(te, frozenset(used[:1]))])
        tl = run_scenario(sc, "warm+fallback", config=cfg_sim,
                          sim_options={"comm": "fair-share"})
        assert tl.feasible
        for seg in tl.segments:
            assert seg.sim.comm == "contention-free"

    def test_warm_cold_fallback_rescues_infeasible_warm(self):
        # full-sweep montage mapping where failing the 4 fastest used
        # processors strands a 192-requirement block: the pure warm
        # start is structurally infeasible (no split in warm mode),
        # the fallback escalates to a cold replan and completes
        plat = default_cluster()
        wf = _wf("montage", 200, 1, plat)
        cfg = SchedulerConfig(kprime=[1, 2, 4, 6, 9, 13, 19, 28, 36])
        base = Scheduler(cfg).schedule(wf, plat)
        q = base.best.quotient
        fastest = sorted({q.proc[v] for v in q.members},
                         key=lambda j: -plat.speed(j))[:4]
        sc = Scenario(wf, plat,
                      [ProcFailure(0.1 * base.makespan,
                                   frozenset(fastest))])
        warm = run_scenario(sc, "pinned-warm-start", config=cfg,
                            initial_report=base)
        assert not warm.feasible
        assert warm.infeasibility.stage == "merge"
        rescued = run_scenario(sc, "warm+fallback", config=cfg,
                               initial_report=base)
        assert rescued.feasible and rescued.validate() == []
        assert rescued.policy == "pinned-warm-start+cold-fallback"

    def test_infeasible_initial_plan_is_structured(self):
        tiny = Platform([Processor("p0", 1.0, 1.0),
                         Processor("p1", 1.0, 1.0)], 1.0)
        wf = _wf("blast", 60, 3)  # memories far above 1.0
        tl = run_scenario(Scenario(wf, tiny, [ProcFailure(5.0, {0})]),
                          config=SchedulerConfig(kprime=[1, 2]))
        assert not tl.feasible and tl.segments == []
        assert tl.failed_at == 0.0 and tl.infeasibility is not None

    def test_json_roundtrip_and_gantt(self, setting):
        plat, wf, cfg, base, used, te = setting
        sc = Scenario(wf, plat, [ProcFailure(te, frozenset(used[:2]))])
        tl = run_scenario(sc, "pinned-warm-start", config=cfg)
        back = TimelineReport.from_json(tl.to_json())
        assert back.makespan == tl.makespan
        assert back.policy == tl.policy
        assert len(back.segments) == len(tl.segments)
        assert [m.to_dict() for m in back.migrations] == \
            [m.to_dict() for m in tl.migrations]
        g = tl.gantt(width=48)
        assert "▼" in g and "░" not in g.split("\n")[0]
        # deserialized reports flag missing live mappings, not crash
        assert any("live mapping" in e for e in back.validate())


# ---------------------------------------------------------------------- #
# Scheduler.resume / warm-start mode
# ---------------------------------------------------------------------- #
class TestSchedulerResume:
    def test_resume_of_own_partition_reproduces_makespan(self):
        plat = default_cluster()
        wf = _wf()
        rep = schedule(wf, plat, kprime=KPRIME)
        q = rep.best.quotient
        vids = sorted(q.members)
        state = ResumeState(
            wf=wf, platform=plat,
            blocks=[sorted(q.members[v]) for v in vids],
            proc_of_block=[q.proc[v] for v in vids])
        warm = Scheduler(SchedulerConfig()).resume(state)
        assert warm.feasible
        assert warm.algorithm == "warm_start"
        # Step 4 already converged in the cold run: no further gain,
        # and the warm result must still be valid
        assert warm.makespan <= rep.makespan
        assert validate_mapping(wf, warm.best) == []

    def test_pinned_blocks_never_move(self):
        plat = default_cluster()
        wf = _wf("bwa", 150, 4, plat)
        rep = schedule(wf, plat, kprime=KPRIME)
        q = rep.best.quotient
        vids = sorted(q.members)
        pinned = set(range(len(vids)))  # pin everything
        state = ResumeState(
            wf=wf, platform=plat,
            blocks=[sorted(q.members[v]) for v in vids],
            proc_of_block=[q.proc[v] for v in vids],
            pinned=pinned)
        warm = Scheduler(SchedulerConfig()).resume(state)
        assert warm.feasible
        q2 = warm.best.quotient
        for i, v in enumerate(vids):
            members = set(q.members[v])
            match = [v2 for v2, m2 in q2.members.items()
                     if members <= m2]
            assert len(match) == 1
            assert q2.proc[match[0]] == q.proc[v]

    def test_resume_state_validates_pins(self):
        wf = _wf("blast", 20, 0)
        plat = default_cluster()
        with pytest.raises(ValueError, match="pin"):
            ResumeState(wf=wf, platform=plat,
                        blocks=[list(range(wf.n))],
                        proc_of_block=[None], pinned={0})

    def test_orphaned_block_rehomed_or_structured_failure(self):
        plat = default_cluster()
        wf = _wf()
        rep = schedule(wf, plat, kprime=KPRIME)
        q = rep.best.quotient
        vids = sorted(q.members)
        procs = [q.proc[v] for v in vids]
        procs[0] = None  # orphan one block
        state = ResumeState(
            wf=wf, platform=plat,
            blocks=[sorted(q.members[v]) for v in vids],
            proc_of_block=procs)
        warm = Scheduler(SchedulerConfig()).resume(state)
        assert warm.feasible  # plenty of idle processors to re-home to
        assert validate_mapping(wf, warm.best) == []


# ---------------------------------------------------------------------- #
# events
# ---------------------------------------------------------------------- #
class TestEvents:
    def test_roundtrip(self):
        evs = [
            ProcFailure(3.0, frozenset({1, 4})),
            ProcArrival(5.0, (Processor("x", 2.0, 8.0),)),
            SpeedChange(7.0, proc=2, factor=0.5),
            LinkDegrade(9.0, src=0, dst=3, bandwidth=0.1,
                        symmetric=False),
        ]
        for e in evs:
            back = event_from_dict(json.loads(json.dumps(e.to_dict())))
            assert back == e

    def test_validation(self):
        with pytest.raises(ValueError):
            ProcFailure(-1.0, {0})
        with pytest.raises(ValueError):
            ProcFailure(1.0, frozenset())
        with pytest.raises(ValueError):
            SpeedChange(1.0, proc=0, factor=0.0)
        with pytest.raises(ValueError):
            LinkDegrade(1.0, src=0, dst=1, bandwidth=-2.0)
        plat = Platform([Processor("a", 1.0, 1.0),
                         Processor("b", 1.0, 1.0)], 1.0)
        with pytest.raises(ValueError, match="every processor"):
            ProcFailure(0.0, {0, 1}).apply(plat)
        with pytest.raises(ValueError, match="out of range"):
            SpeedChange(0.0, proc=9, factor=0.5).apply(plat)

    def test_failure_proc_map_compacts(self):
        plat = default_cluster()
        new, m = ProcFailure(0.0, {1, 3}).apply(plat)
        assert new.k == plat.k - 2
        assert m[1] is None and m[3] is None
        assert m[0] == 0 and m[2] == 1 and m[4] == 2
        assert new.procs[m[4]].name == plat.procs[4].name


# ---------------------------------------------------------------------- #
# straggler monitor -> scenario events
# ---------------------------------------------------------------------- #
class TestStragglerEvents:
    def _monitor(self):
        mon = StragglerMonitor(threshold=1.5)
        for _ in range(8):
            mon.record(0, 1.0)
            mon.record(1, 1.1)
            mon.record(2, 4.0)
        return mon

    def test_median_based_slowdown_factor(self):
        mon = self._monitor()
        factors = mon.slowdown_factors()
        # overall lower median of {1.0, 1.1, 4.0} is 1.1; only host 2
        # exceeds 1.5x it, delivering 1.1/4.0 of nominal speed
        assert set(factors) == {2}
        assert factors[2] == pytest.approx(1.1 / 4.0)

    def test_emits_speed_change_events(self):
        mon = self._monitor()
        plat = Platform([Processor(f"p{i}", 100.0, 10.0)
                         for i in range(3)], 1.0)
        evs = mon.speed_events(plat, host_of_proc=lambda j: j, at=12.5)
        assert len(evs) == 1
        (ev,) = evs
        assert isinstance(ev, SpeedChange)
        assert ev.time == 12.5 and ev.proc == 2
        assert ev.factor == pytest.approx(1.1 / 4.0)
        degraded, m = ev.apply(plat)
        assert degraded.speed(2) == pytest.approx(100.0 * 1.1 / 4.0)
        assert m == {0: 0, 1: 1, 2: 2}

    def test_degraded_platform_composes_events(self):
        mon = self._monitor()
        plat = Platform([Processor(f"p{i}", 100.0, 10.0)
                         for i in range(3)], 1.0,
                        link_bandwidth={(0, 1): 0.5, (1, 0): 0.5})
        degraded = mon.degraded_platform(plat, host_of_proc=lambda j: j)
        assert degraded.speed(2) == pytest.approx(100.0 * 1.1 / 4.0)
        assert degraded.speed(0) == 100.0
        # the old rebuild dropped link overrides; composition keeps them
        assert degraded.link_bandwidth == plat.link_bandwidth
        assert degraded.name.endswith("-degraded")

    def test_scenario_consumes_straggler_events(self):
        plat = default_cluster()
        wf = _wf("soykb", 120, 5, plat)
        cfg = SchedulerConfig(kprime=[2, 4])
        base = Scheduler(cfg).schedule(wf, plat)
        mon = StragglerMonitor(threshold=1.5)
        q = base.best.quotient
        slow = sorted({q.proc[v] for v in q.members})[0]
        for _ in range(8):
            for j in range(plat.k):
                mon.record(j, 3.0 if j == slow else 1.0)
        evs = mon.speed_events(plat, host_of_proc=lambda j: j,
                               at=0.3 * base.makespan)
        assert evs
        tl = run_scenario(Scenario(wf, plat, evs),
                          "pinned-warm-start", config=cfg)
        assert tl.feasible and tl.validate() == []


# ---------------------------------------------------------------------- #
# elastic rescale on the scenario API
# ---------------------------------------------------------------------- #
class TestRescalePlan:
    def _fleet(self, n_v5e=48, n_v4=16):
        from repro.core.platform import tpu_fleet_si
        return tpu_fleet_si({"v5e": n_v5e, "v4": n_v4})

    def test_infeasible_before_failure_is_structured(self):
        from repro.configs import get_config, shape_by_name
        from repro.runtime import rescale_plan
        cfg = get_config("jamba_15_large")  # 400B params, tiny fleet
        report = rescale_plan(cfg, shape_by_name("decode_32k"),
                              self._fleet(4, 0), failed={0},
                              kprime=[1, 2, 4])
        assert not report.feasible
        assert report.old_plan is None and report.new_plan is None
        assert report.infeasibility is not None
        assert report.timeline.segments == []

    def test_mid_trace_warm_start_rescale(self):
        from repro.configs import get_config, shape_by_name
        from repro.runtime import rescale_plan
        cfg = get_config("olmoe_1b_7b")
        plat = self._fleet()
        probe = rescale_plan(cfg, shape_by_name("decode_32k"), plat,
                             failed={0, 1, 2, 3},
                             kprime=[16, 32, 48, 64])
        assert probe.feasible
        report = rescale_plan(cfg, shape_by_name("decode_32k"), plat,
                              failed={0, 1, 2, 3},
                              at=0.5 * probe.est_step_before_s,
                              policy="pinned-warm-start",
                              kprime=[16, 32, 48, 64])
        assert report.feasible
        assert report.new_plan.valid
        assert report.timeline.makespan > 0
        assert report.new_plan.mapping.platform.k == plat.k - 4
        # mid-trace: the failure fired, so a migration was logged
        assert len(report.timeline.migrations) == 1
