"""Per-architecture smoke tests (reduced configs, CPU).

For each of the 10 assigned architectures: instantiate the reduced
config, run one forward pass and one train step, assert output shapes
and finiteness; run the decode path and check it matches the forward
pass (teacher forcing) where applicable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import LM
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_inputs(cfg, bsz=2, seq=12, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (bsz, seq)),
                         jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (bsz, seq)),
                         jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend_tokens:
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(bsz, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    return batch


def make_model(cfg, **kw):
    kw.setdefault("param_dtype", jnp.float32)
    kw.setdefault("attn_chunk", 8)
    kw.setdefault("mamba_chunk", 4)
    kw.setdefault("max_seq", 32)
    return LM(cfg, **kw)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    m = make_model(cfg)
    params = m.init(0)
    batch = make_inputs(cfg)
    logits, aux = m.forward(params, batch["tokens"], batch.get("frontend"))
    assert logits.shape == (2, 12, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    m = make_model(cfg)
    params = m.init(0)
    batch = make_inputs(cfg)
    ocfg = AdamWConfig(lr=1e-3)
    state = adamw_init(params)

    loss0, grads = jax.value_and_grad(m.loss)(params, batch)
    assert np.isfinite(float(loss0))
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves)
    params2, state2, metrics = adamw_update(ocfg, params, grads, state)
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved
    # a second step lowers the loss on the same batch (usually); at
    # minimum it stays finite
    loss1 = m.loss(params2, batch)
    assert np.isfinite(float(loss1))


@pytest.mark.parametrize("arch", ["llama3_8b", "mixtral_8x7b", "rwkv6_1b6",
                                  "jamba_15_large", "llama32_vision_90b",
                                  "seamless_m4t_v2"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    # capacity_factor high enough that no MoE tokens are dropped, so
    # expert-choice equals token-choice and decode == forward exactly
    m = make_model(cfg, capacity_factor=16.0)
    params = m.init(0)
    batch = make_inputs(cfg, seed=1)
    tokens = batch["tokens"]
    ref, _ = m.forward(params, tokens, batch.get("frontend"))
    mem = m.encode_memory(params, batch.get("frontend"))
    cache = m.init_cache(2, 32, dtype=jnp.float32)
    for t in range(tokens.shape[1]):
        logits, cache = m.decode_step(params, cache, tokens[:, t:t + 1], t,
                                      memory=mem)
        err = float(jnp.max(jnp.abs(logits[:, 0] - ref[:, t])))
        assert err < 2e-3, f"t={t}: {err}"


def test_jamba_layer_pattern():
    cfg = get_smoke_config("jamba_15_large")
    m = make_model(cfg)
    kinds = [s.kind for s in m.specs]
    moes = [s.moe for s in m.specs]
    assert kinds.count("attn") == 1 and kinds[-1] == "attn"
    assert any(moes) and not all(moes)


def test_rwkv_is_attention_free():
    cfg = get_smoke_config("rwkv6_1b6")
    m = make_model(cfg)
    assert all(s.kind == "rwkv" for s in m.specs)


def test_vlm_cross_attention_period():
    cfg = get_smoke_config("llama32_vision_90b")
    m = make_model(cfg)
    crosses = [s.cross for s in m.specs]
    assert sum(crosses) == len(crosses) // cfg.cross_attn_period


def test_encdec_has_encoder_params():
    cfg = get_smoke_config("seamless_m4t_v2")
    m = make_model(cfg)
    params = m.init(0)
    assert "encoder" in params
    # frontend must flow through the encoder
    batch = make_inputs(cfg)
    mem = m.encode_memory(params, batch["frontend"])
    assert mem.shape == (2, cfg.frontend_tokens, cfg.d_model)


def test_full_configs_param_counts():
    """Exact-config parameter counts match published sizes (±10%)."""
    from repro.configs import get_config
    expected = {
        "mixtral_8x7b": 46.7e9,
        "olmoe_1b_7b": 6.9e9,
        "qwen25_32b": 32.5e9,
        "llama3_8b": 8.0e9,
        "jamba_15_large": 398e9,
        "llama32_vision_90b": 90e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).total_params()
        assert abs(got - want) / want < 0.10, f"{arch}: {got/1e9:.1f}B"


@pytest.mark.parametrize("arch", ["llama3_8b", "llama32_vision_90b"])
def test_int8_kv_cache_decode(arch):
    """Quantized KV serving stays within 5% of the bf16 logits."""
    cfg = get_smoke_config(arch)
    ref_m = make_model(cfg, capacity_factor=16.0)
    q_m = make_model(cfg, capacity_factor=16.0, kv_dtype="int8")
    params = ref_m.init(0)
    batch = make_inputs(cfg, seed=3)
    tokens = batch["tokens"]
    ref, _ = ref_m.forward(params, tokens, batch.get("frontend"))
    mem = q_m.encode_memory(params, batch.get("frontend"))
    cache = q_m.init_cache(2, 32, dtype=jnp.float32)
    worst = 0.0
    for t in range(tokens.shape[1]):
        logits, cache = q_m.decode_step(params, cache, tokens[:, t:t + 1],
                                        t, memory=mem)
        worst = max(worst, float(jnp.max(jnp.abs(logits[:, 0] - ref[:, t]))))
    assert worst / float(jnp.max(jnp.abs(ref))) < 0.05
    # the quantized cache really is int8
    assert cache[0]["k"].dtype == jnp.int8
