"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (per-kernel allclose harness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    flash_attention,
    reference_attention,
    reference_wkv,
    rwkv_wkv,
)

_TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _attn_ref(q, k, v, causal):
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    tr = lambda t, hh: t.transpose(0, 2, 1, 3).reshape(b * hh, s, hd)
    o = reference_attention(tr(q, h), tr(k, hkv), tr(v, hkv), causal=causal)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,hkv,hd", [
        (1, 32, 2, 2, 16),     # MHA
        (2, 64, 4, 2, 32),     # GQA 2:1
        (1, 128, 8, 1, 64),    # MQA
        (2, 48, 4, 4, 128),    # uneven S vs block, MXU-width head
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, b, s, h, hkv, hd, causal):
        rng = np.random.default_rng(b * s + h)
        q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
        o = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16,
                            interpret=True)
        np.testing.assert_allclose(o, _attn_ref(q, k, v, causal),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.normal(size=(2, 32, 4, 32)), dtype)
        k = jnp.asarray(rng.normal(size=(2, 32, 2, 32)), dtype)
        v = jnp.asarray(rng.normal(size=(2, 32, 2, 32)), dtype)
        o = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
        assert o.dtype == dtype
        np.testing.assert_allclose(
            o.astype(jnp.float32),
            _attn_ref(q, k, v, True).astype(jnp.float32),
            atol=_TOL[dtype], rtol=_TOL[dtype])

    def test_block_shape_independence(self):
        """Numerics must not depend on the BlockSpec tiling."""
        rng = np.random.default_rng(11)
        q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.float32)
        o1 = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
        o2 = flash_attention(q, k, v, block_q=64, block_k=32, interpret=True)
        np.testing.assert_allclose(o1, o2, atol=1e-5, rtol=1e-5)

    def test_matches_model_chunked_path(self):
        """The model's online-softmax scan is the same math."""
        from repro.models.attention import gqa_attention
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)
        o_kernel = flash_attention(q, k, v, block_q=16, block_k=16,
                                   interpret=True)
        o_model = gqa_attention(q, k, v, causal=True, chunk=16)
        np.testing.assert_allclose(o_kernel, o_model, atol=2e-5, rtol=2e-5)


class TestRwkvWkv:
    def _inputs(self, b, s, h, hd, dtype=jnp.float32, seed=0):
        rng = np.random.default_rng(seed)
        mk = lambda: jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
        r, k, v = mk(), mk(), mk()
        w = jnp.asarray(rng.uniform(0.2, 0.95, size=(b, s, h, hd)), dtype)
        u = jnp.asarray(rng.normal(size=(h, hd)), dtype)
        s0 = jnp.asarray(rng.normal(size=(b, h, hd, hd)), jnp.float32)
        return r, k, v, w, u, s0

    @pytest.mark.parametrize("b,s,h,hd,chunk", [
        (1, 16, 1, 8, 4),
        (2, 32, 2, 16, 8),
        (1, 64, 4, 64, 16),    # rwkv6 production head size
        (2, 24, 2, 32, 24),    # single chunk
    ])
    def test_matches_reference(self, b, s, h, hd, chunk):
        r, k, v, w, u, s0 = self._inputs(b, s, h, hd, seed=s + hd)
        o, sT = rwkv_wkv(r, k, v, w, u, s0, chunk=chunk, interpret=True)
        tr = lambda t: t.transpose(0, 2, 1, 3)
        o_ref, sT_ref = reference_wkv(tr(r), tr(k), tr(v), tr(w), u, s0)
        np.testing.assert_allclose(o, o_ref.transpose(0, 2, 1, 3),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(sT, sT_ref, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        r, k, v, w, u, s0 = self._inputs(1, 16, 2, 16, dtype=dtype, seed=5)
        o, sT = rwkv_wkv(r, k, v, w, u, s0, chunk=8, interpret=True)
        assert o.dtype == dtype and sT.dtype == jnp.float32
        tr = lambda t: t.transpose(0, 2, 1, 3)
        o_ref, sT_ref = reference_wkv(tr(r), tr(k), tr(v), tr(w), u, s0)
        np.testing.assert_allclose(
            o.astype(jnp.float32),
            o_ref.transpose(0, 2, 1, 3).astype(jnp.float32),
            atol=_TOL[dtype], rtol=_TOL[dtype])

    def test_chunk_independence(self):
        r, k, v, w, u, s0 = self._inputs(1, 48, 2, 16, seed=9)
        o1, s1 = rwkv_wkv(r, k, v, w, u, s0, chunk=8, interpret=True)
        o2, s2 = rwkv_wkv(r, k, v, w, u, s0, chunk=48, interpret=True)
        np.testing.assert_allclose(o1, o2, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(s1, s2, atol=1e-5, rtol=1e-5)

    def test_state_passing_equals_two_calls(self):
        """Running [0:S/2] then [S/2:S] with the carried state must equal
        one full call — the invariant behind chunked serving."""
        r, k, v, w, u, s0 = self._inputs(2, 32, 2, 16, seed=13)
        o_full, s_full = rwkv_wkv(r, k, v, w, u, s0, chunk=8, interpret=True)
        half = 16
        sl = lambda t, a, b: t[:, a:b]
        o1, s_mid = rwkv_wkv(sl(r, 0, half), sl(k, 0, half), sl(v, 0, half),
                             sl(w, 0, half), u, s0, chunk=8, interpret=True)
        o2, s_end = rwkv_wkv(sl(r, half, 32), sl(k, half, 32),
                             sl(v, half, 32), sl(w, half, 32), u, s_mid,
                             chunk=8, interpret=True)
        np.testing.assert_allclose(
            jnp.concatenate([o1, o2], axis=1), o_full, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(s_end, s_full, atol=1e-5, rtol=1e-5)

    def test_matches_model_rwkv_path(self):
        """kernels.ref and the model's wkv_scan_ref agree."""
        from repro.models.rwkv import wkv_scan_ref
        r, k, v, w, u, s0 = self._inputs(2, 16, 2, 16, seed=21)
        o_kernel, sT_kernel = rwkv_wkv(r, k, v, w, u, s0, chunk=8,
                                       interpret=True)
        o_model, sT_model = wkv_scan_ref(r, k, v, w, u, s0=s0)
        np.testing.assert_allclose(o_kernel, o_model, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(sT_kernel, sT_model, atol=1e-5, rtol=1e-5)
