"""Peak-memory traversal tests (MemDag role) — incl. hypothesis oracle
checks of the greedy heuristic against the exact subset DP."""
import itertools

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    Workflow,
    block_requirement,
    exact_min_peak,
    greedy_min_peak,
    simulate_peak,
)

from conftest import make_random_dag


def brute_force_min_peak(wf, ext_in=None, ext_out=None):
    """Min peak over *all* topological orders (n ≤ 8)."""
    best = float("inf")
    nodes = list(range(wf.n))
    for perm in itertools.permutations(nodes):
        pos = {u: i for i, u in enumerate(perm)}
        if any(pos[u] > pos[v] for u in nodes for v in wf.succ[u]):
            continue
        best = min(best, simulate_peak(wf, perm, ext_in, ext_out))
    return best


class TestSimulate:
    def test_chain_peak(self):
        # chain a->b->c, unit files; peak at any step: live + m + out
        wf = Workflow(3)
        wf.mem[:] = [5.0, 1.0, 2.0]
        wf.add_edge(0, 1, 3.0)
        wf.add_edge(1, 2, 4.0)
        # step a: 0 + 5 + 3 = 8; step b: 3 (in live) + 1 + 4 = 8;
        # step c: 4 + 2 = 6
        assert simulate_peak(wf, [0, 1, 2]) == pytest.approx(8.0)

    def test_order_matters(self):
        # fork a -> {b, c}: running the fat-memory child while the fat
        # file is still live is worse than consuming the fat file first
        wf = Workflow(3)
        wf.mem[:] = [1.0, 5.0, 1.0]
        wf.add_edge(0, 1, 10.0)
        wf.add_edge(0, 2, 1.0)
        p_bc = simulate_peak(wf, [0, 1, 2])   # b first: 11 live + 5 = 16
        p_cb = simulate_peak(wf, [0, 2, 1])   # c first: 10 live + 5 = 15
        assert p_bc == pytest.approx(16.0)
        assert p_cb == pytest.approx(15.0)

    def test_invalid_order_rejected(self):
        wf = Workflow(2)
        wf.add_edge(0, 1)
        with pytest.raises(ValueError):
            simulate_peak(wf, [1, 0])

    def test_external_files(self):
        wf = Workflow(1)
        wf.mem[0] = 2.0
        assert simulate_peak(wf, [0], {0: 3.0}, {0: 5.0}) == pytest.approx(10.0)


class TestExact:
    def test_exact_equals_bruteforce_small(self):
        for seed in range(15):
            wf = make_random_dag(6, seed, p=0.4)
            assert exact_min_peak(wf) == pytest.approx(
                brute_force_min_peak(wf))

    def test_exact_with_boundary(self):
        for seed in range(5):
            wf = make_random_dag(5, seed, p=0.5)
            ext_in = {0: 7.0}
            ext_out = {wf.n - 1: 3.0}
            assert exact_min_peak(wf, ext_in, ext_out) == pytest.approx(
                brute_force_min_peak(wf, ext_in, ext_out))


@st.composite
def small_dags(draw):
    n = draw(st.integers(2, 8))
    wf = Workflow(n)
    for u in range(n):
        wf.mem[u] = draw(st.floats(0.0, 50.0, allow_nan=False))
    for u in range(n):
        for v in range(u + 1, n):
            if draw(st.booleans()):
                wf.add_edge(u, v, draw(st.floats(0.1, 10.0)))
    return wf


class TestGreedyVsExact:
    @settings(max_examples=60, deadline=None)
    @given(small_dags())
    def test_greedy_upper_bounds_exact(self, wf):
        exact = exact_min_peak(wf)
        greedy = greedy_min_peak(wf)
        assert greedy >= exact - 1e-9

    @settings(max_examples=60, deadline=None)
    @given(small_dags())
    def test_greedy_is_feasible_simulation(self, wf):
        peak, order = greedy_min_peak(wf, return_order=True)
        assert simulate_peak(wf, order) == pytest.approx(peak)

    @settings(max_examples=40, deadline=None)
    @given(small_dags())
    def test_exact_never_above_any_topological_order(self, wf):
        exact = exact_min_peak(wf)
        order = wf.topological_order()
        assert exact <= simulate_peak(wf, order) + 1e-9


class TestBlockRequirement:
    def test_exact_path_taken_for_small_blocks(self):
        wf = make_random_dag(6, 3, p=0.4)
        r_exact = block_requirement(wf, range(6), exact_limit=10)
        r_greedy = block_requirement(wf, range(6), exact_limit=0)
        assert r_exact <= r_greedy + 1e-9

    def test_subset_block_with_boundary(self):
        wf = Workflow(3)
        wf.mem[:] = [1.0, 2.0, 3.0]
        wf.add_edge(0, 1, 5.0)
        wf.add_edge(1, 2, 7.0)
        # block {1}: ext_in 5 + m 2 + ext_out 7
        assert block_requirement(wf, [1]) == pytest.approx(14.0)

    def test_greedy_quality_on_larger_graphs(self):
        # greedy should stay within 2x of exact for moderate DAGs
        for seed in range(5):
            wf = make_random_dag(12, seed, p=0.25)
            exact = exact_min_peak(wf)
            greedy = greedy_min_peak(wf)
            assert greedy <= 2.0 * exact + 1e-9
