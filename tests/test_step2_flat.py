"""Flat-array Step 2 vs the scalar reference — bit-identity properties.

The flat-array path (:mod:`repro.core.memdag`, ``_FlatWorkflow``) must
reproduce the scalar implementation *exactly*: identical peaks,
identical traversal orders, identical FitBlock split points — the
scheduler's bit-identical-makespan anchor (PR 1/PR 3) rests on it.
These tests drive both implementations over random subDAGs, random
block subsets and full FitBlock split sequences and compare with
``==``, never ``approx``.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import default_cluster, generate_workflow, schedule
from repro.core.dag import Workflow
from repro.core.heuristic import _biggest_assign
from repro.core.memdag import (
    _greedy_min_peak_members_flat,
    _greedy_min_peak_members_scalar,
    _simulate_peak_members_flat,
    greedy_min_peak_members,
    occupancy_steps,
    set_step2_impl,
    simulate_peak_members,
    step2_impl,
)

from conftest import make_random_dag


@pytest.fixture(autouse=True)
def _restore_impl():
    prev = step2_impl()
    yield
    set_step2_impl(prev)


@st.composite
def dag_and_block(draw):
    """A random DAG plus a random non-empty ascending block of it."""
    n = draw(st.integers(2, 120))
    seed = draw(st.integers(0, 10_000))
    p = draw(st.sampled_from([0.05, 0.15, 0.35]))
    wf = make_random_dag(n, seed, p=p)
    rng = random.Random(seed ^ 0xBEEF)
    size = rng.randint(1, n)
    nodes = sorted(rng.sample(range(n), size))
    return wf, nodes


class TestGreedyFlatVsScalar:
    @settings(max_examples=60, deadline=None)
    @given(dag_and_block())
    def test_peak_and_order_bit_identical(self, case):
        wf, nodes = case
        ps, os_ = _greedy_min_peak_members_scalar(wf, nodes)
        pf, of_ = _greedy_min_peak_members_flat(wf, nodes)
        assert ps == pf          # exact float equality, not approx
        assert os_ == of_        # identical traversal, task by task

    @settings(max_examples=40, deadline=None)
    @given(dag_and_block())
    def test_peak_sim_bit_identical(self, case):
        wf, nodes = case
        _, order = _greedy_min_peak_members_scalar(wf, nodes)
        members = set(nodes)
        scalar = 0.0
        for _, during, _ in occupancy_steps(wf, members, order):
            if during > scalar:
                scalar = during
        assert _simulate_peak_members_flat(wf, order) == scalar

    def test_dispatch_modes_agree(self):
        wf = make_random_dag(90, 7, p=0.2)
        nodes = list(range(90))
        out = {}
        for mode in ("scalar", "flat", "auto"):
            set_step2_impl(mode)
            out[mode] = greedy_min_peak_members(wf, nodes)
            assert simulate_peak_members(wf, set(nodes), out[mode][1]) \
                == simulate_peak_members(wf, set(nodes), out["scalar"][1])
        assert out["scalar"] == out["flat"] == out["auto"]

    def test_set_step2_impl_rejects_unknown(self):
        with pytest.raises(ValueError):
            set_step2_impl("simd")

    def test_flat_cache_survives_and_tracks_edits(self):
        wf = make_random_dag(60, 3, p=0.2)
        nodes = list(range(60))
        _greedy_min_peak_members_flat(wf, nodes)
        assert wf._flat_cache is not None
        cached_view = wf._flat_cache[2]
        # structural growth invalidates via the (n, n_edges) guard:
        # the stale CSR view is rebuilt and results track the scalar
        # path on the *edited* workflow (node 0 gained an ext output)
        u = wf.add_task(work=1.0, mem=2.0)
        wf.add_edge(0, u, 5.0)
        second = _greedy_min_peak_members_flat(wf, sorted(nodes + [u]))
        assert wf._flat_cache[2] is not cached_view
        assert second == _greedy_min_peak_members_scalar(
            wf, sorted(nodes + [u]))
        assert _greedy_min_peak_members_flat(wf, nodes) \
            == _greedy_min_peak_members_scalar(wf, nodes)


class TestSplitSequences:
    """FitBlock's recursive bisection must pick identical split points
    (hence identical assigned/unassigned block sets) on both paths."""

    def _step2(self, wf, platform, kprime, mode):
        from repro.core.partitioner import acyclic_partition

        set_step2_impl(mode)
        assignment = acyclic_partition(wf, kprime)
        groups = {}
        for u, b in enumerate(assignment):
            groups.setdefault(b, []).append(u)
        blocks = [groups[b] for b in sorted(groups)]
        return _biggest_assign(wf, platform, blocks, exact_limit=0,
                               memo={})

    @settings(max_examples=12, deadline=None)
    @given(st.integers(0, 500), st.integers(2, 6))
    def test_biggest_assign_bit_identical(self, seed, kprime):
        plat = default_cluster()
        wf = generate_workflow("montage", 300, seed=seed, platform=plat)
        a = self._step2(wf, plat, kprime, "scalar")
        b = self._step2(wf, plat, kprime, "flat")
        assert a.assigned == b.assigned    # same blocks, same processors
        assert a.unassigned == b.unassigned

    @pytest.mark.parametrize("family", ["epigenomics", "blast", "soykb"])
    def test_full_pipeline_makespan_identical(self, family):
        plat = default_cluster()
        wf = generate_workflow(family, 400, seed=3, platform=plat)
        out = {}
        for mode in ("scalar", "flat"):
            set_step2_impl(mode)
            rep = schedule(wf, plat, algorithm="dag_het_part",
                           kprime=[1, 3, 7])
            out[mode] = (rep.makespan,
                         rep.summary.block_of_task,
                         sorted(rep.summary.proc_of_block.items()))
        assert out["scalar"] == out["flat"]


class TestFlatCacheInvalidation:
    def test_existing_edge_accumulation_drops_stale_view(self):
        wf = make_random_dag(60, 9, p=0.25)
        nodes = list(range(60))
        _greedy_min_peak_members_flat(wf, nodes)
        stale_view = wf._flat_cache[2]
        # accumulate onto an existing edge: (n, n_edges) both unchanged,
        # so only the explicit add_edge invalidation protects the view
        u = next(u for u in range(60) if wf.succ[u])
        v = next(iter(wf.succ[u]))
        wf.add_edge(u, v, 123.0)
        assert wf._flat_cache is None  # stale CSR view dropped
        after_flat = _greedy_min_peak_members_flat(wf, nodes)
        assert wf._flat_cache[2] is not stale_view
        assert after_flat == _greedy_min_peak_members_scalar(wf, nodes)


class TestWorkflowEdgeCount:
    def test_n_edges_maintained(self):
        wf = Workflow(4)
        assert wf.n_edges == 0
        wf.add_edge(0, 1, 1.0)
        wf.add_edge(1, 2, 1.0)
        assert wf.n_edges == 2
        wf.add_edge(0, 1, 2.5)   # duplicate: accumulates, not a new edge
        assert wf.n_edges == 2
        assert wf.succ[0][1] == pytest.approx(3.5)
        u = wf.add_task()
        wf.add_edge(2, u)
        assert wf.n_edges == 3
