"""Property tests: the chunked (GLA-style) WKV formulation is
equivalent to the sequential recurrence — the invariant behind the
rwkv hillclimb in EXPERIMENTS.md §Perf."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.models.rwkv import wkv_chunked, wkv_scan_ref


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 2),
    s=st.integers(1, 40),
    h=st.integers(1, 3),
    hd=st.sampled_from([8, 16]),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
    strong_decay=st.booleans(),
)
def test_chunked_equals_sequential(b, s, h, hd, chunk, seed, strong_decay):
    rng = np.random.default_rng(seed)
    r, k, v = (_rand(rng, b, s, h, hd) for _ in range(3))
    hi = 8.0 if strong_decay else 1.0
    w = jnp.exp(-jnp.asarray(rng.uniform(1e-3, hi, size=(b, s, h, hd)),
                             jnp.float32))
    u = _rand(rng, h, hd)
    s0 = _rand(rng, b, h, hd, hd)
    o1, st1 = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    o2, st2 = wkv_scan_ref(r, k, v, w, u, s0)
    scale = max(1.0, float(jnp.max(jnp.abs(o2))))
    np.testing.assert_allclose(o1, o2, atol=5e-4 * scale, rtol=5e-4)
    np.testing.assert_allclose(st1, st2, atol=5e-4, rtol=5e-4)


def test_state_passing_across_calls():
    rng = np.random.default_rng(3)
    b, s, h, hd = 2, 32, 2, 16
    r, k, v = (_rand(rng, b, s, h, hd) for _ in range(3))
    w = jnp.exp(-jnp.asarray(rng.uniform(0.01, 3.0, size=(b, s, h, hd)),
                             jnp.float32))
    u = _rand(rng, h, hd)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    o_full, st_full = wkv_chunked(r, k, v, w, u, s0, chunk=8)
    o1, st_mid = wkv_chunked(r[:, :16], k[:, :16], v[:, :16], w[:, :16],
                             u, s0, chunk=8)
    o2, st_end = wkv_chunked(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:],
                             u, st_mid, chunk=8)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), o_full,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(st_end, st_full, atol=1e-4, rtol=1e-4)


def test_gradients_flow():
    import jax
    rng = np.random.default_rng(5)
    b, s, h, hd = 1, 16, 1, 8
    r, k, v = (_rand(rng, b, s, h, hd) for _ in range(3))
    w = jnp.exp(-jnp.asarray(rng.uniform(0.01, 2.0, size=(b, s, h, hd)),
                             jnp.float32))
    u = _rand(rng, h, hd)

    def loss(r):
        o, _ = wkv_chunked(r, k, v, w, u, chunk=8)
        return (o ** 2).mean()

    g = jax.grad(loss)(r)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0
