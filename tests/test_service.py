"""Tests for :mod:`repro.service` — fingerprints, plan cache, admission,
and the multi-workflow event loop.

The load-bearing properties, per the subsystem's contract:

* fingerprints are **stable across process restarts** (no Python hash
  randomization leaking in) and **never collide** for same-shape DAGs
  with different weights — a false cache hit would silently seed the
  wrong partition;
* the single-submission service run is the **identity**: bit-exactly
  ``schedule(wf, platform, simulate=True)``;
* the trace is **deterministic**, including under ``workers > 1``;
* the soak run **conserves jobs**: every submission ends in exactly one
  terminal state, whatever mixture of malformed payloads, quota
  violations and platform events the run throws at it.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - container has no hypothesis
    sys.path.insert(0, str(Path(__file__).parent))
    from _hypothesis_fallback import given, settings, st

from repro.core import default_cluster
from repro.core.dag import Workflow
from repro.core.platform import Platform, Processor
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.workflows import generate_workflow, to_json
from repro.scenario import (
    EventTimelineError,
    ProcArrival,
    ProcFailure,
    SpeedChange,
    validate_event_timeline,
)
from repro.service import (
    PlanCache,
    QuotaConfig,
    ServiceConfig,
    ServiceReport,
    ServiceTrace,
    Submission,
    TenantQuota,
    WorkflowFingerprint,
    fingerprint_workflow,
    platform_signature,
    run_service,
)

KPRIME = [2, 4]


def _wf(family="montage", n=100, seed=1, plat=None):
    return generate_workflow(family, n, seed=seed,
                             platform=plat or default_cluster())


def _cfg(**kw):
    kw.setdefault("kprime", KPRIME)
    kw.setdefault("simulate", True)
    return SchedulerConfig(**kw)


# ---------------------------------------------------------------------- #
# fingerprints
# ---------------------------------------------------------------------- #
class TestFingerprint:
    def test_deterministic_within_process(self):
        wf = _wf()
        assert (fingerprint_workflow(wf).digest
                == fingerprint_workflow(wf).digest)

    def test_survives_json_round_trip(self):
        wf = _wf()
        wf2 = __import__("repro.core.workflows",
                         fromlist=["from_json"]).from_json(to_json(wf))
        assert (fingerprint_workflow(wf).digest
                == fingerprint_workflow(wf2).digest)

    def test_stable_across_process_restarts(self, tmp_path):
        """The digest must not depend on PYTHONHASHSEED or any other
        per-process state — a restarted service must keep hitting the
        plans its previous life cached."""
        wf = _wf(n=60)
        here = fingerprint_workflow(wf).digest
        script = (
            "import sys, json\n"
            f"sys.path.insert(0, {str(Path('src').resolve())!r})\n"
            "from repro.core.workflows import from_json\n"
            "from repro.service import fingerprint_workflow\n"
            f"wf = from_json({to_json(wf)!r})\n"
            "print(fingerprint_workflow(wf).digest)\n"
        )
        for seed in ("0", "12345"):
            out = subprocess.run(
                [sys.executable, "-c", script], capture_output=True,
                text=True, check=True,
                env={"PYTHONHASHSEED": seed, "PATH": "/usr/bin:/bin"},
            )
            assert out.stdout.strip() == here

    def test_weight_change_changes_digest(self):
        wf = _wf(n=60)
        d0 = fingerprint_workflow(wf).digest
        wf.work[3] += 1.0
        wf._flat_cache = None
        assert fingerprint_workflow(wf).digest != d0

    def test_edge_cost_change_changes_digest(self):
        wf = _wf(n=60)
        d0 = fingerprint_workflow(wf).digest
        u = next(u for u in range(wf.n) if wf.succ[u])
        v = next(iter(wf.succ[u]))
        wf.succ[u][v] += 0.5
        wf.pred[v][u] += 0.5
        wf._flat_cache = None
        assert fingerprint_workflow(wf).digest != d0

    def test_round_trips_as_dict(self):
        fp = fingerprint_workflow(_wf(n=60))
        fp2 = WorkflowFingerprint.from_dict(
            json.loads(json.dumps(fp.to_dict())))
        assert fp2 == fp

    @given(st.integers(min_value=0, max_value=10_000),
           st.integers(min_value=0, max_value=59),
           st.floats(min_value=0.001, max_value=1000.0))
    @settings(max_examples=25, deadline=None)
    def test_no_false_hits_property(self, seed, task, bump):
        """Same shape, different weights ⇒ different digest.  A false
        *miss* only costs a cold plan; a false *hit* would replay the
        wrong partition — so perturbations must always separate."""
        wf = _wf(n=60, seed=2)
        task = task % wf.n          # families land near, not at, n
        d0 = fingerprint_workflow(wf).digest
        which = seed % 3
        if which == 0:
            wf.work[task] += bump
        elif which == 1:
            wf.mem[task] += bump
        else:
            u = next(u for u in range(wf.n) if wf.succ[u])
            v = next(iter(wf.succ[u]))
            wf.succ[u][v] += bump
            wf.pred[v][u] += bump
        wf._flat_cache = None
        assert fingerprint_workflow(wf).digest != d0

    def test_platform_signature_ignores_name(self):
        plat = default_cluster()
        renamed = Platform(list(plat.procs), plat.bandwidth, "other",
                           dict(plat.link_bandwidth))
        assert platform_signature(plat) == platform_signature(renamed)
        slower = plat.with_speed(0, plat.speed(0) * 0.5)
        assert platform_signature(plat) != platform_signature(slower)


# ---------------------------------------------------------------------- #
# plan cache
# ---------------------------------------------------------------------- #
class TestPlanCache:
    def test_lru_eviction(self):
        cache = PlanCache(capacity=2)
        plat = default_cluster()
        fps = [fingerprint_workflow(_wf(n=30, seed=s)) for s in range(3)]
        keys = [PlanCache.key(fp, plat) for fp in fps]
        for k in keys:
            cache.put(k, [0] * 30, 2, 1.0)
        assert len(cache) == 2
        assert cache.get(keys[0]) is None       # evicted
        assert cache.get(keys[2]) is not None
        assert cache.stats()["evictions"] == 1

    def test_key_separates_platforms(self):
        fp = fingerprint_workflow(_wf(n=30))
        plat = default_cluster()
        degraded = plat.without({0})
        assert PlanCache.key(fp, plat) != PlanCache.key(fp, degraded)


# ---------------------------------------------------------------------- #
# event-timeline validation (satellite: Scenario build-time checks)
# ---------------------------------------------------------------------- #
class TestTimelineValidation:
    def test_unsorted_rejected(self):
        evs = [SpeedChange(time=5.0, proc=0, factor=0.5),
               ProcFailure(time=1.0, procs={1})]
        with pytest.raises(EventTimelineError) as ei:
            validate_event_timeline(evs)
        assert ei.value.code == "unsorted"
        assert ei.value.index == 1

    def test_bad_type_rejected(self):
        with pytest.raises(EventTimelineError) as ei:
            validate_event_timeline(["not an event"])
        assert ei.value.code == "bad-type"

    def test_scenario_constructor_validates(self):
        from repro.scenario import Scenario
        wf = _wf(n=30)
        evs = [SpeedChange(time=5.0, proc=0, factor=0.5),
               ProcFailure(time=1.0, procs={1})]
        with pytest.raises(EventTimelineError):
            Scenario(wf, default_cluster(), evs)

    def test_service_validates(self):
        wf = _wf(n=30)
        evs = [SpeedChange(time=5.0, proc=0, factor=0.5),
               ProcFailure(time=1.0, procs={1})]
        with pytest.raises(EventTimelineError):
            run_service([Submission(wf)], default_cluster(), evs)

    def test_nonfinite_event_time_rejected(self):
        with pytest.raises(ValueError):
            SpeedChange(time=float("nan"), proc=0, factor=0.5)
        with pytest.raises(ValueError):
            SpeedChange(time=float("inf"), proc=0, factor=0.5)


# ---------------------------------------------------------------------- #
# the service loop
# ---------------------------------------------------------------------- #
class TestServiceLoop:
    def test_identity_anchor(self):
        """One submission at t=0, no events, empty quotas ⇒ bit-exactly
        the plain scheduler call."""
        plat = default_cluster()
        wf = _wf(n=120, seed=3)
        cfg = _cfg()
        ref = Scheduler(cfg).schedule(wf, plat)
        rep = run_service([Submission(wf)], plat,
                          config=ServiceConfig(scheduler=cfg))
        (job,) = rep.jobs
        assert job.status == "completed"
        assert job.planning_path == "cold"
        assert job.queue_wait == 0.0
        assert job.makespan == ref.sim.makespan
        ref_map = ref.summary.to_dict()
        ref_map["runtime_s"] = 0.0
        assert job.mapping == ref_map

    def test_cache_hit_on_repeat(self):
        plat = default_cluster()
        wf = _wf(n=100, seed=5)
        rep = run_service(
            [Submission(wf, name="a"),
             Submission(wf, name="b", arrival_t=1e6)],
            plat, config=ServiceConfig(scheduler=_cfg()))
        a, b = rep.jobs
        assert a.planning_path == "cold"
        assert b.planning_path == "seeded"
        assert rep.cache_stats["service_cache_hits"] == 1
        assert rep.cache_stats["service_cache_stores"] >= 1
        assert rep.cache_hit_rate == 0.5
        # the seeded replay must not cost makespan (same platform,
        # same partition, Steps 2-4 re-run: tiny fp drift tolerated)
        assert b.makespan == pytest.approx(a.makespan, rel=1e-9)

    def test_cache_disabled(self):
        plat = default_cluster()
        wf = _wf(n=80, seed=5)
        rep = run_service(
            [Submission(wf, name="a"),
             Submission(wf, name="b", arrival_t=1e6)],
            plat, config=ServiceConfig(scheduler=_cfg(),
                                       plan_cache=False))
        assert [j.planning_path for j in rep.jobs] == ["cold", "cold"]
        assert rep.cache_hit_rate is None

    def test_external_cache_shared_across_runs(self):
        plat = default_cluster()
        wf = _wf(n=80, seed=6)
        cache = PlanCache()
        cfg = ServiceConfig(scheduler=_cfg())
        r1 = run_service([Submission(wf)], plat, config=cfg, cache=cache)
        r2 = run_service([Submission(wf)], plat, config=cfg, cache=cache)
        assert r1.jobs[0].planning_path == "cold"
        assert r2.jobs[0].planning_path == "seeded"

    def test_cache_persists_fingerprint_keyed(self, tmp_path):
        """save → load round-trips every entry under its fingerprint
        key, and a loaded cache seeds a fresh service run."""
        plat = default_cluster()
        wf = _wf(n=80, seed=6)
        cache = PlanCache()
        cfg = ServiceConfig(scheduler=_cfg())
        run_service([Submission(wf)], plat, config=cfg, cache=cache)
        path = tmp_path / "plans.json"
        cache.save(path)

        loaded = PlanCache.load(path)
        assert len(loaded) == len(cache) == 1
        from repro.service import fingerprint_workflow

        key = PlanCache.key(fingerprint_workflow(wf), plat)
        orig, back = cache._store[key], loaded._store[key]
        assert back.block_of_task == orig.block_of_task
        assert back.k_prime == orig.k_prime
        assert back.makespan == orig.makespan
        # the restart path: a brand-new service seeded from disk
        r = run_service([Submission(wf)], plat, config=cfg,
                        cache=loaded)
        assert r.jobs[0].planning_path == "seeded"

    def test_cache_load_capacity_override_evicts_lru(self, tmp_path):
        cache = PlanCache()
        for i in range(3):
            cache.put(f"k{i}", [0], 1, float(i))
        path = tmp_path / "plans.json"
        cache.save(path)
        small = PlanCache.load(path, capacity=2)
        assert len(small) == 2
        assert "k0" not in small._store  # least recent evicted
        assert {"k1", "k2"} <= set(small._store)
        with pytest.raises(ValueError):
            path.write_text(json.dumps({"version": 99, "entries": []}))
            PlanCache.load(path)

    def test_malformed_payload_rejected_not_raised(self):
        rep = run_service(
            [Submission('{"broken": true}', name="bad"),
             Submission("not json at all", name="worse"),
             Submission({"specification": {"tasks": []}}, name="empty")],
            default_cluster(), config=ServiceConfig(scheduler=_cfg()))
        assert all(j.status == "rejected" for j in rep.jobs)
        assert all(j.rejection["code"] == "malformed" for j in rep.jobs)

    def test_quota_rejections(self):
        plat = default_cluster()
        wf = _wf(n=100, seed=2)
        quotas = QuotaConfig(tenants={
            "small": TenantQuota(max_tasks=50),
            "narrow": TenantQuota(max_pending=1),
        })
        rep = run_service(
            [Submission(wf, tenant="small", name="too-big"),
             Submission(wf, tenant="narrow", name="first"),
             Submission(wf, tenant="narrow", name="second"),
             Submission(wf, tenant="narrow", name="third")],
            plat, config=ServiceConfig(scheduler=_cfg(), quotas=quotas))
        by_name = {j.name: j for j in rep.jobs}
        assert by_name["too-big"].status == "rejected"
        assert by_name["too-big"].rejection["code"] == "size-quota"
        # first dispatches immediately (leaves the queue), second waits
        # in the single pending slot, third overflows it
        assert by_name["first"].status == "completed"
        assert by_name["second"].status == "completed"
        assert by_name["third"].status == "rejected"
        assert by_name["third"].rejection["code"] == "queue-quota"

    def test_fair_share_weights(self):
        """With everything arriving at once and capacity for one job at
        a time, a weight-2 tenant drains ~2x the work per turn."""
        plat = default_cluster()
        wf = _wf(n=100, seed=2)
        quotas = QuotaConfig(tenants={"heavy": TenantQuota(weight=2.0)})
        subs = []
        for i in range(2):
            subs.append(Submission(wf, tenant="heavy", name=f"h{i}"))
            subs.append(Submission(wf, tenant="light", name=f"l{i}"))
        rep = run_service(subs, plat,
                          config=ServiceConfig(scheduler=_cfg(),
                                               quotas=quotas))
        assert all(j.status == "completed" for j in rep.jobs)
        h = [j for j in rep.jobs if j.tenant == "heavy"]
        l = [j for j in rep.jobs if j.tenant == "light"]
        # the heavy tenant's backlog never waits longer than light's
        assert max(j.dispatch_t for j in h) <= max(j.dispatch_t
                                                   for j in l)

    def test_warm_replan_on_owned_slowdown(self):
        plat = default_cluster()
        cfg = _cfg(kprime=[4])
        wf = _wf(n=150, seed=7)
        base = run_service([Submission(wf)], plat,
                           config=ServiceConfig(scheduler=cfg))
        names = set(base.jobs[0].allocation)
        idx = [i for i, p in enumerate(plat.procs) if p.name in names]
        rep = run_service(
            [Submission(wf, name="w")], plat,
            [SpeedChange(time=200.0, proc=idx[0], factor=0.1)],
            ServiceConfig(scheduler=cfg))
        (job,) = rep.jobs
        assert job.status == "completed"
        assert job.n_replans == 1
        replans = [e for e in rep.trace.log if e["kind"] == "replan"]
        assert replans and replans[0]["path"] == "warm"
        # a 10x slowdown on an owned processor must cost makespan
        assert job.finish_t > base.jobs[0].finish_t

    def test_proc_arrival_disturbs_nobody(self):
        plat = default_cluster()
        wf = _wf(n=120, seed=3)
        cfg = ServiceConfig(scheduler=_cfg())
        base = run_service([Submission(wf)], plat, config=cfg)
        rep = run_service(
            [Submission(wf)], plat,
            [ProcArrival(time=100.0,
                         procs=(Processor("new-0", 2.0, 64.0),))],
            cfg)
        assert rep.jobs[0].n_replans == 0
        assert rep.jobs[0].finish_t == base.jobs[0].finish_t

    def test_trace_deterministic_and_round_trips(self):
        plat = default_cluster()
        wfs = [_wf(f, 90, s) for s, f in
               enumerate(["montage", "epigenomics"])]
        subs = [Submission(wfs[0], tenant="a", name="m"),
                Submission(wfs[1], tenant="b", arrival_t=10.0, name="e"),
                Submission("garbage", tenant="c", arrival_t=5.0,
                           name="x")]
        events = [ProcFailure(time=250.0, procs={0, 1})]
        cfg = ServiceConfig(scheduler=_cfg())
        r1 = run_service(subs, plat, events, cfg)
        r2 = run_service(subs, plat, events, cfg)
        assert r1.trace.to_json() == r2.trace.to_json()
        rt = ServiceTrace.from_json(r1.trace.to_json())
        assert rt.to_json() == r1.trace.to_json()
        rr = ServiceReport.from_json(r1.to_json())
        assert rr.trace.to_json() == r1.trace.to_json()

    def test_trace_deterministic_with_workers(self):
        """The parallel k' sweep must not leak nondeterminism into the
        service trace."""
        plat = default_cluster()
        wf = _wf(n=100, seed=4)
        subs = [Submission(wf, name="a"),
                Submission(wf, name="b", arrival_t=50.0)]
        serial = run_service(
            subs, plat,
            config=ServiceConfig(scheduler=_cfg(workers=1)))
        parallel = run_service(
            subs, plat,
            config=ServiceConfig(scheduler=_cfg(workers=2)))
        assert serial.trace.to_json() == parallel.trace.to_json()

    def test_soak_conservation(self):
        """Every submission ends in exactly one terminal state, and the
        terminal counters agree with the trace — across a mixed barrage
        of valid jobs, malformed payloads, quota violations, failures
        and arrivals."""
        plat = default_cluster()
        cfg = _cfg()
        fams = ["montage", "epigenomics", "seismology", "blast"]
        subs = []
        for i in range(10):
            if i % 5 == 4:
                subs.append(Submission('{"oops": %d}' % i,
                                       tenant="mal",
                                       arrival_t=7.0 * i,
                                       name=f"bad{i}"))
            else:
                wf = _wf(fams[i % len(fams)], 60 + 10 * (i % 3), i)
                subs.append(Submission(wf, tenant=f"t{i % 3}",
                                       arrival_t=7.0 * i,
                                       name=f"job{i}"))
        events = [ProcFailure(time=150.0, procs={2, 3}),
                  SpeedChange(time=400.0, proc=0, factor=0.5),
                  ProcArrival(time=800.0,
                              procs=(Processor("spare-0", 2.5, 128.0),))]
        quotas = QuotaConfig(
            tenants={"t0": TenantQuota(max_running=1)},
            default=TenantQuota())
        rep = run_service(subs, plat, events,
                          ServiceConfig(scheduler=cfg, quotas=quotas))
        assert len(rep.jobs) == len(subs)
        terminal = {"completed", "infeasible", "rejected"}
        for j in rep.jobs:
            assert j.status in terminal
            if j.status == "completed":
                assert j.finish_t is not None
                assert j.makespan is not None and j.makespan > 0
                assert j.latency >= j.queue_wait >= 0
            elif j.status == "infeasible":
                assert j.infeasibility is not None
            else:
                assert j.rejection is not None
        tallies = rep.cache_stats
        assert (tallies.get("service_completions", 0)
                == len(rep.completed))
        assert (tallies.get("service_rejections", 0)
                == len(rep.rejected))
        assert (tallies.get("service_infeasible", 0)
                == len(rep.infeasible))
        assert (tallies.get("service_admissions", 0)
                == len(rep.jobs) - len(rep.rejected))
        # determinism holds for the whole soak
        rep2 = run_service(subs, plat, events,
                           ServiceConfig(scheduler=cfg, quotas=quotas))
        assert rep.trace.to_json() == rep2.trace.to_json()

    def test_terminal_infeasibility_is_structured(self):
        """A workflow whose biggest task exceeds every processor memory
        is terminally infeasible — a structured outcome, not a crash."""
        plat = default_cluster()
        wf = Workflow(name="huge")
        a, b = wf.add_task(work=10.0, mem=1e9), wf.add_task(work=5.0,
                                                            mem=4.0)
        wf.add_edge(a, b, 1.0)
        rep = run_service([Submission(wf)], plat,
                          config=ServiceConfig(scheduler=_cfg()))
        (job,) = rep.jobs
        assert job.status == "infeasible"
        assert job.infeasibility["reason"]

    def test_gantt_renders(self):
        plat = default_cluster()
        wf = _wf(n=80, seed=9)
        rep = run_service(
            [Submission(wf, name="a"),
             Submission("junk", name="z", arrival_t=1.0)],
            plat, config=ServiceConfig(scheduler=_cfg()))
        art = rep.gantt()
        assert "a#0" in art and "rejected" in art
        assert "█" in art

    def test_utilization_timeline(self):
        plat = default_cluster()
        wf = _wf(n=80, seed=9)
        rep = run_service([Submission(wf)], plat,
                          config=ServiceConfig(scheduler=_cfg()))
        assert rep.utilization is not None and 0 < rep.utilization <= 1
        assert rep.trace.utilization[0][1] > 0     # busy at dispatch
        assert rep.trace.utilization[-1][1] == 0   # idle at the end
