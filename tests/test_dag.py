"""Unit tests for the workflow DAG and quotient-graph machinery."""
import pytest

from repro.core import Workflow, build_quotient
from repro.core.dag import QuotientGraph

from conftest import make_random_dag


class TestWorkflow:
    def test_construction(self, diamond):
        assert diamond.n == 4
        assert diamond.n_edges == 4
        assert diamond.sources() == [0]
        assert diamond.targets() == [3]
        assert set(diamond.children(0)) == {1, 2}
        assert set(diamond.parents(3)) == {1, 2}

    def test_task_requirement(self, diamond):
        # r_u = in + out + m   (paper §3.1)
        assert diamond.task_requirement(0) == pytest.approx(3.0 + 2.0)
        assert diamond.task_requirement(3) == pytest.approx(2.0 + 2.0)

    def test_topological_order(self, diamond):
        order = diamond.topological_order()
        pos = {u: i for i, u in enumerate(order)}
        for u in range(diamond.n):
            for v in diamond.succ[u]:
                assert pos[u] < pos[v]

    def test_cycle_detection(self):
        wf = Workflow(2)
        wf.add_edge(0, 1)
        wf.add_edge(1, 0)
        assert not wf.is_dag()

    def test_subgraph_and_boundary(self, diamond):
        sub, mapping = diamond.subgraph([1, 3])
        assert sub.n == 2
        assert sub.succ[0] == {1: 1.0}
        ext_in, ext_out = diamond.boundary_costs([1, 3])
        assert ext_in[0] == pytest.approx(1.0)   # edge 0->1
        assert ext_in[1] == pytest.approx(1.0)   # edge 2->3
        assert not ext_out

    def test_self_loop_rejected(self):
        wf = Workflow(1)
        with pytest.raises(ValueError):
            wf.add_edge(0, 0)


class TestQuotient:
    def test_build_quotient_weights(self, diamond):
        q = build_quotient(diamond, [0, 0, 1, 1])
        assert q.n_vertices == 2
        vids = sorted(q.members, key=lambda v: min(q.members[v]))
        a, b = vids
        assert q.weight[a] == pytest.approx(5.0)
        assert q.weight[b] == pytest.approx(4.0)
        # edges 0->2 (2.0) and 1->3 (1.0) cross
        assert q.succ[a][b] == pytest.approx(3.0)

    def test_quotient_cycle_detected(self, diamond):
        # {0, 3} vs {1, 2} creates a 2-cycle in the quotient
        q = build_quotient(diamond, [0, 1, 1, 0])
        assert not q.is_acyclic()
        cyc = q.find_cycle()
        assert cyc is not None and len(cyc) == 2

    def test_merge_unmerge_roundtrip(self, diamond):
        q = build_quotient(diamond, [0, 1, 2, 3])
        before = {
            "members": {v: set(q.members[v]) for v in q.vertices()},
            "succ": {v: dict(q.succ[v]) for v in q.vertices()},
            "pred": {v: dict(q.pred[v]) for v in q.vertices()},
        }
        verts = sorted(q.vertices())
        vm, undo = q.merge(verts[0], verts[1])
        assert q.n_vertices == 3
        assert q.members[vm] == before["members"][verts[0]] | before["members"][verts[1]]
        q.unmerge(undo)
        assert {v: set(q.members[v]) for v in q.vertices()} == before["members"]
        assert {v: dict(q.succ[v]) for v in q.vertices()} == before["succ"]
        assert {v: dict(q.pred[v]) for v in q.vertices()} == before["pred"]

    def test_merge_combines_parallel_edges(self, diamond):
        q = build_quotient(diamond, [0, 1, 2, 3])
        v = {min(q.members[x]): x for x in q.vertices()}
        vm, _ = q.merge(v[1], v[2])          # merge the two middle blocks
        assert q.succ[v[0]][vm] == pytest.approx(3.0)
        assert q.succ[vm][v[3]] == pytest.approx(2.0)
        assert q.is_acyclic()

    def test_assignment_array(self, diamond):
        q = build_quotient(diamond, [0, 0, 1, 1])
        arr = q.assignment_array()
        assert arr[0] == arr[1] and arr[2] == arr[3] and arr[0] != arr[2]

    def test_find_cycle_on_random_partitions(self):
        # arbitrary groupings of random DAGs: find_cycle() must
        # terminate and, when it returns a cycle, the cycle must be real
        for seed in range(20):
            wf = make_random_dag(12, seed)
            block_of = [u % 3 for u in range(wf.n)]
            q = build_quotient(wf, block_of)
            cyc = q.find_cycle()
            if cyc is not None:
                assert len(cyc) >= 2
                for a, b in zip(cyc, cyc[1:] + cyc[:1]):
                    # predecessor-walk produces a cycle in reverse edge
                    # direction: b -> a must be an edge
                    assert a in q.succ[b] or b in q.succ[a]
