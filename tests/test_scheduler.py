"""The unified Scheduler/Plan API (repro.core.scheduler).

Covers the k'-sweep policy, ScheduleReport JSON round-trips,
structured infeasibility on undersized platforms (every workflow
family), serial-vs-parallel sweep equivalence, the on_sweep_result
reporting channel, stage toggles / custom pipelines, and the
deprecated dag_het_part / dag_het_mem wrappers.
"""
import types

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    FAMILIES,
    Platform,
    Processor,
    ScheduleReport,
    Scheduler,
    SchedulerConfig,
    dag_het_mem,
    dag_het_part,
    default_cluster,
    generate_workflow,
    kprime_sweep_values,
    random_layered_dag,
    schedule,
    small_cluster,
    validate_mapping,
)

TINY = Platform([Processor("t0", 1.0, 0.5), Processor("t1", 2.0, 0.4)],
                bandwidth=1.0)


def _uniform_platform(k: int) -> Platform:
    return Platform([Processor(f"p{i}", 1.0, 8.0) for i in range(k)],
                    bandwidth=1.0)


# ---------------------------------------------------------------------- #
# k' sweep policy (the heuristic's Step-1 driver knob)
# ---------------------------------------------------------------------- #
class TestKprimeSweepPolicy:
    small_wf = types.SimpleNamespace(n=100)      # auto => full range
    large_wf = types.SimpleNamespace(n=10_000)   # auto => geometric subset

    @pytest.mark.parametrize("k", [1, 2, 7, 64])
    def test_full_mode_is_the_whole_range(self, k):
        vals = kprime_sweep_values(self.large_wf, _uniform_platform(k),
                                   "full")
        assert vals == list(range(1, k + 1))

    @pytest.mark.parametrize("k", [1, 2, 7, 64])
    def test_auto_small_workflow_is_the_whole_range(self, k):
        vals = kprime_sweep_values(self.small_wf, _uniform_platform(k),
                                   "auto")
        assert vals == list(range(1, k + 1))

    @pytest.mark.parametrize("k", [1, 2, 7, 64])
    def test_auto_large_workflow_subset_invariants(self, k):
        vals = kprime_sweep_values(self.large_wf, _uniform_platform(k),
                                   "auto")
        # sorted, deduplicated, in range
        assert vals == sorted(set(vals))
        assert all(1 <= v <= k for v in vals)
        # anchors: 1, k and half the platform are always swept
        assert 1 in vals
        assert k in vals
        assert max(1, k // 2) in vals

    def test_auto_large_workflow_k64_includes_half(self):
        vals = kprime_sweep_values(self.large_wf, _uniform_platform(64),
                                   "auto")
        assert 32 in vals  # the geometric ladder (…20, 33, 53) skips it

    def test_auto_large_workflow_k1_is_singleton(self):
        vals = kprime_sweep_values(self.large_wf, _uniform_platform(1),
                                   "auto")
        assert vals == [1]


# ---------------------------------------------------------------------- #
# ScheduleReport: structure + JSON round-trips
# ---------------------------------------------------------------------- #
class TestScheduleReport:
    def _feasible_report(self, workers: int = 1) -> ScheduleReport:
        plat = default_cluster()
        wf = generate_workflow("blast", 150, seed=5, platform=plat)
        return schedule(wf, plat, kprime=[1, 4, 9], workers=workers)

    def test_feasible_report_shape(self):
        rep = self._feasible_report()
        assert rep.feasible
        assert rep.best is not None and rep.summary is not None
        assert rep.infeasibility is None
        assert rep.makespan == rep.summary.makespan
        assert [p.k_prime for p in rep.sweep] == [1, 4, 9]
        assert set(rep.stage_times) == {
            "partition", "assign", "merge", "swap", "idle_moves"}
        assert rep.summary.block_of_task  # per-task assignment exported
        assert rep.summary.k_prime in (1, 4, 9)

    def test_json_round_trip_feasible(self):
        rep = self._feasible_report()
        back = ScheduleReport.from_json(rep.to_json())
        assert back == rep          # `best` is excluded from equality
        assert back.best is None    # live objects don't survive JSON
        assert back.to_json() == rep.to_json()

    def test_json_round_trip_infeasible(self):
        wf = generate_workflow("blast", 60, seed=1,
                               platform=default_cluster())
        rep = schedule(wf, TINY, kprime=[1, 2])
        assert not rep.feasible
        back = ScheduleReport.from_json(rep.to_json())
        assert back == rep
        assert back.infeasibility == rep.infeasibility
        assert back.to_json() == rep.to_json()

    @pytest.mark.parametrize("family", FAMILIES)
    def test_infeasibility_on_undersized_platform(self, family):
        """Every family: a too-small platform yields a populated
        Infeasibility (never None) with an actionable diagnosis."""
        wf = generate_workflow(family, 60, seed=1,
                               platform=default_cluster())
        rep = schedule(wf, TINY, kprime=[1, 2, 3])
        assert not rep.feasible
        assert rep.best is None
        inf = rep.infeasibility
        assert inf is not None
        assert inf.stage in ("assign", "merge")
        assert inf.reason
        assert inf.smallest_kprime == 1
        assert inf.attempts == 3
        # memory deficit: how much more memory would have been needed
        assert inf.tightest_gap is not None and inf.tightest_gap > 0

    def test_baseline_infeasibility_on_undersized_platform(self):
        wf = generate_workflow("montage", 60, seed=1,
                               platform=default_cluster())
        rep = schedule(wf, TINY, algorithm="dag_het_mem")
        assert not rep.feasible
        assert rep.infeasibility.stage == "pack"
        assert rep.infeasibility.smallest_kprime is None
        assert [p.k_prime for p in rep.sweep] == [None]


# ---------------------------------------------------------------------- #
# parallel k' sweep
# ---------------------------------------------------------------------- #
class TestParallelSweep:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_workers2_matches_serial(self, family):
        plat = default_cluster()
        wf = generate_workflow(family, 120, seed=2, platform=plat)
        serial = schedule(wf, plat, kprime=[1, 4, 9, 19])
        par = schedule(wf, plat, kprime=[1, 4, 9, 19], workers=2)
        assert par.feasible == serial.feasible
        assert par.makespan == serial.makespan  # bit-identical
        assert ([p.makespan for p in par.sweep]
                == [p.makespan for p in serial.sweep])
        if par.feasible:
            assert validate_mapping(wf, par.best) == []

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 60), n=st.integers(30, 80))
    def test_property_workers2_equals_serial(self, seed, n):
        """workers=2 and workers=1 pick identical best makespans on
        arbitrary random instances (feasible or not)."""
        plat = small_cluster()
        wf = random_layered_dag(n, seed=seed)
        from repro.core.workflows import scale_memory_to_platform
        scale_memory_to_platform(wf, plat)
        serial = schedule(wf, plat, kprime=[1, 3, 8, 18])
        par = schedule(wf, plat, kprime=[1, 3, 8, 18], workers=2)
        assert par.feasible == serial.feasible
        assert par.makespan == serial.makespan

    @pytest.mark.slow
    def test_workers_match_serial_n1000(self):
        """Acceptance-scale check: n=1000, parallel == serial."""
        plat = default_cluster()
        wf = generate_workflow("seismology", 1000, seed=1, platform=plat)
        serial = schedule(wf, plat, kprime=[1, 4, 9, 19, 36])
        par = schedule(wf, plat, kprime=[1, 4, 9, 19, 36], workers=4)
        assert par.makespan == serial.makespan

    def test_time_budget_truncates_but_completes_one(self):
        plat = default_cluster()
        wf = generate_workflow("bwa", 150, seed=3, platform=plat)
        rep = schedule(wf, plat, kprime=[1, 4, 9, 19], time_budget_s=0.0)
        assert rep.truncated
        assert len(rep.sweep) == 1  # at least (and here exactly) one k'
        assert rep.feasible or rep.infeasibility is not None


# ---------------------------------------------------------------------- #
# reporting channel: verbose + on_sweep_result
# ---------------------------------------------------------------------- #
class TestReportingChannel:
    def test_callback_receives_every_point_in_order(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 120, seed=4, platform=plat)
        seen = []
        rep = schedule(wf, plat, kprime=[1, 4, 9],
                       on_sweep_result=seen.append)
        assert [p.k_prime for p in seen] == [1, 4, 9]
        assert [p.makespan for p in seen] == [p.makespan
                                              for p in rep.sweep]

    def test_callback_fires_in_parent_with_workers(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 120, seed=4, platform=plat)
        seen = []
        schedule(wf, plat, kprime=[1, 4, 9], workers=2,
                 on_sweep_result=seen.append)
        assert [p.k_prime for p in seen] == [1, 4, 9]

    def test_verbose_prints_through_the_same_channel(self, caplog):
        # since PR 8 the default printer narrates through the module
        # logger (CLI entry points call repro.obs.setup_logging() to
        # put it back on stdout)
        import logging

        plat = default_cluster()
        wf = generate_workflow("blast", 120, seed=4, platform=plat)
        with caplog.at_level(logging.INFO, logger="repro.core.scheduler"):
            schedule(wf, plat, kprime=[1, 4], verbose=True)
        out = caplog.text
        assert "k'=1" in out and "k'=4" in out and "makespan" in out


# ---------------------------------------------------------------------- #
# stages: toggles, custom pipelines, registry
# ---------------------------------------------------------------------- #
class TestStages:
    def test_step4_toggles(self):
        plat = default_cluster()
        wf = generate_workflow("montage", 150, seed=4, platform=plat)
        full = schedule(wf, plat, kprime=[6, 12])
        plain = schedule(wf, plat, kprime=[6, 12],
                         swap=False, idle_moves=False)
        assert plain.feasible
        assert validate_mapping(wf, plain.best) == []
        assert set(plain.stage_times) == {"partition", "assign", "merge"}
        # refinement only ever improves the same merge result
        assert full.makespan <= plain.makespan + 1e-9

    def test_custom_stage_list_equals_toggled_pipeline(self):
        plat = default_cluster()
        wf = generate_workflow("montage", 150, seed=4, platform=plat)
        toggled = schedule(wf, plat, kprime=[6, 12],
                           swap=False, idle_moves=False)
        explicit = schedule(wf, plat, kprime=[6, 12],
                            stages=("partition", "assign", "merge"))
        assert explicit.makespan == toggled.makespan

    def test_unknown_algorithm_raises(self):
        with pytest.raises(ValueError, match="unknown algorithm"):
            Scheduler(SchedulerConfig(algorithm="nope")).stage_names()

    def test_stage_names_respect_config(self):
        s = Scheduler(SchedulerConfig(swap=False))
        assert s.stage_names() == ("partition", "assign", "merge",
                                   "idle_moves")
        assert Scheduler(SchedulerConfig(
            algorithm="dag_het_mem")).stage_names() == ("pack",)


# ---------------------------------------------------------------------- #
# deprecated wrappers
# ---------------------------------------------------------------------- #
class TestDeprecatedWrappers:
    def test_dag_het_part_warns_and_matches_scheduler(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 120, seed=4, platform=plat)
        with pytest.warns(DeprecationWarning, match="dag_het_part"):
            res = dag_het_part(wf, plat, kprime=[1, 4, 9])
        rep = schedule(wf, plat, kprime=[1, 4, 9])
        assert res is not None
        assert res.makespan == rep.makespan

    def test_dag_het_mem_warns_and_matches_scheduler(self):
        plat = default_cluster()
        wf = generate_workflow("blast", 120, seed=4, platform=plat)
        with pytest.warns(DeprecationWarning, match="dag_het_mem"):
            res = dag_het_mem(wf, plat)
        rep = schedule(wf, plat, algorithm="dag_het_mem")
        assert res is not None
        assert res.makespan == rep.makespan

    def test_wrappers_keep_the_none_contract(self):
        wf = random_layered_dag(60, seed=1)
        with pytest.warns(DeprecationWarning):
            assert dag_het_mem(wf, TINY) is None
        with pytest.warns(DeprecationWarning):
            assert dag_het_part(wf, TINY, kprime=[1, 2]) is None
