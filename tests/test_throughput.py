"""Tests for repro.throughput — steady-state pipelined scheduling.

The load-bearing anchor: **one instance arriving at rate→0 reproduces
``schedule(wf, platform, simulate=True)`` bit-exactly** — same specs,
same engine, same backward pass — asserted on all seven n=1000
families.  Around it: the engine's release floor, seeded arrival
processes, dominance-matched replication (disjoint groups, inherited
feasibility), the N-instance sandwich property (single ≤ pipelined
horizon ≤ N × single), the summed memory-occupancy tracker with
per-instance violation pinpointing, the scheduler's ``throughput``
pipeline (rate-max k' selection, structured latency-bound
infeasibility), sustained service admission through the plan cache,
and the per-instance trace tooling.
"""
import importlib.util
import json
from pathlib import Path

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from conftest import make_random_dag
from repro.core import (
    FAMILIES,
    Platform,
    Processor,
    Workflow,
    default_cluster,
    generate_workflow,
    makespan,
    schedule,
)
from repro.core.dag import build_quotient
from repro.sim import BlockSpec, ContentionFreeComm, EdgeSpec, run_engine
from repro.service import PlanCache, run_sustained
from repro.throughput import (
    ArrivalSpec,
    PipelinedReport,
    ThroughputPlan,
    build_pipelined_specs,
    plan_throughput,
    proc_busy_times,
    replicate_plan,
    saturation_sweep,
    simulate_pipelined,
)
from repro.throughput.pipeline import _pipelined_memory_trace

ANCHOR_N = 1000


@pytest.fixture(scope="module")
def plat() -> Platform:
    return default_cluster()


@pytest.fixture(scope="module")
def family_wfs(plat):
    """The seven n=1000 instances, generated once per module."""
    return {f: generate_workflow(f, ANCHOR_N, seed=1, platform=plat)
            for f in FAMILIES}


def unit_procs(k: int, mem: float = 1e9) -> Platform:
    return Platform([Processor(f"p{i}", 1.0, mem) for i in range(k)], 1.0)


def chain_workflow(n: int = 3) -> Workflow:
    wf = Workflow(n)
    wf.work[:] = [2.0] * n
    wf.mem[:] = [1.0] * n
    for u in range(n - 1):
        wf.add_edge(u, u + 1, 1.0)
    return wf


def singleton_mapping(wf: Workflow, platform: Platform):
    """Every task its own block on its own processor (round-robin)."""
    q = build_quotient(wf, list(range(wf.n)))
    for i, vid in enumerate(sorted(q.members)):
        q.proc[vid] = i % platform.k
    return q


# ---------------------------------------------------------------------- #
# engine release floor
# ---------------------------------------------------------------------- #
class TestEngineRelease:
    def test_release_floors_start(self):
        blocks = [BlockSpec(0, 0, 1.0)]
        tr = run_engine(blocks, [], ContentionFreeComm(), unit_procs(1),
                        release={0: 5.0})
        assert tr.start[0] == 5.0
        assert tr.finish[0] == 6.0

    def test_release_does_not_delay_late_readiness(self):
        # pred finishes at 2.0 > release 1.0: release floor is inert
        blocks = [BlockSpec(0, 0, 2.0), BlockSpec(1, 1, 1.0)]
        edges = [EdgeSpec(0, 1, 0.0)]
        tr = run_engine(blocks, edges, ContentionFreeComm(),
                        unit_procs(2), release={1: 1.0})
        assert tr.start[1] == 2.0

    def test_empty_release_bit_identical(self):
        wf = chain_workflow(4)
        plat = unit_procs(4)
        q = singleton_mapping(wf, plat)
        from repro.sim import build_specs

        blocks, edges = build_specs(q, plat)
        a = run_engine(blocks, edges, ContentionFreeComm(), plat)
        b = run_engine(blocks, edges, ContentionFreeComm(), plat,
                       release={})
        assert a.start == b.start and a.finish == b.finish
        assert a.horizon == b.horizon


# ---------------------------------------------------------------------- #
# arrival processes
# ---------------------------------------------------------------------- #
class TestArrivals:
    def test_deterministic_kind(self):
        t = ArrivalSpec(0.5, "deterministic", start=3.0).times(4)
        assert list(t) == [3.0, 5.0, 7.0, 9.0]

    def test_poisson_seeded_and_monotone(self):
        spec = ArrivalSpec(2.0, "poisson")
        a = spec.times(64, seed=7)
        b = spec.times(64, seed=7)
        c = spec.times(64, seed=8)
        assert list(a) == list(b)
        assert list(a) != list(c)
        assert all(x < y for x, y in zip(a, a[1:]))
        assert a[0] >= 0.0

    def test_validation(self):
        with pytest.raises(ValueError):
            ArrivalSpec(0.0)
        with pytest.raises(ValueError):
            ArrivalSpec(1.0, "weibull")
        with pytest.raises(ValueError):
            ArrivalSpec(1.0, start=-1.0)
        with pytest.raises(ValueError):
            ArrivalSpec(1.0).times(0)


# ---------------------------------------------------------------------- #
# steady-state pricing + replication
# ---------------------------------------------------------------------- #
class TestReplication:
    def test_busy_times_price_compute_and_comm(self):
        wf = chain_workflow(2)
        plat = unit_procs(2)
        q = singleton_mapping(wf, plat)
        busy = proc_busy_times(q, plat, include_comm=True)
        # 2.0 work / speed 1 + edge 1.0 / beta 1 on both endpoints
        assert busy == {0: 3.0, 1: 3.0}
        nc = proc_busy_times(q, plat, include_comm=False)
        assert nc == {0: 2.0, 1: 2.0}

    def test_groups_disjoint_and_dominant(self, plat, family_wfs):
        rep = schedule(family_wfs["genome"], plat, kprime=[3],
                       workers=1)
        plan = replicate_plan(rep.best, plat)
        assert plan.n_replicas >= 2
        seen: set[int] = set()
        for g in plan.groups:
            procs = set(g.procs)
            assert not (procs & seen)
            seen |= procs
        base = plan.groups[0]
        for g in plan.groups[1:]:
            for (b, _), (_, r) in zip(base.proc_map, g.proc_map):
                assert plat.procs[r].speed >= plat.procs[b].speed
                assert plat.procs[r].memory >= plat.procs[b].memory
            assert g.latency <= base.latency * (1 + 1e-12)
        assert plan.rate == plan.n_replicas / plan.period
        assert plan.period == max(g.period for g in plan.groups)

    def test_max_replicas_one_is_unreplicated(self, plat, family_wfs):
        rep = schedule(family_wfs["genome"], plat, kprime=[3],
                       workers=1)
        plan = replicate_plan(rep.best, plat, max_replicas=1)
        assert plan.n_replicas == 1
        assert plan.rate == 1.0 / plan.period

    def test_identity_group_latency_is_analytic_makespan(
            self, plat, family_wfs):
        rep = schedule(family_wfs["blast"], plat, kprime=[4], workers=1)
        plan = replicate_plan(rep.best, plat)
        assert plan.groups[0].latency == rep.makespan

    def test_plan_round_trips(self, plat, family_wfs):
        rep = schedule(family_wfs["genome"], plat, kprime=[3],
                       workers=1)
        plan = replicate_plan(rep.best, plat, latency_bound=1e12)
        again = ThroughputPlan.from_dict(
            json.loads(json.dumps(plan.to_dict())))
        assert again == plan


# ---------------------------------------------------------------------- #
# the identity anchor (ISSUE acceptance criterion)
# ---------------------------------------------------------------------- #
class TestIdentityAnchor:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_rate_to_zero_bit_exact_n1000(self, family, family_wfs,
                                          plat):
        """One instance at arrival 0 IS ``schedule(..., simulate=True)``:
        same specs, same engine — horizon and makespan bit-equal."""
        wf = family_wfs[family]
        rep = schedule(wf, plat, kprime=[6], workers=1, simulate=True)
        assert rep.feasible, family
        p = simulate_pipelined(rep.best, plat, arrivals=[0.0])
        assert p.single_makespan == rep.sim.makespan, family
        assert p.horizon == rep.sim.horizon, family
        assert p.n_instances == 1
        rec = p.instances[0]
        assert rec.arrival == 0.0 and rec.finish == p.horizon
        assert p.memory is not None and p.memory.feasible

    def test_specs_bit_identical_for_instance_zero(self, plat,
                                                   family_wfs):
        from repro.sim import build_specs

        rep = schedule(family_wfs["bwa"], plat, kprime=[4], workers=1)
        q = rep.best.quotient
        plan = replicate_plan(rep.best, plat, max_replicas=1)
        blocks, edges, release, stride = build_pipelined_specs(
            q, plat, plan, [0.0])
        base_blocks, base_edges = build_specs(q, plat)
        assert blocks == list(base_blocks)
        assert sorted((e.src, e.dst, e.volume) for e in edges) == \
            sorted((e.src, e.dst, e.volume) for e in base_edges)
        assert set(release.values()) == {0.0}
        assert stride == max(q.members) + 1


# ---------------------------------------------------------------------- #
# pipelined replay properties
# ---------------------------------------------------------------------- #
class TestPipelinedReplay:
    @settings(max_examples=10, deadline=None)
    @given(
        n_tasks=st.integers(min_value=8, max_value=40),
        seed=st.integers(min_value=0, max_value=10**6),
        n_instances=st.integers(min_value=2, max_value=6),
    )
    def test_makespan_sandwich(self, n_tasks, seed, n_instances):
        """Burst of N instances: single ≤ pipelined horizon ≤
        N × single (pipelining can only help vs. back-to-back runs,
        and interference can only hurt vs. one lone instance)."""
        wf = make_random_dag(n_tasks, seed)
        plat = unit_procs(4)
        rep = schedule(wf, plat, workers=1)
        if not rep.feasible:
            return
        p = simulate_pipelined(rep.best, plat,
                               arrivals=[0.0] * n_instances,
                               memory=False)
        single = p.single_makespan
        assert p.horizon >= single * (1 - 1e-9)
        assert p.horizon <= n_instances * single * (1 + 1e-9)

    def test_deterministic_replay(self, plat, family_wfs):
        rep = schedule(family_wfs["genome"], plat, kprime=[3],
                       workers=1)
        a = simulate_pipelined(rep.best, plat, rate=0.0008,
                               n_instances=8, seed=4, memory=False)
        b = simulate_pipelined(rep.best, plat, rate=0.0008,
                               n_instances=8, seed=4, memory=False)
        assert a.block_start == b.block_start
        assert [r.to_list() for r in a.instances] == \
            [r.to_list() for r in b.instances]

    def test_round_robin_dealing(self, plat, family_wfs):
        rep = schedule(family_wfs["genome"], plat, kprime=[3],
                       workers=1)
        p = simulate_pipelined(rep.best, plat, rate=0.0008,
                               n_instances=6, memory=False)
        assert p.n_replicas >= 2
        assert [r.replica for r in p.instances] == \
            [i % p.n_replicas for i in range(6)]

    def test_replicated_memory_feasible_at_overlap_peak(
            self, plat, family_wfs):
        """ISSUE acceptance: replicated plans never exceed processor
        memory at the overlap peak — asserted via the occupancy
        trace of a saturating burst."""
        rep = schedule(family_wfs["genome"], plat, kprime=[3],
                       workers=1)
        plan = replicate_plan(rep.best, plat)
        assert plan.n_replicas >= 2
        p = simulate_pipelined(rep.best, plat, plan=plan,
                               arrivals=[0.0] * (2 * plan.n_replicas))
        assert p.memory.feasible
        for j, pk in p.memory.peak.items():
            assert pk <= plat.memory(j) * (1 + 1e-9)

    def test_report_round_trips(self, plat, family_wfs):
        rep = schedule(family_wfs["genome"], plat, kprime=[3],
                       workers=1)
        p = simulate_pipelined(rep.best, plat, rate=0.0008,
                               n_instances=4, record_events=True)
        again = PipelinedReport.from_dict(
            json.loads(json.dumps(p.to_dict())))
        assert again.to_dict() == p.to_dict()
        assert again.latencies == p.latencies


# ---------------------------------------------------------------------- #
# summed occupancy tracker: violation pinpointing
# ---------------------------------------------------------------------- #
class TestSummedMemoryTracker:
    def test_violation_names_the_instance(self):
        """Two overlapping instances of one block on one processor:
        the second instance's task start pushes occupancy over, and
        the violation names instance 1 (not 0)."""
        wf = Workflow(1)
        wf.work[:] = [2.0]
        wf.mem[:] = [3.0]
        plat = unit_procs(1, mem=5.0)
        q = build_quotient(wf, [0])
        q.proc[0] = 0
        from repro.core.baseline import MappingResult

        res = MappingResult(algo="test", quotient=q, platform=plat,
                            makespan=makespan(q, plat), runtime_s=0.0,
                            k_used=1, extras={})
        plan = replicate_plan(res, plat, max_replicas=1)
        # overlapping windows (as if the engine had two exec units)
        start = {0: 0.0, 1: 1.0}
        finish = {0: 2.0, 1: 3.0}
        mt = _pipelined_memory_trace(wf, q, plat, plan, start, finish,
                                     stride=1, n_instances=2)
        assert not mt.feasible
        assert mt.peak[0] == 6.0
        v = mt.violations[0]
        assert v.instance == 1 and v.proc == 0 and v.capacity == 5.0
        # serialization keeps the instance attribution
        from repro.sim.report import MemoryViolation

        again = MemoryViolation.from_dict(
            json.loads(json.dumps(v.to_dict())))
        assert again.instance == 1

    def test_single_instance_within_capacity(self):
        wf = Workflow(1)
        wf.work[:] = [2.0]
        wf.mem[:] = [3.0]
        plat = unit_procs(1, mem=5.0)
        q = build_quotient(wf, [0])
        q.proc[0] = 0
        from repro.core.baseline import MappingResult

        res = MappingResult(algo="test", quotient=q, platform=plat,
                            makespan=makespan(q, plat), runtime_s=0.0,
                            k_used=1, extras={})
        plan = replicate_plan(res, plat, max_replicas=1)
        mt = _pipelined_memory_trace(wf, q, plat, plan,
                                     {0: 0.0}, {0: 2.0},
                                     stride=1, n_instances=1)
        assert mt.feasible and mt.peak[0] == 3.0


# ---------------------------------------------------------------------- #
# the scheduler's throughput pipeline
# ---------------------------------------------------------------------- #
class TestThroughputPlanning:
    def test_plan_attached_and_rate_positive(self, plat, family_wfs):
        tr = plan_throughput(family_wfs["genome"], plat, kprime=[3],
                             workers=1)
        assert tr.feasible
        assert tr.rate > 0 and tr.latency > 0
        assert tr.best.extras["throughput"] == tr.plan
        assert tr.plan.n_replicas >= 2

    def test_rate_max_selection_across_sweep(self, plat, family_wfs):
        """The winner maximizes the *replicated rate*, which need not
        be the makespan winner."""
        tr = plan_throughput(family_wfs["genome"], plat,
                             kprime=[3, 9], workers=1)
        assert tr.feasible
        rates = {}
        for pt in tr.report.sweep:
            h = pt.metrics.get("histograms", {}).get("throughput_rate")
            if pt.feasible and h:
                rates[pt.k_prime] = float(h["sum"])
        assert len(rates) == 2
        assert tr.k_prime == max(rates, key=lambda k: rates[k])
        assert tr.rate == pytest.approx(rates[tr.k_prime], rel=1e-12)

    def test_latency_bound_is_structured_infeasibility(
            self, plat, family_wfs):
        tr = plan_throughput(family_wfs["genome"], plat, kprime=[3],
                             workers=1, latency_bound=1e-9)
        assert not tr.feasible
        assert tr.report.infeasibility is not None
        assert tr.report.infeasibility.stage == "throughput"

    def test_latency_bound_caps_replication(self, plat, family_wfs):
        wide = plan_throughput(family_wfs["genome"], plat, kprime=[3],
                               workers=1)
        bound = wide.plan.groups[0].latency  # only group 0 fits a
        tr = plan_throughput(family_wfs["genome"], plat, kprime=[3],
                             workers=1, latency_bound=bound)
        assert tr.feasible
        assert tr.latency <= bound

    def test_saturation_sweep_finds_the_knee(self, plat, family_wfs):
        tr = plan_throughput(family_wfs["genome"], plat, kprime=[3],
                             workers=1)
        rows = saturation_sweep(
            tr.best, plat, plan=tr.plan,
            rates=[0.3 * tr.rate, 3.0 * tr.rate], n_instances=16)
        assert not rows[0]["saturated"]
        assert rows[1]["saturated"]
        assert rows[1]["p99"] >= rows[0]["p99"]
        for row in rows:
            assert row["p50"] <= row["p99"]


# ---------------------------------------------------------------------- #
# sustained service admission
# ---------------------------------------------------------------------- #
class TestRunSustained:
    def test_cold_then_seeded_identical_timings(self, plat,
                                                family_wfs):
        wf = family_wfs["genome"]
        cache = PlanCache(8)
        a = run_sustained(wf, plat, rate=0.0008, n_instances=8,
                          seed=2, cache=cache, kprime=[3])
        b = run_sustained(wf, plat, rate=0.0008, n_instances=8,
                          seed=2, cache=cache, kprime=[3])
        assert a.jobs[0].planning_path == "cold"
        assert b.jobs[0].planning_path == "seeded"
        assert a.cache_stats["service_cache_misses"] == 1
        assert b.cache_stats["service_cache_hits"] == 1
        assert [j.finish_t for j in a.jobs] == \
            [j.finish_t for j in b.jobs]

    def test_report_carries_throughput_views(self, plat, family_wfs):
        rep = run_sustained(family_wfs["genome"], plat, rate=0.0008,
                            n_instances=8, kprime=[3])
        assert len(rep.jobs) == 8
        assert all(j.status == "completed" for j in rep.jobs)
        assert rep.instances_per_s > 0
        assert rep.saturation_rate > 0
        pct = rep.instance_latency_percentiles
        assert pct is not None and pct["p50"] <= pct["p99"]
        assert rep.pipelined is not None
        assert rep.pipelined.memory.feasible
        # allocation is the replica group's processor names
        assert rep.jobs[0].allocation
        # the trace JSON round-trips (pipelined/spans excluded)
        from repro.service import ServiceReport

        again = ServiceReport.from_json(rep.to_json())
        assert again.trace.to_dict() == rep.trace.to_dict()

    def test_infeasible_is_structured(self, plat, family_wfs):
        rep = run_sustained(family_wfs["genome"], plat, rate=0.0008,
                            n_instances=4, kprime=[3],
                            latency_bound=1e-9)
        assert len(rep.jobs) == 1
        assert rep.jobs[0].status == "infeasible"
        assert rep.jobs[0].infeasibility["stage"] == "throughput"
        assert rep.pipelined is None


# ---------------------------------------------------------------------- #
# per-instance trace tooling
# ---------------------------------------------------------------------- #
class TestInstanceTraceTooling:
    def test_stride_decoding_and_per_instance_tracks(
            self, tmp_path, plat, family_wfs):
        from repro.obs.export import sim_proc_events, write_chrome_trace

        rep = schedule(family_wfs["genome"], plat, kprime=[3],
                       workers=1)
        p = simulate_pipelined(rep.best, plat, rate=0.0008,
                               n_instances=3, record_events=True,
                               memory=False)
        ev = sim_proc_events(p, stride=p.stride)
        insts = {e["args"]["instance"] for e in ev
                 if e["cat"] == "task"}
        assert insts == {0, 1, 2}
        assert all(e["args"]["vertex"] < p.stride for e in ev
                   if e["cat"] == "task")
        path = tmp_path / "pipe.json"
        write_chrome_trace(path, ev)

        spec = importlib.util.spec_from_file_location(
            "trace_view",
            Path(__file__).resolve().parent.parent
            / "tools" / "trace_view.py")
        tv = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(tv)
        spans = tv.load_spans(path)
        tv.split_per_instance(spans)
        tids = {s["tid"] for s in spans}
        assert any("#i1" in t for t in tids)
        out = tv.format_table(spans, 5, False)
        assert "#i" in out

    def test_histogram_mean(self):
        from repro.obs.metrics import Histogram

        h = Histogram()
        assert h.mean is None
        h.observe(2.0)
        h.observe(4.0)
        assert h.mean == 3.0
