"""End-to-end system behaviour tests."""
import numpy as np

from repro.core import (
    default_cluster,
    generate_workflow,
    schedule,
    validate_mapping,
)


def test_end_to_end_schedule_and_validate():
    """Full pipeline: generate -> schedule (both algorithms) ->
    validate every DAGP-PM constraint -> heuristic beats baseline."""
    plat = default_cluster()
    wf = generate_workflow("seismology", 300, seed=7, platform=plat)
    base = schedule(wf, plat, algorithm="dag_het_mem")
    het = schedule(wf, plat, kprime=[1, 4, 9, 19, 36])
    assert base.feasible and het.feasible
    assert validate_mapping(wf, base.best) == []
    assert validate_mapping(wf, het.best) == []
    assert het.makespan <= base.makespan


def test_estimated_makespan_is_deterministic():
    plat = default_cluster()
    wf = generate_workflow("bwa", 250, seed=3, platform=plat)
    r1 = schedule(wf, plat, kprime=[9, 19])
    r2 = schedule(wf, plat, kprime=[9, 19])
    assert r1.makespan == r2.makespan


def test_model_to_scheduler_to_runtime_roundtrip(tmp_path):
    """The three layers compose: arch config -> workflow DAG ->
    placement plan; same arch config -> reduced model -> train step."""
    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.core.autoshard import plan
    from repro.core.platform import tpu_fleet_si
    from repro.runtime import Trainer, TrainerConfig

    arch = "llama3_8b"
    p = plan(get_config(arch), ShapeConfig("d", 32768, 128, "decode"),
             tpu_fleet_si({"v5e": 48, "v4": 16}), kprime=[16, 32, 64])
    assert p is not None and p.valid

    shape = ShapeConfig("t", 16, 4, "train")
    trainer = Trainer(get_smoke_config(arch), shape,
                      TrainerConfig(steps=3, ckpt_every=2,
                                    ckpt_dir=str(tmp_path)),
                      attn_chunk=8)
    hist = trainer.run()
    assert len(hist["loss"]) == 3
    assert all(np.isfinite(x) for x in hist["loss"])
