"""Tests of the dry-run machinery itself: sharding rules, step
builders, and the trip-count-aware HLO analyzer — on the single local
device (the 512-device pass runs via launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_serve_step, build_train_step

import repro.configs.base as config_base

# register tiny shapes usable by the step builders
config_base.SHAPES.setdefault(
    "unit_train", ShapeConfig("unit_train", 32, 4, "train"))
config_base.SHAPES.setdefault(
    "unit_decode", ShapeConfig("unit_decode", 64, 4, "decode"))


class TestHloAnalyzer:
    def test_scan_flops_weighted_by_trip_count(self):
        def f(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y

        x = jnp.zeros((64, 64))
        c = jax.jit(f).lower(x, x).compile()
        st = analyze_hlo(c.as_text())
        assert st.flops == pytest.approx(10 * 2 * 64**3, rel=0.01)
        assert st.max_trip == 10

    def test_nested_scans_multiply(self):
        def g(x, w):
            def inner(c, _):
                return c @ w, None

            def outer(c, _):
                c, _ = jax.lax.scan(inner, c, None, length=5)
                return c, None

            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y

        x = jnp.zeros((32, 32))
        c = jax.jit(g).lower(x, x).compile()
        st = analyze_hlo(c.as_text())
        assert st.flops == pytest.approx(15 * 2 * 32**3, rel=0.01)

    def test_xla_cost_analysis_undercounts(self):
        """The reason this analyzer exists."""
        def f(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y

        x = jnp.zeros((64, 64))
        c = jax.jit(f).lower(x, x).compile()
        xla_cost = c.cost_analysis()
        if isinstance(xla_cost, (list, tuple)):  # jax < 0.5
            xla_cost = xla_cost[0]
        xla_flops = xla_cost["flops"]
        ours = analyze_hlo(c.as_text()).flops
        assert ours > 5 * xla_flops

    def test_dynamic_slice_not_counted_as_full_operand(self):
        def f(big):
            def body(acc, i):
                return acc + jax.lax.dynamic_slice_in_dim(big, i, 8), None
            out, _ = jax.lax.scan(body, jnp.zeros((8, 256)),
                                  jnp.arange(64))
            return out

        big = jnp.zeros((1024, 256))
        c = jax.jit(f).lower(big).compile()
        st = analyze_hlo(c.as_text())
        # 64 iterations touching ~8x256 floats each, not 1024x256
        assert st.bytes_accessed < 64 * (8 * 256 * 4) * 12


class TestCellCaching:
    """Cache + artifact hygiene for launch/dryrun.py (no compilation)."""

    def test_ok_cell_is_cached(self, tmp_path):
        from repro.launch.dryrun import _cached_ok
        p = tmp_path / "cell.json"
        p.write_text('{"status": "ok", "arch": "a"}')
        assert _cached_ok(p)

    def test_error_cell_is_stale(self, tmp_path):
        from repro.launch.dryrun import _cached_ok
        p = tmp_path / "cell.json"
        p.write_text('{"status": "error", "error": "boom"}')
        assert not _cached_ok(p)

    def test_unreadable_cell_is_stale(self, tmp_path):
        from repro.launch.dryrun import _cached_ok
        p = tmp_path / "cell.json"
        p.write_text("{truncated")
        assert not _cached_ok(p)
        assert not _cached_ok(tmp_path / "missing.json")

    def test_write_hlo_survives_missing_zstandard(self, tmp_path):
        """zstandard is optional: the gzip fallback must round-trip."""
        import gzip
        from repro.launch.dryrun import _write_hlo
        out = _write_hlo(tmp_path / "cell.hlo", "HloModule m")
        assert out.exists()
        if out.suffix == ".gz":
            assert gzip.decompress(out.read_bytes()) == b"HloModule m"
        else:  # zstandard present in this environment
            import zstandard
            assert zstandard.ZstdDecompressor().decompress(
                out.read_bytes()) == b"HloModule m"

    def test_traceback_paths_relativized(self):
        from repro.launch.dryrun import _REPO_ROOT, _sanitize_traceback
        tb = (f'  File "{_REPO_ROOT}/src/repro/launch/dryrun.py", '
              'line 1, in main\n')
        clean = _sanitize_traceback(tb)
        assert _REPO_ROOT not in clean
        assert 'File "src/repro/launch/dryrun.py"' in clean


class TestStepBuilders:
    def test_train_bundle_lowers_and_analyzes(self):
        mesh = make_local_mesh(1, 1)
        cfg = get_smoke_config("mixtral_8x7b")
        b = build_train_step("mixtral_8x7b", "unit_train", mesh, cfg=cfg,
                             attn_chunk=16)
        with mesh:
            compiled = b.step_fn.lower(
                b.input_specs["params"], b.input_specs["opt_state"],
                b.input_specs["batch"]).compile()
        st = analyze_hlo(compiled.as_text())
        assert st.flops > 0
        assert st.bytes_accessed > 0
        assert compiled.memory_analysis().temp_size_in_bytes > 0

    def test_serve_bundle_lowers(self):
        mesh = make_local_mesh(1, 1)
        cfg = get_smoke_config("jamba_15_large")
        b = build_serve_step("jamba_15_large", "unit_decode", mesh,
                             cfg=cfg, attn_chunk=16)
        with mesh:
            compiled = b.step_fn.lower(
                b.input_specs["params"], b.input_specs["cache"],
                b.input_specs["tokens"]).compile()
        assert analyze_hlo(compiled.as_text()).flops > 0

    def test_policy_picker(self):
        from repro.launch.sharding import pick_policy
        assert pick_policy(int(1e9)) == "tp"
        assert pick_policy(int(5e10)) == "fsdp_tp"
