"""Sharding-rule tests: divisibility fallbacks and policy coverage —
every parameter of every arch gets a legal PartitionSpec on the
production mesh shape (validated against array dims, no devices
needed beyond the local one)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import param_sharding_rules
from repro.models import LM


class FakeMesh:
    """Duck-typed mesh exposing only what the rules consume."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


PROD = FakeMesh({"data": 16, "model": 16})
PROD_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _leaves_with_specs(arch, mesh, policy):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    shapes = jax.eval_shape(lambda: model.init(0))
    specs = param_sharding_rules(shapes, mesh, policy)
    return list(zip(jax.tree.leaves(shapes),
                    jax.tree.leaves(
                        specs, is_leaf=lambda x: isinstance(x, P))))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [PROD, PROD_MP])
@pytest.mark.parametrize("policy", ["tp", "fsdp_tp"])
def test_specs_are_legal(arch, mesh, policy):
    def axsize(ax):
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            return int(np.prod([mesh.shape[a] for a in ax]))
        return mesh.shape[ax]

    for leaf, spec in _leaves_with_specs(arch, mesh, policy):
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            assert dim % axsize(ax) == 0, (arch, leaf.shape, spec)


def test_fsdp_tp_shards_more_than_tp():
    """fsdp_tp must strictly increase the number of sharded dims on
    the big matrices (that's the point of the policy)."""
    def sharded_dims(policy):
        total = 0
        for leaf, spec in _leaves_with_specs("llama3_8b", PROD, policy):
            total += sum(1 for ax in tuple(spec) if ax is not None)
        return total

    assert sharded_dims("fsdp_tp") > sharded_dims("tp")


def test_norms_replicated():
    for leaf, spec in _leaves_with_specs("llama3_8b", PROD, "fsdp_tp"):
        if len(leaf.shape) == 1 and leaf.shape[0] <= 64:
            assert all(ax is None for ax in tuple(spec))


def test_fsdp_policy_shards_over_all_axes():
    """Pure FSDP: exactly one dim sharded over the combined axes, no
    tensor parallelism anywhere (EXPERIMENTS.md §Perf iteration 4)."""
    for leaf, spec in _leaves_with_specs("qwen25_32b", PROD, "fsdp"):
        axes = [ax for ax in tuple(spec) if ax is not None]
        assert len(axes) <= 1
        for ax in axes:
            assert isinstance(ax, tuple)  # the combined-axes tuple
            assert set(ax) <= {"pod", "data", "model"}


def test_fsdp_batch_sharding_uses_model_axis():
    from repro.launch.sharding import batch_sharding

    mesh = make_local_mesh(1, 1)  # real mesh with data/model axes
    sh = batch_sharding(mesh, 256, policy="fsdp")
    assert tuple(sh.spec)[0] == ("data", "model")
    sh2 = batch_sharding(mesh, 256, policy="fsdp_tp")
    assert tuple(sh2.spec)[0] in ("data", ("data",))  # P normalizes 1-tuples
