"""Acyclic-partitioner tests: the acyclicity invariant is the paper's
hard requirement (quotient must be a DAG for the makespan to exist)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep absent: seeded-random fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    Workflow,
    acyclic_partition,
    build_quotient,
    edge_cut,
    partition_block,
    random_layered_dag,
)

from conftest import make_random_dag


def assert_valid_partition(wf, block_of, k):
    assert len(block_of) == wf.n
    ids = set(block_of)
    assert len(ids) <= k
    assert ids == set(range(len(ids))), "block ids must be compact"
    # topological-id invariant => acyclic quotient
    for u in range(wf.n):
        for v in wf.succ[u]:
            assert block_of[u] <= block_of[v]
    q = build_quotient(wf, block_of)
    assert q.is_acyclic()


class TestAcyclicPartition:
    @pytest.mark.parametrize("k", [1, 2, 3, 7])
    def test_valid_on_random_dags(self, k):
        for seed in range(10):
            wf = make_random_dag(30, seed)
            assert_valid_partition(wf, acyclic_partition(wf, k), k)

    def test_requests_more_blocks_than_tasks(self):
        wf = make_random_dag(3, 0)
        block_of = acyclic_partition(wf, 10)
        assert_valid_partition(wf, block_of, 10)

    def test_k1_single_block(self):
        wf = make_random_dag(20, 1)
        assert set(acyclic_partition(wf, 1)) == {0}

    def test_balance(self):
        wf = random_layered_dag(400, seed=2)
        block_of = acyclic_partition(wf, 8, eps=0.2)
        k_eff = len(set(block_of))
        weights = [0.0] * k_eff
        for u in range(wf.n):
            weights[block_of[u]] += wf.work[u]
        target = sum(wf.work) / k_eff
        assert max(weights) <= 1.5 * target  # loose: refinement may shift

    def test_refinement_does_not_worsen_cut(self):
        for seed in range(5):
            wf = random_layered_dag(300, seed=seed)
            cut_refined = edge_cut(wf, acyclic_partition(wf, 6, passes=4))
            cut_raw = edge_cut(wf, acyclic_partition(wf, 6, passes=0))
            assert cut_refined <= cut_raw + 1e-9

    @settings(max_examples=40, deadline=None)
    @given(
        n=st.integers(2, 40),
        k=st.integers(1, 8),
        seed=st.integers(0, 1000),
    )
    def test_property_always_acyclic_and_complete(self, n, k, seed):
        wf = make_random_dag(n, seed, p=0.25)
        assert_valid_partition(wf, acyclic_partition(wf, k), k)


class TestPartitionBlock:
    def test_strict_progress_for_fitblock(self):
        """FitBlock relies on a >1-task block always splitting."""
        for seed in range(10):
            wf = make_random_dag(15, seed, p=0.5)
            parts = partition_block(wf, list(range(wf.n)), 2)
            assert len(parts) >= 2
            assert sum(len(p) for p in parts) == wf.n

    def test_skewed_weights_still_split(self):
        # one task dominating the weight must not prevent a 2-way split
        wf = Workflow(2)
        wf.work[:] = [1000.0, 0.001]
        wf.add_edge(0, 1, 1.0)
        parts = partition_block(wf, [0, 1], 2)
        assert len(parts) == 2

    def test_subset_partition_acyclic_in_parent(self):
        wf = random_layered_dag(100, seed=5)
        nodes = list(range(40, 90))
        parts = partition_block(wf, nodes, 3)
        # contiguity within the parent graph: no edge from a later part
        # back into an earlier one
        part_of = {}
        for i, p in enumerate(parts):
            for u in p:
                part_of[u] = i
        for u in nodes:
            for v in wf.succ[u]:
                if v in part_of:
                    assert part_of[u] <= part_of[v]

    def test_singleton_passthrough(self):
        wf = make_random_dag(5, 0)
        assert partition_block(wf, [2], 2) == [[2]]
