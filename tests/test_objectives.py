"""repro.objectives: reliability/energy accounting + objective stages.

Property tests pin the accounting identities (energy decomposition,
reliability bounds and monotonicity), bit-inertness of the objective
stages on model-free platforms, the structured infeasibility of an
unreachable reliability floor, the sim-side energy integrals, and the
checkpoint-pricing decisions in the replan path.
"""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    Platform,
    ProcPower,
    Processor,
    Scheduler,
    SchedulerConfig,
    default_cluster,
    generate_workflow,
)
from repro.objectives import (
    EnergyReport,
    ReliabilityReport,
    block_exposures,
    energy_from_sim,
    energy_plan,
    plan_energy,
    plan_reliability,
    schedule_energy,
    schedule_reliability,
)
from repro.sim import simulate


@pytest.fixture(scope="module")
def platform():
    return default_cluster()


@pytest.fixture(scope="module")
def wf(platform):
    return generate_workflow("genome", 120, seed=3, platform=platform)


@pytest.fixture(scope="module")
def mapping(wf, platform):
    rep = Scheduler(SchedulerConfig()).schedule(wf, platform)
    assert rep.feasible
    return rep.best


def _modeled(platform, rng_rates=None, power_kw=None):
    k = platform.k
    # calibrated so the nominal schedule's success prob ≈ 0.96 on the
    # module fixture — floors of 0.9/0.95 are reachable, 0.999999 not
    rates = rng_rates or {j: 5e-7 * (j + 1) for j in range(k)}
    power = power_kw or {j: ProcPower(0.5 + 0.1 * j, 2.0) for j in range(k)}
    return platform.with_failure_rates(rates).with_power(power)


# ---------------------------------------------------------------------- #
# reliability accounting
# ---------------------------------------------------------------------- #
class TestReliability:
    def test_no_model_is_trivial(self, mapping, platform):
        rel = schedule_reliability(mapping, platform)
        assert rel.success_prob == 1.0
        assert rel.weighted_makespan == rel.makespan

    @given(scale=st.floats(1e-6, 1e-2))
    @settings(max_examples=20, deadline=None)
    def test_bounds_and_monotonicity(self, mapping, platform, scale):
        """success_prob ∈ (0, 1], and scaling every failure rate up
        (more exposure-weighted hazard) never increases it."""
        k = platform.k
        p1 = platform.with_failure_rates(
            {j: scale for j in range(k)})
        p2 = platform.with_failure_rates(
            {j: 2 * scale for j in range(k)})
        r1 = schedule_reliability(mapping, p1)
        r2 = schedule_reliability(mapping, p2)
        for r in (r1, r2):
            assert 0.0 < r.success_prob <= 1.0
            assert r.weighted_makespan >= r.makespan
        assert r2.success_prob <= r1.success_prob

    def test_monotone_in_exposure(self, mapping, platform):
        """Slowing blocks down (longer exposure at the same rates)
        never increases the success probability."""
        pf = platform.with_failure_rates(
            {j: 1e-4 for j in range(platform.k)})
        fast = schedule_reliability(mapping, pf)
        slow = schedule_reliability(
            mapping, pf,
            speed_scale={v: 0.5 for v in mapping.quotient.members})
        assert slow.success_prob <= fast.success_prob
        exp_fast = block_exposures(mapping, pf)
        exp_slow = block_exposures(
            mapping, pf,
            {v: 0.5 for v in mapping.quotient.members})
        for v in exp_fast:
            assert exp_slow[v] == pytest.approx(2 * exp_fast[v])

    def test_closed_form(self, mapping, platform):
        pf = platform.with_failure_rates({0: 3e-4, 2: 7e-4})
        rel = schedule_reliability(mapping, pf)
        q = mapping.quotient
        hazard = sum(pf.failure_rate(q.proc[v]) * dur
                     for v, dur in rel.exposure.items())
        assert rel.success_prob == pytest.approx(math.exp(-hazard))
        assert rel.hazard == pytest.approx(
            sum(rel.proc_hazard.values()))

    def test_json_roundtrip(self, mapping, platform):
        rel = schedule_reliability(mapping, _modeled(platform))
        assert ReliabilityReport.from_dict(rel.to_dict()) == rel


# ---------------------------------------------------------------------- #
# energy accounting
# ---------------------------------------------------------------------- #
class TestEnergy:
    @given(static=st.floats(0.0, 5.0), dyn=st.floats(0.1, 5.0),
           alpha=st.floats(1.0, 3.0))
    @settings(max_examples=20, deadline=None)
    def test_decomposition_identity(self, mapping, platform,
                                    static, dyn, alpha):
        """energy(plan) == Σ per-block dynamic + Σ per-proc static."""
        pw = platform.with_power(
            {j: ProcPower(static, dyn, alpha) for j in range(platform.k)})
        e = schedule_energy(mapping, pw)
        assert e.total == pytest.approx(
            sum(e.per_block_dynamic.values())
            + sum(e.per_proc_static.values()), rel=1e-12)
        assert e.dynamic == pytest.approx(
            sum(e.per_block_dynamic.values()), rel=1e-12)
        assert e.static == pytest.approx(
            sum(e.per_proc_static.values()), rel=1e-12)

    def test_block_dynamic_closed_form(self, mapping, platform):
        pw = platform.with_power(
            {j: ProcPower(0.0, 3.0, 2.0) for j in range(platform.k)})
        e = schedule_energy(mapping, pw)
        q = mapping.quotient
        for v, ev in e.per_block_dynamic.items():
            s = platform.procs[q.proc[v]].speed
            assert ev == pytest.approx(3.0 * q.weight[v] * s)  # (α-1)=1

    def test_dvfs_scaling_saves_dynamic_energy(self, mapping, platform):
        pw = platform.with_power(
            {j: ProcPower(0.0, 2.0, 2.0) for j in range(platform.k)})
        nominal = schedule_energy(mapping, pw)
        half = schedule_energy(
            mapping, pw,
            speed_of_block={v: 0.5 for v in mapping.quotient.members})
        assert half.dynamic == pytest.approx(0.5 * nominal.dynamic)
        assert half.horizon == pytest.approx(2 * nominal.horizon)

    def test_json_roundtrip(self, mapping, platform):
        e = schedule_energy(mapping, _modeled(platform),
                            reliability_floor=0.9)
        assert EnergyReport.from_dict(e.to_dict()) == e


class TestEnergyPlan:
    def test_floor_met_or_none(self, mapping, platform):
        pf = _modeled(platform)
        plan = energy_plan(mapping, pf, reliability_floor=0.95,
                           speed_levels=(0.5, 0.75, 1.0))
        assert plan is not None
        assert plan.reliability >= 0.95
        # greedy only raises speeds above the all-lowest start
        assert all(0.5 <= f <= 1.0 for f in plan.speed_of_block.values())

    def test_unconstrained_runs_lowest_level(self, mapping, platform):
        pf = _modeled(platform)
        plan = energy_plan(mapping, pf, speed_levels=(0.25, 1.0))
        assert set(plan.speed_of_block.values()) == {0.25}

    def test_unreachable_floor_is_none(self, mapping, platform):
        hot = platform.with_failure_rates(
            {j: 0.5 for j in range(platform.k)}).with_power(
            {j: ProcPower(1.0, 1.0) for j in range(platform.k)})
        assert energy_plan(mapping, hot,
                           reliability_floor=0.999999) is None

    def test_bad_levels_rejected(self, mapping, platform):
        with pytest.raises(ValueError):
            energy_plan(mapping, _modeled(platform),
                        speed_levels=(0.0,))
        with pytest.raises(ValueError):
            energy_plan(mapping, _modeled(platform),
                        speed_levels=(1.5,))


# ---------------------------------------------------------------------- #
# sim-side accounting (per-proc busy integrals)
# ---------------------------------------------------------------------- #
class TestSimEnergy:
    def test_attached_when_modeled(self, mapping, platform):
        pf = _modeled(platform)
        sim = simulate(mapping, pf)
        assert sim.energy is not None
        acc = energy_from_sim(sim, pf)
        assert sim.energy == acc
        assert acc["total"] == pytest.approx(
            sum(acc["dynamic"].values()) + sum(acc["static"].values()),
            rel=1e-12)
        assert 0 < acc["success_prob"] <= 1

    def test_absent_without_model(self, mapping, platform):
        assert simulate(mapping, platform).energy is None

    def test_matches_analytic_at_nominal(self, mapping, platform):
        """Deterministic replay: per-proc busy integrals equal the sum
        of block durations, so sim dynamic energy == analytic dynamic
        energy (statics differ only via horizon vs makespan)."""
        pf = _modeled(platform)
        sim = simulate(mapping, pf)
        analytic = schedule_energy(mapping, pf)
        assert sum(sim.energy["dynamic"].values()) == pytest.approx(
            analytic.dynamic, rel=1e-9)
        hazard_sim = sim.energy["hazard"]
        rel = schedule_reliability(mapping, pf)
        assert hazard_sim == pytest.approx(rel.hazard, rel=1e-9)

    def test_json_roundtrip(self, mapping, platform):
        from repro.sim import SimReport

        sim = simulate(mapping, _modeled(platform))
        assert SimReport.from_json(sim.to_json()).energy == sim.energy


# ---------------------------------------------------------------------- #
# objective stages: registration, sweep, inertness, infeasibility
# ---------------------------------------------------------------------- #
class TestObjectiveStages:
    def test_registered_pipelines(self):
        from repro.core.scheduler import PIPELINES

        assert PIPELINES["reliability"][-1] == "reliability"
        assert PIPELINES["energy"][-1] == "energy"

    def test_bit_inert_without_models(self, wf, platform):
        base = Scheduler(SchedulerConfig()).schedule(wf, platform)
        for algo in ("reliability", "energy"):
            rep = Scheduler(SchedulerConfig(),
                            algorithm=algo).schedule(wf, platform)
            assert rep.makespan == base.makespan
            assert rep.best.extras.get(algo) is None
            assert [p.makespan for p in rep.sweep] == \
                [p.makespan for p in base.sweep]

    def test_reliability_reported_on_schedule_report(self, wf, platform):
        pf = _modeled(platform)
        rep = Scheduler(SchedulerConfig(),
                        algorithm="reliability").schedule(wf, pf)
        assert rep.feasible
        assert rep.reliability is not None
        assert 0 < rep.reliability.success_prob <= 1

    def test_parallel_sweep_matches_serial(self, wf, platform):
        pf = _modeled(platform)
        serial = plan_reliability(wf, pf, workers=1)
        par = plan_reliability(wf, pf, workers=2)
        assert serial.reliability.weighted_makespan == pytest.approx(
            par.reliability.weighted_makespan)
        assert serial.k_prime == par.k_prime

    def test_plan_reliability_picks_weighted_winner(self, wf, platform):
        pf = _modeled(platform)
        res = plan_reliability(wf, pf)
        assert res.feasible
        best_w = res.reliability.weighted_makespan
        for p in res.report.sweep:
            if not p.feasible:
                continue
            h = p.metrics.get("histograms", {}).get(
                "objective_rel_weighted_ms")
            if h and h.get("count"):
                assert best_w <= h["sum"] + 1e-9

    def test_plan_energy_floor_and_infeasibility(self, wf, platform):
        pf = _modeled(platform)
        ok = plan_energy(wf, pf, reliability_floor=0.9,
                         speed_levels=(0.5, 1.0))
        assert ok.feasible and ok.energy.reliability >= 0.9
        hot = platform.with_failure_rates(
            {j: 0.5 for j in range(platform.k)}).with_power(
            {j: ProcPower(1.0, 1.0) for j in range(platform.k)})
        bad = plan_energy(wf, hot, reliability_floor=0.999999)
        assert not bad.feasible
        assert bad.report.infeasibility is not None
        assert bad.report.infeasibility.stage == "objective"

    def test_objective_metrics_observed(self, wf, platform):
        pf = _modeled(platform)
        rep = Scheduler(SchedulerConfig(),
                        algorithm="energy").schedule(wf, pf)
        hists = rep.metrics.get("histograms", {})
        assert "objective_energy_total" in hists
        assert "objective_success_prob" in hists


# ---------------------------------------------------------------------- #
# checkpoint-cost-aware migration pricing
# ---------------------------------------------------------------------- #
class TestCheckpointPricing:
    def _timeline(self, price_migration):
        from repro.scenario import ProcFailure, Scenario, run_scenario

        plat = default_cluster()
        w = generate_workflow("genome", 150, seed=5, platform=plat)
        sc = Scenario(w, plat, [ProcFailure(time=30.0, procs={0})])
        return run_scenario(sc, policy="pinned-warm-start",
                            config=SchedulerConfig(simulate=True),
                            price_migration=price_migration), w

    def test_decisions_in_migration_log(self):
        tl, _ = self._timeline(False)
        assert tl.feasible
        assert tl.migrations, "failure must trigger a replan"
        decs = [d for m in tl.migrations for d in m.checkpoint_decisions]
        for d in decs:
            assert d["decision"] in ("restart-in-place", "migrate")
            assert d["restart_cost"] > 0
            assert d["inputs_volume"] >= 0
            assert not d["applied"]  # advisory without price_migration
        # round-trips with the rest of the record
        from repro.scenario import MigrationRecord

        for m in tl.migrations:
            rt = MigrationRecord.from_dict(m.to_dict())
            assert rt.checkpoint_decisions == m.checkpoint_decisions

    def test_price_migration_unpins_winners(self):
        tl, w = self._timeline(True)
        assert tl.feasible
        decs = [d for m in tl.migrations for d in m.checkpoint_decisions]
        for d in decs:
            assert d["applied"] == (d["decision"] == "migrate")
        # invariants still hold with pricing applied
        assert tl.validate(memory_trace=True) == []
        last = tl.segments[-1]
        assert last.completed_before + last.n_tasks == w.n

    def test_pricing_prefers_restart_on_uniform_platform(self):
        """With equal speeds, migrating can never beat restarting in
        place (same compute cost + a transfer)."""
        from repro.scenario import ProcFailure, Scenario, run_scenario

        plat = Platform([Processor(f"u{j}", 1.0, 256.0)
                         for j in range(4)], bandwidth=1.0, name="uni")
        w = generate_workflow("genome", 100, seed=9, platform=plat)
        sc = Scenario(w, plat, [ProcFailure(time=20.0, procs={0})])
        tl = run_scenario(sc, policy="pinned-warm-start",
                          config=SchedulerConfig(simulate=True))
        for m in tl.migrations:
            for d in m.checkpoint_decisions:
                assert d["decision"] == "restart-in-place"
