"""Property tests: platform transform composition & reindexing.

The elastic transforms — ``without(failed)``, ``with_speed``,
``with_link_bandwidth``, ``with_processors`` — are the building blocks
of :mod:`repro.scenario` event application.  These tests pin down the
composition contract: applying a random event sequence in any
interleaving (tracking indices through each event's ``proc_map``)
yields the same surviving processors (by name, speed, memory) and the
same per-link bandwidth configuration.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.core import Platform, ProcPower, Processor
from repro.scenario import (
    LinkDegrade,
    ProcArrival,
    ProcFailure,
    SpeedChange,
)


def _platform(k=8):
    return Platform(
        [Processor(f"p{i}", float(2 + i), float(10 + 4 * i))
         for i in range(k)],
        bandwidth=1.0, name="prop",
        link_bandwidth={(0, 1): 0.5, (1, 0): 0.5, (2, 5): 3.0},
        failure_rates={0: 1e-3, 3: 5e-4, 5: 2e-3},
        power={1: ProcPower(0.5, 2.0), 5: ProcPower(1.0, 3.0, 2.5)},
    )


def _signature(plat: Platform):
    """Index-free fingerprint: processors by name + links by name pair
    + failure/power models by name."""
    procs = {p.name: (p.speed, p.memory) for p in plat.procs}
    links = {
        (plat.procs[a].name, plat.procs[b].name): bw
        for (a, b), bw in plat.link_bandwidth.items()
    }
    rates = {plat.procs[j].name: lam
             for j, lam in plat.failure_rates.items()}
    power = {plat.procs[j].name: pw.to_list()
             for j, pw in plat.power.items()}
    return procs, links, plat.bandwidth, rates, power


@st.composite
def _event_specs(draw):
    """Abstract event specs referencing processors by *original* name,
    so the same sequence can be lowered at different positions."""
    n_ops = draw(st.integers(min_value=1, max_value=6))
    ops = []
    fresh = 0
    for _ in range(n_ops):
        kind = draw(st.sampled_from(
            ["fail", "speed", "link", "arrive"]))
        if kind == "fail":
            ops.append(("fail", draw(st.integers(0, 7))))
        elif kind == "speed":
            ops.append(("speed", draw(st.integers(0, 7)),
                        draw(st.floats(0.1, 4.0))))
        elif kind == "link":
            a = draw(st.integers(0, 7))
            b = draw(st.integers(0, 7))
            if a == b:
                b = (b + 1) % 8
            ops.append(("link", a, b, draw(st.floats(0.05, 5.0)),
                        draw(st.booleans())))
        else:
            ops.append(("arrive", f"new{fresh}",
                        draw(st.floats(1.0, 8.0)),
                        draw(st.floats(8.0, 64.0))))
            fresh += 1
    return ops


def _apply(ops, plat):
    """Lower name-based specs onto ``plat``, tracking the index map."""
    name_to_idx = {p.name: j for j, p in enumerate(plat.procs)}
    cur = plat
    for op in ops:
        if op[0] == "fail":
            j = name_to_idx.get(f"p{op[1]}")
            if j is None or cur.k <= 1:
                continue  # already failed (idempotent spec)
            ev = ProcFailure(0.0, frozenset({j}))
        elif op[0] == "speed":
            j = name_to_idx.get(f"p{op[1]}")
            if j is None:
                continue  # speed change on a dead processor: no-op
            ev = SpeedChange(0.0, proc=j, factor=op[2])
        elif op[0] == "link":
            a = name_to_idx.get(f"p{op[1]}")
            b = name_to_idx.get(f"p{op[2]}")
            if a is None or b is None:
                continue  # link to a dead processor: no-op
            ev = LinkDegrade(0.0, src=a, dst=b, bandwidth=op[3],
                             symmetric=op[4])
        else:
            ev = ProcArrival(0.0, (Processor(op[1], op[2], op[3]),))
        cur, m = ev.apply(cur)
        name_to_idx = {
            name: m[j]
            for name, j in name_to_idx.items() if m[j] is not None
        }
        for j, p in enumerate(cur.procs):
            name_to_idx.setdefault(p.name, j)
    return cur


class TestTransformComposition:
    @given(ops=_event_specs())
    @settings(max_examples=60, deadline=None)
    def test_order_of_commuting_prefixes(self, ops):
        """Speed/link ops commute with each other and with failures of
        *other* processors: front-loading them before the failures
        yields the same signature as the drawn interleaving."""
        plat = _platform()
        mixed = _apply(ops, plat)
        fails = [op for op in ops if op[0] == "fail"]
        rest = [op for op in ops if op[0] != "fail"]
        front = _apply(rest + fails, plat)
        assert _signature(mixed) == _signature(front)

    @given(ops=_event_specs())
    @settings(max_examples=60, deadline=None)
    def test_proc_map_tracks_identity(self, ops):
        """Every surviving processor keeps its name/memory through any
        sequence, and the tracked index always points at it."""
        plat = _platform()
        cur = _apply(ops, plat)
        names = [p.name for p in cur.procs]
        assert len(names) == len(set(names))
        orig = {p.name: p for p in plat.procs}
        for p in cur.procs:
            if p.name in orig:
                assert p.memory == orig[p.name].memory

    @given(ops=_event_specs())
    @settings(max_examples=40, deadline=None)
    def test_links_never_dangle(self, ops):
        plat = _platform()
        cur = _apply(ops, plat)
        for (a, b) in cur.link_bandwidth:
            assert 0 <= a < cur.k and 0 <= b < cur.k

    def test_failure_reindexes_links_and_speed_composes(self):
        plat = _platform()
        # degrade link p2<->p5, slow p5, then fail p0..p1: the link and
        # the slowdown must follow p2/p5 to their compacted indices
        cur, m1 = LinkDegrade(0.0, src=2, dst=5,
                              bandwidth=0.25).apply(plat)
        cur, m2 = SpeedChange(0.0, proc=5, factor=0.5).apply(cur)
        cur, m3 = ProcFailure(0.0, frozenset({0, 1})).apply(cur)
        j2, j5 = m3[m2[m1[2]]], m3[m2[m1[5]]]
        assert cur.procs[j2].name == "p2" and cur.procs[j5].name == "p5"
        assert cur.bandwidth_between(j2, j5) == 0.25
        assert cur.bandwidth_between(j5, j2) == 0.25
        assert cur.speed(j5) == pytest.approx(plat.speed(5) * 0.5)
        # and the same end state when the failure comes first
        alt, n1 = ProcFailure(0.0, frozenset({0, 1})).apply(plat)
        alt, n2 = LinkDegrade(0.0, src=n1[2], dst=n1[5],
                              bandwidth=0.25).apply(alt)
        alt, _ = SpeedChange(0.0, proc=n2[n1[5]], factor=0.5).apply(alt)
        assert _signature(alt) == _signature(cur)


class TestModelCarrying:
    """``failure_rates`` / ``power`` ride the elastic transforms exactly
    like ``link_bandwidth``: preserved by index-stable transforms,
    reindexed by ``without``, dropped with their processor."""

    def test_without_with_speed_with_processors_compose(self):
        plat = _platform()
        cur = plat.with_processors([Processor("new0", 3.0, 32.0)])
        cur = cur.with_speed(5, 0.5)
        cur = cur.without({0, 1})
        # p0's failure rate died with p0; p3/p5's followed the reindex
        rates = {cur.procs[j].name: lam
                 for j, lam in cur.failure_rates.items()}
        assert rates == {"p3": 5e-4, "p5": 2e-3}
        power = {cur.procs[j].name: pw
                 for j, pw in cur.power.items()}
        assert power == {"p5": ProcPower(1.0, 3.0, 2.5)}
        # the speed change neither moved nor scaled the models
        idx = {p.name: j for j, p in enumerate(cur.procs)}
        assert cur.failure_rate(idx["p3"]) == 5e-4
        assert cur.proc_power(idx["p5"]).busy_watts(1.0) == 4.0

    def test_order_independence_direct(self):
        plat = _platform()
        a = plat.with_processors([Processor("x", 1.0, 16.0)]) \
                .with_speed(2, 2.0).without({4})
        b = plat.without({4}).with_speed(2, 2.0) \
                .with_processors([Processor("x", 1.0, 16.0)])
        assert _signature(a) == _signature(b)

    @given(failed=st.sets(st.integers(0, 7), min_size=1, max_size=6))
    @settings(max_examples=40, deadline=None)
    def test_without_reindexes_models(self, failed):
        plat = _platform()
        cur = plat.without(failed)
        surviving = {p.name for p in cur.procs}
        want_rates = {plat.procs[j].name: lam
                      for j, lam in plat.failure_rates.items()
                      if plat.procs[j].name in surviving}
        got_rates = {cur.procs[j].name: lam
                     for j, lam in cur.failure_rates.items()}
        assert got_rates == want_rates
        want_power = {plat.procs[j].name: pw
                      for j, pw in plat.power.items()
                      if plat.procs[j].name in surviving}
        got_power = {cur.procs[j].name: pw
                     for j, pw in cur.power.items()}
        assert got_power == want_power

    def test_with_merge_semantics(self):
        plat = _platform()
        p2 = plat.with_failure_rates({1: 9e-9})
        assert p2.failure_rates == {0: 1e-3, 1: 9e-9, 3: 5e-4, 5: 2e-3}
        p3 = plat.with_failure_rates({1: 9e-9}, merge=False)
        assert p3.failure_rates == {1: 9e-9}
        with pytest.raises(ValueError):
            plat.with_failure_rates({99: 1e-3})
        with pytest.raises(ValueError):
            plat.with_failure_rates({0: -1.0})
        with pytest.raises(TypeError):
            plat.with_power({0: "not a ProcPower"})
