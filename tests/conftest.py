"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py requests 512
placeholder devices (in its own process)."""
import numpy as np
import pytest

from repro.core import Platform, Processor, Workflow
from repro.obs.metrics import METRICS


@pytest.fixture(autouse=True)
def _isolate_metrics():
    """Snapshot/restore the global metrics registry (COUNTERS included
    — it aliases ``METRICS.counters``) around every test, so tests
    that read counter deltas never see another test's increments."""
    snap = METRICS.snapshot()
    yield
    METRICS.restore(snap)


@pytest.fixture
def diamond() -> Workflow:
    """1 → {2, 3} → 4 diamond with distinct weights."""
    wf = Workflow(4)
    wf.work[:] = [4.0, 1.0, 3.0, 1.0]
    wf.mem[:] = [2.0, 1.0, 1.0, 2.0]
    wf.add_edge(0, 1, 1.0)
    wf.add_edge(0, 2, 2.0)
    wf.add_edge(1, 3, 1.0)
    wf.add_edge(2, 3, 1.0)
    return wf


@pytest.fixture
def unit_platform() -> Platform:
    return Platform([Processor(f"p{i}", 1.0, 1e9) for i in range(4)], 1.0)


def make_random_dag(n: int, seed: int, p: float = 0.3) -> Workflow:
    rng = np.random.default_rng(seed)
    wf = Workflow(n)
    for u in range(n):
        wf.work[u] = float(rng.uniform(1, 100))
        wf.mem[u] = float(rng.uniform(1, 50))
    for u in range(n):
        for v in range(u + 1, n):
            if rng.random() < p:
                wf.add_edge(u, v, float(rng.uniform(1, 10)))
    return wf
