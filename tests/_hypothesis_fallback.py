"""Minimal stand-in for the optional ``hypothesis`` dependency.

The container image does not ship ``hypothesis``; rather than skip every
property test, this module provides a tiny seeded-random implementation
of the small API surface the test-suite uses:

* ``st.integers / floats / booleans / sampled_from / lists / sets /
  composite``
* ``@given(...)`` — runs the test body ``max_examples`` times with
  pseudo-random draws (deterministic: seeded per test name),
* ``@settings(max_examples=..., deadline=...)`` — honoured for
  ``max_examples``; ``deadline`` is ignored.

No shrinking, no database, no edge-case heuristics — this is a smoke
fallback, not a replacement.  Test modules import it as::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                      # pragma: no cover
        from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

import functools
import inspect
import random
from types import SimpleNamespace


class _Strategy:
    """A strategy is just a callable drawing one value from an RNG."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value: float = 0.0, max_value: float = 1.0,
            allow_nan: bool = True, allow_infinity: bool = True) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq))


def _lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
           unique: bool = False) -> _Strategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        if not unique:
            return [elements.example(rng) for _ in range(n)]
        out: list = []
        seen: set = set()
        for _ in range(100 * (n + 1)):  # bounded retry for uniqueness
            if len(out) >= n:
                break
            v = elements.example(rng)
            if v not in seen:
                seen.add(v)
                out.append(v)
        return out

    return _Strategy(draw)


def _sets(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    lst = _lists(elements, min_size, max_size, unique=True)
    return _Strategy(lambda rng: set(lst.example(rng)))


def _composite(fn):
    """``@st.composite`` — ``fn(draw, *args)`` becomes a strategy factory."""

    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def draw_value(rng: random.Random):
            def draw(strategy: _Strategy):
                return strategy.example(rng)

            return fn(draw, *args, **kwargs)

        return _Strategy(draw_value)

    return factory


st = SimpleNamespace(
    integers=_integers,
    floats=_floats,
    booleans=_booleans,
    sampled_from=_sampled_from,
    lists=_lists,
    sets=_sets,
    composite=_composite,
)

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording ``max_examples`` for a later ``@given``."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the wrapped test repeatedly with seeded pseudo-random draws."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # `@settings` above `@given` marks the wrapper; below, the fn.
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"fallback:{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn_args = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn_args, **kwargs, **drawn_kw)

        # `@settings` may be applied *above* `@given`; re-export the mark.
        wrapper._fallback_max_examples = getattr(
            fn, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
        # Hide the drawn parameters from pytest (it would otherwise look
        # for fixtures named after them).  Drawn positionals fill the
        # *last* positional slots; drawn keywords are removed by name.
        params = list(inspect.signature(fn).parameters.values())
        if arg_strategies:
            params = params[: -len(arg_strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        wrapper.__wrapped__ = None
        del wrapper.__wrapped__
        return wrapper

    return deco
