"""Substrate tests: data pipeline, checkpointing, fault-tolerant
training, straggler handling, scheduler-driven placement + elastic."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, load_pytree, save_pytree
from repro.configs import get_smoke_config, shape_by_name
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, Prefetcher, SyntheticTokens, host_slice
from repro.runtime import (
    FailureInjector,
    SimulatedFault,
    StragglerMonitor,
    Trainer,
    TrainerConfig,
    rescale_plan,
    run_with_restarts,
)

TINY = ShapeConfig("tiny_train", seq_len=16, global_batch=4, kind="train")


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=3)
        a = SyntheticTokens(cfg).batch_at(7)
        b = SyntheticTokens(cfg).batch_at(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
        batch = SyntheticTokens(cfg).batch_at(0)
        np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                      batch["labels"][:, :-1])

    def test_host_sharding_partitions_batch(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
        parts = [SyntheticTokens(cfg, host_id=h, n_hosts=4).batch_at(5)
                 for h in range(4)]
        assert all(p["tokens"].shape[0] == 2 for p in parts)

    def test_host_slice_validates(self):
        with pytest.raises(ValueError):
            host_slice(10, 0, 3)

    def test_prefetcher_delivers_in_order(self):
        pf = Prefetcher(iter(range(10)), depth=2)
        got = [pf.get() for _ in range(10)]
        assert got == list(range(10))
        pf.close()


class TestCheckpoint:
    def test_roundtrip_with_bf16(self, tmp_path):
        tree = {
            "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": [{"b": jnp.ones((4,), jnp.bfloat16)},
                       {"c": jnp.zeros((2, 2), jnp.int32)}],
        }
        path = tmp_path / "ck.msgpack"
        save_pytree(path, tree, {"step": 5})
        loaded = load_pytree(path, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype

    def test_retention_and_latest(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            ck.save(s, {"x": jnp.full((2,), s)})
        assert ck.steps() == [3, 4]
        tree, meta = ck.restore({"x": jnp.zeros((2,))})
        assert meta["step"] == 4
        assert float(tree["x"][0]) == 4.0

    def test_async_save_visible_after_wait(self, tmp_path):
        ck = Checkpointer(tmp_path, async_save=True)
        ck.save(7, {"x": jnp.ones((3,))})
        ck.wait()
        assert ck.latest_step() == 7

    def test_no_tmp_left_behind(self, tmp_path):
        ck = Checkpointer(tmp_path, async_save=False)
        ck.save(1, {"x": jnp.ones((2,))})
        assert not list(tmp_path.glob("*.tmp"))


import jax  # noqa: E402  (used by TestCheckpoint above)


def make_trainer(tmp_path, injector=None, steps=6):
    cfg = get_smoke_config("llama3_8b")
    tcfg = TrainerConfig(steps=steps, ckpt_every=2, ckpt_dir=str(tmp_path),
                         async_ckpt=False)
    return Trainer(cfg, TINY, tcfg, attn_chunk=8, injector=injector)


class TestTrainer:
    def test_runs_and_loss_finite(self, tmp_path):
        t = make_trainer(tmp_path)
        hist = t.run()
        assert len(hist["loss"]) == 6
        assert all(np.isfinite(x) for x in hist["loss"])
        # training on repeated synthetic data should not increase loss
        assert hist["loss"][-1] <= hist["loss"][0] * 1.2

    def test_checkpoint_restart_resumes(self, tmp_path):
        t = make_trainer(tmp_path, steps=4)
        t.run()
        t2 = make_trainer(tmp_path, steps=8)
        hist = t2.run()
        assert hist["restarted_at"] == 4
        assert hist["step"][0] == 4 and hist["step"][-1] == 7

    def test_fault_injection_and_supervised_restart(self, tmp_path):
        calls = {"restarts": 0}
        # one injector across restarts: the fault fires once (a real
        # lost host does not come back deterministically every run)
        inj = FailureInjector(fail_at_steps=(3,), max_failures=1)

        def make_state():
            return make_trainer(tmp_path, injector=inj, steps=6)

        def run(trainer):
            return trainer.run()

        def on_restart(n):
            calls["restarts"] = n

        hist, restarts = run_with_restarts(make_state, run,
                                           on_restart=on_restart)
        assert restarts == 1
        assert calls["restarts"] == 1
        # resumed from the step-2 checkpoint, finished all 6 steps
        assert hist["step"][-1] == 5
        assert hist["restarted_at"] >= 2

    def test_gives_up_after_max_restarts(self, tmp_path):
        def make_state():
            inj = FailureInjector(fail_at_steps=(0,), max_failures=99)
            return make_trainer(tmp_path / "x", injector=inj, steps=3)

        with pytest.raises(SimulatedFault):
            run_with_restarts(make_state, lambda t: t.run(),
                              max_restarts=2)


class TestStragglers:
    def test_flags_slow_host(self):
        mon = StragglerMonitor(threshold=1.5)
        for _ in range(8):
            mon.record(0, 1.0)
            mon.record(1, 1.05)
            mon.record(2, 3.0)
        assert mon.stragglers() == [2]

    def test_degraded_platform_feeds_scheduler(self):
        from repro.core.platform import Platform, Processor
        mon = StragglerMonitor(threshold=1.5)
        for _ in range(8):
            mon.record(0, 1.0)
            mon.record(1, 4.0)
        plat = Platform([Processor("a", 100.0, 10.0),
                         Processor("b", 100.0, 10.0)], 1.0)
        degraded = mon.degraded_platform(plat, host_of_proc=lambda j: j)
        assert degraded.procs[0].speed == pytest.approx(100.0)
        assert degraded.procs[1].speed == pytest.approx(25.0)


class TestAutoshardElastic:
    def _fleet(self, n_v5e=48, n_v4=16):
        from repro.core.platform import tpu_fleet_si
        return tpu_fleet_si({"v5e": n_v5e, "v4": n_v4})

    def test_plan_valid_and_expert_spread(self):
        from repro.configs import get_config
        from repro.core.autoshard import plan
        cfg = get_config("mixtral_8x7b")
        p = plan(cfg, shape_by_name("decode_32k"), self._fleet(),
                 kprime=[8, 16, 32, 64])
        assert p is not None and p.valid
        assert p.n_stages > 1
        # experts of one layer spread over >1 stage (emergent EP)
        stages_l0 = {p.expert_placement[(0, e)] for e in range(8)}
        assert len(stages_l0) >= 1
        assert len(set(p.expert_placement.values())) > 4

    def test_baseline_algo_also_plans(self):
        from repro.configs import get_config
        from repro.core.autoshard import plan
        cfg = get_config("olmoe_1b_7b")
        p = plan(cfg, shape_by_name("decode_32k"), self._fleet(),
                 algo="dag_het_mem")
        assert p is not None and p.valid

    def test_infeasible_fleet_returns_none(self):
        from repro.configs import get_config
        from repro.core.autoshard import plan
        cfg = get_config("jamba_15_large")   # 400B params
        p = plan(cfg, shape_by_name("decode_32k"),
                 self._fleet(n_v5e=4, n_v4=0), kprime=[1, 2, 4])
        assert p is None

    def test_elastic_rescale_replans(self):
        # olmoe decode_32k holds ~550 GB of (MHA) KV cache: a 32-chip
        # fleet is ~88% full and correctly infeasible for the heuristic;
        # use a 64-chip fleet with headroom for the post-failure re-plan.
        from repro.configs import get_config
        cfg = get_config("olmoe_1b_7b")
        plat = self._fleet(48, 16)
        report = rescale_plan(cfg, shape_by_name("decode_32k"), plat,
                              failed={0, 1, 2, 3},
                              kprime=[16, 32, 48, 64])
        assert report.feasible
        assert report.new_plan.valid
        assert report.est_step_after_s > 0
        # the surviving platform has fewer processors than before
        assert report.new_plan.mapping.platform.k == plat.k - 4
