"""Multi-tenant scheduler-as-a-service: quotas, plan cache, events.

Three tenants share one cluster through `repro.service`.  Alice (a
weight-2 tenant) and Bob submit real scientific pipelines — Alice
resubmits hers, so her repeats hit the plan cache and replay without a
k' sweep; Mallory submits garbage that admission turns into structured
rejections, never exceptions.  Mid-run, two of the big-memory
processors fail (affected jobs freeze their completed prefix and
warm-start replan on what they still own) and a spare node arrives
later (new capacity dispatches waiting jobs, disturbing nobody).

Prints the per-job outcome table, the plan-cache economics, and the
stitched multi-job Gantt with event markers.

Run:  PYTHONPATH=src python examples/multi_tenant_service.py
"""
from repro.core import default_cluster, generate_workflow
from repro.core.platform import Processor
from repro.core.scheduler import SchedulerConfig
from repro.scenario import ProcArrival, ProcFailure
from repro.service import (
    QuotaConfig,
    ServiceConfig,
    Submission,
    TenantQuota,
    run_service,
)


def main():
    plat = default_cluster()
    cfg = ServiceConfig(
        scheduler=SchedulerConfig(simulate=True, kprime=[2, 4, 6]),
        quotas=QuotaConfig(tenants={
            "alice": TenantQuota(weight=2.0),
            "bob": TenantQuota(max_running=1),
            "mallory": TenantQuota(max_tasks=500),
        }),
        name="demo")

    mk = lambda fam, n, s: generate_workflow(fam, n, seed=s,
                                             platform=plat)
    subs = [
        # alice's production pipelines, resubmitted (cache hits)
        Submission(mk("montage", 120, 1), tenant="alice",
                   arrival_t=0.0, name="mosaic"),
        Submission(mk("montage", 120, 1), tenant="alice",
                   arrival_t=40.0, name="mosaic"),
        Submission(mk("epigenomics", 100, 2), tenant="alice",
                   arrival_t=80.0, name="methyl"),
        Submission(mk("epigenomics", 100, 2), tenant="alice",
                   arrival_t=120.0, name="methyl"),
        # bob's one-offs
        Submission(mk("seismology", 90, 3), tenant="bob",
                   arrival_t=10.0, name="quake"),
        Submission(mk("blast", 80, 4), tenant="bob",
                   arrival_t=60.0, name="align"),
        # mallory's garbage: structured rejections
        Submission("{definitely not json", tenant="mallory",
                   arrival_t=5.0, name="junk"),
        Submission('{"workflow": {"specification": {"tasks": []}}}',
                   tenant="mallory", arrival_t=15.0, name="hollow"),
    ]
    events = [
        ProcFailure(time=300.0, procs={plat.k - 2, plat.k - 1}),
        ProcArrival(time=900.0,
                    procs=(Processor("spare-0", 2.5, 192.0),)),
    ]

    report = run_service(subs, plat, events, cfg)

    print("=== job outcomes ===")
    hdr = (f"{'job':10s} {'tenant':8s} {'status':10s} {'path':7s} "
           f"{'wait':>8s} {'makespan':>9s} {'replans':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for j in report.jobs:
        wait = f"{j.queue_wait:.0f}" if j.queue_wait is not None else "-"
        span = f"{j.makespan:.0f}" if j.makespan is not None else "-"
        why = ""
        if j.status == "rejected":
            why = f"  [{j.rejection['code']}]"
        print(f"{j.name:10s} {j.tenant:8s} {j.status:10s} "
              f"{j.planning_path or '-':7s} {wait:>8s} {span:>9s} "
              f"{j.n_replans:>7d}{why}")

    print("\n=== plan cache ===")
    cs = report.cache_stats
    print(f"hits={cs.get('service_cache_hits', 0)} "
          f"misses={cs.get('service_cache_misses', 0)} "
          f"stores={cs.get('service_cache_stores', 0)} "
          f"hit_rate={report.cache_hit_rate:.2f}")
    for path, walls in sorted(report.plan_wall_s.items()):
        ms = 1e3 * sum(walls) / len(walls)
        print(f"  {path:7s} planning: {ms:8.1f} ms avg over {len(walls)}")

    print(f"\nutilization: {report.utilization:.1%} of "
          f"{report.trace.n_procs} processors over "
          f"{report.trace.horizon:.0f} time units")
    print("\n=== stitched timeline ===")
    print(report.gantt(width=68))


if __name__ == "__main__":
    main()
