"""Sustained throughput: replicate, pipeline, find the saturation knee.

One genome workflow arrives over and over.  The paper's objective —
one instance's makespan — is the wrong number here; what matters is
instances/s sustained under the latency and memory bounds.  This
walkthrough plans the workflow for steady state with a deliberately
coarse partition (k'=3 leaves the big-memory processors free, so the
whole block group replicates onto a second dominance-matched group and
doubles the rate), checks the identity anchor (one instance at rate→0
reproduces `schedule(..., simulate=True)` bit-exactly), replays a
Poisson stream through `run_sustained` twice (the second run seeds
from the plan cache — no k' sweep), and walks an offered-rate ladder
until the pipeline saturates.

Prints the replication pay-off, the anchor check, per-rate achieved
throughput with latency percentiles, and the plan-cache economics.

Run:  PYTHONPATH=src python examples/sustained_throughput.py
"""
from repro.core import default_cluster, generate_workflow, schedule
from repro.service import PlanCache, run_sustained
from repro.throughput import (
    plan_throughput,
    replicate_plan,
    simulate_pipelined,
)


def main():
    plat = default_cluster()
    wf = generate_workflow("genome", 1000, seed=1, platform=plat)

    # --- steady state: coarse partition + replication ------------- #
    tr = plan_throughput(wf, plat, kprime=[3], workers=1)
    unrep = replicate_plan(tr.best, plat, max_replicas=1)
    print("=== steady-state plan (k'=3) ===")
    print(f"unreplicated: period {unrep.period:9.1f}  "
          f"rate {unrep.rate:.6f} inst/unit")
    print(f"replicated:   period {tr.plan.period:9.1f}  "
          f"rate {tr.plan.rate:.6f} inst/unit  "
          f"({tr.plan.n_replicas} groups, "
          f"{tr.plan.rate / unrep.rate:.2f}x)")
    for gi, g in enumerate(tr.plan.groups):
        names = sorted(plat.procs[p].name for p in g.procs)
        print(f"  group {gi}: {len(names)} procs, "
              f"latency {g.latency:.1f}  ({', '.join(names[:4])}"
              f"{', …' if len(names) > 4 else ''})")

    # --- identity anchor: one instance == the makespan path ------- #
    ref = schedule(wf, plat, kprime=[3], workers=1, simulate=True)
    solo = simulate_pipelined(ref.best, plat, arrivals=[0.0])
    print("\n=== identity anchor (rate→0) ===")
    print(f"schedule(simulate=True) makespan {ref.sim.makespan:.6f}")
    print(f"simulate_pipelined([0.0]) makespan "
          f"{solo.single_makespan:.6f}  "
          f"bit-equal: {solo.single_makespan == ref.sim.makespan}")

    # --- offered-rate ladder through the plan cache --------------- #
    cache = PlanCache()
    print("\n=== offered-rate ladder (32 Poisson arrivals/rung) ===")
    hdr = (f"{'offered':>10s} {'achieved':>10s} {'path':>7s} "
           f"{'p50':>9s} {'p99':>9s} {'sat?':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for frac in (0.3, 0.6, 0.9, 1.1):
        offered = frac * tr.plan.rate
        rep = run_sustained(wf, plat, rate=offered, n_instances=32,
                            seed=1, cache=cache, kprime=[3])
        pct = rep.instance_latency_percentiles
        sat = rep.instances_per_s < 0.95 * offered
        print(f"{offered:10.6f} {rep.instances_per_s:10.6f} "
              f"{rep.jobs[0].planning_path:>7s} "
              f"{pct['p50']:9.0f} {pct['p99']:9.0f} "
              f"{'yes' if sat else 'no':>5s}")
    print(f"analytic sustainable rate: {tr.plan.rate:.6f} "
          "(the 1.1x rung is past it — latency grows, achieved caps)")

    hits = rep.cache_stats.get("service_cache_hits", 0)
    print(f"\nplan cache: size {len(cache)}, last rung planned "
          f"'{rep.jobs[0].planning_path}' "
          f"({'hit' if hits else 'miss'}: the k' sweep ran only on "
          "the cold rung)")


if __name__ == "__main__":
    main()
