"""Pipeline parallelism end-to-end: the paper's scheduler decides the
stage split; the GPipe runner executes it.

Runs on 4 host-platform devices (set before jax import), builds a
4-stage MLP "model", trains it a few steps with gradients flowing
through the pipeline (collective_permute transposes give the backward
schedule for free).

Run:  PYTHONPATH=src python examples/pipeline_training.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Platform, Processor, Workflow, schedule
from repro.runtime.pipeline import pipeline_apply, stack_stage_params


def plan_stages(n_layers: int, n_stages: int) -> list[list[int]]:
    """Let the scheduler split a layer chain into pipeline stages."""
    wf = Workflow(name="mlp-chain")
    prev = None
    for i in range(n_layers):
        t = wf.add_task(work=1.0, mem=0.1, persistent=1.0,
                        label=f"layer{i}")
        if prev is not None:
            wf.add_edge(prev, t, 0.5)
        prev = t
    # memory: 2 layers of weights (1.0 each) + transient activations
    plat = Platform([Processor(f"d{i}", 1.0, n_layers / n_stages + 1.5)
                     for i in range(n_stages)], bandwidth=10.0)
    report = schedule(wf, plat, kprime=[n_stages])
    assert report.feasible, report.infeasibility
    res = report.best
    stages = [sorted(m) for m in res.quotient.members.values()]
    stages.sort(key=min)
    print(f"scheduler split {n_layers} layers into "
          f"{[len(s) for s in stages]} per stage "
          f"(makespan {res.makespan:.2f})")
    return stages


def main():
    n_layers, n_stages, d, batch = 8, 4, 32, 16
    stages = plan_stages(n_layers, n_stages)
    assert len(stages) == n_stages

    rng = np.random.default_rng(0)
    layers_per_stage = len(stages[0])
    params = stack_stage_params([
        {"w": jnp.asarray(
            rng.normal(size=(layers_per_stage, d, d)) / np.sqrt(d),
            jnp.float32)}
        for _ in range(n_stages)
    ])

    def stage_fn(p, x):
        def layer(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(layer, x, p["w"])
        return y

    mesh = jax.make_mesh((n_stages,), ("stage",))
    x = jnp.asarray(rng.normal(size=(batch, d)), jnp.float32)
    y_target = jnp.asarray(rng.normal(size=(batch, d)), jnp.float32)

    @jax.jit
    def train_step(params, x, y):
        def loss(p):
            out = pipeline_apply(stage_fn, p, x, mesh=mesh,
                                 microbatches=4)
            return ((out - y) ** 2).mean()
        l, g = jax.value_and_grad(loss)(params)
        params = jax.tree.map(lambda p, g: p - 0.1 * g, params, g)
        return params, l

    with mesh:
        losses = []
        for _ in range(20):
            params, l = train_step(params, x, y_target)
            losses.append(float(l))
    print(f"pipeline training: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
