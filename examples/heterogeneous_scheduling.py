"""The paper's experiment, interactively: how heterogeneity awareness
changes the mapping of a memory-constrained workflow.

Walks one workflow through all four DagHetPart steps, printing what
each step did, replays the winning mapping through the discrete-event
simulator (repro.sim: bit-exact paper model, link contention, jitter
envelope, a small Gantt), then sweeps cluster heterogeneity like the
paper's Fig. 4.

Run:  PYTHONPATH=src python examples/heterogeneous_scheduling.py
"""
from repro.core import (
    Scheduler,
    SchedulerConfig,
    bottom_weights,
    default_cluster,
    generate_workflow,
    less_het_cluster,
    more_het_cluster,
    no_het_cluster,
    schedule,
)
from repro.sim import simulate

SWEEP = [1, 4, 9, 19, 36]


def describe_mapping(tag, wf, report, plat):
    if not report.feasible:
        inf = report.infeasibility
        print(f"{tag}: no valid mapping "
              f"(stage '{inf.stage}': {inf.reason})")
        return
    res = report.best
    q = res.quotient
    print(f"{tag}: makespan {res.makespan:.1f} with {q.n_vertices} blocks")
    by_speed = {}
    for vid in q.vertices():
        p = plat.procs[q.proc[vid]]
        kind = p.name.rsplit("-", 1)[0]
        by_speed[kind] = by_speed.get(kind, 0) + len(q.members[vid])
    dist = ", ".join(f"{k}:{v}" for k, v in sorted(by_speed.items()))
    print(f"  tasks per processor kind: {dist}")


def main():
    plat = default_cluster()
    wf = generate_workflow("montage", 300, seed=2, platform=plat)
    print(f"workflow: montage, {wf.n} tasks, {wf.n_edges} edges\n")

    base = schedule(wf, plat, algorithm="dag_het_mem")
    describe_mapping("DagHetMem (memory-only baseline)", wf, base, plat)

    # the sweep reports through the on_sweep_result callback — the one
    # channel shared by verbose mode, benchmarks and the process pool
    print("DagHetPart k' sweep:")
    het = Scheduler(SchedulerConfig(
        kprime=SWEEP,
        on_sweep_result=lambda p: print(
            f"  k'={p.k_prime}: "
            + (f"makespan {p.makespan:.1f}" if p.feasible
               else f"infeasible at stage '{p.failed_stage}'")),
    )).schedule(wf, plat)
    describe_mapping("DagHetPart (heterogeneity-aware)", wf, het, plat)
    print(f"\nimprovement: {base.makespan / het.makespan:.2f}x\n")

    # -------------------------------------------------------------- #
    # execute the plan: the analytic makespan is a proxy, repro.sim
    # replays the schedule event by event
    # -------------------------------------------------------------- #
    print("simulated execution (repro.sim):")
    sim = simulate(het.best)
    print(f"  paper model: makespan {sim.makespan:.1f} "
          f"(bit-identical to analytic: {sim.makespan == het.makespan}; "
          f"memory trace feasible: {sim.memory.feasible})")
    cont = simulate(het.best, comm="fair-share", memory=False)
    print(f"  fair-share link contention: {cont.makespan:.1f} "
          f"({100 * cont.makespan / het.makespan - 100:+.1f}% vs analytic)")
    env = simulate(het.best, jitter=0.2, replicas=16,
                   memory=False, record_events=False).envelope
    print(f"  20% duration jitter (16 replicas): makespan in "
          f"[{env.lo:.1f}, {env.hi:.1f}], mean {env.mean:.1f}")

    small = generate_workflow("montage", 40, seed=2, platform=plat)
    srep = schedule(small, plat, kprime=[4], simulate=True)
    print(f"\nGantt of a 40-task montage mapping "
          f"(simulated makespan {srep.sim.makespan:.1f}):")
    print(srep.sim.gantt(width=60))
    print()

    print("heterogeneity sweep (paper Fig. 4):")
    for name, cl in (("NoHet", no_het_cluster()),
                     ("LessHet", less_het_cluster()),
                     ("default", default_cluster()),
                     ("MoreHet", more_het_cluster())):
        wfc = generate_workflow("montage", 300, seed=2, platform=cl)
        b = schedule(wfc, cl, algorithm="dag_het_mem")
        h = schedule(wfc, cl, kprime=SWEEP)
        if b.feasible and h.feasible:
            print(f"  {name:8s}: relative makespan "
                  f"{100 * h.makespan / b.makespan:5.1f}%")


if __name__ == "__main__":
    main()
