"""The paper's experiment, interactively: how heterogeneity awareness
changes the mapping of a memory-constrained workflow.

Walks one workflow through all four DagHetPart steps, printing what
each step did, then sweeps cluster heterogeneity like the paper's
Fig. 4.

Run:  PYTHONPATH=src python examples/heterogeneous_scheduling.py
"""
from repro.core import (
    bottom_weights,
    dag_het_mem,
    dag_het_part,
    default_cluster,
    generate_workflow,
    less_het_cluster,
    more_het_cluster,
    no_het_cluster,
)


def describe_mapping(tag, wf, res, plat):
    if res is None:
        print(f"{tag}: no valid mapping")
        return
    q = res.quotient
    print(f"{tag}: makespan {res.makespan:.1f} with {q.n_vertices} blocks")
    by_speed = {}
    for vid in q.vertices():
        p = plat.procs[q.proc[vid]]
        kind = p.name.rsplit("-", 1)[0]
        by_speed[kind] = by_speed.get(kind, 0) + len(q.members[vid])
    dist = ", ".join(f"{k}:{v}" for k, v in sorted(by_speed.items()))
    print(f"  tasks per processor kind: {dist}")


def main():
    plat = default_cluster()
    wf = generate_workflow("montage", 300, seed=2, platform=plat)
    print(f"workflow: montage, {wf.n} tasks, {wf.n_edges} edges\n")

    base = dag_het_mem(wf, plat)
    describe_mapping("DagHetMem (memory-only baseline)", wf, base, plat)
    het = dag_het_part(wf, plat, kprime=[1, 4, 9, 19, 36])
    describe_mapping("DagHetPart (heterogeneity-aware)", wf, het, plat)
    print(f"\nimprovement: {base.makespan / het.makespan:.2f}x\n")

    print("heterogeneity sweep (paper Fig. 4):")
    for name, cl in (("NoHet", no_het_cluster()),
                     ("LessHet", less_het_cluster()),
                     ("default", default_cluster()),
                     ("MoreHet", more_het_cluster())):
        wfc = generate_workflow("montage", 300, seed=2, platform=cl)
        b = dag_het_mem(wfc, cl)
        h = dag_het_part(wfc, cl, kprime=[1, 4, 9, 19, 36])
        if b and h:
            print(f"  {name:8s}: relative makespan "
                  f"{100 * h.makespan / b.makespan:5.1f}%")


if __name__ == "__main__":
    main()
