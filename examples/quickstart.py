"""Quickstart: the paper's scheduler + the framework in 60 seconds.

1. Map a memory-constrained workflow through the unified Scheduler
   API: the baseline (DagHetMem) and the four-step heuristic
   (DagHetPart) are stage pipelines behind one facade, every run
   returns a ScheduleReport (best mapping or a structured
   infeasibility, k'→makespan sweep trace, per-stage timings), and
   ``workers>1`` sweeps k' on a process pool — the paper's core
   experiment in miniature.
2. Lower one of the assigned architectures to a workflow DAG and let
   the same scheduler place it on a mixed TPU fleet.
3. Train a small LM for a few steps through the fault-tolerant runtime.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.configs import get_config, get_smoke_config, shape_by_name
from repro.configs.base import ShapeConfig
from repro.core import (
    Scheduler,
    SchedulerConfig,
    default_cluster,
    generate_workflow,
    schedule,
    validate_mapping,
)
from repro.core.autoshard import plan
from repro.core.platform import tpu_fleet_si
from repro.runtime import Trainer, TrainerConfig


def part1_paper_core():
    print("=== 1. DAGP-PM: baseline vs four-step heuristic ===")
    plat = default_cluster()
    wf = generate_workflow("blast", 400, seed=1, platform=plat)
    # one facade for both algorithms; reports are never None
    base = schedule(wf, plat, algorithm="dag_het_mem")
    het = Scheduler(SchedulerConfig(
        algorithm="dag_het_part", kprime=[1, 4, 9, 19, 36], workers=2,
    )).schedule(wf, plat)
    assert base.feasible and het.feasible
    assert validate_mapping(wf, base.best) == []
    assert validate_mapping(wf, het.best) == []
    print(f"workflow: blast, {wf.n} tasks on {plat.k} heterogeneous procs")
    print(f"DagHetMem  makespan: {base.makespan:10.1f}  "
          f"(blocks: {base.summary.k_used})")
    print(f"DagHetPart makespan: {het.makespan:10.1f}  "
          f"(blocks: {het.summary.k_used})")
    trace = ", ".join(
        f"k'={p.k_prime}:" + (f"{p.makespan:.0f}" if p.feasible else "inf")
        for p in het.sweep)
    print(f"sweep trace ({het.workers} workers): {trace}")
    slowest = max(het.stage_times, key=het.stage_times.get)
    print(f"stage timings: hottest stage '{slowest}' "
          f"({het.stage_times[slowest]:.2f}s of {het.total_time_s:.2f}s)")
    print(f"improvement: {base.makespan / het.makespan:.2f}x "
          f"(paper: 2.44x average)\n")


def part2_model_placement():
    print("=== 2. The scheduler as the framework's placement layer ===")
    cfg = get_config("mixtral_8x7b")
    fleet = tpu_fleet_si({"v5e": 48, "v4": 16})
    p = plan(cfg, shape_by_name("decode_32k"), fleet,
             kprime=[8, 16, 32, 64])
    print(f"mixtral-8x7b decode_32k on 64 mixed chips:")
    print(f"  stages: {p.n_stages}, valid: {p.valid}")
    print(f"  est step latency: {p.est_step_s * 1e3:.2f} ms")
    best_kp = p.report.summary.k_prime
    print(f"  k' sweep: {len(p.report.sweep)} attempts, "
          f"best at k'={best_kp}")
    spread = len(set(p.expert_placement.values()))
    print(f"  expert placement spread: {spread} stages "
          f"(emergent expert parallelism)\n")


def part3_training():
    print("=== 3. Fault-tolerant training on a reduced config ===")
    cfg = get_smoke_config("llama3_8b")
    shape = ShapeConfig("quickstart", seq_len=16, global_batch=4,
                        kind="train")
    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(cfg, shape,
                          TrainerConfig(steps=8, ckpt_every=4, ckpt_dir=d),
                          attn_chunk=8)
        hist = trainer.run()
    print(f"8 steps: loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")
    print("done.")


if __name__ == "__main__":
    part1_paper_core()
    part2_model_placement()
    part3_training()
