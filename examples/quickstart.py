"""Quickstart: the paper's scheduler + the framework in 60 seconds.

1. Generate a memory-constrained workflow, map it with the baseline
   (DagHetMem) and the four-step heuristic (DagHetPart), compare
   makespans — the paper's core experiment in miniature.
2. Lower one of the assigned architectures to a workflow DAG and let
   the same scheduler place it on a mixed TPU fleet.
3. Train a small LM for a few steps through the fault-tolerant runtime.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.configs import get_config, get_smoke_config, shape_by_name
from repro.configs.base import ShapeConfig
from repro.core import (
    dag_het_mem,
    dag_het_part,
    default_cluster,
    generate_workflow,
    validate_mapping,
)
from repro.core.autoshard import plan
from repro.core.platform import tpu_fleet_si
from repro.runtime import Trainer, TrainerConfig


def part1_paper_core():
    print("=== 1. DAGP-PM: baseline vs four-step heuristic ===")
    plat = default_cluster()
    wf = generate_workflow("blast", 400, seed=1, platform=plat)
    base = dag_het_mem(wf, plat)
    het = dag_het_part(wf, plat, kprime=[1, 4, 9, 19, 36])
    assert validate_mapping(wf, base) == []
    assert validate_mapping(wf, het) == []
    print(f"workflow: blast, {wf.n} tasks on {plat.k} heterogeneous procs")
    print(f"DagHetMem  makespan: {base.makespan:10.1f}  "
          f"(blocks: {base.k_used})")
    print(f"DagHetPart makespan: {het.makespan:10.1f}  "
          f"(blocks: {het.k_used})")
    print(f"improvement: {base.makespan / het.makespan:.2f}x "
          f"(paper: 2.44x average)\n")


def part2_model_placement():
    print("=== 2. The scheduler as the framework's placement layer ===")
    cfg = get_config("mixtral_8x7b")
    fleet = tpu_fleet_si({"v5e": 48, "v4": 16})
    p = plan(cfg, shape_by_name("decode_32k"), fleet,
             kprime=[8, 16, 32, 64])
    print(f"mixtral-8x7b decode_32k on 64 mixed chips:")
    print(f"  stages: {p.n_stages}, valid: {p.valid}")
    print(f"  est step latency: {p.est_step_s * 1e3:.2f} ms")
    spread = len(set(p.expert_placement.values()))
    print(f"  expert placement spread: {spread} stages "
          f"(emergent expert parallelism)\n")


def part3_training():
    print("=== 3. Fault-tolerant training on a reduced config ===")
    cfg = get_smoke_config("llama3_8b")
    shape = ShapeConfig("quickstart", seq_len=16, global_batch=4,
                        kind="train")
    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(cfg, shape,
                          TrainerConfig(steps=8, ckpt_every=4, ckpt_dir=d),
                          attn_chunk=8)
        hist = trainer.run()
    print(f"8 steps: loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")
    print("done.")


if __name__ == "__main__":
    part1_paper_core()
    part2_model_placement()
    part3_training()
