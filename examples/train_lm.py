"""End-to-end training driver: train a small LM with the full stack —
data pipeline, AdamW, async checkpointing, restart-on-failure.

Presets:
  tiny (default, ~1 min on CPU): 2-layer, ~0.3M params, 60 steps
  20m  (~15 min):                8-layer d=384, ~20M params, 100 steps
  100m (hour-scale; the deliverable-scale run for real hardware):
        12-layer d=768 GQA, ~103M params, 300 steps

Run:  PYTHONPATH=src python examples/train_lm.py [--preset tiny]
"""
import argparse
import time

from repro.configs.base import ModelConfig, ShapeConfig
from repro.runtime import FailureInjector, Trainer, TrainerConfig, \
    run_with_restarts

PRESETS = {
    "tiny": dict(
        cfg=ModelConfig("tiny-lm", "dense", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=2, d_ff=128,
                        vocab_size=512),
        shape=ShapeConfig("tiny", seq_len=32, global_batch=8,
                          kind="train"),
        steps=60,
    ),
    "20m": dict(
        cfg=ModelConfig("lm-20m", "dense", n_layers=8, d_model=384,
                        n_heads=6, n_kv_heads=2, d_ff=1024,
                        vocab_size=8192),
        shape=ShapeConfig("s20m", seq_len=128, global_batch=8,
                          kind="train"),
        steps=100,
    ),
    "100m": dict(
        cfg=ModelConfig("lm-100m", "dense", n_layers=12, d_model=768,
                        n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab_size=32768),
        shape=ShapeConfig("s100m", seq_len=256, global_batch=16,
                          kind="train"),
        steps=300,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-fault-at", type=int, default=None)
    args = ap.parse_args()

    preset = PRESETS[args.preset]
    cfg, shape = preset["cfg"], preset["shape"]
    steps = args.steps or preset["steps"]
    print(f"model: {cfg.name} ({cfg.total_params() / 1e6:.1f}M params), "
          f"{steps} steps of {shape.global_batch}x{shape.seq_len} tokens")

    injector = None
    if args.inject_fault_at is not None:
        injector = FailureInjector(fail_at_steps=(args.inject_fault_at,))

    def make_trainer():
        return Trainer(
            cfg, shape,
            TrainerConfig(steps=steps, ckpt_every=max(steps // 6, 5),
                          ckpt_dir=args.ckpt_dir),
            attn_chunk=64,
            injector=injector,
        )

    t0 = time.perf_counter()
    hist, restarts = run_with_restarts(make_trainer, lambda t: t.run())
    dt = time.perf_counter() - t0
    tok_s = len(hist["loss"]) * shape.global_batch * shape.seq_len / dt
    print(f"loss: {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f} "
          f"({dt:.1f}s, {tok_s:.0f} tok/s, {restarts} restarts)")
    assert hist["loss"][-1] < hist["loss"][0], "training did not improve"


if __name__ == "__main__":
    main()
