"""Elastic serving: mid-trace node failure → warm-start replan → serve on.

Simulates losing 8 chips of a 64-chip mixed fleet serving
mixtral-8x7b at 32k context *mid-execution* — the failure strikes
partway through the simulated schedule, completed work is frozen,
in-flight work is pinned in place, and only the residual is replanned
(`repro.scenario` through `rescale_plan`).  Prints the stitched Gantt
with the event marker and the migration summary, then demonstrates the
actual serving path (greedy decode) on a reduced config.

Run:  PYTHONPATH=src python examples/elastic_serving.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, shape_by_name
from repro.core.platform import tpu_fleet_si
from repro.launch.serve import greedy_decode
from repro.models import LM
from repro.runtime import rescale_plan


def part1_replan():
    print("=== elastic re-planning after mid-trace chip loss ===")
    cfg = get_config("mixtral_8x7b")
    fleet = tpu_fleet_si({"v5e": 48, "v4": 16})

    # probe the healthy step time to place the failure mid-step
    from repro.core.autoshard import plan
    healthy = plan(cfg, shape_by_name("decode_32k"), fleet,
                   kprime=[8, 16, 32, 56])
    if healthy is None:
        print("infeasible before failure")
        return
    t_fail = 0.5 * healthy.est_step_s

    report = rescale_plan(cfg, shape_by_name("decode_32k"), fleet,
                          failed=set(range(8)), at=t_fail,
                          policy="pinned-warm-start",
                          kprime=[8, 16, 32, 56])
    tl = report.timeline
    print(f"fleet: 64 chips -> lost 8 at t={t_fail * 1e3:.2f} ms "
          f"(mid-step)")
    print(f"est step before: {report.est_step_before_s * 1e3:.2f} ms")
    if report.feasible:
        print(f"est step after:  {report.est_step_after_s * 1e3:.2f} ms")
        print(f"stitched finish: {tl.makespan * 1e3:.2f} ms")
        print(f"new plan valid:  {report.new_plan.valid}")
        m = tl.migrations[0]
        print(f"migration: {m.moved_tasks} moved, "
              f"{m.displaced_tasks} displaced (lost chips), "
              f"{m.restarted_tasks} in-flight restarted "
              f"(lost work {m.lost_work:.3g} ops)")
        for frm, to, n in m.moves[:6]:
            print(f"    {n:4d} task(s)  {frm} -> {to}")
        if len(m.moves) > 6:
            print(f"    ... {len(m.moves) - 6} more routes")
        print()
        print(tl.gantt(width=64))
    else:
        print("infeasible on survivors -> needs a bigger fleet")
        print("diagnosis:", report.infeasibility)
    print()


def part2_serve():
    print("=== serving a reduced mixtral (greedy decode) ===")
    cfg = get_smoke_config("mixtral_8x7b")
    model = LM(cfg, param_dtype=jnp.float32, attn_chunk=16, max_seq=64)
    params = model.init(0)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    out = greedy_decode(model, params, prompt, new_tokens=8)
    print("generated:", np.asarray(out).tolist())


if __name__ == "__main__":
    part1_replan()
    part2_serve()
