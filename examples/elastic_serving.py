"""Elastic serving: node failure → scheduler re-plan → serve on.

Simulates losing 8 chips of a 64-chip mixed fleet serving
mixtral-8x7b at 32k context, re-plans placement with the paper's
heuristic, and reports the migration. Then demonstrates the actual
serving path (greedy decode) on a reduced config.

Run:  PYTHONPATH=src python examples/elastic_serving.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, shape_by_name
from repro.core.platform import tpu_fleet_si
from repro.launch.serve import greedy_decode
from repro.models import LM
from repro.runtime import rescale_plan


def part1_replan():
    print("=== elastic re-planning after chip loss ===")
    cfg = get_config("mixtral_8x7b")
    fleet = tpu_fleet_si({"v5e": 48, "v4": 16})
    report = rescale_plan(cfg, shape_by_name("decode_32k"), fleet,
                          failed=set(range(8)),
                          kprime=[8, 16, 32, 56])
    print(f"fleet: 64 chips -> lost 8")
    print(f"est step before: {report.est_step_before_s * 1e3:.2f} ms")
    if report.feasible:
        print(f"est step after:  {report.est_step_after_s * 1e3:.2f} ms")
        print(f"tasks remapped:  {report.moved_tasks}")
        print(f"new plan valid:  {report.new_plan.valid}")
    else:
        print("infeasible on survivors -> needs a bigger fleet")
    print()


def part2_serve():
    print("=== serving a reduced mixtral (greedy decode) ===")
    cfg = get_smoke_config("mixtral_8x7b")
    model = LM(cfg, param_dtype=jnp.float32, attn_chunk=16, max_seq=64)
    params = model.init(0)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    out = greedy_decode(model, params, prompt, new_tokens=8)
    print("generated:", np.asarray(out).tolist())


if __name__ == "__main__":
    part1_replan()
    part2_serve()
