"""Shared model building blocks (pure-functional JAX).

Parameters are nested dicts of ``jnp`` arrays.  Everything here is
written to lower cleanly under ``jax.jit`` with GSPMD sharding — no
Python-level data-dependent control flow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Initializer",
    "rms_norm",
    "swiglu",
    "rope_frequencies",
    "apply_rope",
    "embed",
    "unembed",
]


class Initializer:
    """Deterministic param initializer with a fan-in scaled normal."""

    def __init__(self, seed: int, param_dtype=jnp.bfloat16):
        self.key = jax.random.PRNGKey(seed)
        self.param_dtype = param_dtype

    def next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def normal(self, shape, fan_in: int | None = None, scale: float = 1.0):
        fan = fan_in if fan_in is not None else shape[0]
        std = scale / np.sqrt(max(fan, 1))
        x = jax.random.normal(self.next_key(), shape, dtype=jnp.float32) * std
        return x.astype(self.param_dtype)

    def zeros(self, shape):
        return jnp.zeros(shape, dtype=self.param_dtype)

    def ones(self, shape):
        return jnp.ones(shape, dtype=self.param_dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with float32 accumulation."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(dtype) * gamma


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU FFN: (silu(x @ Wg) * (x @ Wu)) @ Wd."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def rope_frequencies(head_dim: int, max_pos: int, theta: float) -> jax.Array:
    """[max_pos, head_dim//2] complex-free cos/sin table (f32)."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    pos = np.arange(max_pos)
    ang = np.einsum("p,f->pf", pos, inv)
    return jnp.asarray(np.stack([np.cos(ang), np.sin(ang)]), jnp.float32)


def apply_rope(x: jax.Array, cos_sin: jax.Array,
               positions: jax.Array) -> jax.Array:
    """Rotate ``x [..., S, H, hd]`` by per-position angles.

    ``positions [..., S]`` are absolute token positions (supports
    decode where the single query sits at ``cache_len``).
    """
    cos = cos_sin[0][positions]  # [..., S, hd//2]
    sin = cos_sin[1][positions]
    cos = cos[..., None, :]      # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Project hidden states to vocabulary logits (f32)."""
    return jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
