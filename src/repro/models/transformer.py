"""Unified model covering all assigned architectures.

One ``LM`` class dispatches per-layer kinds from ``ModelConfig``:

* dense / MoE decoders (llama3, granite, qwen2.5, minitron, mixtral,
  olmoe),
* attention-free RWKV6,
* hybrid Mamba/attention with MoE (jamba),
* VLM backbone with periodic cross-attention to stub patch embeddings
  (llama-3.2-vision),
* encoder–decoder with cross-attention every decoder layer
  (seamless-m4t; stub frame embeddings feed the encoder).

Layers are *scanned*: the layer pattern has period ``p`` (lcm of the
attention/MoE/cross periods), parameters are stacked ``[L/p, ...]`` per
in-period position, and ``jax.lax.scan`` runs the repeats — keeping the
HLO size O(p) instead of O(L), which is what makes the 100-layer
dry-runs compile quickly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .layers import Initializer, apply_rope, embed, rms_norm, rope_frequencies, swiglu, unembed

__all__ = ["LM", "LayerSpec"]


@dataclass(frozen=True)
class LayerSpec:
    kind: str        # attn | mamba | rwkv
    moe: bool
    cross: bool


def _lcm(*vals: int) -> int:
    out = 1
    for v in vals:
        if v > 1:
            out = out * v // math.gcd(out, v)
    return out


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) absmax int8 quantization. x: [B, 1, H, hd]."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale.astype(
        jnp.bfloat16)


class LM:
    """Functional language model; params are nested dicts."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        param_dtype=jnp.bfloat16,
        attn_chunk: int = 512,
        mamba_chunk: int = 256,
        capacity_factor: float = 1.25,
        max_seq: int = 0,
        remat: str = "none",        # none | full | dots
        shard_act=None,             # fn(x, kind) -> x sharding constraint
        rwkv_chunk: int = 16,
        kv_dtype: str = "bf16",     # bf16 | int8 (quantized KV cache)
    ) -> None:
        self.cfg = cfg
        self.param_dtype = param_dtype
        self.attn_chunk = attn_chunk
        self.mamba_chunk = mamba_chunk
        self.capacity_factor = capacity_factor
        self.max_seq = max_seq or 8192
        self.remat = remat
        self.shard_act = shard_act or (lambda x, kind="act": x)
        self.rwkv_chunk = rwkv_chunk
        self.kv_dtype = kv_dtype

        p = _lcm(
            cfg.attn_layer_period or 1,
            cfg.moe_layer_period if cfg.is_moe else 1,
            cfg.cross_attn_period or 1,
        )
        if cfg.n_layers % p != 0:
            p = cfg.n_layers  # fall back to fully unrolled stack
        self.period = p
        self.n_rep = cfg.n_layers // p
        self.specs = [self._spec(j) for j in range(p)]
        # encoder (enc-dec archs): plain non-causal attention stack
        self.enc_rep = cfg.n_encoder_layers

    def _spec(self, j: int) -> LayerSpec:
        cfg = self.cfg
        cross = cfg.layer_cross_attends(j) or cfg.is_encdec
        return LayerSpec(cfg.layer_kind(j), cfg.layer_is_moe(j), cross)

    # ------------------------------------------------------------------ #
    # init
    # ------------------------------------------------------------------ #
    def _init_mixer(self, init, spec: LayerSpec) -> dict:
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.hd
        if spec.kind == "attn":
            p = {
                "norm": init.ones((d,)),
                "wq": init.normal((d, cfg.n_heads * hd), fan_in=d),
                "wk": init.normal((d, cfg.n_kv_heads * hd), fan_in=d),
                "wv": init.normal((d, cfg.n_kv_heads * hd), fan_in=d),
                "wo": init.normal((cfg.n_heads * hd, d), fan_in=cfg.n_heads * hd),
            }
            if cfg.qkv_bias:
                p["bq"] = init.zeros((cfg.n_heads * hd,))
                p["bk"] = init.zeros((cfg.n_kv_heads * hd,))
                p["bv"] = init.zeros((cfg.n_kv_heads * hd,))
            return p
        if spec.kind == "mamba":
            return {
                "norm": init.ones((d,)),
                **ssm_mod.init_mamba(init, d, cfg.mamba_d_state,
                                     cfg.mamba_d_conv, cfg.mamba_expand),
            }
        return {
            "norm": init.ones((d,)),
            **rwkv_mod.init_rwkv(init, d, cfg.n_heads, hd),
        }

    def _init_layer(self, init, spec: LayerSpec) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        p = {"mixer": self._init_mixer(init, spec)}
        if spec.cross:
            p["cross"] = {
                "norm": init.ones((d,)),
                "wq": init.normal((d, cfg.n_heads * cfg.hd), fan_in=d),
                "wk": init.normal((d, cfg.n_kv_heads * cfg.hd), fan_in=d),
                "wv": init.normal((d, cfg.n_kv_heads * cfg.hd), fan_in=d),
                "wo": init.normal((cfg.n_heads * cfg.hd, d),
                                  fan_in=cfg.n_heads * cfg.hd),
            }
        p["ffn_norm"] = init.ones((d,))
        if spec.moe:
            p["moe"] = moe_mod.init_moe(init, d, cfg.d_ff, cfg.n_experts)
        else:
            p["ffn"] = {
                "w_gate": init.normal((d, cfg.d_ff), fan_in=d),
                "w_up": init.normal((d, cfg.d_ff), fan_in=d),
                "w_down": init.normal((cfg.d_ff, d), fan_in=cfg.d_ff),
            }
        return p

    def init(self, seed: int = 0) -> dict:
        cfg = self.cfg
        init = Initializer(seed, self.param_dtype)
        params: dict = {
            "embed": init.normal((cfg.vocab_size, cfg.d_model),
                                 fan_in=cfg.d_model),
            "final_norm": init.ones((cfg.d_model,)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init.normal(
                (cfg.vocab_size, cfg.d_model), fan_in=cfg.d_model)
        # decoder stack: stack n_rep copies per in-period position
        blocks = []
        for j, spec in enumerate(self.specs):
            reps = [self._init_layer(init, spec) for _ in range(self.n_rep)]
            blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps))
        params["blocks"] = blocks
        if cfg.is_encdec:
            enc_spec = LayerSpec("attn", False, False)
            reps = [self._init_layer(init, enc_spec)
                    for _ in range(cfg.n_encoder_layers)]
            params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
            params["enc_norm"] = init.ones((cfg.d_model,))
        if cfg.frontend_tokens and cfg.frontend_dim != cfg.d_model:
            params["frontend_proj"] = init.normal(
                (cfg.frontend_dim, cfg.d_model), fan_in=cfg.frontend_dim)
        return params

    # ------------------------------------------------------------------ #
    # building blocks
    # ------------------------------------------------------------------ #
    def _rope(self, max_pos: int):
        return rope_frequencies(self.cfg.hd, max_pos, self.cfg.rope_theta)

    def _self_attn(self, p, x, cos_sin, positions, causal=True):
        cfg = self.cfg
        b, s, d = x.shape
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        if s > 1:
            h = self.shard_act(h, "attn_in")
        q = jnp.einsum("bsd,de->bse", h, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,de->bse", h, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,de->bse", h, p["wv"].astype(x.dtype))
        if cfg.qkv_bias and "bq" in p:
            q = q + p["bq"].astype(x.dtype)
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        q = q.reshape(b, s, cfg.n_heads, cfg.hd)
        k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
        q = apply_rope(q, cos_sin, positions)
        k = apply_rope(k, cos_sin, positions)
        o = attn.gqa_attention(q, k, v, causal=causal,
                               chunk=self.attn_chunk,
                               sliding_window=cfg.sliding_window)
        o = o.reshape(b, s, cfg.n_heads * cfg.hd)
        return jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype)), (k, v)

    def _cross_attn(self, p, x, memory):
        """memory: [B, M, d] (frontend embeddings / encoder output)."""
        cfg = self.cfg
        b, s, d = x.shape
        m = memory.shape[1]
        h = rms_norm(x, p["norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,de->bse", h, p["wq"].astype(x.dtype))
        k = jnp.einsum("bmd,de->bme", memory, p["wk"].astype(x.dtype))
        v = jnp.einsum("bmd,de->bme", memory, p["wv"].astype(x.dtype))
        q = q.reshape(b, s, cfg.n_heads, cfg.hd)
        k = k.reshape(b, m, cfg.n_kv_heads, cfg.hd)
        v = v.reshape(b, m, cfg.n_kv_heads, cfg.hd)
        o = attn.cross_attention(q, k, v, chunk=self.attn_chunk)
        o = o.reshape(b, s, cfg.n_heads * cfg.hd)
        return jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))

    def _ffn(self, p, spec, x):
        cfg = self.cfg
        h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
        if spec.moe:
            y, aux = moe_mod.moe_ffn(
                p["moe"], h, top_k=cfg.experts_per_token,
                capacity_factor=self.capacity_factor,
                shard=self.shard_act)
            return y, aux
        f = p["ffn"]
        return swiglu(h, f["w_gate"].astype(x.dtype),
                      f["w_up"].astype(x.dtype),
                      f["w_down"].astype(x.dtype)), 0.0

    def _layer_seq(self, p, spec: LayerSpec, x, memory, cos_sin, positions):
        """Full-sequence layer (train / prefill). Returns (x, aux, kv)."""
        cfg = self.cfg
        kv = None
        if spec.kind == "attn":
            o, kv = self._self_attn(p["mixer"], x, cos_sin, positions)
            # constrain partial sums to the residual sharding *before*
            # the add so GSPMD reduce-scatters instead of all-reducing
            # the full [B,S,d] tensor (Megatron-SP exit)
            x = x + self.shard_act(o, "residual")
        elif spec.kind == "mamba":
            h = rms_norm(x, p["mixer"]["norm"], cfg.norm_eps)
            x = x + ssm_mod.mamba_seq(p["mixer"], h, chunk=self.mamba_chunk,
                                      shard=self.shard_act)
        else:  # rwkv
            h = rms_norm(x, p["mixer"]["norm"], cfg.norm_eps)
            x = x + rwkv_mod.rwkv_seq(p["mixer"], h, cfg.n_heads, cfg.hd,
                                      cfg.norm_eps,
                                      chunk=self.rwkv_chunk)
        if spec.cross and memory is not None:
            x = x + self.shard_act(self._cross_attn(p["cross"], x, memory),
                                   "residual")
        y, aux = self._ffn(p, spec, x)
        return x + self.shard_act(y, "residual"), aux, kv

    def _maybe_remat(self, body):
        """Activation checkpointing policy for the layer-scan body."""
        if self.remat == "full":
            return jax.checkpoint(body)
        if self.remat == "dots":
            return jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            )
        return body

    # ------------------------------------------------------------------ #
    # forward (train / prefill logits)
    # ------------------------------------------------------------------ #
    def _frontend_memory(self, params, frontend, dtype):
        if frontend is None:
            return None
        mem = frontend.astype(dtype)
        if "frontend_proj" in params:
            mem = jnp.einsum("bmf,fd->bmd", mem,
                             params["frontend_proj"].astype(dtype))
        return mem

    def _encode(self, params, memory):
        """Encoder stack over frontend embeddings (enc-dec archs)."""
        cfg = self.cfg
        b, m, d = memory.shape
        cos_sin = self._rope(m)
        positions = jnp.arange(m)[None, :]
        enc_spec = LayerSpec("attn", False, False)

        def body(x, lp):
            o, _ = self._self_attn(lp["mixer"], x, cos_sin, positions,
                                   causal=False)
            x = x + self.shard_act(o, "residual")
            y, _ = self._ffn(lp, enc_spec, x)
            x = self.shard_act(x + y, "residual")
            return x, None

        body = self._maybe_remat(body)
        x, _ = jax.lax.scan(body, memory, params["encoder"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def hidden_states(self, params, tokens, frontend=None):
        """Final-norm hidden states [B, S, d] + MoE aux loss."""
        cfg = self.cfg
        x = embed(params["embed"], tokens).astype(self.param_dtype)
        b, s, _ = x.shape
        memory = self._frontend_memory(params, frontend, x.dtype)
        if cfg.is_encdec and memory is not None:
            memory = self._encode(params, memory)
        cos_sin = self._rope(max(s, 1))
        positions = jnp.arange(s)[None, :]

        aux_total = 0.0
        for j, spec in enumerate(self.specs):
            def body(carry, lp, spec=spec):
                x, aux = carry
                x, a, _ = self._layer_seq(lp, spec, x, memory, cos_sin,
                                          positions)
                x = self.shard_act(x, "residual")
                return (x, aux + a), None
            body = self._maybe_remat(body)
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), params["blocks"][j])

        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux_total

    def forward(self, params, tokens, frontend=None, last_only=False):
        """Causal logits. tokens: [B, S].

        ``last_only`` avoids materializing the [B, S, V] logits tensor —
        serving prefill only needs the final position.
        """
        x, aux_total = self.hidden_states(params, tokens, frontend)
        table = params.get("lm_head", params["embed"])
        if last_only:
            x = x[:, -1:]
        logits = self.shard_act(unembed(x, table), "logits")
        return logits, aux_total

    def loss(self, params, batch, vocab_chunk: int = 512):
        """Next-token cross entropy, chunked over the sequence so the
        [B, S, V] logits tensor is never resident (production LMs with
        128k+ vocabularies cannot afford it).  batch: tokens, labels."""
        cfg = self.cfg
        x, aux = self.hidden_states(params, batch["tokens"],
                                    batch.get("frontend"))
        labels = batch["labels"]
        table = params.get("lm_head", params["embed"])
        b, s, d = x.shape
        chunk = min(vocab_chunk, s)
        n_chunks = s // chunk if s % chunk == 0 else 1
        if s % chunk != 0:
            chunk = s

        xs = x.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
        ls = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
        mask = batch.get("mask")
        ms = (mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
              if mask is not None else jnp.ones_like(ls, jnp.float32))

        # checkpointed: without it the scan saves every chunk's
        # [B, c, V] logits + one-hot for backward (67 GiB/device on
        # seamless's 256k vocabulary); recomputing them is one extra
        # unembed matmul per chunk.
        @jax.checkpoint
        def body(acc, xs_):
            xc, lc, mc = xs_
            logits = unembed(xc, table)                    # [B, c, V] f32
            logits = self.shard_act(logits, "logits")
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            onehot = jax.nn.one_hot(lc, cfg.vocab_size,
                                    dtype=self.param_dtype)
            picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
            nll = (lse - picked) * mc
            return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

        (total, denom), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (xs, ls, ms))
        return total / jnp.maximum(denom, 1.0) + 0.01 * aux

    # ------------------------------------------------------------------ #
    # serving: prefill + decode
    # ------------------------------------------------------------------ #
    def init_cache(self, bsz: int, max_len: int, dtype=None) -> list:
        """Stacked per-position caches mirroring ``params['blocks']``.

        With ``kv_dtype="int8"`` the KV entries are stored quantized
        (per-token-per-head absmax scales) — 1.94× less cache
        residency, the knob that brings 100-layer 32k-context decode
        under a 16 GiB HBM budget (EXPERIMENTS.md §Perf extras).
        """
        cfg = self.cfg
        dtype = dtype or self.param_dtype
        caches = []
        for spec in self.specs:
            if spec.kind == "attn":
                shape = (self.n_rep, bsz, max_len, cfg.n_kv_heads, cfg.hd)
                if self.kv_dtype == "int8":
                    sshape = shape[:-1] + (1,)
                    c = {
                        "k": jnp.zeros(shape, jnp.int8),
                        "v": jnp.zeros(shape, jnp.int8),
                        "k_scale": jnp.zeros(sshape, jnp.bfloat16),
                        "v_scale": jnp.zeros(sshape, jnp.bfloat16),
                    }
                else:
                    c = {
                        "k": jnp.zeros(shape, dtype),
                        "v": jnp.zeros(shape, dtype),
                    }
            elif spec.kind == "mamba":
                c = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (self.n_rep,) + x.shape),
                    ssm_mod.init_mamba_cache(bsz, cfg.d_model,
                                             cfg.mamba_d_state,
                                             cfg.mamba_d_conv,
                                             cfg.mamba_expand, dtype))
            else:
                c = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (self.n_rep,) + x.shape),
                    rwkv_mod.init_rwkv_cache(bsz, cfg.d_model, cfg.n_heads,
                                             cfg.hd, dtype))
            if spec.cross:
                c = dict(c) if isinstance(c, dict) else {"inner": c}
                # cross-attention K/V over the memory are filled by prefill
            caches.append(c)
        return caches

    def _layer_step(self, p, spec: LayerSpec, x, cache, memory, cos_sin,
                    pos):
        """One-token layer step. x: [B,1,d]; cache: this layer's slice."""
        cfg = self.cfg
        new_cache = dict(cache)
        if spec.kind == "attn":
            b = x.shape[0]
            h = rms_norm(x, p["mixer"]["norm"], cfg.norm_eps)
            q = jnp.einsum("bsd,de->bse", h, p["mixer"]["wq"].astype(x.dtype))
            k = jnp.einsum("bsd,de->bse", h, p["mixer"]["wk"].astype(x.dtype))
            v = jnp.einsum("bsd,de->bse", h, p["mixer"]["wv"].astype(x.dtype))
            if cfg.qkv_bias and "bq" in p["mixer"]:
                q = q + p["mixer"]["bq"].astype(x.dtype)
                k = k + p["mixer"]["bk"].astype(x.dtype)
                v = v + p["mixer"]["bv"].astype(x.dtype)
            q = q.reshape(b, 1, cfg.n_heads, cfg.hd)
            k = k.reshape(b, 1, cfg.n_kv_heads, cfg.hd)
            v = v.reshape(b, 1, cfg.n_kv_heads, cfg.hd)
            # pos: scalar (whole batch at one cursor) or [B] vector
            # (continuous batching: per-slot cursors)
            pos_vec = jnp.asarray(pos)
            if pos_vec.ndim == 0:
                positions = jnp.full((b, 1), pos_vec)
                upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
                    buf, val.astype(buf.dtype), pos, axis=1)
            else:
                positions = pos_vec[:, None]
                upd = lambda buf, val: jax.vmap(
                    lambda bb, vv, pp:
                    jax.lax.dynamic_update_slice_in_dim(
                        bb, vv.astype(bb.dtype), pp, axis=0)
                )(buf, val, pos_vec)
            q = apply_rope(q, cos_sin, positions)
            k = apply_rope(k, cos_sin, positions)
            if "k_scale" in cache:        # int8-quantized cache
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                kc = upd(cache["k"], kq)
                vc = upd(cache["v"], vq)
                ksc = upd(cache["k_scale"], ks)
                vsc = upd(cache["v_scale"], vs)
                k_deq = kc.astype(x.dtype) * ksc.astype(x.dtype)
                v_deq = vc.astype(x.dtype) * vsc.astype(x.dtype)
                o = attn.decode_attention(q, k_deq, v_deq, pos_vec + 1,
                                          sliding_window=cfg.sliding_window)
                new_cache.update({"k": kc, "v": vc,
                                  "k_scale": ksc, "v_scale": vsc})
            else:
                kc = upd(cache["k"], k)
                vc = upd(cache["v"], v)
                o = attn.decode_attention(q, kc, vc, pos_vec + 1,
                                          sliding_window=cfg.sliding_window)
                new_cache.update({"k": kc, "v": vc})
            o = o.reshape(b, 1, cfg.n_heads * cfg.hd)
            x = x + jnp.einsum("bse,ed->bsd", o,
                               p["mixer"]["wo"].astype(x.dtype))
        elif spec.kind == "mamba":
            h = rms_norm(x, p["mixer"]["norm"], cfg.norm_eps)
            inner = {k2: cache[k2] for k2 in ("conv", "ssm")}
            o, inner = ssm_mod.mamba_step(p["mixer"], h, inner)
            x = x + o
            new_cache.update(inner)
        else:  # rwkv
            h = rms_norm(x, p["mixer"]["norm"], cfg.norm_eps)
            inner = {k2: cache[k2] for k2 in ("last_x", "state")}
            o, inner = rwkv_mod.rwkv_step(p["mixer"], h, cache=inner,
                                          n_heads=cfg.n_heads,
                                          head_dim=cfg.hd,
                                          norm_eps=cfg.norm_eps)
            x = x + o
            new_cache.update(inner)
        if spec.cross and memory is not None:
            x = x + self._cross_attn(p["cross"], x, memory)
        y, _ = self._ffn(p, spec, x)
        return x + y, new_cache

    def decode_step(self, params, cache, tokens, pos, memory=None):
        """Generate logits for one new token.

        tokens: [B, 1] int32; pos: scalar int (current cache length).
        ``memory``: optional [B, M, d] cross-attention memory (VLM
        frontend / encoder output), already projected/encoded.
        """
        cfg = self.cfg
        x = embed(params["embed"], tokens).astype(self.param_dtype)
        cos_sin = self._rope(self.max_seq)

        new_caches = []
        for j, spec in enumerate(self.specs):
            def body(x, scanned, spec=spec):
                lp, c = scanned
                x, c2 = self._layer_step(lp, spec, x, c, memory, cos_sin,
                                         pos)
                return x, c2
            x, nc = jax.lax.scan(body, x, (params["blocks"][j], cache[j]))
            new_caches.append(nc)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params.get("lm_head", params["embed"])
        return unembed(x, table), new_caches

    def encode_memory(self, params, frontend):
        """Prepare cross-attention memory once per request batch."""
        mem = self._frontend_memory(params, frontend, self.param_dtype)
        if mem is not None and self.cfg.is_encdec:
            mem = self._encode(params, mem)
        return mem
