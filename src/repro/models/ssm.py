"""Mamba (selective S6) block — chunked selective scan in pure JAX.

The recurrence per channel c and state dim n::

    h_t = exp(A_c,n · dt_t,c) · h_{t-1} + dt_t,c · B_t,n · x_t,c
    y_t,c = Σ_n C_t,n · h_t,c,n + D_c · x_t,c

Sequence processing scans over *chunks* (default 256 steps) with an
inner ``lax.associative_scan``, which is the TPU-friendly formulation
(bounded live state, MXU-aligned inner ops).  Decode keeps ``(conv
state, ssm state)`` and advances one step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_mamba", "mamba_seq", "mamba_step", "init_mamba_cache"]


def _dt_rank(d_model: int) -> int:
    return max(1, -(-d_model // 16))


def init_mamba(init, d_model: int, d_state: int, d_conv: int,
               expand: int) -> dict:
    d_in = expand * d_model
    r = _dt_rank(d_model)
    return {
        "in_proj": init.normal((d_model, 2 * d_in), fan_in=d_model),
        "conv_w": init.normal((d_conv, d_in), fan_in=d_conv),
        "conv_b": init.zeros((d_in,)),
        "x_proj": init.normal((d_in, r + 2 * d_state), fan_in=d_in),
        "dt_proj": init.normal((r, d_in), fan_in=r),
        "dt_bias": init.zeros((d_in,)),
        # S4D-real initialization: A = -(1..N), stored as log
        "a_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)),
            (d_in, d_state)).astype(init.param_dtype),
        "d_skip": init.ones((d_in,)),
        "out_proj": init.normal((d_in, d_model), fan_in=d_in),
    }


def _ssm_params(params, xc):
    """Common projections. xc: [..., d_in] (post-conv, silu'd)."""
    r = params["dt_proj"].shape[0]
    n = params["a_log"].shape[1]
    proj = jnp.einsum("...i,ij->...j", xc, params["x_proj"].astype(xc.dtype))
    dt_r, b, c = jnp.split(proj, [r, r + n], axis=-1)
    dt = jnp.einsum("...r,ri->...i", dt_r, params["dt_proj"].astype(xc.dtype))
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))       # [d_in, N]
    return dt, a, b.astype(jnp.float32), c.astype(jnp.float32)


def _selective_scan_chunk(h0, dt, a, b, c, xc):
    """Associative scan within one chunk.

    h0: [B, d_in, N]; dt, xc: [B, L, d_in]; b, c: [B, L, N].
    Returns (y [B, L, d_in], hL).
    """
    # elementwise decay and input terms per step: [B, L, d_in, N]
    decay = jnp.exp(dt[..., None] * a[None, None])
    inp = (dt * xc)[..., None] * b[:, :, None, :]

    def combine(e1, e2):
        d1, i1 = e1
        d2, i2 = e2
        return d1 * d2, i1 * d2 + i2

    dec_c, inp_c = jax.lax.associative_scan(combine, (decay, inp), axis=1)
    h = dec_c * h0[:, None] + inp_c                         # [B, L, d_in, N]
    y = jnp.einsum("blin,bln->bli", h, c)
    return y, h[:, -1]


def mamba_seq(params: dict, x: jax.Array, chunk: int = 256,
              shard=None) -> jax.Array:
    """Full-sequence Mamba block. x: [B, S, d_model] -> same shape.

    ``shard(tensor, kind)`` pins the d_in dimension of the big scan
    intermediates to the "model" axis (d_in = 2·d_model: jamba's
    [B, chunk, d_in, N] selective-scan tensors are ~4 GiB each when
    replicated across the TP group).
    """
    shard = shard or (lambda v, kind: v)
    btype = x.dtype
    bsz, s, _ = x.shape
    d_in = params["dt_bias"].shape[0]
    n = params["a_log"].shape[1]

    xz = jnp.einsum("bsd,di->bsi", x, params["in_proj"].astype(btype))
    xr, z = jnp.split(xz, 2, axis=-1)
    xr = shard(xr, "mamba_din")

    # depthwise causal conv over sequence
    w = params["conv_w"].astype(btype)                       # [K, d_in]
    k = w.shape[0]
    xp = jnp.pad(xr, ((0, 0), (k - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + s] * w[i] for i in range(k))
    xc = jax.nn.silu(xc + params["conv_b"].astype(btype))

    dt, a, b, c = _ssm_params(params, xc)
    dt = shard(dt, "mamba_din")
    xcf = shard(xc.astype(jnp.float32), "mamba_din")

    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        xcf = jnp.pad(xcf, ((0, 0), (0, pad), (0, 0)))

    # checkpointed: the scan otherwise saves each chunk's full hidden
    # trajectory [B, L, d_in, N] for backward (~68 GiB/device on jamba
    # train_4k); recomputing the chunk from (h0, inputs) is cheap.
    @jax.checkpoint
    def outer(h, xs):
        dt_k, b_k, c_k, x_k = xs
        y_k, h_new = _selective_scan_chunk(h, dt_k, a, b_k, c_k, x_k)
        return h_new, y_k

    reshape = lambda t: t.reshape(bsz, n_chunks, chunk, -1).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((bsz, d_in, n), jnp.float32)
    _, ys = jax.lax.scan(outer, h0,
                         (reshape(dt), reshape(b), reshape(c), reshape(xcf)))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, n_chunks * chunk, d_in)[:, :s]

    y = y + xcf * params["d_skip"].astype(jnp.float32)
    y = y.astype(btype) * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, params["out_proj"].astype(btype))


def init_mamba_cache(bsz: int, d_model: int, d_state: int, d_conv: int,
                     expand: int, dtype=jnp.float32) -> dict:
    d_in = expand * d_model
    return {
        "conv": jnp.zeros((bsz, d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((bsz, d_in, d_state), jnp.float32),
    }


def mamba_step(params: dict, x: jax.Array, cache: dict
               ) -> tuple[jax.Array, dict]:
    """Single decode step. x: [B, 1, d_model]."""
    btype = x.dtype
    xz = jnp.einsum("bsd,di->bsi", x, params["in_proj"].astype(btype))
    xr, z = jnp.split(xz, 2, axis=-1)                        # [B,1,d_in]

    w = params["conv_w"].astype(btype)
    k = w.shape[0]
    window = jnp.concatenate([cache["conv"].astype(btype), xr], axis=1)
    xc = jnp.einsum("bki,ki->bi", window, w)[:, None]
    xc = jax.nn.silu(xc + params["conv_b"].astype(btype))

    dt, a, b, c = _ssm_params(params, xc)
    decay = jnp.exp(dt[:, 0, :, None] * a[None])             # [B,d_in,N]
    inp = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * b[:, 0, None, :]
    h = cache["ssm"] * decay + inp
    y = jnp.einsum("bin,bn->bi", h, c[:, 0])[:, None]
    y = y + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = y.astype(btype) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"].astype(btype))
    new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype), "ssm": h}
    return out, new_cache
