"""Model zoo: one unified functional LM covering all assigned archs."""
from .transformer import LM, LayerSpec

__all__ = ["LM", "LayerSpec"]
