"""RWKV6 "Finch" block — time-mix with data-dependent decay.

Per head (size ``hd``), with state S ∈ R^{hd×hd}::

    out_t = r_t · (S + (u ⊙ k_t) v_tᵀ)
    S     = diag(w_t) S + k_t v_tᵀ,   w_t = exp(-exp(ww_t))

``ww_t`` is data-dependent (low-rank LoRA on the shifted input) — the
defining RWKV6 feature.  Sequence processing scans over chunks; the
Pallas kernel in ``repro.kernels.rwkv_wkv`` is the TPU-target version
of the same recurrence (kernels/ref.py mirrors this module).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_rwkv", "rwkv_seq", "rwkv_step", "init_rwkv_cache",
           "wkv_scan_ref"]

_LORA = 64


def init_rwkv(init, d_model: int, n_heads: int, head_dim: int) -> dict:
    dh = n_heads * head_dim
    return {
        "mix_r": init.ones((d_model,)) * 0.5,
        "mix_k": init.ones((d_model,)) * 0.5,
        "mix_v": init.ones((d_model,)) * 0.5,
        "mix_w": init.ones((d_model,)) * 0.5,
        "mix_g": init.ones((d_model,)) * 0.5,
        "w_r": init.normal((d_model, dh), fan_in=d_model),
        "w_k": init.normal((d_model, dh), fan_in=d_model),
        "w_v": init.normal((d_model, dh), fan_in=d_model),
        "w_g": init.normal((d_model, dh), fan_in=d_model),
        "w_o": init.normal((dh, d_model), fan_in=dh),
        # data-dependent decay LoRA
        "decay_a": init.normal((d_model, _LORA), fan_in=d_model),
        "decay_b": init.normal((_LORA, dh), fan_in=_LORA),
        "decay_base": init.zeros((dh,)),
        "bonus_u": init.normal((n_heads, head_dim), fan_in=head_dim),
        "ln_x": init.ones((dh,)),
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros / cache for t = 0)."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _projections(params, x, x_prev, n_heads, head_dim):
    btype = x.dtype

    def mix(name):
        m = params[f"mix_{name}"].astype(btype)
        return x * m + x_prev * (1.0 - m)

    b, s, _ = x.shape
    shp = (b, s, n_heads, head_dim)
    r = jnp.einsum("bsd,de->bse", mix("r"), params["w_r"].astype(btype)).reshape(shp)
    k = jnp.einsum("bsd,de->bse", mix("k"), params["w_k"].astype(btype)).reshape(shp)
    v = jnp.einsum("bsd,de->bse", mix("v"), params["w_v"].astype(btype)).reshape(shp)
    g = jnp.einsum("bsd,de->bse", mix("g"), params["w_g"].astype(btype))
    ww = jnp.einsum("bsd,dl->bsl", mix("w"), params["decay_a"].astype(btype))
    ww = jnp.einsum("bsl,le->bse", jnp.tanh(ww), params["decay_b"].astype(btype))
    ww = ww.astype(jnp.float32) + params["decay_base"].astype(jnp.float32)
    # decay in (0, 1); per-step log-decay clamped to ≥ −8 so the
    # chunked formulation stays in f32 range (a channel decaying below
    # e⁻⁸ per step is dead after two steps regardless)
    w = jnp.exp(-jnp.minimum(jnp.exp(ww), 8.0)).reshape(shp)
    return r, k, v, g, w


def wkv_chunked(r, k, v, w, u, s0=None, chunk: int = 16):
    """Chunked (GLA-style) WKV — the TPU-native formulation.

    Mathematically equal to :func:`wkv_scan_ref` (property-tested), but
    processes the sequence in chunks of ``chunk`` steps using
    MXU-friendly matmuls, carrying the state once per chunk instead of
    once per timestep (≈ chunk× less HBM state traffic, and a scan
    that saves O(S/chunk) instead of O(S) residuals for backward).

    Stability: within a chunk, pairwise decay factors are computed in
    log space around the chunk *midpoint* reference, so every
    intermediate is bounded by e^(8·chunk/2); per-step log-decays are
    clamped to ≥ −8 (a decay below e⁻⁸ kills a channel within two
    steps anyway).  r,k,v,w: [B, S, H, hd]; w = decay in (0, 1).
    """
    b, s, h, hd = r.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    lw = jnp.maximum(jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38)),
                     -8.0)
    if pad:
        rf = jnp.pad(rf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    resh = lambda t: t.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, lwc = resh(rf), resh(kf), resh(vf), resh(lw)
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    mid = chunk // 2

    # checkpointed: recompute per-chunk decay/pairwise tensors in the
    # backward pass instead of stacking them across S/chunk iterations
    @jax.checkpoint
    def body(S, xs):
        rb, kb, vb, lwb = xs                      # [B, C, H, hd]
        la = jnp.cumsum(lwb, axis=1)              # la_t = Σ_{1..t} log w
        la_prev = la - lwb                        # la_{t-1}
        ref = la[:, mid]                          # [B, H, hd]
        rt = rb * jnp.exp(la_prev - ref[:, None])
        kt = kb * jnp.exp(ref[:, None] - la)
        # pairwise coefficients A[t, τ] = Σ_i r̃_t k̃_τ, strictly causal
        A = jnp.einsum("bthi,bzhi->bhtz", rt, kt)
        A = jnp.tril(A, k=-1)
        intra = jnp.einsum("bhtz,bzhj->bthj", A, vb)
        cross = jnp.einsum("bthi,bhij->bthj", rb * jnp.exp(la_prev), S)
        diag = jnp.einsum("bthi,hi,bthi->bth", rb, uf, kb)
        out = cross + intra + diag[..., None] * vb
        la_end = la[:, -1]                        # [B, H, hd]
        S_new = (jnp.exp(la_end)[..., None] * S
                 + jnp.einsum("bthi,bthj->bhij",
                              kb * jnp.exp(la_end[:, None] - la), vb))
        return S_new, out

    sT, outs = jax.lax.scan(body, s0, (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, hd)
    return out[:, :s].astype(r.dtype), sT


def wkv_scan_ref(r, k, v, w, u, s0=None):
    """Sequential WKV recurrence (oracle for the Pallas kernel).

    r,k,v,w: [B, S, H, hd]; u: [H, hd].  Returns (out [B,S,H,hd], sT).
    State S: [B, H, hd(key), hd(value)], f32.
    """
    b, s, h, hd = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(state, xs):
        rt, kt, vt, wt = xs                       # [B, H, hd]
        kv = kt[..., :, None] * vt[..., None, :]  # [B, H, hd, hd]
        out = jnp.einsum(
            "bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        state = state * wt[..., :, None] + kv
        return state, out

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, wf))
    sT, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3), sT


def rwkv_seq(params: dict, x: jax.Array, n_heads: int, head_dim: int,
             norm_eps: float = 1e-5, chunk: int = 16) -> jax.Array:
    """Full-sequence RWKV6 time-mix. x: [B, S, d_model]."""
    from .layers import rms_norm

    btype = x.dtype
    b, s, d = x.shape
    x_prev = _shift(x)
    r, k, v, g, w = _projections(params, x, x_prev, n_heads, head_dim)
    out, _ = wkv_chunked(r, k, v, w, params["bonus_u"], chunk=chunk)
    out = out.reshape(b, s, n_heads * head_dim).astype(btype)
    out = rms_norm(out, params["ln_x"], norm_eps)
    out = out * jax.nn.silu(g)
    return jnp.einsum("bse,ed->bsd", out, params["w_o"].astype(btype))


def init_rwkv_cache(bsz: int, d_model: int, n_heads: int, head_dim: int,
                    dtype=jnp.float32) -> dict:
    return {
        "last_x": jnp.zeros((bsz, d_model), dtype),
        "state": jnp.zeros((bsz, n_heads, head_dim, head_dim), jnp.float32),
    }


def rwkv_step(params: dict, x: jax.Array, cache: dict, n_heads: int,
              head_dim: int, norm_eps: float = 1e-5
              ) -> tuple[jax.Array, dict]:
    """Single decode step. x: [B, 1, d_model]."""
    from .layers import rms_norm

    btype = x.dtype
    b, _, d = x.shape
    x_prev = cache["last_x"][:, None].astype(btype)
    r, k, v, g, w = _projections(params, x, x_prev, n_heads, head_dim)
    out, s_new = wkv_scan_ref(r, k, v, w, params["bonus_u"],
                              s0=cache["state"])
    out = out.reshape(b, 1, n_heads * head_dim).astype(btype)
    out = rms_norm(out, params["ln_x"], norm_eps)
    out = out * jax.nn.silu(g)
    y = jnp.einsum("bse,ed->bsd", out, params["w_o"].astype(btype))
    return y, {"last_x": x[:, 0].astype(cache["last_x"].dtype),
               "state": s_new}
