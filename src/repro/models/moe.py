"""Mixture-of-Experts FFN — capacity-based expert-choice gather.

TPU-idiomatic MoE without giant one-hot dispatch einsums and without
ragged ops: tokens are routed per *group* (a group = one sequence, so
routing stays local under batch sharding), each expert gathers its
top-C tokens (C = tokens·top_k·capacity_factor / E), computes a batched
SwiGLU, and results are scatter-added back.  Tokens over capacity are
dropped (standard dropped-token MoE; capacity_factor 1.25 ⇒ ≲2% drops
at equilibrium).  Compute cost is capacity_factor × active-FLOPs — the
roofline accounting in benchmarks uses the same convention.

Gradients flow through gathers, scatter-add and gate values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_moe", "moe_ffn", "moe_capacity"]


def moe_capacity(tokens_per_group: int, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25) -> int:
    c = int(tokens_per_group * top_k * capacity_factor / n_experts)
    return max(1, min(c, tokens_per_group))


def init_moe(init, d_model: int, d_ff: int, n_experts: int) -> dict:
    return {
        "router": init.normal((d_model, n_experts), fan_in=d_model),
        "w_gate": init.normal((n_experts, d_model, d_ff), fan_in=d_model),
        "w_up": init.normal((n_experts, d_model, d_ff), fan_in=d_model),
        "w_down": init.normal((n_experts, d_ff, d_model), fan_in=d_ff),
    }


def moe_ffn(params: dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25,
            shard=None) -> tuple[jax.Array, jax.Array]:
    """Apply the MoE FFN.

    x: [G, T, d] (G groups routed independently — callers pass
    [batch, seq, d] for train/prefill and [1, batch, d] for decode).
    Returns ``(y, aux_loss)`` where ``aux_loss`` is the load-balancing
    loss (Switch-style, mean over groups).

    ``shard(tensor, kind)`` pins the sharding of the big gather
    intermediates; without it GSPMD may resolve the expert-einsum
    contraction conflict by *replicating* the [G, E, C, d] tensors
    across the data axes (measured: 31 GiB/device for mixtral train_4k)
    instead of gathering the (much smaller) expert weights.
    """
    shard = shard or (lambda v, kind: v)
    g, t, d = x.shape
    e = params["router"].shape[1]
    cap = moe_capacity(t, e, top_k, capacity_factor)

    logits = jnp.einsum("gtd,de->gte", x, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # [G,T,E]

    # token-choice top-k, renormalized (Mixtral convention)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)              # [G,T,k]
    top_vals = top_vals / jnp.maximum(
        top_vals.sum(-1, keepdims=True), 1e-9)

    # per-(token, expert) gate value; 0 when the expert is not in the
    # token's top-k.  [G, T, E]
    routed = jnp.zeros((g, t, e), jnp.float32)
    routed = jax.vmap(
        lambda r, i, v: r.at[jnp.arange(t)[:, None], i].set(v)
    )(routed, top_idx, top_vals)

    # expert-choice capacity selection: each expert picks its top-C
    # tokens by gate value.  [G, E, C]
    scores = routed.transpose(0, 2, 1)                           # [G,E,T]
    sel_vals, sel_tok = jax.lax.top_k(scores, cap)
    valid = sel_vals > 0.0
    weights = (sel_vals * valid).astype(x.dtype)                 # [G,E,C]

    # gather token activations per expert slot: [G, E, C, d]
    xs = jnp.take_along_axis(
        x[:, None, :, :],                                        # [G,1,T,d]
        sel_tok[..., None],                                      # [G,E,C,1]
        axis=2,
    )
    xs = shard(xs, "moe_tokens")

    # batched SwiGLU over experts
    h_gate = jnp.einsum("gecd,edf->gecf", xs, params["w_gate"].astype(x.dtype))
    h_up = jnp.einsum("gecd,edf->gecf", xs, params["w_up"].astype(x.dtype))
    h = shard(jax.nn.silu(h_gate) * h_up, "moe_hidden")
    ys = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(x.dtype))
    ys = shard(ys * weights[..., None], "moe_tokens")

    # scatter-add back to token positions
    y = jnp.zeros((g, t, d), ys.dtype)
    y = jax.vmap(
        lambda acc, tok, val: acc.at[tok.reshape(-1)].add(
            val.reshape(-1, d))
    )(y, sel_tok, ys)

    # Switch load-balancing loss: E * sum_e f_e * p_e
    frac_routed = (routed > 0).astype(jnp.float32).mean(axis=1)  # [G,E]
    mean_prob = probs.mean(axis=1)                               # [G,E]
    aux = e * jnp.mean(jnp.sum(frac_routed * mean_prob, axis=-1))
    return y.astype(x.dtype), aux
