"""Grouped-query attention with flash-style chunked softmax.

Three entry points:

* :func:`gqa_attention` — self-attention over a full sequence (train /
  prefill).  Uses an online-softmax scan over KV chunks, so the S×S
  score matrix is never materialized — the pure-jnp analogue of the
  Pallas flash kernel in ``repro.kernels.flash_attention`` (which is
  the TPU-target implementation of the same math).
* :func:`decode_attention` — one new query against a KV cache.
* :func:`cross_attention` — queries attend to a fixed memory (VLM
  frontend tokens / encoder output).

All softmax statistics are f32; inputs/outputs bf16-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gqa_attention",
    "decode_attention",
    "cross_attention",
    "repeat_kv",
]

_NEG_INF = -1e30


def repeat_kv(kv: jax.Array, groups: int) -> jax.Array:
    """[B, S, Hkv, hd] -> [B, S, Hkv*groups, hd]."""
    if groups == 1:
        return kv
    b, s, h, d = kv.shape
    kv = jnp.broadcast_to(kv[:, :, :, None, :], (b, s, h, groups, d))
    return kv.reshape(b, s, h * groups, d)


def _chunked_mha(q, k, v, *, causal: bool, chunk: int,
                 sliding_window: int = 0,
                 q_offset: int = 0):
    """Online-softmax attention, scanning over KV chunks.

    q: [B, Sq, H, hd]; k, v: [B, Sk, H, hd].  Returns [B, Sq, H, hd].
    ``q_offset`` is the absolute position of q[0] (prefill: 0).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    qs = q * scale  # keep input dtype: MXU takes bf16 in, f32 accum

    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, hd)
    vc = v.reshape(b, n_chunks, chunk, h, hd)

    q_pos = q_offset + jnp.arange(sq)

    # The chunk body is checkpointed: the backward pass recomputes the
    # score/softmax tensors per chunk instead of stacking them across
    # the scan — the same recompute strategy as the Pallas flash kernel,
    # and the difference between O(S·chunk) and O(S²) attention
    # residency.
    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        kb, vb, start = xs
        s = jax.lax.dot_general(
            qs, kb, (((3,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=jnp.float32)      # [B, H, Sq, chunk]
        k_pos = start + jnp.arange(chunk)
        mask = k_pos[None, :] < sk  # padding
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if sliding_window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - sliding_window)
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(vb.dtype), vb, (((3,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32)      # [B, H, Sq, hd]
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    starts = jnp.arange(n_chunks) * chunk
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), starts),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # [B, Sq, H, hd]


def gqa_attention(q, k, v, *, causal: bool = True, chunk: int = 512,
                  sliding_window: int = 0) -> jax.Array:
    """Self-attention; q [B,S,Hq,hd], k/v [B,S,Hkv,hd].

    GQA without materializing repeated KV: query heads are folded into
    a [B, S, Hkv, group, hd] view so the online-softmax dots contract
    directly against the Hkv-headed K/V (repeat_kv would multiply KV
    HBM traffic by the group factor — measured 64+ GB/step on the
    llama3 decode cell).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    groups = hq // hkv
    chunk = min(chunk, s)
    if groups == 1:
        return _chunked_mha(q, k, v, causal=causal, chunk=chunk,
                            sliding_window=sliding_window)
    qg = q.reshape(b, s, hkv, groups, hd)
    og = _chunked_gqa(qg, k, v, causal=causal, chunk=chunk,
                      sliding_window=sliding_window)
    return og.reshape(b, s, hq, hd)


def _chunked_gqa(q, k, v, *, causal: bool, chunk: int,
                 sliding_window: int = 0):
    """Grouped online-softmax attention.

    q: [B, Sq, Hkv, G, hd]; k, v: [B, Sk, Hkv, hd].
    Returns [B, Sq, Hkv, G, hd].
    """
    b, sq, hkv, g, hd = q.shape
    sk = k.shape[1]
    qs = q * (hd ** -0.5)

    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, hkv, hd)
    vc = v.reshape(b, n_chunks, chunk, hkv, hd)
    q_pos = jnp.arange(sq)

    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        kb, vb, start = xs
        # batch (B, Hkv), lhs free (Sq, G), rhs free (chunk)
        # -> s: [B, Hkv, Sq, G, chunk]
        s = jax.lax.dot_general(
            qs, kb, (((4,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=jnp.float32)
        k_pos = start + jnp.arange(chunk)
        mask = k_pos[None, :] < sk
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if sliding_window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - sliding_window)
        s = jnp.where(mask[None, None, :, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(vb.dtype), vb, (((4,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32)   # [B, Hkv, Sq, G, hd]
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, sq, g), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, sq, g), jnp.float32)
    acc0 = jnp.zeros((b, hkv, sq, g, hd), jnp.float32)
    starts = jnp.arange(n_chunks) * chunk
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # [B, Hkv, Sq, G, hd] -> [B, Sq, Hkv, G, hd]
    return out.transpose(0, 2, 1, 3, 4).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     sliding_window: int = 0) -> jax.Array:
    """One-step attention: q [B,1,Hq,hd] vs cache [B,Smax,Hkv,hd].

    ``cache_len`` — number of valid cache entries (the new token's KV
    must already be written at ``cache_len - 1``).  The GQA grouping is
    folded into the dots — the cache is never repeated across query
    heads (repeat_kv costs group× the cache's HBM traffic per token).
    """
    b, one, hq, hd = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = (q * (hd ** -0.5)).reshape(b, one, hkv, g, hd)
    # batch (B, Hkv); lhs free (1, G); rhs free (Smax)
    s = jax.lax.dot_general(
        qg, k_cache, (((4,), (3,)), ((0, 2), (0, 2))),
        preferred_element_type=jnp.float32)      # [B, Hkv, 1, G, Smax]
    k_pos = jnp.arange(smax)
    # cache_len: scalar, or [B] per-slot lengths (continuous batching)
    clen = jnp.asarray(cache_len)
    if clen.ndim == 0:
        clen = jnp.full((b,), clen)
    mask = k_pos[None, :] < clen[:, None]                 # [B, Smax]
    if sliding_window > 0:
        mask = mask & (k_pos[None, :] > clen[:, None] - 1 - sliding_window)
    s = jnp.where(mask[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jax.lax.dot_general(
        p.astype(v_cache.dtype), v_cache,
        (((4,), (1,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.float32)      # [B, Hkv, 1, G, hd]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, one, hq, hd).astype(
        q.dtype)


def cross_attention(q, k, v, chunk: int = 512) -> jax.Array:
    """Non-causal attention of q [B,Sq,Hq,hd] over memory k/v [B,Sm,Hkv,hd]."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    groups = hq // hkv
    chunk = min(chunk, k.shape[1])
    if groups == 1:
        return _chunked_mha(q, k, v, causal=False, chunk=chunk)
    qg = q.reshape(b, sq, hkv, groups, hd)
    og = _chunked_gqa(qg, k, v, causal=False, chunk=chunk)
    return og.reshape(b, sq, hq, hd)
