"""Acyclic DAG partitioning — stand-in for dagP (paper Step 1).

The paper uses the external multilevel partitioner dagP (Herrmann et
al., SISC 2019) as a black box: ``Partition(G, k)`` returns an acyclic
k-way partition optimizing edge cut under a balance constraint.  We
implement the same interface natively (DESIGN.md §3.4):

1. a *locality-preserving topological order* (ready tasks whose parents
   were scheduled most recently go first — keeps chains together),
2. a *contiguous split* of that order into ``k`` chunks of roughly equal
   vertex weight — by construction every edge goes from an
   earlier-or-equal chunk to a later-or-equal chunk, so the quotient
   graph is acyclic,
3. *FM-style boundary refinement*: single-vertex moves between
   neighbouring chunks that reduce the edge cut, constrained so the
   ``b(u) <= b(v)`` invariant (and hence acyclicity) is preserved and
   blocks stay within ``(1 + eps)`` of the weight target.

The refinement is repeated for ``passes`` rounds of best-improvement
sweeps.  Deterministic throughout.
"""
from __future__ import annotations

from typing import Sequence

from .dag import Workflow

__all__ = ["acyclic_partition", "partition_block", "edge_cut"]


def _locality_topo_order(wf: Workflow) -> list[int]:
    """Kahn's algorithm, ready tasks keyed by most-recent parent.

    Memoized per workflow instance (the k' sweep re-partitions the same
    graph up to k times); the cache key guards against mutation via the
    task/edge counts.
    """
    import heapq

    cached = getattr(wf, "_locality_order_cache", None)
    if cached is not None:
        n, n_edges, order = cached
        if n == wf.n and n_edges == wf.n_edges:
            return order

    indeg = [len(wf.pred[u]) for u in range(wf.n)]
    pos = [-1] * wf.n  # scheduling position of each task
    # key: (-last_parent_position, task id)  → children follow parents
    heap = [(0, u) for u in range(wf.n) if indeg[u] == 0]
    heapq.heapify(heap)
    order: list[int] = []
    while heap:
        _, u = heapq.heappop(heap)
        pos[u] = len(order)
        order.append(u)
        for v in wf.succ[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                last = max(pos[p] for p in wf.pred[v])
                heapq.heappush(heap, (-last, v))
    if len(order) != wf.n:
        raise ValueError("cannot partition a cyclic graph")
    wf._locality_order_cache = (wf.n, wf.n_edges, order)
    return order


def edge_cut(wf: Workflow, block_of: Sequence[int]) -> float:
    """Total weight of edges crossing blocks."""
    return sum(
        c
        for u in range(wf.n)
        for v, c in wf.succ[u].items()
        if block_of[u] != block_of[v]
    )


def acyclic_partition(
    wf: Workflow,
    k: int,
    *,
    eps: float = 0.2,
    passes: int = 4,
) -> list[int]:
    """Acyclic ``k``-way partition of ``wf`` (block ids ``0..k'-1``).

    May return fewer than ``k`` non-empty blocks when ``wf.n < k``
    (paper: the partitioner cannot always reach the requested count).
    Block ids respect topological order: for every edge ``(u, v)``,
    ``block_of[u] <= block_of[v]``.
    """
    n = wf.n
    if n == 0:
        return []
    k = max(1, min(k, n))
    order = _locality_topo_order(wf)
    total = sum(wf.work[u] for u in order) or float(n)
    target = total / k

    # --- contiguous split by cumulative work -------------------------- #
    block_of = [0] * n
    b = 0
    acc = 0.0
    remaining = n
    for idx, u in enumerate(order):
        wu = wf.work[u] if total != float(n) else 1.0
        # close the block if the next task overshoots the target, but
        # keep enough tasks to make all remaining blocks non-empty.
        # open block b+1 only if the remaining tasks (incl. this one)
        # can still populate blocks b+1 .. k-1 with ≥1 task each.
        if (
            b < k - 1
            and acc > 0.0
            and acc + wu > target * 1.0001
            and remaining >= (k - 1 - b)
        ):
            b += 1
            acc = 0.0
        block_of[u] = b
        acc += wu
        remaining -= 1
    k_eff = b + 1

    if k_eff <= 1:
        return block_of

    # --- FM-style boundary refinement --------------------------------- #
    weights = [0.0] * k_eff
    for u in range(n):
        weights[block_of[u]] += wf.work[u]
    cap = (1.0 + eps) * (total / k_eff)

    def gain(u: int, dst: int) -> float:
        src = block_of[u]
        g = 0.0
        for v, c in wf.succ[u].items():
            if block_of[v] == dst:
                g += c
            elif block_of[v] == src:
                g -= c
        for v, c in wf.pred[u].items():
            if block_of[v] == dst:
                g += c
            elif block_of[v] == src:
                g -= c
        return g

    for _ in range(passes):
        improved = False
        for u in range(n):
            src = block_of[u]
            # fused legality/candidacy probe (keys only, no floats):
            # moving down needs no pred in >= src; up needs no succ in
            # <= src; a direction with no edge into the target block
            # has gain <= 0 and is never taken — same decisions as
            # evaluating gain() for every direction, at a fraction of
            # the traversals.
            down_ok = src > 0
            up_ok = src < k_eff - 1
            has_down = has_up = False
            for s in wf.succ[u]:
                b = block_of[s]
                if b <= src:
                    up_ok = False
                if b == src - 1:
                    has_down = True
                elif b == src + 1:
                    has_up = True
            for p in wf.pred[u]:
                b = block_of[p]
                if b >= src:
                    down_ok = False
                if b == src - 1:
                    has_down = True
                elif b == src + 1:
                    has_up = True
            for dst in (src - 1, src + 1):
                if dst < src:
                    if not (down_ok and has_down):
                        continue
                else:
                    if not (up_ok and has_up):
                        continue
                g = gain(u, dst)
                if g <= 0.0:
                    continue
                if weights[dst] + wf.work[u] > cap:
                    continue
                # don't empty a block (keeps k' stable during refinement)
                if weights[src] - wf.work[u] <= 0.0 and sum(
                    1 for x in range(n) if block_of[x] == src
                ) <= 1:
                    continue
                block_of[u] = dst
                weights[src] -= wf.work[u]
                weights[dst] += wf.work[u]
                improved = True
                break
        if not improved:
            break

    # compress ids in case refinement emptied a block entirely
    used = sorted(set(block_of))
    remap = {b: i for i, b in enumerate(used)}
    return [remap[b] for b in block_of]


def partition_block(
    wf: Workflow,
    nodes: Sequence[int],
    parts: int,
    *,
    eps: float = 0.2,
) -> list[list[int]]:
    """Partition a block of ``wf`` into up to ``parts`` sub-blocks.

    Used by the heuristic's FitBlock (paper Algorithm 2).  Returns the
    sub-blocks as lists of *original* task ids (≥ 1 sub-blocks; may be
    fewer than ``parts`` for tiny blocks, may be more only never —
    unlike dagP we control the split exactly, but callers still treat
    the result as "one or more blocks").
    """
    nodes = list(nodes)
    if len(nodes) <= 1 or parts <= 1:
        return [nodes]
    sub, mapping = wf.subgraph(nodes)
    assignment = acyclic_partition(sub, parts, eps=eps)
    groups: dict[int, list[int]] = {}
    for i, b in enumerate(assignment):
        groups.setdefault(b, []).append(mapping[i])
    if len(groups) == 1:
        # safety net: callers (FitBlock) rely on strict progress — fall
        # back to a topological midpoint split.
        order = _locality_topo_order(sub)
        half = len(order) // 2
        first = {order[i] for i in range(half)}
        return [
            [mapping[i] for i in sorted(first)],
            [mapping[i] for i in range(sub.n) if i not in first],
        ]
    return [groups[b] for b in sorted(groups)]
