"""Acyclic DAG partitioning — stand-in for dagP (paper Step 1).

The paper uses the external multilevel partitioner dagP (Herrmann et
al., SISC 2019) as a black box: ``Partition(G, k)`` returns an acyclic
k-way partition optimizing edge cut under a balance constraint.  We
implement the same interface natively (DESIGN.md §3.4):

1. a *locality-preserving topological order* (ready tasks whose parents
   were scheduled most recently go first — keeps chains together),
2. a *contiguous split* of that order into ``k`` chunks of roughly equal
   vertex weight — by construction every edge goes from an
   earlier-or-equal chunk to a later-or-equal chunk, so the quotient
   graph is acyclic,
3. *FM-style boundary refinement*: single-vertex moves between
   neighbouring chunks that reduce the edge cut, constrained so the
   ``b(u) <= b(v)`` invariant (and hence acyclicity) is preserved and
   blocks stay within ``(1 + eps)`` of the weight target.

The refinement is repeated for ``passes`` rounds of best-improvement
sweeps.  Deterministic throughout.

Two implementations share the decision logic (the Step-2 pattern from
:mod:`repro.core.memdag`):

* the **scalar** path walks the adjacency dicts directly,
* the **flat** path works over the CSR snapshot
  (:func:`repro.core.memdag._flat_view`) and replaces the
  all-vertices-per-pass scan with a vectorized boundary prefilter — a
  vertex is only visited when it had a block-distance-1 neighbour at
  pass start or a neighbour moved earlier in the pass.  Every visited
  vertex is then evaluated with verbatim scalar logic, so the flat
  single-level path is *bit-identical in decisions* to the scalar one
  (property-tested in ``tests/test_step1_flat.py``).

:func:`set_step1_impl` selects the path like ``memdag.set_step2_impl``.
``acyclic_partition(..., multilevel=True)`` additionally enables
**multilevel** partitioning (coarsen → partition → uncoarsen, the dagP
shape): deterministic heavy-edge acyclic coarsening contracts only
edges whose contraction keeps the quotient acyclic, the coarsest graph
is partitioned with the standard path, and each level refines with the
flat FM sweep.  Multilevel intentionally changes cuts, so it is opt-in
(``SchedulerConfig.step1_multilevel``), never part of ``"auto"``.
"""
from __future__ import annotations

import heapq
from typing import Sequence

import numpy as np

from . import counters
from .dag import Workflow
from .memdag import _flat_view

__all__ = [
    "acyclic_partition",
    "partition_block",
    "edge_cut",
    "set_step1_impl",
    "step1_impl",
]

#: Step-1 partitioner implementation: "auto" dispatches large graphs to
#: the flat-array path and small ones to the scalar path; "scalar" /
#: "flat" force one side (property tests, benchmarks).  Both paths are
#: bit-identical (see docs/architecture.md, "Flat-array Step 1").
_STEP1_IMPL = "auto"

#: graphs below this many tasks stay on the scalar path in "auto" mode —
#: the numpy prefilter and CSR gathers only amortize once a pass over
#: all vertices costs more than a few array ops (measured crossover in
#: the few-hundreds of tasks).
_FLAT_CUTOVER = 512

#: multilevel coarsening stops once a level has at most
#: ``max(8 * k, _COARSEN_FLOOR)`` vertices — enough resolution for the
#: contiguous split to balance k blocks well.
_COARSEN_FLOOR = 256

#: bounded DFS budget of the coarsening cycle probe; on exhaustion the
#: candidate edge is conservatively rejected (never contracted), which
#: preserves acyclicity at worst coarsening speed.
_PROBE_CAP = 64


def set_step1_impl(mode: str) -> str:
    """Select the Step-1 implementation; returns the previous mode.

    ``"auto"`` (default) uses the flat-array path for graphs of at
    least ``_FLAT_CUTOVER`` tasks and the scalar path below;
    ``"scalar"`` / ``"flat"`` force one implementation everywhere.
    Results are bit-identical in every mode (asserted by
    ``tests/test_step1_flat.py``); the knob exists for benchmarks
    (``make bench-step1`` records the scalar-vs-flat Step-1 share
    under ``"step1"`` in ``BENCH_runtime.json``) and property tests.
    """
    global _STEP1_IMPL
    if mode not in ("auto", "scalar", "flat"):
        raise ValueError(f"unknown Step-1 impl {mode!r}")
    prev = _STEP1_IMPL
    _STEP1_IMPL = mode
    return prev


def step1_impl() -> str:
    """The currently selected Step-1 implementation mode."""
    return _STEP1_IMPL


def _use_flat(n: int) -> bool:
    """Dispatch predicate of :func:`acyclic_partition`."""
    if _STEP1_IMPL == "flat":
        return True
    return _STEP1_IMPL == "auto" and n >= _FLAT_CUTOVER


# ---------------------------------------------------------------------- #
# locality order (shared by both paths)
# ---------------------------------------------------------------------- #
def _order_and_total(wf: Workflow) -> tuple[list[int], float]:
    """Locality topo order plus total work, memoized per workflow.

    Kahn's algorithm with ready tasks keyed by most-recent parent.  The
    k' sweep re-partitions the same graph up to k times, so the order
    (and the total, whose float association the contiguous split's
    decisions depend on) is cached on the instance.  The cache key
    includes the task/edge counts *and* the workflow mutation counter
    (``Workflow._version``), so a same-shape edit — e.g. accumulating
    cost onto an existing edge — can never return a stale order.
    """
    cached = getattr(wf, "_locality_order_cache", None)
    version = getattr(wf, "_version", 0)
    if cached is not None:
        n, n_edges, ver, order, total = cached
        if n == wf.n and n_edges == wf.n_edges and ver == version:
            return order, total

    indeg = [len(wf.pred[u]) for u in range(wf.n)]
    pos = [-1] * wf.n  # scheduling position of each task
    # key: (-last_parent_position, task id)  → children follow parents
    heap = [(0, u) for u in range(wf.n) if indeg[u] == 0]
    heapq.heapify(heap)
    order: list[int] = []
    while heap:
        _, u = heapq.heappop(heap)
        pos[u] = len(order)
        order.append(u)
        for v in wf.succ[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                last = max(pos[p] for p in wf.pred[v])
                heapq.heappush(heap, (-last, v))
    if len(order) != wf.n:
        raise ValueError("cannot partition a cyclic graph")
    total = sum(wf.work[u] for u in order) or float(wf.n)
    wf._locality_order_cache = (wf.n, wf.n_edges, version, order, total)
    return order, total


def _locality_topo_order(wf: Workflow) -> list[int]:
    """Kahn's algorithm, ready tasks keyed by most-recent parent."""
    return _order_and_total(wf)[0]


def edge_cut(wf: Workflow, block_of: Sequence[int]) -> float:
    """Total weight of edges crossing blocks.

    Large graphs take a vectorized path over the CSR snapshot; its
    pairwise float summation can differ from the scalar loop's
    sequential association by rounding noise, which is fine for an
    observability metric (never a scheduling decision input).
    """
    if wf.n_edges >= 2048:
        fv = _flat_view(wf)
        b = np.asarray(block_of, dtype=np.int64)
        e_src = np.repeat(np.arange(wf.n, dtype=np.int64),
                          np.diff(fv.s_indptr))
        return float(fv.s_cost[b[e_src] != b[fv.s_dst]].sum())
    return sum(
        c
        for u in range(wf.n)
        for v, c in wf.succ[u].items()
        if block_of[u] != block_of[v]
    )


# ---------------------------------------------------------------------- #
# contiguous split (shared decision logic of both paths)
# ---------------------------------------------------------------------- #
def _contiguous_split(
    order: list[int], work: Sequence[float], total: float, k: int
) -> tuple[list[int], int]:
    """Split ``order`` into ≤ k contiguous chunks of ~equal work.

    Returns ``(block_of, k_eff)``.  Every edge then goes from an
    earlier-or-equal chunk to a later-or-equal chunk, so the quotient
    is acyclic by construction.
    """
    n = len(order)
    block_of = [0] * n
    b = 0
    acc = 0.0
    remaining = n
    uniform = total == float(n)
    target = total / k
    thresh = target * 1.0001
    for u in order:
        wu = 1.0 if uniform else work[u]
        # close the block if the next task overshoots the target, but
        # keep enough tasks to make all remaining blocks non-empty.
        # open block b+1 only if the remaining tasks (incl. this one)
        # can still populate blocks b+1 .. k-1 with ≥1 task each.
        if (
            b < k - 1
            and acc > 0.0
            and acc + wu > thresh
            and remaining >= (k - 1 - b)
        ):
            b += 1
            acc = 0.0
        block_of[u] = b
        acc += wu
        remaining -= 1
    return block_of, b + 1


def _compress_ids(block_of: list[int]) -> list[int]:
    """Compact block ids (refinement may empty a block entirely)."""
    used = sorted(set(block_of))
    remap = {b: i for i, b in enumerate(used)}
    return [remap[b] for b in block_of]


# ---------------------------------------------------------------------- #
# scalar path
# ---------------------------------------------------------------------- #
def _acyclic_partition_scalar(
    wf: Workflow, k: int, eps: float, passes: int
) -> list[int]:
    n = wf.n
    counters.bump("step1_scalar_calls")
    order, total = _order_and_total(wf)
    block_of, k_eff = _contiguous_split(order, wf.work, total, k)
    if k_eff <= 1:
        return block_of

    # --- FM-style boundary refinement --------------------------------- #
    weights = [0.0] * k_eff
    counts = [0] * k_eff  # O(1) "don't empty a block" guard
    for u in range(n):
        weights[block_of[u]] += wf.work[u]
        counts[block_of[u]] += 1
    cap = (1.0 + eps) * (total / k_eff)

    def gain(u: int, dst: int) -> float:
        src = block_of[u]
        g = 0.0
        for v, c in wf.succ[u].items():
            if block_of[v] == dst:
                g += c
            elif block_of[v] == src:
                g -= c
        for v, c in wf.pred[u].items():
            if block_of[v] == dst:
                g += c
            elif block_of[v] == src:
                g -= c
        return g

    moves = 0
    passes_run = 0
    for _ in range(passes):
        passes_run += 1
        improved = False
        for u in range(n):
            src = block_of[u]
            # fused legality/candidacy probe (keys only, no floats):
            # moving down needs no pred in >= src; up needs no succ in
            # <= src; a direction with no edge into the target block
            # has gain <= 0 and is never taken — same decisions as
            # evaluating gain() for every direction, at a fraction of
            # the traversals.
            down_ok = src > 0
            up_ok = src < k_eff - 1
            has_down = has_up = False
            for s in wf.succ[u]:
                b = block_of[s]
                if b <= src:
                    up_ok = False
                if b == src - 1:
                    has_down = True
                elif b == src + 1:
                    has_up = True
            for p in wf.pred[u]:
                b = block_of[p]
                if b >= src:
                    down_ok = False
                if b == src - 1:
                    has_down = True
                elif b == src + 1:
                    has_up = True
            for dst in (src - 1, src + 1):
                if dst < src:
                    if not (down_ok and has_down):
                        continue
                else:
                    if not (up_ok and has_up):
                        continue
                g = gain(u, dst)
                if g <= 0.0:
                    continue
                if weights[dst] + wf.work[u] > cap:
                    continue
                # don't empty a block (keeps k' stable during refinement)
                if weights[src] - wf.work[u] <= 0.0 and counts[src] <= 1:
                    continue
                block_of[u] = dst
                weights[src] -= wf.work[u]
                weights[dst] += wf.work[u]
                counts[src] -= 1
                counts[dst] += 1
                moves += 1
                improved = True
                break
        if not improved:
            break
    counters.bump("step1_moves", moves)
    counters.bump("step1_passes", passes_run)

    return _compress_ids(block_of)


# ---------------------------------------------------------------------- #
# flat path: CSR refinement with a vectorized boundary prefilter
# ---------------------------------------------------------------------- #
def _refine_csr(
    lists: tuple,
    arrs: tuple,
    work: Sequence[float],
    block_of: list[int],
    k_eff: int,
    weights: list[float],
    counts: list[int],
    cap: float,
    passes: int,
) -> tuple[int, int]:
    """FM refinement over CSR adjacency lists — scalar decisions, flat scan.

    Replays the scalar pass exactly: the numpy prefilter only *selects*
    which vertices can possibly move, and every visited vertex is
    evaluated with the verbatim scalar legality/gain/cap logic, in
    ascending id order exactly as the scalar loop reaches them.  The
    prefilter keeps a vertex iff, at pass-start state, one direction's
    gates pass — ``has_up`` needs a successor one block ahead and
    ``up_ok`` additionally no successor in the own block (dually for
    down via predecessors; with the ``b[u] <= b[v]`` invariant those
    are the only ways the scalar gates can open) — *and* that
    direction's gain is positive.  Gate comparisons are integer; the
    pass-start gains are bit-exact replicas of the scalar
    accumulation: ``np.bincount`` adds its weights sequentially in
    input order, the concatenated (successor CSR, predecessor CSR)
    edge stream visits each vertex's terms in exactly the scalar
    interleaving, and the zero terms ``np.where`` contributes for
    uninvolved edges cannot perturb an IEEE sum (``x + 0.0 == x``; no
    ``-0.0`` arises from ``+c``/``-c`` cancellation).  A skipped
    vertex therefore falls through the scalar loop's gates or its
    ``g <= 0.0`` check with no side effects — unless a
    earlier-positioned neighbour moved first, in which case the move
    pushes it into the dirty min-heap and it is replayed at its scalar
    position.  Mutates ``block_of`` / ``weights`` / ``counts`` in
    place; returns ``(moves, passes_run)``.
    """
    si, sd, sc, pi, ps, pc = lists
    e_src, e_dst, s_cost, p_edst, p_src, p_cost = arrs
    n = len(block_of)
    b_arr = np.fromiter(block_of, dtype=np.int64, count=n)
    cat_bins = np.concatenate([e_src, p_edst])

    moves = 0
    passes_run = 0
    for _ in range(passes):
        passes_run += 1
        bu = b_arr[e_src]
        bv = b_arr[e_dst]
        d = bv - bu
        delta1 = d == 1
        same = d == 0
        has_up = np.zeros(n, dtype=bool)
        has_up[e_src[delta1]] = True
        has_down = np.zeros(n, dtype=bool)
        has_down[e_dst[delta1]] = True
        up_fail = np.zeros(n, dtype=bool)
        up_fail[e_src[same]] = True        # a successor in the own block
        down_fail = np.zeros(n, dtype=bool)
        down_fail[e_dst[same]] = True      # a predecessor in the own block
        # pass-start gains, scalar association (see docstring)
        bp = b_arr[p_src]
        bup = b_arr[p_edst]
        w_up = np.concatenate([
            np.where(delta1, s_cost, np.where(same, -s_cost, 0.0)),
            np.where(bp == bup, -p_cost, 0.0),
        ])
        w_down = np.concatenate([
            np.where(same, -s_cost, 0.0),
            np.where(bp == bup - 1, p_cost,
                     np.where(bp == bup, -p_cost, 0.0)),
        ])
        gain_up = np.bincount(cat_bins, weights=w_up, minlength=n)
        gain_down = np.bincount(cat_bins, weights=w_down, minlength=n)
        cand = np.flatnonzero(
            (has_up & ~up_fail & (b_arr < k_eff - 1) & (gain_up > 0.0))
            | (has_down & ~down_fail & (b_arr > 0) & (gain_down > 0.0))
        ).tolist()
        improved = False
        visited = bytearray(n)
        dirty: list[int] = []  # min-heap of not-yet-reached neighbours
        i = 0
        ncand = len(cand)
        bl = block_of
        while i < ncand or dirty:
            if dirty and (i >= ncand or dirty[0] < cand[i]):
                u = heapq.heappop(dirty)
            else:
                u = cand[i]
                i += 1
            if visited[u]:
                continue
            visited[u] = 1
            src = bl[u]
            # one fused sweep per adjacency side: the scalar legality
            # flags plus *both* direction gains.  Each gain variable
            # accumulates exactly the ±c sequence the scalar gain()
            # loop would produce for that direction (same edges, same
            # order), so the floats are bit-identical.
            down_ok = src > 0
            up_ok = src < k_eff - 1
            has_down = has_up = False
            g_down = 0.0
            g_up = 0.0
            later: list[int] = []  # dirty queue if the move is taken
            s0, s1 = si[u], si[u + 1]
            for j in range(s0, s1):
                w = sd[j]
                b = bl[w]
                if b <= src:
                    up_ok = False
                    if b == src:
                        c = sc[j]
                        g_down -= c
                        g_up -= c
                    elif b == src - 1:
                        has_down = True
                        g_down += sc[j]
                elif b == src + 1:
                    has_up = True
                    g_up += sc[j]
                if w > u and not visited[w]:
                    later.append(w)
            p0, p1 = pi[u], pi[u + 1]
            for j in range(p0, p1):
                w = ps[j]
                b = bl[w]
                if b >= src:
                    down_ok = False
                    if b == src:
                        c = pc[j]
                        g_down -= c
                        g_up -= c
                    elif b == src + 1:
                        has_up = True
                        g_up += pc[j]
                elif b == src - 1:
                    has_down = True
                    g_down += pc[j]
                if w > u and not visited[w]:
                    later.append(w)
            for dst in (src - 1, src + 1):
                if dst < src:
                    if not (down_ok and has_down):
                        continue
                    g = g_down
                else:
                    if not (up_ok and has_up):
                        continue
                    g = g_up
                if g <= 0.0:
                    continue
                wu = work[u]
                if weights[dst] + wu > cap:
                    continue
                if weights[src] - wu <= 0.0 and counts[src] <= 1:
                    continue
                bl[u] = dst
                b_arr[u] = dst
                weights[src] -= wu
                weights[dst] += wu
                counts[src] -= 1
                counts[dst] += 1
                moves += 1
                improved = True
                # the move can newly enable neighbours the scalar loop
                # has not reached yet (ids > u) — queue them for replay
                for w in later:
                    heapq.heappush(dirty, w)
                break
        if not improved:
            break
    return moves, passes_run


def _edge_endpoints(s_indptr: np.ndarray) -> np.ndarray:
    """Edge source ids matching the CSR edge order."""
    n = len(s_indptr) - 1
    return np.repeat(np.arange(n, dtype=np.int64), np.diff(s_indptr))


def _csr_lists(wf: Workflow, fv) -> tuple[tuple, np.ndarray]:
    """CSR adjacency as plain lists plus the edge-source array.

    The sequential replay indexes the adjacency per visited vertex;
    plain-list indexing beats numpy scalar indexing by ~5x there, and
    the k' sweep re-partitions the same workflow up to k times, so the
    converted lists are cached per instance.  Validity is by identity
    of the underlying :class:`_FlatWorkflow` view — ``_flat_view``
    already rebuilds a fresh object on any mutation it can observe.
    """
    cached = getattr(wf, "_step1_lists_cache", None)
    if cached is not None and cached[0] is fv:
        return cached[1], cached[2]
    lists = (fv.s_indptr.tolist(), fv.s_dst.tolist(), fv.s_cost.tolist(),
             fv.p_indptr.tolist(), fv.p_src.tolist(), fv.p_cost.tolist())
    arrs = (_edge_endpoints(fv.s_indptr), fv.s_dst, fv.s_cost,
            _edge_endpoints(fv.p_indptr), fv.p_src, fv.p_cost)
    wf._step1_lists_cache = (fv, lists, arrs)
    return lists, arrs


def _cut_of(b_arr: np.ndarray, e_src: np.ndarray, e_dst: np.ndarray,
            s_cost: np.ndarray) -> float:
    return float(s_cost[b_arr[e_src] != b_arr[e_dst]].sum())


def _acyclic_partition_flat(
    wf: Workflow, k: int, eps: float, passes: int
) -> list[int]:
    n = wf.n
    counters.bump("step1_flat_calls")
    order, total = _order_and_total(wf)
    block_of, k_eff = _contiguous_split(order, wf.work, total, k)
    if k_eff <= 1:
        return block_of

    fv = _flat_view(wf)
    lists, arrs = _csr_lists(wf, fv)
    e_src, e_dst = arrs[0], arrs[1]
    b_arr = np.fromiter(block_of, dtype=np.int64, count=n)
    counters.bump("step1_cut_before",
                  int(round(_cut_of(b_arr, e_src, e_dst, fv.s_cost))))
    work_np = np.asarray(wf.work, dtype=np.float64)
    # bincount accumulates sequentially in input order — the same float
    # association as the scalar path's per-vertex loop
    weights = np.bincount(b_arr, weights=work_np, minlength=k_eff).tolist()
    counts = np.bincount(b_arr, minlength=k_eff).tolist()
    cap = (1.0 + eps) * (total / k_eff)

    moves, passes_run = _refine_csr(
        lists, arrs, wf.work, block_of, k_eff, weights, counts,
        cap, passes)
    counters.bump("step1_moves", moves)
    counters.bump("step1_passes", passes_run)
    b_arr = np.fromiter(block_of, dtype=np.int64, count=n)
    counters.bump("step1_cut_after",
                  int(round(_cut_of(b_arr, e_src, e_dst, fv.s_cost))))

    return _compress_ids(block_of)


# ---------------------------------------------------------------------- #
# multilevel path: coarsen -> partition -> uncoarsen (dagP shape)
# ---------------------------------------------------------------------- #
# A level is the tuple (s_indptr, s_dst, s_cost, p_indptr, p_src,
# p_cost, work) of numpy arrays; level 0 is the workflow's CSR view.


def _no_alternative_path(
    u: int, v: int, si: list, sd: list, mate: list[int]
) -> bool:
    """No u→v path besides the direct edge, in the contracted-so-far
    graph (clusters expanded through ``mate``).  Conservative: returns
    False — "assume a path exists" — when the bounded DFS gives up, so
    a True answer is always safe to contract on.
    """
    if si[u + 1] - si[u] > _PROBE_CAP:
        return False  # hub source: seeding alone would blow the budget
    stack: list[int] = []
    seen = {u, v}
    for j in range(si[u], si[u + 1]):
        w = sd[j]
        if w == v:
            continue  # the edge being contracted
        if w not in seen:
            seen.add(w)
            stack.append(w)
    budget = _PROBE_CAP
    while stack:
        x = stack.pop()
        budget -= 1
        if budget < 0:
            return False
        mx = mate[x]
        if mx == -1:
            group = (x,)
        else:
            seen.add(mx)
            group = (x, mx)
        for y in group:
            if si[y + 1] - si[y] > _PROBE_CAP:
                return False  # hub expansion would blow the budget
            for j in range(si[y], si[y + 1]):
                w = sd[j]
                if w == v:
                    return False
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
    return True


def _coarsen_match(level: tuple, max_cluster: float) -> tuple[np.ndarray, int]:
    """One round of deterministic heavy-edge acyclic matching.

    Edges are visited heaviest-first (ties: ascending (src, dst)) and
    contracted when both endpoints are free, the merged weight respects
    ``max_cluster``, and the contraction provably keeps the quotient
    acyclic: contracting ``u→v`` is safe iff no alternative u→v path
    exists.  Two O(1) certificates skip the probe — ``outdeg(u) == 1``
    (every exit of the pair leaves from v) and ``indeg(v) == 1`` (every
    entry arrives at u) — otherwise a bounded DFS over the
    contracted-so-far graph decides, rejecting on budget exhaustion.
    Returns ``(cluster_of, n_clusters)`` with clusters numbered by
    ascending smallest member.
    """
    s_indptr, s_dst, s_cost, p_indptr, _p_src, _p_cost, work = level
    n = len(work)
    e_src = _edge_endpoints(s_indptr)
    order = np.lexsort((s_dst, e_src, -s_cost))
    es = e_src[order].tolist()
    ed = s_dst[order].tolist()
    si = s_indptr.tolist()
    sd = s_dst.tolist()
    outdeg = np.diff(s_indptr).tolist()
    indeg = np.diff(p_indptr).tolist()
    work_l = work.tolist()
    mate = [-1] * n
    for idx in range(len(es)):
        u = es[idx]
        if mate[u] != -1:
            continue
        v = ed[idx]
        if mate[v] != -1:
            continue
        if work_l[u] + work_l[v] > max_cluster:
            continue
        if outdeg[u] == 1 or indeg[v] == 1 or \
                _no_alternative_path(u, v, si, sd, mate):
            mate[u] = v
            mate[v] = u
    cid = np.empty(n, dtype=np.int64)
    nc = 0
    for u in range(n):
        m = mate[u]
        if m == -1 or m > u:
            cid[u] = nc
            if m != -1:
                cid[m] = nc
            nc += 1
    return cid, nc


def _contract_level(level: tuple, cid: np.ndarray, nc: int) -> tuple:
    """The quotient of ``level`` under ``cid`` (vectorized build)."""
    s_indptr, s_dst, s_cost, _pi, _ps, _pc, work = level
    e_src = _edge_endpoints(s_indptr)
    cwork = np.bincount(cid, weights=work, minlength=nc)
    eu = cid[e_src]
    ev = cid[s_dst]
    keep = eu != ev
    key = eu[keep] * np.int64(nc) + ev[keep]
    uniq, inv = np.unique(key, return_inverse=True)
    ccost = np.bincount(inv, weights=s_cost[keep])
    cu = (uniq // nc).astype(np.int64)
    cv = (uniq % nc).astype(np.int64)
    cs_indptr = np.zeros(nc + 1, dtype=np.int64)
    np.cumsum(np.bincount(cu, minlength=nc), out=cs_indptr[1:])
    po = np.lexsort((cu, cv))
    cp_indptr = np.zeros(nc + 1, dtype=np.int64)
    np.cumsum(np.bincount(cv, minlength=nc), out=cp_indptr[1:])
    return (cs_indptr, cv, ccost, cp_indptr, cu[po], ccost[po], cwork)


def _level_lists(level: tuple) -> tuple:
    """A level's CSR adjacency converted to plain lists."""
    return (level[0].tolist(), level[1].tolist(), level[2].tolist(),
            level[3].tolist(), level[4].tolist(), level[5].tolist())


def _csr_locality_order(level: tuple) -> list[int]:
    """The locality topo order of a level (array-backed Kahn)."""
    s_indptr, s_dst, _sc, p_indptr, p_src, _pc, work = level
    n = len(work)
    si = s_indptr.tolist()
    sd = s_dst.tolist()
    pi = p_indptr.tolist()
    ps = p_src.tolist()
    indeg = [pi[u + 1] - pi[u] for u in range(n)]
    pos = [-1] * n
    heap = [(0, u) for u in range(n) if indeg[u] == 0]
    heapq.heapify(heap)
    order: list[int] = []
    while heap:
        _, u = heapq.heappop(heap)
        pos[u] = len(order)
        order.append(u)
        for j in range(si[u], si[u + 1]):
            v = sd[j]
            indeg[v] -= 1
            if indeg[v] == 0:
                last = max(pos[ps[jj]] for jj in range(pi[v], pi[v + 1]))
                heapq.heappush(heap, (-last, v))
    if len(order) != n:
        raise ValueError("coarse level is cyclic — contraction bug")
    return order


def _partition_level(level: tuple, k: int, eps: float,
                     passes: int) -> np.ndarray:
    """Split-and-refine one level; returns a compact block array."""
    work = level[6]
    nl = len(work)
    k = max(1, min(k, nl))
    order = _csr_locality_order(level)
    work_l = work.tolist()
    total = sum(work_l[u] for u in order) or float(nl)
    block_of, k_eff = _contiguous_split(order, work_l, total, k)
    if k_eff > 1:
        arrs = (_edge_endpoints(level[0]), level[1], level[2],
                _edge_endpoints(level[3]), level[4], level[5])
        b_arr = np.fromiter(block_of, dtype=np.int64, count=nl)
        weights = np.bincount(b_arr, weights=work,
                              minlength=k_eff).tolist()
        counts = np.bincount(b_arr, minlength=k_eff).tolist()
        cap = (1.0 + eps) * (total / k_eff)
        moves, passes_run = _refine_csr(
            _level_lists(level), arrs, work_l, block_of,
            k_eff, weights, counts, cap, passes)
        counters.bump("step1_moves", moves)
        counters.bump("step1_passes", passes_run)
    block = np.fromiter(block_of, dtype=np.int64, count=nl)
    used = np.unique(block)
    return np.searchsorted(used, block)


def _multilevel_partition(
    wf: Workflow, k: int, eps: float, passes: int
) -> list[int]:
    counters.bump("step1_multilevel_calls")
    fv = _flat_view(wf)
    work = np.asarray(wf.work, dtype=np.float64)
    total = float(work.sum()) or float(wf.n)
    levels = [(fv.s_indptr, fv.s_dst, fv.s_cost,
               fv.p_indptr, fv.p_src, fv.p_cost, work)]
    maps: list[np.ndarray] = []
    floor = max(8 * k, _COARSEN_FLOOR)
    max_cluster = total / float(k)
    while len(levels[-1][6]) > floor:
        ln = len(levels[-1][6])
        cid, nc = _coarsen_match(levels[-1], max_cluster)
        if nc > 0.97 * ln:  # matching stalled — coarser won't help
            break
        levels.append(_contract_level(levels[-1], cid, nc))
        maps.append(cid)
    counters.bump("step1_coarsen_levels", len(maps))

    block = _partition_level(levels[-1], k, eps, passes)

    for lvl in range(len(maps) - 1, -1, -1):
        block = block[maps[lvl]]  # project onto the finer level
        level = levels[lvl]
        work_lv = level[6]
        nl = len(work_lv)
        e_src = _edge_endpoints(level[0])
        e_dst = level[1]
        if not bool((block[e_src] <= block[e_dst]).all()):
            raise RuntimeError(
                "multilevel projection broke the topological-id "
                "invariant — coarsening contracted a cycle-creating edge"
            )
        if lvl == 0:
            counters.bump(
                "step1_cut_before",
                int(round(_cut_of(block, e_src, e_dst, level[2]))))
        k_eff = int(block.max()) + 1
        if k_eff > 1:
            block_of = block.tolist()
            work_l = work_lv.tolist()
            weights = np.bincount(block, weights=work_lv,
                                  minlength=k_eff).tolist()
            counts = np.bincount(block, minlength=k_eff).tolist()
            ltotal = float(work_lv.sum()) or float(nl)
            cap = (1.0 + eps) * (ltotal / k_eff)
            if lvl == 0:
                lists, arrs = _csr_lists(wf, fv)
            else:
                lists = _level_lists(level)
                arrs = (e_src, e_dst, level[2],
                        _edge_endpoints(level[3]), level[4], level[5])
            moves, passes_run = _refine_csr(
                lists, arrs, work_l, block_of, k_eff,
                weights, counts, cap, passes)
            counters.bump("step1_moves", moves)
            counters.bump("step1_passes", passes_run)
            block = np.fromiter(block_of, dtype=np.int64, count=nl)
        used = np.unique(block)
        if len(used) != k_eff:
            block = np.searchsorted(used, block)
        if lvl == 0:
            counters.bump(
                "step1_cut_after",
                int(round(_cut_of(block, e_src, e_dst, level[2]))))
    return block.tolist()


# ---------------------------------------------------------------------- #
# public entry points
# ---------------------------------------------------------------------- #
def acyclic_partition(
    wf: Workflow,
    k: int,
    *,
    eps: float = 0.2,
    passes: int = 4,
    multilevel: bool = False,
) -> list[int]:
    """Acyclic ``k``-way partition of ``wf`` (block ids ``0..k'-1``).

    May return fewer than ``k`` non-empty blocks when ``wf.n < k``
    (paper: the partitioner cannot always reach the requested count).
    Block ids respect topological order: for every edge ``(u, v)``,
    ``block_of[u] <= block_of[v]``.

    ``multilevel=True`` opts into coarsen→partition→uncoarsen (dagP
    shape) for large graphs — it changes cuts (usually for the better
    at n ≥ 10⁵) and is therefore never chosen implicitly; small graphs
    fall through to the single-level path.  The single-level result is
    bit-identical across :func:`set_step1_impl` modes.
    """
    n = wf.n
    if n == 0:
        return []
    k = max(1, min(k, n))
    if multilevel and n >= 2 * max(8 * k, _COARSEN_FLOOR):
        return _multilevel_partition(wf, k, eps, passes)
    if _use_flat(n):
        return _acyclic_partition_flat(wf, k, eps, passes)
    return _acyclic_partition_scalar(wf, k, eps, passes)


def partition_block(
    wf: Workflow,
    nodes: Sequence[int],
    parts: int,
    *,
    eps: float = 0.2,
) -> list[list[int]]:
    """Partition a block of ``wf`` into up to ``parts`` sub-blocks.

    Used by the heuristic's FitBlock (paper Algorithm 2).  Returns the
    sub-blocks as lists of *original* task ids (≥ 1 sub-blocks; may be
    fewer than ``parts`` for tiny blocks, may be more only never —
    unlike dagP we control the split exactly, but callers still treat
    the result as "one or more blocks").  Goes through the same
    scalar/flat dispatch as :func:`acyclic_partition`, so large
    FitBlock splits ride the flat path too.
    """
    nodes = list(nodes)
    if len(nodes) <= 1 or parts <= 1:
        return [nodes]
    sub, mapping = wf.subgraph(nodes)
    assignment = acyclic_partition(sub, parts, eps=eps)
    groups: dict[int, list[int]] = {}
    for i, b in enumerate(assignment):
        groups.setdefault(b, []).append(mapping[i])
    if len(groups) == 1:
        # safety net: callers (FitBlock) rely on strict progress — fall
        # back to a topological midpoint split.
        order = _locality_topo_order(sub)
        half = len(order) // 2
        first = {order[i] for i in range(half)}
        return [
            [mapping[i] for i in sorted(first)],
            [mapping[i] for i in range(sub.n) if i not in first],
        ]
    return [groups[b] for b in sorted(groups)]
