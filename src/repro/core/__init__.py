"""repro.core — the paper's contribution.

Memory-constrained partitioning and mapping of DAG workflows onto
heterogeneous platforms (Kulagina, Meyerhenke, Benoit — ICPP'24):

* :mod:`repro.core.dag` — workflow / quotient-graph model,
* :mod:`repro.core.platform` — heterogeneous clusters (paper Tables 2–3
  plus TPU-fleet presets),
* :mod:`repro.core.memdag` — min-peak-memory traversals (MemDag role),
* :mod:`repro.core.partitioner` — acyclic DAG partitioning (dagP role),
* :mod:`repro.core.makespan` — bottom weights / makespan / critical path,
* :mod:`repro.core.incremental` — delta-evaluated makespan engine
  (bounded probes + transactional merges for the heuristic hot paths),
* :mod:`repro.core.baseline` — DagHetMem,
* :mod:`repro.core.heuristic` — DagHetPart (the four-step heuristic),
* :mod:`repro.core.scheduler` — the unified Scheduler/Plan API,
* :mod:`repro.core.workflows` — workflow-instance generators,
* :mod:`repro.core.modelgraph` — model architectures as workflow DAGs,
* :mod:`repro.core.autoshard` — placement planning for the JAX runtime,
* :mod:`repro.core.counters` — perf-cache counters surfaced as
  ``ScheduleReport.cache_stats``.

Layered on top: :mod:`repro.sim` (discrete-event execution),
:mod:`repro.scenario` (platform timelines + pause/replan/stitch) and
:mod:`repro.service` (continuous multi-workflow operation — the
service loop drives ``Scheduler.seeded`` for plan-cache hits and
``Scheduler.resume`` for event-driven warm replans).

Start with the top-level ``README.md`` for the quickstart and
subsystem map; ``docs/architecture.md`` covers the pipeline-stage
registry, the warm-start flow, the service layer and the scaling
machinery, and ``docs/benchmarks.md`` the ``BENCH_runtime.json``
schema.  All code fences in those documents are executable
(``make docs-check``).

Scheduling API
--------------
:class:`~repro.core.scheduler.Scheduler` is the entry point for all
mapping runs.  It executes registered pipeline *stages*; the paper's
steps map to stage names as follows:

========  ============  ===============================================
paper     stage name    role
========  ============  ===============================================
Step 1    partition     acyclic k'-way partition (dagP role)
Step 2    assign        BiggestAssign/FitBlock (Algorithms 1–2)
Step 3    merge         MergeUnassignedToAssigned (Algorithms 3–4)
Step 4    swap          best-improvement block swaps (Algorithm 5)
Step 4    idle_moves    critical-path moves to faster idle processors
§4.1      pack          DagHetMem min-peak traversal packing
========  ============  ===============================================

``schedule(wf, platform, kprime=[1, 4, 9], workers=4)`` sweeps the k'
values (in parallel for ``workers > 1``, bit-identical best makespans)
and always returns a :class:`~repro.core.scheduler.ScheduleReport`:
the best :class:`MappingResult` *or* a structured
:class:`~repro.core.scheduler.Infeasibility`, plus per-stage timings,
per-run cache statistics (``cache_stats``) and the full k'→makespan
sweep trace (``to_json``/``from_json`` for benchmark artifacts).  The
legacy :func:`dag_het_part` / :func:`dag_het_mem` entry points are
deprecated thin wrappers over it.

Scaling (30k–1M-task instances)
-------------------------------
All four ROADMAP hot spots are closed: the k' sweep parallelizes
(PR 2); Step 2 runs on flat numpy arrays — a cached CSR view of the
workflow with token-stamped per-task vectors computes every block's
``during``/``delta`` constants via sequential ``np.bincount`` (bit-
identical floats) and the greedy ready-heap pops ``np.lexsort`` ranks;
committed Step-3 merges keep topological ranks exact through
Pearce–Kelly localized reordering, which also bounds the merge
acyclicity probe to the affected rank window; and Step-4 rescans reuse
probe verdicts whose dependency region an applied swap did not touch.
Step 1 rides the same pattern (:func:`set_step1_impl`, default
``"auto"``): refinement replays the scalar move sequence over the
shared CSR view behind an exact vectorized gain/legality prefilter,
and an opt-in multilevel mode (``SchedulerConfig(step1_multilevel=
True)``) coarsens by acyclic heavy-edge matching so n=100k–1M
partitions complete in seconds.  Every layer is decision-for-decision
identical to the scalar/uncached paths (property-tested); ``make
bench-large`` / ``make bench-step1`` record the before/after under
``"step2"`` / ``"step1"`` in ``BENCH_runtime.json``.  Design notes in
``docs/architecture.md``.

Simulation
----------
The analytic makespan is a *proxy*; :mod:`repro.sim` is the ground
truth that executes a mapping as a discrete-event schedule replay::

    from repro.sim import simulate
    sim = simulate(schedule(wf, platform).best)   # paper comm model
    sim.makespan      # bit-identical to makespan(q, platform)
    sim.memory        # time-resolved occupancy + transient violations
    print(sim.gantt())

or inline, as the optional ``simulate`` pipeline stage:
``schedule(wf, platform, simulate=True).sim``.  Communication models
are pluggable (``comm="contention-free"`` — the paper's β model, whose
deterministic replay is the bit-exact anchor — or ``comm="fair-share"``
for max-min egress/ingress/link sharing; implement the small protocol
in :mod:`repro.sim.comm` to add one).  ``jitter=σ, replicas=N`` adds a
seeded robustness envelope.  ``validate_mapping(...,
memory_trace=True)`` replays the schedule through the simulator's
memory tracker and pinpoints the first time/processor of any transient
violation — feasibility of the *trace*, not just of the block sums.
Per-link bandwidth overrides (:meth:`Platform.with_link_bandwidth`,
composable with :meth:`Platform.without` for failure scenarios) are
honoured by the simulator while the analytic formula keeps the uniform
β; ``make bench-sim`` tracks the resulting gap.  Workflows serialize
via :func:`repro.core.workflows.to_json` / ``from_json`` (a
WfCommons-flavored schema) so instances and traces can be saved,
reloaded and swapped for real dumps later.

Scenarios & replanning
----------------------
:mod:`repro.scenario` turns the static platform into a timeline: a
``Scenario`` is a workflow + platform + ordered ``PlatformEvent`` list
(``ProcFailure`` / ``ProcArrival`` / ``SpeedChange`` /
``LinkDegrade``), and ``run_scenario(scenario, policy)`` executes it —
simulate, pause the engine at each event (``run_engine(...,
stop_time=t)``), freeze the completed prefix, extract the residual DAG
(:func:`repro.core.workflows.residual_workflow`: frontier tasks become
sources, already-materialized boundary inputs fold into task memory so
``r_u`` is preserved), replan, stitch — returning a ``TimelineReport``
(end-to-end makespan, per-segment reports, migration log, Gantt with
event markers).

The scheduler side is :meth:`Scheduler.resume`: a **warm-start mode**
fed by a :class:`~repro.core.scheduler.ResumeState` (residual workflow
+ inherited partition + per-block processor, ``None`` where the
processor disappeared + pinned in-flight blocks).  The ``warm_start``
pipeline inherits the partition instead of re-running Steps 1–2, Step
3 re-homes orphaned blocks, and the Step-4 stages are *pin-aware*:
they never move a pinned block.  Replan policies are pluggable —
``pinned-warm-start`` (cheap), ``full-replan`` (cold, the quality
ceiling), ``no-replan`` (the do-nothing baseline) — and ``make
bench-scenario`` quantifies what warm-starting buys (replan latency,
makespan degradation vs failure time).

Platform events compose the elastic transforms :meth:`Platform.without`
∘ :meth:`Platform.with_speed` ∘ :meth:`Platform.with_link_bandwidth` ∘
:meth:`Platform.with_processors` — link overrides survive failures and
reindexing (property-tested in ``tests/test_platform_transforms.py``).

**Migration notes:** ``repro.runtime.elastic.rescale_plan`` is now a
one-event scenario: it never raises on infeasibility (structured
``Infeasibility`` on ``report.infeasibility``), returns a
``TimelineReport``-backed ``RescaleReport`` (``report.timeline``), and
takes ``at=`` (failure time on the execution clock) and ``policy=``
(``"full-replan"`` keeps the old cold-replan behaviour and default).
``StragglerMonitor.degraded_platform`` is now built from
``StragglerMonitor.speed_events`` — ``SpeedChange`` events consumable
by ``repro.scenario`` directly.
"""
from .dag import QuotientGraph, Workflow, build_quotient
from .platform import (
    Platform,
    ProcPower,
    Processor,
    default_cluster,
    large_cluster,
    less_het_cluster,
    more_het_cluster,
    no_het_cluster,
    small_cluster,
    tpu_fleet,
)
from .incremental import IncrementalEvaluator
from .makespan import bottom_weights, bottom_weights_flat, critical_path, makespan
from .memdag import (
    block_requirement,
    block_requirement_witness,
    exact_min_peak,
    greedy_min_peak,
    set_step2_impl,
    simulate_peak,
    simulate_peak_members,
    step2_impl,
)
from .partitioner import (
    acyclic_partition,
    edge_cut,
    partition_block,
    set_step1_impl,
    step1_impl,
)
from .baseline import MappingResult, dag_het_mem, validate_mapping
from .heuristic import dag_het_part, kprime_sweep_values
from .scheduler import (
    Infeasibility,
    MappingSummary,
    ResumeState,
    ScheduleReport,
    Scheduler,
    SchedulerConfig,
    Stage,
    SweepPoint,
    schedule,
)
from .workflows import (
    FAMILIES,
    WorkflowValidationError,
    generate_workflow,
    random_layered_dag,
    real_like_workflows,
    residual_workflow,
)

__all__ = [
    "Workflow", "QuotientGraph", "build_quotient",
    "Platform", "ProcPower", "Processor",
    "default_cluster", "small_cluster", "large_cluster",
    "more_het_cluster", "less_het_cluster", "no_het_cluster", "tpu_fleet",
    "bottom_weights", "bottom_weights_flat", "critical_path", "makespan",
    "IncrementalEvaluator",
    "block_requirement", "block_requirement_witness",
    "exact_min_peak", "greedy_min_peak",
    "set_step2_impl", "step2_impl",
    "simulate_peak", "simulate_peak_members",
    "acyclic_partition", "edge_cut", "partition_block",
    "set_step1_impl", "step1_impl",
    "MappingResult", "dag_het_mem", "dag_het_part", "validate_mapping",
    "Scheduler", "SchedulerConfig", "ScheduleReport", "SweepPoint",
    "Infeasibility", "MappingSummary", "ResumeState", "Stage", "schedule",
    "kprime_sweep_values",
    "FAMILIES", "generate_workflow", "real_like_workflows",
    "random_layered_dag", "residual_workflow",
    "WorkflowValidationError",
]
