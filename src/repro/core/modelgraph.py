"""Model architectures as workflow DAGs — the bridge between the
assigned architectures and the paper's scheduler.

Every (ModelConfig × ShapeConfig) lowers to a :class:`Workflow` whose
tasks are the model's macro-ops (embedding, per-layer mixers/FFNs,
individual experts, frontend/encoder, LM head):

* ``w_u``   — analytic FLOPs of the op under the shape,
* ``m_u``   — bytes resident while the op runs (weights + working set;
  decode adds the op's KV/state cache),
* ``c_uv``  — activation bytes flowing between ops (residual streams,
  routed expert tokens, cross-attention memories).

MoE experts become *individual parallel tasks*, so DagHetPart's
partitioning of the graph performs expert placement as a by-product —
see DESIGN.md §4.  Units: FLOPs and bytes, matching
``repro.core.platform.tpu_fleet`` (speed = FLOP/s, memory = bytes,
β = bytes/s).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig

from .dag import Workflow

__all__ = ["build_model_graph", "TaskInfo"]

BYTES = 2          # bf16 activations/weights
OPT_FACTOR = 9     # train: weights + grads + f32 (master, m, v) ≈ 18B/param


@dataclass(frozen=True)
class TaskInfo:
    kind: str              # embed | attn | mamba | rwkv | ffn | expert |
                           # router | cross | encoder | head | frontend
    layer: int             # -1 for non-layer tasks
    expert: int            # -1 unless kind == expert


def _train_factor(shape: ShapeConfig) -> float:
    """fwd+bwd ≈ 3× forward FLOPs for training shapes."""
    return 3.0 if shape.kind == "train" else 1.0


def _attn_flops(cfg: ModelConfig, tokens: int, kv_len: int) -> float:
    d, hd = cfg.d_model, cfg.hd
    proj = 2.0 * tokens * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    proj += 2.0 * tokens * cfg.n_heads * hd * d
    win = kv_len if cfg.sliding_window <= 0 else min(kv_len,
                                                     cfg.sliding_window)
    scores = 2.0 * 2.0 * tokens * win * cfg.n_heads * hd
    return proj + scores


def _mamba_flops(cfg: ModelConfig, tokens: int) -> float:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    r = max(1, d // 16)
    n = cfg.mamba_d_state
    return tokens * (
        2.0 * d * 2 * di + di * cfg.mamba_d_conv + 2.0 * di * (r + 2 * n)
        + 2.0 * r * di + 8.0 * di * n + 2.0 * di * d)


def _rwkv_flops(cfg: ModelConfig, tokens: int) -> float:
    d = cfg.d_model
    dh = cfg.n_heads * cfg.hd
    return tokens * (5 * 2.0 * d * dh + 2.0 * dh * d + 6.0 * dh * cfg.hd)


def _ffn_flops(cfg: ModelConfig, tokens: int) -> float:
    return 2.0 * 3.0 * tokens * cfg.d_model * cfg.d_ff


def build_model_graph(cfg: ModelConfig, shape: ShapeConfig,
                      *, microbatches: int = 1) -> tuple[Workflow, dict]:
    """Returns (workflow, info) where ``info[task_id] -> TaskInfo``.

    ``microbatches`` scales the activation working set for pipelined
    training (the scheduler sees per-microbatch memory).
    """
    b, s = shape.global_batch, shape.seq_len
    decode = shape.is_decode
    tokens = b * (1 if decode else s)
    tf = _train_factor(shape)
    act_bytes = (b * (1 if decode else s) * cfg.d_model * BYTES
                 / max(microbatches, 1))
    wfac = OPT_FACTOR if shape.kind == "train" else 1
    kv_len = s

    wf = Workflow(name=f"{cfg.name}:{shape.name}")
    info: dict[int, TaskInfo] = {}

    def task(kind, layer, flops, param_count, extra_mem=0.0, expert=-1,
             label=None):
        t = wf.add_task(
            work=flops * tf,
            mem=2.0 * act_bytes,  # transient working set while the op runs
            label=label or f"{kind}{layer if layer >= 0 else ''}",
            # weights (+ optimizer state when training) and KV/state
            # caches stay resident on the block's processor
            persistent=param_count * BYTES * wfac + extra_mem,
        )
        info[t] = TaskInfo(kind, layer, expert)
        return t

    # --- embedding ----------------------------------------------------- #
    embed = task("embed", -1, tokens * cfg.d_model,
                 cfg.vocab_size * cfg.d_model)
    prev = embed

    # --- frontend / encoder -------------------------------------------- #
    memory_src = None
    if cfg.frontend_tokens:
        fr_tokens = b * cfg.frontend_tokens
        fr_bytes = fr_tokens * cfg.d_model * BYTES
        frontend = task("frontend", -1, fr_tokens * cfg.d_model,
                        cfg.frontend_dim * cfg.d_model, label="frontend")
        memory_src = frontend
        if cfg.is_encdec:
            for i in range(cfg.n_encoder_layers):
                fl = (_attn_flops(cfg, fr_tokens, cfg.frontend_tokens)
                      + _ffn_flops(cfg, fr_tokens))
                t = task("encoder", i, fl,
                         cfg.attn_params() + cfg.mlp_params(),
                         label=f"enc{i}")
                wf.add_edge(memory_src, t, fr_bytes)
                memory_src = t

    # --- decoder layers -------------------------------------------------#
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            kv_cache = (b * kv_len * 2 * cfg.n_kv_heads * cfg.hd * BYTES
                        if decode else 0.0)
            fl = _attn_flops(cfg, tokens, kv_len if decode else s)
            if decode and kv_cache > 0:
                # Decode: the KV cache dominates a layer's residency; a
                # whole layer would be an atomic 10s-of-GB task no chip
                # can hold.  Split by KV head groups — the partitioner
                # then performs head-level tensor parallelism (the
                # placement analogue of sharding the cache over the
                # "model" axis in repro.launch.sharding).
                groups = max(1, cfg.n_kv_heads // 2)
                fan = task("attn_split", i, tokens * cfg.d_model, 0,
                           label=f"attnsplit{i}")
                wf.add_edge(prev, fan, act_bytes)
                join = task("attn_join", i, tokens * cfg.d_model, 0,
                            label=f"attnjoin{i}")
                for gidx in range(groups):
                    gt = task("attn", i, fl / groups,
                              cfg.attn_params() // groups,
                              extra_mem=kv_cache / groups,
                              label=f"attn{i}h{gidx}")
                    wf.add_edge(fan, gt, act_bytes / groups)
                    wf.add_edge(gt, join, act_bytes / groups)
                mix = join
                prev = fan  # keep residual edge bookkeeping simple
            else:
                mix = task("attn", i, fl, cfg.attn_params(),
                           extra_mem=kv_cache)
        elif kind == "mamba":
            state = (b * cfg.mamba_expand * cfg.d_model
                     * cfg.mamba_d_state * 4 if decode else 0.0)
            mix = task("mamba", i, _mamba_flops(cfg, tokens),
                       cfg.mamba_params(), extra_mem=state)
        else:
            state = (b * cfg.n_heads * cfg.hd * cfg.hd * 4
                     if decode else 0.0)
            mix = task("rwkv", i, _rwkv_flops(cfg, tokens),
                       cfg.rwkv_params(), extra_mem=state)
        wf.add_edge(prev, mix, act_bytes)

        if cfg.layer_cross_attends(i) and memory_src is not None:
            cross = task("cross", i, _attn_flops(cfg, tokens,
                                                 cfg.frontend_tokens),
                         cfg.attn_params(), label=f"cross{i}")
            wf.add_edge(mix, cross, act_bytes)
            wf.add_edge(memory_src, cross,
                        b * cfg.frontend_tokens * cfg.d_model * BYTES)
            mix = cross

        if cfg.layer_is_moe(i):
            router = task("router", i, 2.0 * tokens * cfg.d_model
                          * cfg.n_experts,
                          cfg.d_model * cfg.n_experts, label=f"router{i}")
            wf.add_edge(mix, router, act_bytes)
            join = task("combine", i, tokens * cfg.d_model,
                        0, label=f"combine{i}")
            routed = act_bytes * cfg.experts_per_token / cfg.n_experts
            per_exp_tokens = (tokens * cfg.experts_per_token
                              / cfg.n_experts)
            for e in range(cfg.n_experts):
                ex = task("expert", i, _ffn_flops(cfg, per_exp_tokens),
                          cfg.mlp_params(), expert=e,
                          label=f"L{i}e{e}")
                wf.add_edge(router, ex, routed)
                wf.add_edge(ex, join, routed)
            prev = join
        else:
            ffn = task("ffn", i, _ffn_flops(cfg, tokens),
                       cfg.mlp_params())
            wf.add_edge(mix, ffn, act_bytes)
            prev = ffn

    # --- head ------------------------------------------------------------#
    head_params = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    head = task("head", -1, 2.0 * tokens * cfg.d_model * cfg.vocab_size,
                head_params)
    wf.add_edge(prev, head, act_bytes)
    return wf, info
