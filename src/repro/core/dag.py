"""Workflow DAG model (paper §3.1).

A workflow is a directed acyclic graph ``G = (V, E)``:

* each task ``u`` performs ``w[u]`` operations (makespan weight),
* each task needs ``m[u]`` memory for its own execution,
* each edge ``(u, v)`` carries ``c[u, v]`` bytes — the (logical) output file
  written by ``u`` and read by ``v``.

The task memory *requirement* (paper Eq. before §3.2)::

    r_u = sum_in c[v,u] + sum_out c[u,v] + m[u]

This module deliberately avoids heavyweight graph libraries in the hot
paths: adjacency is stored as ``list[dict[int, float]]`` which is fast
enough for the paper's largest instances (30 000 tasks) while staying
mutable and simple.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

import numpy as np

__all__ = [
    "Workflow",
    "QuotientGraph",
    "FlatQuotient",
    "build_quotient",
]


class Workflow:
    """A weighted DAG workflow.

    Attributes:
      work: per-task makespan weights ``w_u`` (operations).
      mem:  per-task memory weights ``m_u``.
      succ: ``succ[u][v] = c[u, v]`` for each edge ``(u, v)``.
      pred: ``pred[v][u] = c[u, v]`` (reverse adjacency).
      name: optional label (workflow family, arch id, ...).
    """

    def __init__(self, n: int = 0, name: str = "workflow") -> None:
        self.name = name
        self._n_edges = 0
        # Monotone mutation counter: bumped by every add_task/add_edge
        # so per-instance caches (the partitioner's locality-order
        # cache) can detect *any* edit, including same-shape ones —
        # accumulating cost onto an existing edge moves neither n nor
        # n_edges, which a (n, n_edges) guard alone cannot see.
        self._version = 0
        self.work: list[float] = [0.0] * n
        self.mem: list[float] = [0.0] * n
        # Persistent residency (bytes held for the whole execution —
        # e.g. model weights / KV caches in the placement layer).  The
        # paper's model has only transient task memory; persistent == 0
        # reproduces it exactly.  block requirement = Σ persistent +
        # transient traversal peak (see memdag.block_requirement).
        self.persistent: list[float] = [0.0] * n
        self.succ: list[dict[int, float]] = [dict() for _ in range(n)]
        self.pred: list[dict[int, float]] = [dict() for _ in range(n)]
        self.labels: list[str] = [f"t{i}" for i in range(n)]

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    def add_task(self, work: float = 1.0, mem: float = 1.0,
                 label: str | None = None,
                 persistent: float = 0.0) -> int:
        u = len(self.work)
        self._version += 1
        self.work.append(float(work))
        self.mem.append(float(mem))
        self.persistent.append(float(persistent))
        self.succ.append(dict())
        self.pred.append(dict())
        self.labels.append(label if label is not None else f"t{u}")
        return u

    def add_edge(self, u: int, v: int, cost: float = 1.0) -> None:
        if u == v:
            raise ValueError(f"self loop on task {u}")
        self._version += 1
        if v not in self.succ[u]:
            self._n_edges += 1
        elif getattr(self, "_flat_cache", None) is not None:
            # accumulating onto an existing edge changes costs without
            # moving (n, n_edges) — the flat CSR view's validity guard
            # cannot see it, so drop the view explicitly
            self._flat_cache = None
        self.succ[u][v] = self.succ[u].get(v, 0.0) + float(cost)
        self.pred[v][u] = self.pred[v].get(u, 0.0) + float(cost)

    # ------------------------------------------------------------------ #
    # basic queries
    # ------------------------------------------------------------------ #
    @property
    def n(self) -> int:
        return len(self.work)

    @property
    def n_edges(self) -> int:
        """Distinct edge count, maintained by :meth:`add_edge` (O(1)).

        Hot path: the flat-array Step-2 view and the partitioner's
        locality-order cache guard their validity on ``(n, n_edges)``
        per call, so this must not rescan the adjacency.
        """
        return self._n_edges

    def parents(self, u: int) -> Iterable[int]:
        return self.pred[u].keys()

    def children(self, u: int) -> Iterable[int]:
        return self.succ[u].keys()

    def sources(self) -> list[int]:
        return [u for u in range(self.n) if not self.pred[u]]

    def targets(self) -> list[int]:
        return [u for u in range(self.n) if not self.succ[u]]

    def in_cost(self, u: int) -> float:
        return sum(self.pred[u].values())

    def out_cost(self, u: int) -> float:
        return sum(self.succ[u].values())

    def task_requirement(self, u: int) -> float:
        """``r_u`` — input files + output files + task memory."""
        return self.in_cost(u) + self.out_cost(u) + self.mem[u]

    def total_work(self) -> float:
        return float(sum(self.work))

    # ------------------------------------------------------------------ #
    # orders / validity
    # ------------------------------------------------------------------ #
    def topological_order(
        self, priority: Callable[[int], float] | None = None
    ) -> list[int]:
        """Kahn's algorithm; ready tasks popped by ``priority`` (min-heap).

        Raises ``ValueError`` when the graph has a cycle.
        """
        indeg = [len(self.pred[u]) for u in range(self.n)]
        if priority is None:
            prio = lambda u: u  # deterministic FIFO-ish
        else:
            prio = priority
        heap = [(prio(u), u) for u in range(self.n) if indeg[u] == 0]
        heapq.heapify(heap)
        order: list[int] = []
        while heap:
            _, u = heapq.heappop(heap)
            order.append(u)
            for v in self.succ[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    heapq.heappush(heap, (prio(v), v))
        if len(order) != self.n:
            raise ValueError("workflow graph contains a cycle")
        return order

    def is_dag(self) -> bool:
        try:
            self.topological_order()
            return True
        except ValueError:
            return False

    # ------------------------------------------------------------------ #
    # sub-workflows
    # ------------------------------------------------------------------ #
    def subgraph(self, nodes: Sequence[int]) -> tuple["Workflow", list[int]]:
        """Induced sub-workflow over ``nodes``.

        Returns ``(sub, mapping)`` where ``mapping[i]`` is the original id
        of sub-task ``i``.  Edges crossing the boundary are *not* part of
        the sub-workflow; callers that need them (peak-memory computation)
        use :meth:`boundary_costs`.
        """
        mapping = list(nodes)
        inv = {u: i for i, u in enumerate(mapping)}
        sub = Workflow(len(mapping), name=f"{self.name}-sub")
        for i, u in enumerate(mapping):
            sub.work[i] = self.work[u]
            sub.mem[i] = self.mem[u]
            sub.persistent[i] = self.persistent[u]
            sub.labels[i] = self.labels[u]
            for v, c in self.succ[u].items():
                j = inv.get(v)
                if j is not None:
                    sub.add_edge(i, j, c)
        return sub, mapping

    def boundary_costs(
        self, nodes: Sequence[int]
    ) -> tuple[dict[int, float], dict[int, float]]:
        """External input / output volume per member of ``nodes``.

        Returns ``(ext_in, ext_out)`` keyed by *local* index in ``nodes``:
        the summed weight of edges arriving from outside the set and
        leaving towards outside the set.
        """
        members = set(nodes)
        ext_in: dict[int, float] = {}
        ext_out: dict[int, float] = {}
        for i, u in enumerate(nodes):
            cin = sum(c for v, c in self.pred[u].items() if v not in members)
            cout = sum(c for v, c in self.succ[u].items() if v not in members)
            if cin:
                ext_in[i] = cin
            if cout:
                ext_out[i] = cout
        return ext_in, ext_out


# ---------------------------------------------------------------------- #
# quotient graph (paper §3.3)
# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class FlatQuotient:
    """Flat CSR snapshot of a :class:`QuotientGraph`'s adjacency.

    Vertices appear in topological order; ``vids[i]`` is the quotient
    vertex id at position ``i`` and ``pos`` maps back.  ``indptr`` /
    ``indices`` / ``costs`` describe successor adjacency in CSR form
    (``indices`` holds *positions*, not vids), so bottom-weight sweeps
    can run array-driven instead of dict-driven.
    """

    vids: np.ndarray      # int64 [n]   vertex ids in topological order
    pos: dict             # vid -> position
    indptr: np.ndarray    # int64 [n+1] successor row pointers
    indices: np.ndarray   # int64 [nnz] successor positions
    costs: np.ndarray     # float64 [nnz] edge costs
    weight: np.ndarray    # float64 [n]  block work

    @property
    def n(self) -> int:
        return len(self.vids)


@dataclass
class QuotientGraph:
    """Mutable quotient DAG ``Γ`` induced by a partition of a workflow.

    Vertices are blocks of the original DAG.  Supports the merge /
    unmerge operations needed by the paper's Step 3 (Algorithm 3/4) and
    the swaps of Step 4.  ``proc[v]`` is the processor index a block is
    assigned to, or ``None``.
    """

    wf: Workflow
    members: dict[int, set[int]] = field(default_factory=dict)  # vid -> tasks
    weight: dict[int, float] = field(default_factory=dict)      # Σ w_u
    succ: dict[int, dict[int, float]] = field(default_factory=dict)
    pred: dict[int, dict[int, float]] = field(default_factory=dict)
    proc: dict[int, int | None] = field(default_factory=dict)
    _next_vid: int = 0

    # -------------------------------------------------------------- #
    def vertices(self) -> list[int]:
        return list(self.members.keys())

    @property
    def n_vertices(self) -> int:
        return len(self.members)

    def new_vertex(self, tasks: set[int]) -> int:
        vid = self._next_vid
        self._next_vid += 1
        self.members[vid] = set(tasks)
        self.weight[vid] = float(sum(self.wf.work[u] for u in tasks))
        self.succ[vid] = {}
        self.pred[vid] = {}
        self.proc[vid] = None
        return vid

    def add_edge(self, a: int, b: int, cost: float) -> None:
        if a == b:
            return
        self.succ[a][b] = self.succ[a].get(b, 0.0) + cost
        self.pred[b][a] = self.pred[b].get(a, 0.0) + cost

    # -------------------------------------------------------------- #
    def is_acyclic(self) -> bool:
        return self.find_cycle() is None

    def find_cycle(self) -> list[int] | None:
        """Return some cycle (list of vertices) or ``None``.

        Uses Kahn peeling: whatever cannot be peeled belongs to a cycle;
        we then walk successor links within the residual to extract one
        explicit cycle (the paper's Step 3 needs its *length*).
        """
        indeg = {v: len(self.pred[v]) for v in self.members}
        stack = [v for v, d in indeg.items() if d == 0]
        seen = 0
        while stack:
            v = stack.pop()
            seen += 1
            for w in self.succ[v]:
                indeg[w] -= 1
                if indeg[w] == 0:
                    stack.append(w)
        if seen == len(self.members):
            return None
        residual = {v for v, d in indeg.items() if d > 0}
        # Every residual vertex kept an unprocessed predecessor, which is
        # itself residual — so walking predecessor links must loop.
        start = next(iter(residual))
        path: list[int] = []
        pos: dict[int, int] = {}
        v = start
        while v not in pos:
            pos[v] = len(path)
            path.append(v)
            v = next(w for w in self.pred[v] if w in residual)
        return path[pos[v]:]

    def topological_order(self) -> list[int]:
        indeg = {v: len(self.pred[v]) for v in self.members}
        heap = [v for v, d in indeg.items() if d == 0]
        heapq.heapify(heap)
        order = []
        while heap:
            v = heapq.heappop(heap)
            order.append(v)
            for w in self.succ[v]:
                indeg[w] -= 1
                if indeg[w] == 0:
                    heapq.heappush(heap, w)
        if len(order) != len(self.members):
            raise ValueError("quotient graph is cyclic")
        return order

    def topological_order_fast(self) -> list[int]:
        """Stack-based Kahn: any valid order, no id-ordering guarantee.

        Used where only *a* topological order matters (rank refreshes
        in the incremental evaluator) — the heap in
        :meth:`topological_order` buys deterministic id-sorted layers
        that rank maintenance does not need.
        """
        indeg = {v: len(self.pred[v]) for v in self.members}
        stack = [v for v, d in indeg.items() if d == 0]
        order: list[int] = []
        while stack:
            v = stack.pop()
            order.append(v)
            for w in self.succ[v]:
                indeg[w] -= 1
                if indeg[w] == 0:
                    stack.append(w)
        if len(order) != len(self.members):
            raise ValueError("quotient graph is cyclic")
        return order

    # -------------------------------------------------------------- #
    # merge / unmerge (Step 3 machinery)
    # -------------------------------------------------------------- #
    def merge(self, a: int, b: int) -> tuple[int, dict]:
        """Merge vertices ``a`` and ``b`` into a new vertex.

        Returns ``(vm, undo)`` where ``undo`` restores the previous state
        via :meth:`unmerge`.  The merged vertex inherits *no* processor
        assignment; callers set it explicitly.

        The undo record is O(deg(a) + deg(b)): the dicts of ``a`` and
        ``b`` are kept *by reference* (merge never mutates them — it
        only unlinks them from the graph), and for each touched
        neighbour we remember exactly which key was cut instead of
        snapshotting its whole adjacency.  Unmerges must be LIFO with
        respect to merges (nested merge trials unwind in reverse).
        """
        undo = {
            "a": a,
            "b": b,
            "a_state": (self.members[a], self.weight[a],
                        self.succ[a], self.pred[a], self.proc[a]),
            "b_state": (self.members[b], self.weight[b],
                        self.succ[b], self.pred[b], self.proc[b]),
            "cut_pred": [],   # (w, old, c): edge old->w removed from pred[w]
            "cut_succ": [],   # (w, old, c): edge w->old removed from succ[w]
        }
        tasks = self.members[a] | self.members[b]
        vm = self.new_vertex(tasks)
        undo["vm"] = vm
        for old in (a, b):
            for w, c in self.succ[old].items():
                if w in (a, b):
                    continue
                undo["cut_pred"].append((w, old, c))
                del self.pred[w][old]
                self.add_edge(vm, w, c)
            for w, c in self.pred[old].items():
                if w in (a, b):
                    continue
                undo["cut_succ"].append((w, old, c))
                del self.succ[w][old]
                self.add_edge(w, vm, c)
        for old in (a, b):
            del self.members[old], self.weight[old]
            del self.succ[old], self.pred[old], self.proc[old]
        return vm, undo

    def unmerge(self, undo: dict) -> None:
        vm = undo["vm"]
        del self.members[vm], self.weight[vm]
        del self.succ[vm], self.pred[vm], self.proc[vm]
        for w, old, c in undo["cut_pred"]:
            self.pred[w].pop(vm, None)
            self.pred[w][old] = c
        for w, old, c in undo["cut_succ"]:
            self.succ[w].pop(vm, None)
            self.succ[w][old] = c
        for v, st in ((undo["a"], undo["a_state"]),
                      (undo["b"], undo["b_state"])):
            members, weight, succ, pred, proc = st
            self.members[v] = members
            self.weight[v] = weight
            self.succ[v] = succ
            self.pred[v] = pred
            self.proc[v] = proc

    def cycle_through(self, v: int) -> list[int] | None:
        """A cycle through ``v`` (or ``None``) — localized cycle probe.

        After ``merge(a, b) -> vm`` on a previously acyclic graph, every
        new cycle passes through ``vm`` (merge only rewires edges
        incident to the merged vertex), so this is a complete acyclicity
        check for the merge result.  2-cycles — the case Step 3 resolves
        by triple merges — are detected first in O(deg(v)).
        """
        two = self.succ[v].keys() & self.pred[v].keys()
        if two:
            return [v, min(two)]
        # iterative DFS from v's successors looking for v
        parent: dict[int, int] = {}
        stack = [(v, iter(self.succ[v]))]
        seen = {v}
        while stack:
            u, it = stack[-1]
            for w in it:
                if w == v:
                    cycle = [v]
                    while u != v:
                        cycle.append(u)
                        u = parent[u]
                    cycle.reverse()
                    return cycle
                if w not in seen:
                    seen.add(w)
                    parent[w] = u
                    stack.append((w, iter(self.succ[w])))
                    break
            else:
                stack.pop()
        return None

    # -------------------------------------------------------------- #
    def csr_arrays(self, order: Sequence[int] | None = None) -> FlatQuotient:
        """Flat CSR snapshot of the current adjacency (see FlatQuotient).

        ``order`` may supply a precomputed topological order to avoid
        recomputing it.  The snapshot is immutable and detached: later
        mutations of the quotient graph do not update it.
        """
        vid_list = list(order) if order is not None else \
            self.topological_order()
        n = len(vid_list)
        pos = {v: i for i, v in enumerate(vid_list)}
        indptr = np.zeros(n + 1, dtype=np.int64)
        for i, v in enumerate(vid_list):
            indptr[i + 1] = indptr[i] + len(self.succ[v])
        nnz = int(indptr[-1])
        indices = np.empty(nnz, dtype=np.int64)
        costs = np.empty(nnz, dtype=np.float64)
        k = 0
        for v in vid_list:
            for w, c in self.succ[v].items():
                indices[k] = pos[w]
                costs[k] = c
                k += 1
        weight = np.fromiter((self.weight[v] for v in vid_list),
                             dtype=np.float64, count=n)
        return FlatQuotient(
            vids=np.asarray(vid_list, dtype=np.int64),
            pos=pos, indptr=indptr, indices=indices, costs=costs,
            weight=weight,
        )

    def assignment_array(self) -> np.ndarray:
        """Per-task block id (−1 where unassigned to any block)."""
        arr = np.full(self.wf.n, -1, dtype=np.int64)
        for vid, tasks in self.members.items():
            for u in tasks:
                arr[u] = vid
        return arr


def build_quotient(wf: Workflow, block_of: Sequence[int]) -> QuotientGraph:
    """Build the quotient graph Γ for partition function ``block_of``.

    ``block_of[u]`` is an arbitrary hashable block id per task.  Tasks
    mapped to the same id become one quotient vertex.
    """
    q = QuotientGraph(wf)
    groups: dict[object, set[int]] = {}
    for u, b in enumerate(block_of):
        groups.setdefault(b, set()).add(u)
    vid_of: dict[object, int] = {}
    # Deterministic vertex numbering: sort groups by smallest member.
    for b in sorted(groups, key=lambda b: min(groups[b])):
        vid_of[b] = q.new_vertex(groups[b])
    for u in range(wf.n):
        bu = vid_of[block_of[u]]
        for v, c in wf.succ[u].items():
            bv = vid_of[block_of[v]]
            if bu != bv:
                q.add_edge(bu, bv, c)
    return q
