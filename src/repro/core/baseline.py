"""DagHetMem — the memory-aware baseline (paper §4.1).

Builds directly on the MemDag-style traversal: compute a (near)
minimum-peak-memory order of the *entire* workflow, then pack tasks in
that order onto processors sorted by decreasing memory, closing a block
whenever the next task would overflow the current processor.

The baseline ignores processor speeds and DAG parallelism — it exists to
produce *valid* mappings (memory constraints respected) against which
the four-step heuristic is measured.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field

from .dag import QuotientGraph, Workflow, build_quotient
from .makespan import makespan as compute_makespan
from .memdag import greedy_min_peak
from .platform import Platform

__all__ = ["MappingResult", "dag_het_mem", "validate_mapping"]


@dataclass
class MappingResult:
    """A valid solution of DAGP-PM: partition + processor mapping."""

    algo: str
    quotient: QuotientGraph
    platform: Platform
    makespan: float
    runtime_s: float
    k_used: int
    extras: dict = field(default_factory=dict)

    def block_of_task(self) -> list[int]:
        arr = self.quotient.assignment_array()
        return [int(x) for x in arr]


def dag_het_mem(wf: Workflow, platform: Platform) -> MappingResult | None:
    """Memory-first greedy packing along a min-peak traversal.

    .. deprecated::
        Use :class:`repro.core.scheduler.Scheduler` with
        ``algorithm="dag_het_mem"`` (or ``schedule(wf, platform,
        algorithm="dag_het_mem")``), which returns a
        :class:`~repro.core.scheduler.ScheduleReport` — never ``None``
        — with stage timings and a structured infeasibility diagnosis.
        This wrapper keeps the old ``MappingResult | None`` contract by
        returning ``report.best``.
    """
    warnings.warn(
        "dag_het_mem() is deprecated; use repro.core.scheduler."
        "Scheduler with algorithm='dag_het_mem' (returns a "
        "ScheduleReport instead of MappingResult | None)",
        DeprecationWarning, stacklevel=2,
    )
    from .scheduler import schedule

    return schedule(wf, platform, algorithm="dag_het_mem").best


def _pack_min_peak(
    wf: Workflow, platform: Platform
) -> tuple[MappingResult | None, dict | None]:
    """The DagHetMem packing itself: ``(result, failure)``.

    Exactly one of the pair is non-``None``.  ``failure`` carries
    ``{"reason", "gap"}``: ``gap`` is the deficit (requirement minus
    capacity) of the single task that broke the packing when that task
    alone cannot fit — ``None`` when the shortfall is aggregate (the
    platform's total memory ran out) rather than per-block.  The paper's
    reading of either case: "the workflow needs a larger platform".
    """
    t0 = time.perf_counter()
    if wf.n == 0:
        raise ValueError("empty workflow")

    _, order = greedy_min_peak(wf, return_order=True)
    proc_order = platform.sorted_by_memory()

    block_of = [-1] * wf.n
    blocks_procs: list[int] = []   # processor of block i
    cur_block = 0
    cur_count = 0                  # tasks in the current block
    cur_proc_idx = 0               # index into proc_order
    cap = platform.memory(proc_order[0])
    live: dict[tuple[int, int], float] = {}  # in-block files -> cost
    live_total = 0.0
    block_peak = 0.0

    persist = 0.0
    i = 0
    while i < wf.n:
        u = order[i]
        # inputs produced inside the current block are already live;
        # inputs from earlier (closed) blocks stream in on demand.
        ext_in = sum(
            c for p, c in wf.pred[u].items() if (p, u) not in live
        )
        # persistent residency (placement layer) is held for the whole
        # block execution, so the block requirement is Σ persistent +
        # the transient traversal peak — block_peak tracks transients
        during = live_total + ext_in + wf.mem[u] + wf.out_cost(u)
        peak_cand = max(block_peak, during)
        if peak_cand + persist + wf.persistent[u] <= cap:
            block_of[u] = cur_block
            persist += wf.persistent[u]
            for p in wf.pred[u]:
                c = live.pop((p, u), None)
                if c is not None:
                    live_total -= c
            for v, c in wf.succ[u].items():
                live[(u, v)] = c
                live_total += c
            block_peak = peak_cand
            cur_count += 1
            i += 1
            continue
        # close the current block (if non-empty) and move to next proc
        if cur_count > 0:
            blocks_procs.append(proc_order[cur_proc_idx])
            cur_block += 1
            cur_count = 0
        cur_proc_idx += 1
        single = (wf.persistent[u] + wf.mem[u] + wf.in_cost(u)
                  + wf.out_cost(u))
        if cur_proc_idx >= platform.k:
            # not enough memory in the platform
            gap = single - platform.max_memory()
            return None, {
                "reason": (
                    f"all {platform.k} processors exhausted with "
                    f"{wf.n - i} of {wf.n} tasks unpacked"
                ),
                "gap": gap if gap > 0 else None,
            }
        cap = platform.memory(proc_order[cur_proc_idx])
        live = {}
        live_total = 0.0
        block_peak = 0.0
        persist = 0.0
        # Guard: task alone exceeding every remaining (smaller) memory
        if single > cap:
            return None, {
                "reason": (
                    f"task {u} needs {single:.4g} alone, more than any "
                    f"remaining processor memory ({cap:.4g})"
                ),
                "gap": single - cap,
            }
    blocks_procs.append(proc_order[cur_proc_idx])

    q = build_quotient(wf, block_of)
    # build_quotient numbers vertices by smallest member; recover the
    # traversal block ids to attach processors.
    vid_by_block: dict[int, int] = {}
    for vid, members in q.members.items():
        b = block_of[next(iter(members))]
        vid_by_block[b] = vid
    for b, pj in enumerate(blocks_procs):
        q.proc[vid_by_block[b]] = pj
    # Retain the packing traversal per block: it is a *witness* that the
    # block fits its processor (the greedy re-derivation in validation
    # may find a worse order).
    orders: dict[int, list[int]] = {vid: [] for vid in q.members}
    for u in order:
        orders[vid_by_block[block_of[u]]].append(u)
    if not q.is_acyclic():
        # The traversal order is topological, and blocks are contiguous
        # in it, so this cannot happen; keep as a hard invariant.
        raise AssertionError("baseline produced a cyclic quotient graph")
    ms = compute_makespan(q, platform)
    return MappingResult(
        algo="DagHetMem",
        quotient=q,
        platform=platform,
        makespan=ms,
        runtime_s=time.perf_counter() - t0,
        k_used=len(blocks_procs),
        extras={"orders": orders},
    ), None


def validate_mapping(
    wf: Workflow,
    result: MappingResult,
    *,
    exact_limit: int = 0,
    memory_trace: bool = False,
) -> list[str]:
    """Check all DAGP-PM constraints; returns a list of violations.

    * every task in exactly one block,
    * acyclic quotient graph,
    * injective block→processor mapping,
    * every block's memory requirement within its processor's memory.

    ``memory_trace=True`` additionally replays the schedule through the
    simulator's memory tracker (:mod:`repro.sim`) and reports every
    *transient* violation with its first time-point and processor.
    Block sums are priced with the best traversal known (min of witness
    and greedy re-derivation), while the trace replays the traversal
    execution would actually use — so a plan whose witness order
    overflows is caught here even when a better traversal makes the
    block sum pass.  Trace checking requires the structural constraints
    to hold and is skipped (with a note) when they do not.

    ``r_{V_i}`` is the *minimum* peak over traversals; any witness order
    (e.g. the baseline's packing traversal or the heuristic's composed
    merge witnesses, stored in ``result.extras["orders"]``) upper-bounds
    it.  The witness is simulated *first* — when it already proves the
    fit, the much costlier greedy re-derivation is skipped entirely,
    which keeps validation affordable at 30k tasks.
    """
    from .memdag import block_requirement, simulate_peak_members

    errors: list[str] = []
    simulable = True  # trace needs an acyclic, fully assigned quotient
    q = result.quotient
    covered: set[int] = set()
    for vid, members in q.members.items():
        dup = covered & members
        if dup:
            errors.append(f"tasks {sorted(dup)[:5]} in multiple blocks")
        covered |= members
    if covered != set(range(wf.n)):
        errors.append(
            f"{wf.n - len(covered)} tasks not covered by any block"
        )
    if not q.is_acyclic():
        errors.append("quotient graph is cyclic")
        simulable = False
    used: dict[int, int] = {}
    for vid in q.vertices():
        pj = q.proc[vid]
        if pj is None:
            errors.append(f"block {vid} unassigned")
            simulable = False
            continue
        if pj in used:
            errors.append(f"processor {pj} used by blocks {used[pj]} and {vid}")
        used[pj] = vid
        members = q.members[vid]
        cap = result.platform.memory(pj)
        witness = result.extras.get("orders", {}).get(vid)
        r = None
        if witness is not None and set(witness) == members:
            done: set[int] = set()
            valid = True
            for u in witness:
                if any(p in members and p not in done
                       for p in wf.pred[u]):
                    valid = False
                    break
                done.add(u)
            if valid:
                base = sum(wf.persistent[u] for u in members)
                r = base + simulate_peak_members(wf, members, witness)
        if r is None or r > cap:
            r_greedy = block_requirement(wf, sorted(members),
                                         exact_limit=exact_limit)
            r = r_greedy if r is None else min(r, r_greedy)
        if r > cap * (1 + 1e-9):
            errors.append(
                f"block {vid}: requirement {r:.3f} exceeds memory "
                f"{cap:.3f} of processor {pj}"
            )
    if memory_trace:
        if not simulable:
            errors.append(
                "memory trace skipped: quotient not simulable "
                "(cyclic or unassigned blocks)"
            )
        else:
            # deferred import: sim builds on core
            from repro.sim import trace_memory

            trace = trace_memory(result, result.platform)
            for v in trace.violations:
                errors.append(
                    f"transient memory violation at t={v.time:.6g} on "
                    f"processor {v.proc} (block {v.vertex}, task "
                    f"{v.task}): occupancy {v.occupancy:.6g} exceeds "
                    f"memory {v.capacity:.6g}"
                )
    return errors
