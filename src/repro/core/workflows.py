"""Workflow instance generators (paper §5.1.1).

The paper evaluates on (a) real nf-core workflows and (b) WfCommons/
WFGen-simulated workflows of seven model families.  Neither the nf-core
dumps nor WFGen are available offline, so this module generates
topologically faithful synthetic instances of the same seven families
(structure summarized from the WfCommons model descriptions) plus small
"real-like" nf-core-shaped instances.

Weights follow the paper's simulated setup: edge weights ~ U(1, 10),
work ~ U(1, 1000), memory ~ U(1, 192); deterministic per seed.  As in
the paper, memory weights are scaled so the most demanding single task
still fits on some processor of the target platform.
"""
from __future__ import annotations

import json

import numpy as np

from .dag import Workflow
from .platform import Platform

__all__ = [
    "FAMILIES",
    "SCHEMA_VERSION",
    "generate_workflow",
    "random_weights",
    "residual_workflow",
    "scale_memory_to_platform",
    "real_like_workflows",
    "random_layered_dag",
    "to_json",
    "from_json",
    "WorkflowValidationError",
]

FAMILIES = (
    "genome",       # 1000Genome: phased parallel analysis per population
    "blast",        # split → wide blast fan → merge
    "bwa",          # two-level fan-out/fan-in
    "epigenomics",  # several long parallel pipelines, late merge
    "montage",      # diamond: project fan → fit → background fan → add
    "seismology",   # wide independent pairs → join
    "soykb",        # chain prologue → fork-join epilogue
)


# ---------------------------------------------------------------------- #
# topology builders.  Each returns a Workflow with unit weights; weights
# are drawn afterwards by ``random_weights``.
# ---------------------------------------------------------------------- #
def _chain(wf: Workflow, length: int) -> list[int]:
    ids = [wf.add_task() for _ in range(length)]
    for a, b in zip(ids, ids[1:]):
        wf.add_edge(a, b)
    return ids


def _blast(n: int) -> Workflow:
    wf = Workflow(name="blast")
    split = wf.add_task(label="split_fasta")
    width = max(1, n - 3)
    mids = []
    for i in range(width):
        t = wf.add_task(label=f"blastall_{i}")
        wf.add_edge(split, t)
        mids.append(t)
    cat = wf.add_task(label="cat_blast")
    out = wf.add_task(label="cat_all")
    for t in mids:
        wf.add_edge(t, cat)
    wf.add_edge(cat, out)
    return wf


def _bwa(n: int) -> Workflow:
    wf = Workflow(name="bwa")
    idx = wf.add_task(label="bwa_index")
    width = max(1, (n - 4) // 2)
    joins = []
    for i in range(width):
        a = wf.add_task(label=f"bwa_aln_{i}")
        b = wf.add_task(label=f"bwa_sampe_{i}")
        wf.add_edge(idx, a)
        wf.add_edge(a, b)
        joins.append(b)
    cat = wf.add_task(label="cat_sam")
    out = wf.add_task(label="merge")
    for t in joins:
        wf.add_edge(t, cat)
    wf.add_edge(cat, out)
    return wf


def _seismology(n: int) -> Workflow:
    wf = Workflow(name="seismology")
    width = max(1, (n - 1) // 2)
    join = None
    pairs = []
    for i in range(width):
        a = wf.add_task(label=f"sG1_{i}")
        b = wf.add_task(label=f"wrapper_{i}")
        wf.add_edge(a, b)
        pairs.append(b)
    join = wf.add_task(label="sG2")
    for t in pairs:
        wf.add_edge(t, join)
    return wf


def _epigenomics(n: int) -> Workflow:
    wf = Workflow(name="epigenomics")
    lanes = max(2, int(np.sqrt(max(n, 4)) / 2))
    stage_len = max(1, (n - 3) // (lanes * 4))
    src = wf.add_task(label="fastqsplit")
    ends = []
    for l in range(lanes):
        prev = src
        for s, op in enumerate(("filter", "map", "sort", "dedup")):
            for j in range(stage_len):
                t = wf.add_task(label=f"{op}_{l}_{j}")
                wf.add_edge(prev, t)
                prev = t
        ends.append(prev)
    merge = wf.add_task(label="mapmerge")
    out = wf.add_task(label="maqindex")
    for t in ends:
        wf.add_edge(t, merge)
    wf.add_edge(merge, out)
    return wf


def _montage(n: int) -> Workflow:
    wf = Workflow(name="montage")
    width = max(2, (n - 4) // 3)
    projects = [wf.add_task(label=f"mProject_{i}") for i in range(width)]
    # overlapping diff tasks between neighbouring projections
    diffs = []
    for i in range(width - 1):
        d = wf.add_task(label=f"mDiffFit_{i}")
        wf.add_edge(projects[i], d)
        wf.add_edge(projects[i + 1], d)
        diffs.append(d)
    fit = wf.add_task(label="mConcatFit")
    for d in diffs:
        wf.add_edge(d, fit)
    bgmodel = wf.add_task(label="mBgModel")
    wf.add_edge(fit, bgmodel)
    bgs = []
    for i, p in enumerate(projects):
        b = wf.add_task(label=f"mBackground_{i}")
        wf.add_edge(p, b)
        wf.add_edge(bgmodel, b)
        bgs.append(b)
    add = wf.add_task(label="mAdd")
    for b in bgs:
        wf.add_edge(b, add)
    shrink = wf.add_task(label="mShrink")
    wf.add_edge(add, shrink)
    return wf


def _genome(n: int) -> Workflow:
    wf = Workflow(name="genome")
    phases = max(2, n // 600)
    per_phase = max(2, (n - 2) // (phases * 2))
    prev_join = wf.add_task(label="individuals_in")
    for ph in range(phases):
        mids = []
        for i in range(per_phase):
            a = wf.add_task(label=f"individuals_{ph}_{i}")
            b = wf.add_task(label=f"sifting_{ph}_{i}")
            wf.add_edge(prev_join, a)
            wf.add_edge(a, b)
            mids.append(b)
        join = wf.add_task(label=f"mutation_overlap_{ph}")
        for t in mids:
            wf.add_edge(t, join)
        prev_join = join
    return wf


def _soykb(n: int) -> Workflow:
    wf = Workflow(name="soykb")
    chain_len = max(1, n // 3)
    ids = _chain(wf, chain_len)
    width = max(1, n - chain_len - 2)
    fans = []
    for i in range(width):
        t = wf.add_task(label=f"haplotype_{i}")
        wf.add_edge(ids[-1], t)
        fans.append(t)
    join = wf.add_task(label="merge_gcvf")
    out = wf.add_task(label="indel_realign")
    for t in fans:
        wf.add_edge(t, join)
    wf.add_edge(join, out)
    return wf


_BUILDERS = {
    "genome": _genome,
    "blast": _blast,
    "bwa": _bwa,
    "epigenomics": _epigenomics,
    "montage": _montage,
    "seismology": _seismology,
    "soykb": _soykb,
}


# ---------------------------------------------------------------------- #
# weights
# ---------------------------------------------------------------------- #
def random_weights(
    wf: Workflow,
    seed: int,
    *,
    work_range: tuple[float, float] = (1.0, 1000.0),
    mem_range: tuple[float, float] = (1.0, 192.0),
    edge_range: tuple[float, float] = (1.0, 10.0),
    work_multiplier: float = 1.0,
    mem_dist: str = "lognormal",
) -> Workflow:
    """Draw paper-§5.1.1 weights in place (returns ``wf``).

    Work and edge weights are uniform as in the paper.  For memory we
    default to a *heavy-tailed* (lognormal) draw normalized so the
    biggest task hits ``mem_range[1]`` (= the biggest processor after
    the paper's normalization).  Rationale (documented deviation, see
    DESIGN.md §3 item 7): a literal U(1, 192) draw gives an average
    task memory of 96 — under the MemDag memory model the default
    36-processor cluster (total memory 1 968) can then hold only a few
    hundred tasks in *any* valid mapping, contradicting the paper's own
    experiments which schedule 30 000-task instances on it.  The
    paper's generator mimics historical nf-core traces, which are
    heavy-tailed (most tasks tiny, few huge); ``mem_dist="uniform"``
    restores the literal text.
    """
    rng = np.random.default_rng(seed)
    n = wf.n
    work = rng.uniform(*work_range, size=n) * work_multiplier
    if mem_dist == "uniform":
        mem = rng.uniform(*mem_range, size=n)
    elif mem_dist == "lognormal":
        v = rng.lognormal(mean=0.0, sigma=1.6, size=n)
        mem = np.maximum(v / v.max() * mem_range[1], mem_range[0])
    else:
        raise ValueError(f"unknown mem_dist {mem_dist!r}")
    for u in range(n):
        wf.work[u] = float(work[u])
        wf.mem[u] = float(mem[u])
    for u in range(n):
        for v in list(wf.succ[u]):
            c = float(rng.uniform(*edge_range))
            wf.succ[u][v] = c
            wf.pred[v][u] = c
    wf._flat_cache = None  # weights changed in place: drop the CSR view
    return wf


def scale_memory_to_platform(wf: Workflow, platform: Platform) -> Workflow:
    """Paper: grow processor memories proportionally until the most
    demanding task fits somewhere.  We instead scale task memory *down*
    by the equivalent factor, which keeps platform definitions fixed."""
    worst = max(wf.task_requirement(u) for u in range(wf.n))
    cap = platform.max_memory()
    if worst <= cap:
        return wf
    # small relative margin so float round-off in downstream sums can
    # never push the worst task above the largest memory again
    f = cap * (1.0 - 1e-9) / worst
    for u in range(wf.n):
        wf.mem[u] *= f
        for v in list(wf.succ[u]):
            wf.succ[u][v] *= f
            wf.pred[v][u] *= f
    wf._flat_cache = None  # weights changed in place: drop the CSR view
    return wf


def generate_workflow(
    family: str,
    n_tasks: int,
    seed: int = 0,
    *,
    platform: Platform | None = None,
    work_multiplier: float = 1.0,
) -> Workflow:
    """Generate a weighted workflow of ``family`` with ≈ ``n_tasks`` tasks."""
    if family not in _BUILDERS:
        raise KeyError(f"unknown family {family!r}; choose from {FAMILIES}")
    wf = _BUILDERS[family](n_tasks)
    random_weights(wf, seed, work_multiplier=work_multiplier)
    if platform is not None:
        scale_memory_to_platform(wf, platform)
    return wf


# ---------------------------------------------------------------------- #
# "real-like" instances: nf-core workflows are small (11–58 tasks) with
# long chains, sparse fans, and a heavy-tailed weight distribution where
# half the tasks carry weight 1 (missing historical data).
# ---------------------------------------------------------------------- #
def real_like_workflows(seed: int = 0) -> list[Workflow]:
    rng = np.random.default_rng(seed)
    out = []
    for i, n in enumerate((11, 17, 24, 37, 58)):
        wf = Workflow(name=f"nfcore_like_{n}")
        ids = [wf.add_task() for _ in range(n)]
        for v in range(1, n):
            # mostly chain-like: attach to a recent predecessor
            u = int(rng.integers(max(0, v - 4), v))
            wf.add_edge(ids[u], ids[v])
            if rng.random() < 0.25 and v >= 2:
                w = int(rng.integers(0, v - 1))
                if w != u:
                    wf.add_edge(ids[w], ids[v])
        for u in range(n):
            has_data = rng.random() < 0.5
            wf.work[u] = float(rng.uniform(10, 500)) if has_data else 1.0
            wf.mem[u] = float(rng.uniform(1, 100)) if has_data else 1.0
        for u in range(n):
            for v in list(wf.succ[u]):
                c = float(rng.uniform(1, 8))
                wf.succ[u][v] = c
                wf.pred[v][u] = c
        wf._flat_cache = None  # weights rewritten in place (see _flat_view)
        out.append(wf)
    return out


def random_layered_dag(
    n: int,
    seed: int = 0,
    *,
    width: int = 8,
    edge_prob: float = 0.35,
) -> Workflow:
    """Random layered DAG — used by property tests, not by benchmarks."""
    rng = np.random.default_rng(seed)
    wf = Workflow(name=f"random_{n}")
    layers: list[list[int]] = []
    made = 0
    while made < n:
        lw = int(rng.integers(1, width + 1))
        lw = min(lw, n - made)
        layers.append([wf.add_task() for _ in range(lw)])
        made += lw
    for li in range(1, len(layers)):
        for v in layers[li]:
            parents = layers[li - 1]
            got = False
            for u in parents:
                if rng.random() < edge_prob:
                    wf.add_edge(u, v, float(rng.uniform(1, 10)))
                    got = True
            if not got:
                u = parents[int(rng.integers(0, len(parents)))]
                wf.add_edge(u, v, float(rng.uniform(1, 10)))
    for u in range(wf.n):
        wf.work[u] = float(rng.uniform(1, 1000))
        wf.mem[u] = float(rng.uniform(1, 192))
    return wf


# ---------------------------------------------------------------------- #
# residual extraction: mid-trace replanning (repro.scenario)
# ---------------------------------------------------------------------- #
def residual_workflow(
    wf: Workflow, completed: set[int]
) -> tuple[Workflow, list[int]]:
    """The workflow left to execute after ``completed`` tasks finished.

    Returns ``(residual, mapping)`` where ``mapping[i]`` is the
    original id of residual task ``i``.  ``completed`` must be closed
    under predecessors (a task cannot finish before its inputs exist) —
    exactly the invariant a simulated execution prefix satisfies.

    Frontier handling: tasks whose predecessors all completed become
    *sources* of the residual DAG.  Each file a completed producer
    feeds across the boundary is already materialized, so its transfer
    is not re-priced; its volume is folded into the consumer's task
    memory instead, which keeps the residual task requirement ``r_u``
    (inputs + outputs + task memory) identical to the original.  Moving
    such a consumer to another processor would in reality re-transfer
    the file — :mod:`repro.scenario` reports those moves in its
    migration log, and pricing them is the checkpoint-cost-aware
    follow-on (ROADMAP).
    """
    bad = [u for u in completed
           if any(p not in completed for p in wf.pred[u])]
    if bad:
        raise ValueError(
            f"completed set not closed under predecessors (e.g. task "
            f"{bad[0]} completed before some of its inputs)"
        )
    remaining = [u for u in range(wf.n) if u not in completed]
    sub, mapping = wf.subgraph(remaining)
    sub.name = f"{wf.name}-residual"
    for i, u in enumerate(mapping):
        ext = sum(c for p, c in wf.pred[u].items() if p in completed)
        if ext:
            sub.mem[i] += ext
    return sub, mapping


# ---------------------------------------------------------------------- #
# serialization: a WfCommons-flavored JSON schema.
#
# WfCommons instances describe tasks (name/id/parents/children) in
# ``workflow.specification`` and measured runtimes in
# ``workflow.execution``; files carry the data volumes.  We mirror that
# split with unit-agnostic weight keys ("work", "memory", "persistent",
# file "size") and make files explicit ``source -> target`` records so
# the round trip is exact.  Real WfCommons dumps map onto this shape by
# renaming keys (runtimeInSeconds -> work, sizeInBytes -> size), which
# is what keeps the door open for dropping real instances in later.
# ---------------------------------------------------------------------- #
SCHEMA_VERSION = "repro-wfcommons-1.0"


def to_json(wf: Workflow, *, indent: int | None = None) -> str:
    """Serialize ``wf`` to the WfCommons-flavored JSON schema."""
    tasks = []
    execution = []
    files = []
    for u in range(wf.n):
        tasks.append({
            "id": f"t{u}",
            "name": wf.labels[u],
            "parents": [f"t{p}" for p in sorted(wf.pred[u])],
            "children": [f"t{c}" for c in sorted(wf.succ[u])],
        })
        execution.append({
            "id": f"t{u}",
            "work": wf.work[u],
            "memory": wf.mem[u],
            "persistent": wf.persistent[u],
        })
        for v in sorted(wf.succ[u]):
            files.append({
                "id": f"t{u}->t{v}",
                "size": wf.succ[u][v],
                "source": f"t{u}",
                "target": f"t{v}",
            })
    doc = {
        "name": wf.name,
        "schemaVersion": SCHEMA_VERSION,
        "workflow": {
            "specification": {"tasks": tasks, "files": files},
            "execution": {"tasks": execution},
        },
    }
    return json.dumps(doc, indent=indent)


class WorkflowValidationError(ValueError):
    """Structured :func:`from_json` rejection: what is wrong, where.

    ``code`` is a stable machine-readable kind (``"bad-json"``,
    ``"bad-schema"``, ``"duplicate-task-id"``, ``"dangling-edge"``,
    ``"self-loop"``, ``"cycle"``, ``"bad-weight"``), ``where`` names
    the offending record (task/file id) when there is one.  The service
    admission path turns this into a ``Rejection`` — malformed
    submissions must never crash the event loop.
    """

    def __init__(self, code: str, detail: str,
                 where: str | None = None) -> None:
        self.code = code
        self.detail = detail
        self.where = where
        at = f" at {where!r}" if where is not None else ""
        super().__init__(f"[{code}]{at}: {detail}")


def _checked_weight(value: object, key: str,
                    where: str) -> float:
    try:
        x = float(value)  # type: ignore[arg-type]
    except (TypeError, ValueError):
        raise WorkflowValidationError(
            "bad-weight", f"{key} is not a number: {value!r}", where
        ) from None
    if not (x >= 0.0) or x == float("inf"):  # NaN fails the >=
        raise WorkflowValidationError(
            "bad-weight", f"{key} must be finite and >= 0, got {x!r}",
            where)
    return x


def from_json(text: str) -> Workflow:
    """Rebuild a :class:`Workflow` from :func:`to_json` output.

    Tasks are numbered by their position in ``specification.tasks``
    (ids may be arbitrary strings); files are authoritative for edges
    and their weights, ``parents``/``children`` being derived views.
    Execution entries are optional per task (weights default to the
    ``add_task`` defaults, as in WfCommons instances lacking history).

    Malformed input raises :class:`WorkflowValidationError` — a
    structured rejection (duplicate task ids, dangling or self-loop
    file endpoints, cycles, negative/non-finite weights, schema
    violations), never a raw ``KeyError``/``TypeError`` from the guts.
    """
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as exc:
        raise WorkflowValidationError("bad-json", str(exc)) from None
    try:
        spec = doc["workflow"]["specification"]
        task_list = spec["tasks"]
    except (KeyError, TypeError) as exc:
        raise WorkflowValidationError(
            "bad-schema",
            f"missing workflow.specification.tasks ({exc!r})"
        ) from None
    if not isinstance(task_list, list):
        raise WorkflowValidationError(
            "bad-schema", "specification.tasks is not a list")
    if not task_list:
        raise WorkflowValidationError(
            "empty", "workflow has no tasks")
    wf = Workflow(name=str(doc.get("name", "workflow")))
    index: dict[str, int] = {}
    for t in task_list:
        if not isinstance(t, dict) or "id" not in t:
            raise WorkflowValidationError(
                "bad-schema", f"task record without an id: {t!r}")
        tid = t["id"]
        if tid in index:
            raise WorkflowValidationError(
                "duplicate-task-id",
                "task id appears more than once", str(tid))
        index[tid] = wf.add_task(label=t.get("name"))
    for f in spec.get("files", []):
        if not isinstance(f, dict):
            raise WorkflowValidationError(
                "bad-schema", f"file record is not an object: {f!r}")
        fid = str(f.get("id", f"{f.get('source')}->{f.get('target')}"))
        for end in ("source", "target"):
            if f.get(end) not in index:
                raise WorkflowValidationError(
                    "dangling-edge",
                    f"file {end} {f.get(end)!r} names no task", fid)
        if f["source"] == f["target"]:
            raise WorkflowValidationError(
                "self-loop", "file source equals target", fid)
        size = _checked_weight(f.get("size", 1.0), "size", fid)
        wf.add_edge(index[f["source"]], index[f["target"]], size)
    for e in doc["workflow"].get("execution", {}).get("tasks", []):
        if not isinstance(e, dict) or e.get("id") not in index:
            raise WorkflowValidationError(
                "dangling-edge",
                f"execution entry names no task: "
                f"{e.get('id') if isinstance(e, dict) else e!r}")
        u = index[e["id"]]
        eid = str(e["id"])
        wf.work[u] = _checked_weight(e.get("work", wf.work[u]),
                                     "work", eid)
        wf.mem[u] = _checked_weight(e.get("memory", wf.mem[u]),
                                    "memory", eid)
        wf.persistent[u] = _checked_weight(
            e.get("persistent", wf.persistent[u]), "persistent", eid)
    # Kahn's sweep: the mapping stack assumes a DAG everywhere, so a
    # cyclic submission must be rejected here, not hang downstream.
    indeg = [len(wf.pred[u]) for u in range(wf.n)]
    ready = [u for u, d in enumerate(indeg) if d == 0]
    seen = 0
    while ready:
        u = ready.pop()
        seen += 1
        for v in wf.succ[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if seen != wf.n:
        raise WorkflowValidationError(
            "cycle", f"{wf.n - seen} task(s) lie on a dependency cycle")
    return wf
