"""Scheduler-driven placement: the paper's algorithm as the framework's
planning layer.

``plan(cfg, shape, platform)`` lowers the architecture to a workflow
DAG (:mod:`modelgraph`), runs DagHetPart (or the DagHetMem baseline)
against a heterogeneous device fleet, and distills the resulting
partition into a :class:`PartitionPlan`:

* contiguous *pipeline stages* (topological order of the quotient
  graph) with their processor assignments,
* per-(layer, expert) placement for MoE layers — expert parallelism
  emerges from the partitioner splitting parallel expert tasks,
* the estimated step latency (the paper's makespan, in seconds for TPU
  fleets),
* per-stage memory requirements (the MemDag peak of each block).

Elastic rescale (node loss) = re-run ``plan`` on ``platform.without``,
then remap — see ``repro.runtime.elastic``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig, ShapeConfig

from .baseline import MappingResult, validate_mapping
from .dag import Workflow
from .makespan import critical_path
from .modelgraph import TaskInfo, build_model_graph
from .platform import Platform
from .scheduler import ScheduleReport, Scheduler, SchedulerConfig

__all__ = ["PartitionPlan", "default_microbatches", "plan"]


def default_microbatches(shape: ShapeConfig) -> int:
    """The planning default: 8 for training shapes (pipelined working
    set), 1 otherwise.  Shared with :func:`repro.runtime.elastic.
    rescale_plan` so pre/post-failure plans lower the same DAG."""
    return 8 if shape.kind == "train" else 1


@dataclass
class PartitionPlan:
    arch: str
    shape: str
    algo: str
    n_stages: int
    stage_of_task: dict[int, int]
    proc_of_stage: list[int]
    stage_members: list[list[str]]          # task labels per stage
    expert_placement: dict[tuple[int, int], int]  # (layer, expert) -> stage
    stage_memory: list[float]               # bytes (MemDag peak)
    est_step_s: float                       # paper makespan (fill latency)
    est_bottleneck_s: float                 # steady-state pipeline bound:
                                            # max stage compute+comm time
    critical_stages: list[int]
    valid: bool
    mapping: MappingResult = field(repr=False, default=None)
    workflow: Workflow = field(repr=False, default=None)
    info: dict = field(repr=False, default=None)
    report: ScheduleReport = field(repr=False, default=None)


def plan(cfg: ModelConfig, shape: ShapeConfig, platform: Platform,
         *, algo: str = "dag_het_part", kprime="auto", workers: int = 1,
         microbatches: int | None = None) -> PartitionPlan | None:
    """Compute a placement plan; None if the fleet can't hold the model.

    Scheduling goes through :class:`repro.core.scheduler.Scheduler`;
    the full :class:`ScheduleReport` (sweep trace, stage timings, or
    the infeasibility diagnosis) rides on ``PartitionPlan.report``, and
    ``workers > 1`` parallelizes the k' sweep.  ``microbatches``
    defaults to 8 for training shapes (pipelined working set) and 1
    otherwise.
    """
    if microbatches is None:
        microbatches = default_microbatches(shape)
    wf, info = build_model_graph(cfg, shape, microbatches=microbatches)
    report = Scheduler(SchedulerConfig(
        algorithm=algo, kprime=kprime, workers=workers,
    )).schedule(wf, platform)
    if not report.feasible:
        return None
    p = _distill(cfg, shape, report.best, wf, info, platform, algo)
    p.report = report
    return p


def _distill(cfg, shape, result, wf, info, platform, algo):
    from .memdag import block_requirement

    q = result.quotient
    order = q.topological_order()
    stage_of_vid = {vid: i for i, vid in enumerate(order)}
    stage_of_task: dict[int, int] = {}
    stage_members: list[list[str]] = [[] for _ in order]
    expert_placement: dict[tuple[int, int], int] = {}
    for vid, members in q.members.items():
        st = stage_of_vid[vid]
        for u in sorted(members):
            stage_of_task[u] = st
            stage_members[st].append(wf.labels[u])
            ti: TaskInfo = info[u]
            if ti.kind == "expert":
                expert_placement[(ti.layer, ti.expert)] = st
    stage_memory = [
        block_requirement(wf, sorted(q.members[vid])) for vid in order
    ]
    crit = [stage_of_vid[v] for v in critical_path(q, platform)]
    bottleneck = max(
        q.weight[vid] / platform.speed(q.proc[vid])
        + sum(q.succ[vid].values()) / platform.bandwidth
        for vid in order
    )
    return PartitionPlan(
        arch=cfg.name,
        shape=shape.name,
        algo=algo,
        n_stages=len(order),
        stage_of_task=stage_of_task,
        proc_of_stage=[q.proc[vid] for vid in order],
        stage_members=stage_members,
        expert_placement=expert_placement,
        stage_memory=stage_memory,
        est_step_s=result.makespan,
        est_bottleneck_s=bottleneck,
        critical_stages=crit,
        valid=validate_mapping(wf, result) == [],
        mapping=result,
        workflow=wf,
        info=info,
    )
