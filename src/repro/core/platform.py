"""Heterogeneous execution environments (paper §3.2 + §5.1.2).

A platform is a set of processors, each with an individual memory size
``M_j`` and speed ``s_j``, plus a uniform interconnect bandwidth ``β``.
Individual directed links may override the uniform β
(:meth:`Platform.with_link_bandwidth`); the analytic makespan keeps
using the uniform value (the paper's model) while the simulator
(:mod:`repro.sim`) honours per-link overrides — the gap between the two
is part of what ``make bench-sim`` measures.

Ships the paper's experimental clusters (Tables 2–3) and TPU-fleet
presets used by the framework's placement layer, where a "processor" is
a TPU chip or a model-parallel group acting as one memory domain.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

__all__ = [
    "Processor",
    "ProcPower",
    "Platform",
    "default_cluster",
    "small_cluster",
    "large_cluster",
    "more_het_cluster",
    "less_het_cluster",
    "no_het_cluster",
    "tpu_fleet",
]


@dataclass(frozen=True)
class Processor:
    name: str
    speed: float   # normalized ops/s (paper: GHz); TPU preset: TFLOP/s
    memory: float  # normalized units (paper: GB); TPU preset: GiB HBM


@dataclass(frozen=True)
class ProcPower:
    """Static + dynamic power model of one processor.

    Busy power at execution speed ``s`` is
    ``static + dynamic * s**alpha`` — the classic DVFS speed-scaling
    form (dynamic power ∝ s^α, α ≈ 2–3): ``static`` is drawn for the
    whole schedule horizon whether the processor computes or idles,
    the dynamic term only while it computes.  Energy accounting over a
    schedule (:mod:`repro.objectives`, ``SimReport.energy``) integrates
    exactly these two terms: per-processor static × horizon plus
    per-block dynamic × compute time.
    """

    static: float
    dynamic: float
    alpha: float = 2.0

    def __post_init__(self) -> None:
        if not (self.static >= 0 and self.dynamic >= 0):
            raise ValueError(
                f"power coefficients must be >= 0, got static="
                f"{self.static!r} dynamic={self.dynamic!r}")
        if not self.alpha >= 1:
            raise ValueError(
                f"speed-scaling exponent alpha must be >= 1, got "
                f"{self.alpha!r}")

    def busy_watts(self, speed: float) -> float:
        """Power drawn while computing at ``speed``."""
        return self.static + self.dynamic * speed ** self.alpha

    def to_list(self) -> list:
        return [self.static, self.dynamic, self.alpha]


@dataclass
class Platform:
    """Computing system S with k processors and uniform bandwidth β.

    ``link_bandwidth`` maps *directed* processor-index pairs ``(i, j)``
    to a bandwidth overriding the uniform β on that link; every other
    link keeps β.  Overrides compose with the other platform
    transforms: :meth:`with_bandwidth` rescales only the uniform base
    and :meth:`without` reindexes surviving links, so failure scenarios
    preserve the link configuration.

    ``failure_rates`` maps a processor index to its exponential failure
    rate λ (failures per time unit; absent ⇒ the processor never
    fails), and ``power`` maps a processor index to its
    :class:`ProcPower` model (absent ⇒ unmetered).  Both are sparse and
    *optional* — a platform without them schedules exactly as before —
    and both compose with the elastic transforms the same way link
    overrides do: :meth:`with_speed` / :meth:`with_processors` /
    :meth:`with_bandwidth` / :meth:`with_link_bandwidth` carry them
    unchanged and :meth:`without` reindexes the surviving entries.
    """

    procs: list[Processor]
    bandwidth: float = 1.0
    name: str = "cluster"
    link_bandwidth: dict[tuple[int, int], float] = field(
        default_factory=dict)
    failure_rates: dict[int, float] = field(default_factory=dict)
    power: dict[int, ProcPower] = field(default_factory=dict)

    @property
    def k(self) -> int:
        return len(self.procs)

    def speed(self, j: int) -> float:
        return self.procs[j].speed

    def memory(self, j: int) -> float:
        return self.procs[j].memory

    def sorted_by_memory(self) -> list[int]:
        """Processor indices by decreasing memory (ties: faster first)."""
        return sorted(
            range(self.k),
            key=lambda j: (-self.procs[j].memory, -self.procs[j].speed),
        )

    def max_memory(self) -> float:
        return max(p.memory for p in self.procs)

    def min_memory(self) -> float:
        return min(p.memory for p in self.procs)

    def bandwidth_between(self, i: int, j: int) -> float:
        """Bandwidth of the directed link ``i → j``.

        Per-link overrides win over the uniform β; the ``i == j``
        "link" is infinitely fast (data staying on a processor is not
        transferred).
        """
        if i == j:
            return math.inf
        return self.link_bandwidth.get((i, j), self.bandwidth)

    def failure_rate(self, j: int) -> float:
        """Exponential failure rate λ of processor ``j`` (0.0 when no
        failure model is set for it — it never fails)."""
        return self.failure_rates.get(j, 0.0)

    def proc_power(self, j: int) -> ProcPower | None:
        """Power model of processor ``j`` (``None`` when unmetered)."""
        return self.power.get(j)

    @property
    def has_failure_model(self) -> bool:
        return bool(self.failure_rates)

    @property
    def has_power_model(self) -> bool:
        return bool(self.power)

    def with_bandwidth(self, beta: float) -> "Platform":
        """Uniform-β rescale; per-link overrides are kept as-is."""
        return Platform(list(self.procs), beta, f"{self.name}@beta={beta}",
                        dict(self.link_bandwidth),
                        dict(self.failure_rates), dict(self.power))

    def with_speed(self, j: int, speed: float) -> "Platform":
        """Platform with processor ``j``'s speed replaced by ``speed``
        (name, memory, links and every other processor unchanged).

        The elastic transform behind ``SpeedChange`` events
        (:mod:`repro.scenario`) and the straggler-mitigation view
        (:meth:`repro.runtime.fault.StragglerMonitor.degraded_platform`);
        composes with :meth:`without` / :meth:`with_link_bandwidth`.
        """
        if not 0 <= j < self.k:
            raise ValueError(
                f"processor {j} out of range for k={self.k}"
            )
        if not speed > 0:
            raise ValueError(
                f"processor speed must be positive, got {speed!r} for "
                f"processor {j}"
            )
        procs = list(self.procs)
        procs[j] = replace(procs[j], speed=float(speed))
        return Platform(procs, self.bandwidth, self.name,
                        dict(self.link_bandwidth),
                        dict(self.failure_rates), dict(self.power))

    def with_processors(self, procs: list["Processor"]) -> "Platform":
        """Platform with ``procs`` appended (elastic scale-up).

        New processors take the next indices, so existing per-link
        overrides, failure rates and power models (and any external
        index references) stay valid.  Arrivals carry no failure/power
        entry; attach one with :meth:`with_failure_rates` /
        :meth:`with_power`.
        """
        return Platform(list(self.procs) + list(procs), self.bandwidth,
                        self.name, dict(self.link_bandwidth),
                        dict(self.failure_rates), dict(self.power))

    def with_link_bandwidth(self, i: int, j: int, beta: float, *,
                            symmetric: bool = True) -> "Platform":
        """Platform with link ``i → j`` (and ``j → i`` when
        ``symmetric``) overridden to ``beta``.

        ``beta`` must be positive (``math.inf`` is fine): a transfer
        over a zero-bandwidth link would never complete.  Model a dead
        *processor* with :meth:`without`; a degraded link with a small
        positive bandwidth.
        """
        if not beta > 0:
            raise ValueError(
                f"link bandwidth must be positive, got {beta!r} for "
                f"link {i} -> {j}"
            )
        links = dict(self.link_bandwidth)
        links[(i, j)] = beta
        if symmetric:
            links[(j, i)] = beta
        return Platform(list(self.procs), self.bandwidth, self.name, links,
                        dict(self.failure_rates), dict(self.power))

    def with_failure_rates(
            self, rates: dict[int, float], *,
            merge: bool = True) -> "Platform":
        """Platform with exponential failure rates attached.

        ``rates`` maps processor index → λ (> 0, finite).  ``merge``
        folds into any existing rates (new entries win); ``merge=False``
        replaces the whole model (``{}`` removes it).
        """
        for j, lam in rates.items():
            if not 0 <= j < self.k:
                raise ValueError(
                    f"failure rate for processor {j} out of range for "
                    f"k={self.k}")
            if not (lam > 0 and math.isfinite(lam)):
                raise ValueError(
                    f"failure rate must be positive and finite, got "
                    f"{lam!r} for processor {j}")
        new = ({**self.failure_rates, **rates} if merge
               else dict(rates))
        return Platform(list(self.procs), self.bandwidth, self.name,
                        dict(self.link_bandwidth), new, dict(self.power))

    def with_power(self, power: dict[int, ProcPower], *,
                   merge: bool = True) -> "Platform":
        """Platform with per-processor :class:`ProcPower` models
        attached (same merge/replace semantics as
        :meth:`with_failure_rates`)."""
        for j, pw in power.items():
            if not 0 <= j < self.k:
                raise ValueError(
                    f"power model for processor {j} out of range for "
                    f"k={self.k}")
            if not isinstance(pw, ProcPower):
                raise TypeError(
                    f"power model for processor {j} must be a ProcPower, "
                    f"got {pw!r}")
        new = {**self.power, **power} if merge else dict(power)
        return Platform(list(self.procs), self.bandwidth, self.name,
                        dict(self.link_bandwidth),
                        dict(self.failure_rates), new)

    def without(self, failed: set[int]) -> "Platform":
        """Platform after losing processors ``failed`` (elastic rescale).

        Surviving per-link overrides, failure rates and power models
        are reindexed to the compacted processor numbering, so a
        degraded platform keeps the same configuration between the
        processors that remain.
        """
        keep = [j for j in range(self.k) if j not in failed]
        new_index = {old: i for i, old in enumerate(keep)}
        links = {
            (new_index[a], new_index[b]): bw
            for (a, b), bw in self.link_bandwidth.items()
            if a in new_index and b in new_index
        }
        rates = {new_index[j]: lam
                 for j, lam in self.failure_rates.items()
                 if j in new_index}
        power = {new_index[j]: pw for j, pw in self.power.items()
                 if j in new_index}
        return Platform([self.procs[j] for j in keep], self.bandwidth,
                        f"{self.name}-degraded", links, rates, power)


# ---------------------------------------------------------------------- #
# Paper clusters (§5.1.2).  (name, speed GHz, memory GB)
# ---------------------------------------------------------------------- #
_DEFAULT_KINDS = [
    ("local", 4.0, 16.0),
    ("A1", 32.0, 32.0),
    ("A2", 6.0, 64.0),
    ("N1", 12.0, 16.0),
    ("N2", 8.0, 8.0),
    ("C2", 32.0, 192.0),
]

_MORE_HET_KINDS = [
    ("local*", 2.0, 8.0),
    ("A1*", 64.0, 64.0),
    ("A2*", 3.0, 128.0),
    ("N1*", 24.0, 8.0),
    ("N2*", 4.0, 4.0),
    ("C2*", 64.0, 384.0),
]

_LESS_HET_KINDS = [
    ("local'", 8.0, 64.0),
    ("A1'", 16.0, 64.0),
    ("A2'", 12.0, 128.0),
    ("N1'", 12.0, 64.0),
    ("N2'", 16.0, 32.0),
    ("C2'", 16.0, 192.0),
]


def _build(kinds, copies: int, beta: float, name: str) -> Platform:
    procs = [
        Processor(f"{kind}-{i}", s, m)
        for kind, s, m in kinds
        for i in range(copies)
    ]
    return Platform(procs, beta, name)


def default_cluster(beta: float = 1.0) -> Platform:
    """36 nodes: six of each kind of Table 2."""
    return _build(_DEFAULT_KINDS, 6, beta, "default")


def small_cluster(beta: float = 1.0) -> Platform:
    """18 nodes: three of each kind."""
    return _build(_DEFAULT_KINDS, 3, beta, "small")


def large_cluster(beta: float = 1.0) -> Platform:
    """60 nodes: ten of each kind."""
    return _build(_DEFAULT_KINDS, 10, beta, "large")


def more_het_cluster(beta: float = 1.0) -> Platform:
    return _build(_MORE_HET_KINDS, 6, beta, "MoreHet")


def less_het_cluster(beta: float = 1.0) -> Platform:
    return _build(_LESS_HET_KINDS, 6, beta, "LessHet")


def no_het_cluster(beta: float = 1.0) -> Platform:
    """Homogeneous: every node must hold the most demanding task → all C2."""
    procs = [Processor(f"C2-{i}", 32.0, 192.0) for i in range(36)]
    return Platform(procs, beta, "NoHet")


# ---------------------------------------------------------------------- #
# TPU fleet presets (framework placement layer).
#
# speed = effective bf16 TFLOP/s per chip; memory = usable HBM GiB
# (hardware minus ~1.5 GiB runtime reserve).  Mixed-generation fleets are
# the realistic source of heterogeneity for the paper's algorithm; the
# "degraded" entries model chips sharing a host with a noisy neighbour
# (straggler mitigation treats them as slower processors rather than
# excluding them).
# ---------------------------------------------------------------------- #
_TPU_KINDS = {
    "v5e": Processor("v5e", 197.0, 14.5),
    "v5p": Processor("v5p", 459.0, 93.0),
    "v4": Processor("v4", 275.0, 30.5),
    "v5e-degraded": Processor("v5e-degraded", 138.0, 12.0),
}


def tpu_fleet(
    spec: dict[str, int] | None = None,
    *,
    ici_gbps: float = 50.0,
) -> Platform:
    """Build a (possibly mixed-generation) TPU fleet.

    ``spec`` maps kind → count, e.g. ``{"v5e": 192, "v4": 64}``.
    Bandwidth is ICI GB/s per link — the uniform-β assumption of the
    paper, kept deliberately (see DESIGN.md §3.2).
    """
    if spec is None:
        spec = {"v5e": 224, "v4": 24, "v5e-degraded": 8}
    procs = []
    for kind, count in spec.items():
        if kind not in _TPU_KINDS:
            raise ValueError(
                f"unknown TPU kind {kind!r}; known kinds: "
                f"{sorted(_TPU_KINDS)}"
            )
        if not isinstance(count, int) or count < 0:
            raise ValueError(
                f"TPU kind {kind!r} needs a count >= 0, got {count!r}"
            )
        base = _TPU_KINDS[kind]
        procs.extend(
            replace(base, name=f"{base.name}-{i}") for i in range(count)
        )
    return Platform(procs, ici_gbps, "tpu-fleet")


def tpu_fleet_si(spec: dict[str, int] | None = None, *,
                 ici_gbps: float = 50.0) -> Platform:
    """Like :func:`tpu_fleet` but in SI units (FLOP/s, bytes, bytes/s)
    — the units :mod:`repro.core.modelgraph` emits."""
    base = tpu_fleet(spec, ici_gbps=ici_gbps)
    procs = [
        Processor(p.name, p.speed * 1e12, p.memory * 2**30)
        for p in base.procs
    ]
    return Platform(procs, ici_gbps * 1e9, base.name)
