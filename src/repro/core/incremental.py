"""Incremental scheduling evaluation for the quotient graph Γ.

The four-step heuristic (``heuristic.py``) explores thousands of
candidate mutations — merges of two blocks (Step 3), processor swaps
and idle moves (Step 4) — and the seed implementation priced every
candidate with a from-scratch bottom-weight sweep: a full topological
sort plus a backward pass over all of Γ per probe.  This module
maintains the bottom weights ``l_ν`` (paper Eq. (1)), the makespan
``max_ν l_ν`` (Eq. (2)) and the critical path *incrementally* under the
three mutations the heuristic actually performs:

* ``set_proc(v, p)``   — processor (re)assignment of one vertex,
* ``merge(a, b)``      — contraction of two vertices (Step 3 trials),
* ``swap(v, w)``       — exchange of two processor assignments.

Invariants
----------
1.  ``l[v] == w_v / s_v + max_{w ∈ succ(v)} (c_vw / β + l[w])`` for
    every vertex of Γ (with the ``s_v = 1`` convention for unassigned
    vertices) whenever the graph is *settled* — i.e. no merge left Γ
    temporarily cyclic.  Values are bit-identical to a from-scratch
    :func:`repro.core.makespan.bottom_weights` sweep: propagation cuts
    off on exact float equality, and per-vertex recomputation applies
    the same arithmetic to the same adjacency dicts.
2.  A mutation only invalidates the bottom weights of the mutated
    vertex and its *ancestors*: descendants' successor subgraphs are
    untouched (a merge rewires only edges incident to the merged
    vertex, and an acyclic merge result cannot place the merged vertex
    below any of its descendants).  Delta propagation therefore walks
    predecessor links only, processing dirty vertices deepest-first
    (by cached topological rank) and stopping as soon as a recomputed
    value is unchanged.
3.  The makespan is served from a lazy max-heap over ``l``: every
    update pushes, queries pop stale entries.  The heap is compacted
    when it outgrows the live vertex set.
4.  Topological ranks are maintained *dynamically*: an acyclic merge
    committed on exact ranks runs a Pearce–Kelly localized reorder
    (``_pk_repair``) — only the affected region between the merged
    vertex and its lowest violating child is reassigned — and the
    acyclicity probe itself is bounded by the same rank window
    (``_cycle_after_merge``).  Full O(V + E) rank refreshes survive
    only for merges applied on top of inexact ranks (committed triple
    merges, whose intermediate state is cyclic).

Transactions
------------
``begin()`` opens a frame; every l-value change, processor change and
merge inside the frame is journalled.  ``rollback()`` restores Γ (LIFO
unmerges) and the exact previous float values; ``commit()`` folds the
journal into the enclosing frame (or drops it at top level).  This is
what makes candidate evaluation with rollback O(affected ancestors)
instead of O(Γ).

A merge that leaves Γ cyclic parks the evaluator in a *broken* state
(``pending`` merges unsettled, makespan queries forbidden) so Step 3
can resolve 2-cycles by a follow-up triple merge before any bottom
weight is touched; ``rollback()`` is the only other exit.
"""
from __future__ import annotations

import heapq
from typing import Iterable

from repro.obs.tracer import current_tracer

from . import counters
from .dag import QuotientGraph
from .makespan import bottom_weights, bottom_weights_flat
from .platform import Platform

__all__ = ["IncrementalEvaluator"]

_MISSING = object()


class _Frame:
    """Journal of one transaction: prior l-values + structural ops."""

    __slots__ = ("lold", "ops", "ranks_exact")

    def __init__(self, ranks_exact: bool) -> None:
        self.lold: dict[int, object] = {}   # vid -> prior l (or _MISSING)
        self.ops: list[tuple] = []          # ("proc", v, old) | ("merge", undo)
        self.ranks_exact = ranks_exact      # flag state to restore on rollback


class IncrementalEvaluator:
    """Maintains bottom weights / makespan / critical path of one Γ.

    All mutations of the quotient graph and of processor assignments
    must go through this object once it is constructed — out-of-band
    edits leave the cached values stale (``rebuild()`` resynchronizes).
    """

    def __init__(self, q: QuotientGraph, platform: Platform) -> None:
        self.q = q
        self.platform = platform
        self.beta = platform.bandwidth
        self._speeds = [p.speed for p in platform.procs]
        self.l: dict[int, float] = {}
        self._heap: list[tuple[float, int]] = []
        self._rank: dict[int, int] = {}
        self._ranks_exact = False
        self._frames: list[_Frame] = []
        self._pending: list[tuple[int, int, int]] = []  # (vm, a, b)
        self._version = 0          # bumped on every l mutation
        self._desc_version = -1    # _values_desc cache tag
        self._desc: list[tuple[float, int]] = []
        self._cp_version = -1      # critical_path cache tag
        self._cp: list[int] = []
        self._cp_set: frozenset[int] = frozenset()
        self._top2_version = -1    # high-degree child-term cache tag
        self._top2: dict[int, tuple] = {}
        #: vertex whose *maintained* bottom weight supplied the
        #: unchanged-part maximum in the last overlay probe's final
        #: check — ``None`` when the probe aborted early or returned a
        #: value.  Step 4's dependency-region verdict cache stores it:
        #: a cached "no improvement" stays valid while this head's
        #: value and the pair's ancestor region are untouched.
        self.last_probe_head: int | None = None
        self.rebuild()

    # -------------------------------------------------------------- #
    # full (re)build — array-driven over a CSR snapshot
    # -------------------------------------------------------------- #
    def rebuild(self) -> None:
        """Recompute everything from scratch (O(V + E))."""
        assert not self._frames and not self._pending
        q = self.q
        order = q.topological_order()
        flat = q.csr_arrays(order)
        lv = bottom_weights_flat(q, self.platform, flat)
        self.l = {v: float(lv[i]) for i, v in enumerate(order)}
        self._rank = {v: i for i, v in enumerate(order)}
        self._ranks_exact = True
        self._heap = [(-x, v) for v, x in self.l.items()]
        heapq.heapify(self._heap)
        self._version += 1

    def refresh_ranks(self) -> None:
        """Recompute exact topological ranks from scratch (O(V + E)).

        With the Pearce–Kelly repair (:meth:`_pk_repair`) committed
        merges keep ranks exact in O(affected region), so this full
        refresh only runs when exactness was lost some other way —
        today that is a settled *triple* merge (the intermediate state
        is cyclic, so no valid ranks exist to repair from).  Bounded
        probes require exact ranks: every vertex is then recomputed
        exactly once per propagation, from settled children, so an
        intermediate value ``>= bound`` proves the final makespan is
        too.
        """
        assert not self._pending
        counters.bump("rank_full_refreshes")
        self._rank = {
            v: i for i, v in enumerate(self.q.topological_order_fast())
        }
        self._ranks_exact = True

    def ensure_exact_ranks(self) -> None:
        """Refresh ranks only if a structural change invalidated them."""
        if not self._ranks_exact:
            self.refresh_ranks()

    # -------------------------------------------------------------- #
    # queries
    # -------------------------------------------------------------- #
    def makespan(self) -> float:
        """Current makespan (Eq. (2)); O(1) amortized."""
        assert not self._pending, "makespan queried on a cyclic (broken) Γ"
        heap, l = self._heap, self.l
        while heap:
            negl, v = heap[0]
            if l.get(v) == -negl:
                return -negl
            heapq.heappop(heap)
        return 0.0

    def argmax(self) -> int | None:
        """Vertex attaining the makespan (None on empty Γ)."""
        self.makespan()
        return self._heap[0][1] if self._heap else None

    def critical_path(self) -> list[int]:
        """Chain realizing the makespan, from the maintained weights.

        Cached between mutations (Step 3 walks it once per queue item,
        but it only changes when some bottom weight does).
        """
        if self._cp_version == self._version:
            return self._cp
        v = self.argmax()
        if v is None:
            path: list[int] = []
        else:
            succ, beta, l = self.q.succ, self.beta, self.l
            path = [v]
            while succ[v]:
                best = None
                bestval = -float("inf")
                for w, c in succ[v].items():
                    val = c / beta + l[w]
                    if val > bestval:
                        bestval = val
                        best = w
                v = best
                path.append(v)
        self._cp = path
        self._cp_set = frozenset(path)
        self._cp_version = self._version
        return path

    def critical_path_set(self) -> frozenset[int]:
        """The critical path as a set (cached with the path itself)."""
        self.critical_path()
        return self._cp_set

    def bottom_weight(self, v: int) -> float:
        return self.l[v]

    def own_time(self, v: int) -> float:
        """``w_v / s_v`` under the current assignment (1.0 unassigned)."""
        return self._own(v)

    # -------------------------------------------------------------- #
    # transactions
    # -------------------------------------------------------------- #
    def begin(self) -> None:
        assert not self._pending, "cannot open a frame on a broken Γ"
        self._frames.append(_Frame(self._ranks_exact))

    def commit(self) -> None:
        assert not self._pending, "cannot commit a broken Γ"
        frame = self._frames.pop()
        if self._frames:
            parent = self._frames[-1]
            for v, old in frame.lold.items():
                parent.lold.setdefault(v, old)
            parent.ops.extend(frame.ops)

    def rollback(self) -> None:
        """Undo every mutation of the innermost frame (exact floats)."""
        frame = self._frames.pop()
        self._pending.clear()
        self._ranks_exact = frame.ranks_exact
        self._version += 1
        q = self.q
        for op in reversed(frame.ops):
            if op[0] == "proc":
                _, v, old = op
                q.proc[v] = old
            elif op[0] == "ranks":  # Pearce–Kelly repair inside a frame
                for v, old in op[1]:
                    self._rank[v] = old
            else:  # ("merge", undo)
                undo = op[1]
                self._rank.pop(undo["vm"], None)
                q.unmerge(undo)
        for v, old in frame.lold.items():
            if old is _MISSING:
                self.l.pop(v, None)
            else:
                self.l[v] = old
                heapq.heappush(self._heap, (-old, v))
        self._compact_if_needed()

    # -------------------------------------------------------------- #
    # mutations
    # -------------------------------------------------------------- #
    def set_proc(self, v: int, p: int | None) -> None:
        """(Re)assign vertex ``v``; propagates deltas to ancestors."""
        assert not self._pending, "set_proc on a cyclic (broken) Γ"
        old = self.q.proc[v]
        if old == p:
            return
        if self._frames:
            self._frames[-1].ops.append(("proc", v, old))
        self.q.proc[v] = p
        self._version += 1
        self._propagate((v,))

    def swap(self, v: int, w: int) -> None:
        """Exchange the processors of ``v`` and ``w``."""
        pv, pw = self.q.proc[v], self.q.proc[w]
        self.set_proc(v, pw)
        self.set_proc(w, pv)

    def swap_and_changes(self, v: int, w: int) -> list[int]:
        """:meth:`swap`, returning the vids whose bottom weight moved.

        Step 4's probe-verdict cache needs the *change set* of an
        applied swap to invalidate only the pairs whose dependency
        region was touched.  Implemented as a throwaway top-level
        transaction: the frame journal already records exactly the
        vertices whose ``l`` changed, and committing at top level
        discards it without further cost.  (``v``/``w`` themselves may
        be absent when the swap left every bottom weight unchanged —
        callers must still treat their *processor* change as a
        mutation.)
        """
        self.begin()
        self.swap(v, w)
        changed = list(self._frames[-1].lold)
        self.commit()
        return changed

    # -------------------------------------------------------------- #
    # bounded probes (Step 4 hot path)
    # -------------------------------------------------------------- #
    def probe_swap(self, v: int, w: int, bound: float) -> float | None:
        """Makespan after swapping ``v``/``w``, or None if ``>= bound``.

        Side-effect-free trial: new values live in an overlay dict, the
        maintained state is never touched (no heap churn, no rollback).
        Requires exact ranks (:meth:`refresh_ranks`) — the propagation
        abort is then an exact rejection, so None means "provably no
        better than ``bound``", never a false negative.
        """
        # per-probe spans are *opt-in* (Tracer.probe_spans): probes fire
        # tens of thousands of times per sweep, so even span-on-trace
        # would blow the enabled-overhead budget
        tr = current_tracer()
        if tr is not None and tr.probe_spans:
            with tr.span("probe.swap", v=v, w=w) as sp:
                ms = self._probe_swap(v, w, bound)
                sp.attrs["beats_bound"] = ms is not None
                return ms
        return self._probe_swap(v, w, bound)

    def _probe_swap(self, v: int, w: int, bound: float) -> float | None:
        proc = self.q.proc
        pv, pw = proc[v], proc[w]
        proc[v], proc[w] = pw, pv
        try:
            return self._overlay_probe((v, w), bound)
        finally:
            proc[v], proc[w] = pv, pw

    def probe_move(self, v: int, p: int | None, bound: float) -> float | None:
        """Makespan after assigning ``v`` to ``p``, or None if ``>= bound``."""
        tr = current_tracer()
        if tr is not None and tr.probe_spans:
            with tr.span("probe.move", v=v, p=p) as sp:
                ms = self._probe_move(v, p, bound)
                sp.attrs["beats_bound"] = ms is not None
                return ms
        return self._probe_move(v, p, bound)

    def _probe_move(self, v: int, p: int | None,
                    bound: float) -> float | None:
        proc = self.q.proc
        pv = proc[v]
        proc[v] = p
        try:
            return self._overlay_probe((v,), bound)
        finally:
            proc[v] = pv

    def probe_merge(
        self,
        a: int,
        b: int,
        proc: int,
        bound: float,
    ) -> float | None:
        """Makespan after merging ``a``/``b`` onto ``proc``, or None.

        Structure-only trial: Γ is merged, priced through the overlay
        (bottom weights untouched), and unmerged before returning.
        None means the merge leaves Γ cyclic or provably cannot beat
        ``bound``.  Callers must rule out 2-cycles beforehand (this
        probe cannot escalate to a triple merge) and guarantee exact
        ranks, as for the other probes.
        """
        tr = current_tracer()
        if tr is not None and tr.probe_spans:
            with tr.span("probe.merge", a=a, b=b, proc=proc) as sp:
                ms = self._probe_merge(a, b, proc, bound)
                sp.attrs["beats_bound"] = ms is not None
                return ms
        return self._probe_merge(a, b, proc, bound)

    def _probe_merge(self, a: int, b: int, proc: int,
                     bound: float) -> float | None:
        q = self.q
        # the rank-windowed cycle probe (not just the bounded overlay)
        # is only sound on exact ranks — fail loudly, not wrongly
        assert self._ranks_exact, "probe_merge requires exact ranks"
        # prime the l-derived caches before the structural trial: built
        # mid-trial they would snapshot the merged adjacency under an
        # unchanged version tag and go stale after the unmerge
        self._top2_terms()
        self._values_desc()
        rv = max(self._rank.get(a, 0), self._rank.get(b, 0))
        vm, undo = q.merge(a, b)
        self._rank[vm] = rv
        ms: float | None = None
        if self._cycle_after_merge(vm, rv) is None:
            q.proc[vm] = proc
            ms = self._overlay_probe((vm,), bound, removed=(a, b))
        del self._rank[vm]
        q.unmerge(undo)
        return ms

    def _overlay_probe(self, seeds, bound: float,
                       removed: tuple = ()) -> float | None:
        rank = self._rank
        heap = [(-rank.get(v, 0), v) for v in seeds]
        heapq.heapify(heap)
        queued = set(seeds)
        heappush, heappop = heapq.heappush, heapq.heappop
        q = self.q
        members, pred, succ = q.members, q.pred, q.succ
        weight, proc = q.weight, q.proc
        speeds, beta, l = self._speeds, self.beta, self.l
        top2 = self._top2_terms()
        overlay: dict[int, float] = {}
        # parent -> [(child, child term)] for children that changed —
        # lets the top2 fast path skip full child scans on fan vertices
        changed: dict[int, list[tuple[int, float]]] = {}
        while heap:
            _, v = heappop(heap)
            queued.discard(v)
            p = proc[v]
            new = weight[v] / speeds[p] if p is not None else weight[v]
            sv = succ[v]
            if sv:
                best = None
                t2e = top2.get(v)
                if t2e is not None:
                    entries = changed.get(v, ())
                    ids = {w for w, _ in entries}
                    ids.update(removed)
                    t1, c1, tb, c2 = t2e
                    if c1 not in ids:
                        static = t1
                    elif c2 is not None and c2 not in ids:
                        static = tb
                    else:
                        static = None  # both best children changed
                    if static is not None:
                        best = static
                        for _, t in entries:
                            if t > best:
                                best = t
                if best is None:
                    best = -float("inf")
                    for w, c in sv.items():
                        lw = overlay.get(w)
                        if lw is None:
                            lw = l[w]
                        cand = c / beta + lw
                        if cand > best:
                            best = cand
                new += best
            if new >= bound:
                self.last_probe_head = None  # abort: bound-independent
                return None
            if new != l.get(v):
                overlay[v] = new
                for u, c in pred[v].items():
                    if u in top2:  # only fan parents use the fast path
                        changed.setdefault(u, []).append(
                            (v, c / beta + new))
                    if u not in queued:
                        queued.add(u)
                        heappush(heap, (-rank.get(u, 0), u))
        # unchanged part: highest maintained value outside the overlay
        # (skipping entries for vertices merged away in this trial)
        ms = max(overlay.values(), default=0.0)
        head = None
        for val, v in self._values_desc():
            if v not in overlay and v in members:
                if val > ms:
                    ms = val
                    head = v
                break
        # every overlay value passed the abort check (< bound), so a
        # final "no improvement" verdict is always head-determined
        self.last_probe_head = head
        return ms if ms < bound else None

    def _values_desc(self) -> list[tuple[float, int]]:
        """``(l, v)`` pairs sorted descending; cached between mutations."""
        if self._desc_version != self._version:
            self._desc = sorted(
                ((x, v) for v, x in self.l.items()), reverse=True)
            self._desc_version = self._version
        return self._desc

    _TOP2_MIN_DEGREE = 2

    def _top2_terms(self) -> dict[int, tuple]:
        """``(t1, c1, t2, c2)`` — two best child terms of every
        high-out-degree vertex, cached between mutations.

        Lets overlay probes recompute a fan vertex in O(#changed
        children) instead of O(out-degree): the best *unchanged* term
        is ``t1`` unless the argmax child itself changed, then ``t2``,
        and only when both changed does the probe fall back to a full
        scan.  Must be (re)built before any structural trial mutates
        the graph — probe_merge primes it explicitly.
        """
        if self._top2_version != self._version:
            beta, l = self.beta, self.l
            mind = self._TOP2_MIN_DEGREE
            d = {}
            for v, sv in self.q.succ.items():
                if len(sv) >= mind:
                    t1 = t2 = -float("inf")
                    c1 = c2 = None
                    for w, c in sv.items():
                        t = c / beta + l[w]
                        if t > t1:
                            t2, c2 = t1, c1
                            t1, c1 = t, w
                        elif t > t2:
                            t2, c2 = t, w
                    d[v] = (t1, c1, t2, c2)
            self._top2 = d
            self._top2_version = self._version
        return self._top2

    def merge(self, a: int, b: int) -> tuple[int, list[int] | None]:
        """Contract ``a`` and ``b``; returns ``(vm, cycle)``.

        When ``cycle`` is not None the evaluator is *broken*: the caller
        must either resolve the cycle with another merge (Step 3's
        triple merge for 2-cycles) or ``rollback()``.  Bottom weights
        are settled only once Γ is acyclic again.

        When the ranks were exact going in, both the acyclicity check
        and the rank maintenance are *localized*: the cycle probe DFS
        is bounded by the affected rank window
        (:meth:`_cycle_after_merge`) and a Pearce–Kelly repair
        (:meth:`_pk_repair`) reorders only the affected region, so
        commits are O(region) instead of O(V + E) and exactness is
        preserved — the full :meth:`refresh_ranks` only remains for
        merges applied on top of inexact ranks (e.g. the second leg of
        a committed triple merge, whose intermediate state is cyclic).
        """
        was_exact = self._ranks_exact
        vm, undo = self.q.merge(a, b)
        if self._frames:
            self._frames[-1].ops.append(("merge", undo))
        rv = max(self._rank.get(a, 0), self._rank.get(b, 0))
        self._rank[vm] = rv
        self._ranks_exact = False
        self._pending.append((vm, a, b))
        self._version += 1
        if was_exact:
            cycle = self._cycle_after_merge(vm, rv)
        else:
            counters.bump("cycle_probe_full_dfs")
            cycle = self.q.cycle_through(vm)
        if cycle is None:
            if was_exact:
                # repair before settling: propagation then runs over
                # exact ranks and recomputes each vertex exactly once
                self._pk_repair(vm, rv)
            self._settle()
        return vm, cycle

    # -------------------------------------------------------------- #
    # localized rank maintenance (Pearce–Kelly)
    # -------------------------------------------------------------- #
    def _cycle_after_merge(self, vm: int, rv: int) -> list[int] | None:
        """A cycle through freshly merged ``vm`` (or ``None``) — the
        rank-localized version of :meth:`QuotientGraph.cycle_through`.

        Requires the *pre-merge* ranks to be exact.  Every edge not
        incident to ``vm`` then goes strictly rank-upward, so a path
        that leaves ``vm`` and returns to it must end in a predecessor
        of ``vm`` (all of which rank below ``rv = max(rank of the
        parts)``) and therefore climbs through vertices ranked below
        ``rv`` only.  The DFS explores exactly that window; on large
        quotients this is the difference between O(affected region)
        and the full-graph wander of the generic probe.  2-cycles (the
        case Step 3 resolves by triple merges) are detected first in
        O(deg), with the same ``[vm, min]`` representative the generic
        probe returns; longer cycles are returned as some explicit
        cycle (callers only branch on the length).
        """
        q = self.q
        succ = q.succ
        two = succ[vm].keys() & q.pred[vm].keys()
        if two:
            counters.bump("cycle_probe_two_cycle")
            return [vm, min(two)]
        counters.bump("cycle_probe_ranked")
        rank = self._rank
        starts = [w for w in succ[vm] if rank[w] < rv]
        if not starts:
            return None
        preds = q.pred[vm].keys()
        parent: dict[int, int] = {}
        seen = set(starts)
        stack = list(starts)
        while stack:
            u = stack.pop()
            if u in preds:  # path vm -> ... -> u -> vm closes a cycle
                cycle = [u]
                while u in parent:
                    u = parent[u]
                    cycle.append(u)
                cycle.append(vm)
                cycle.reverse()
                return cycle
            for w in succ[u]:
                if w not in seen and rank[w] < rv:
                    seen.add(w)
                    parent[w] = u
                    stack.append(w)
        return None

    def _pk_repair(self, vm: int, rv: int) -> None:
        """Pearce–Kelly localized topological reorder after a merge.

        Pre-merge ranks are exact; the merge can only violate order on
        the edges ``vm -> w`` with ``rank[w] < rv`` (parents keep
        ``rank < max(parts) = rv`` automatically).  Discovery walks
        the two affected regions — forward from the violating children
        through ranks ``< rv``, backward from ``vm`` through ranks
        ``>= lb`` (the lowest violating child) — and reassigns the
        union's own rank slots: backward region first, forward region
        after, each in its previous relative order.  All other
        vertices keep their ranks, so the repair is O(region); with no
        violations it degenerates to the O(deg) no-op check.

        Rank *values* are only ever consumed as a topological order
        (probe scheduling), never compared across runs, so swapping
        the full refresh for this repair cannot change any scheduling
        result — property-tested in ``tests/test_incremental.py``.
        """
        rank = self._rank
        q = self.q
        succ, pred = q.succ, q.pred
        viol = [w for w in succ[vm] if rank[w] < rv]
        if not viol:
            self._ranks_exact = True
            counters.bump("rank_pk_noops")
            return
        lb = min(rank[w] for w in viol)
        # forward region: violating children + their descendants < rv
        fwd = list(viol)
        seen_f = set(viol)
        stack = list(viol)
        while stack:
            u = stack.pop()
            for w in succ[u]:
                if w not in seen_f and rank[w] < rv:
                    seen_f.add(w)
                    fwd.append(w)
                    stack.append(w)
        # backward region: vm + its ancestors ranked >= lb
        back = [vm]
        seen_b = {vm}
        stack = [vm]
        while stack:
            u = stack.pop()
            for w in pred[u]:
                if w not in seen_b and rank[w] >= lb:
                    seen_b.add(w)
                    back.append(w)
                    stack.append(w)
        back.sort(key=rank.__getitem__)
        fwd.sort(key=rank.__getitem__)
        region = back + fwd
        slots = sorted(rank[x] for x in region)
        if self._frames:
            self._frames[-1].ops.append(
                ("ranks", [(x, rank[x]) for x in region]))
        for x, s in zip(region, slots):
            rank[x] = s
        self._ranks_exact = True
        counters.bump("rank_pk_repairs")
        counters.bump("rank_pk_region_vertices", len(region))

    # -------------------------------------------------------------- #
    # internals
    # -------------------------------------------------------------- #
    def _settle(self) -> None:
        """Fold pending merges into the bottom weights."""
        final = self._pending[-1][0]
        for _, a, b in self._pending:
            for x in (a, b):
                if x in self.l:
                    self._del_l(x)
        self._pending.clear()
        self._propagate((final,))

    def _del_l(self, v: int) -> None:
        if self._frames:
            self._frames[-1].lold.setdefault(v, self.l[v])
        del self.l[v]

    def _own(self, v: int) -> float:
        p = self.q.proc[v]
        s = self._speeds[p] if p is not None else 1.0
        return self.q.weight[v] / s

    def _recompute(self, v: int) -> float:
        succ = self.q.succ[v]
        own = self._own(v)
        if not succ:
            return own
        beta, l = self.beta, self.l
        return own + max(c / beta + l[w] for w, c in succ.items())

    def _propagate(self, seeds: Iterable[int]) -> None:
        """Fixed-point delta propagation through affected ancestors.

        Processes dirty vertices deepest-first (cached topological
        rank).  Ranks can go stale after merges — that only costs
        re-processing, never correctness: a vertex recomputed from a
        stale child is re-queued when the child settles.  Cutoff is
        exact float equality, which keeps the fixed point bit-identical
        to a from-scratch sweep.  (Bounded/abortable evaluation lives in
        :meth:`_overlay_probe`, which never touches the maintained
        state.)
        """
        rank = self._rank
        heap = [(-rank.get(v, 0), v) for v in seeds]
        heapq.heapify(heap)
        queued = {v for _, v in heap}
        heappush, heappop = heapq.heappush, heapq.heappop
        q = self.q
        members, pred, succ = q.members, q.pred, q.succ
        weight, proc = q.weight, q.proc
        speeds, beta, l = self._speeds, self.beta, self.l
        lheap = self._heap
        frame = self._frames[-1] if self._frames else None
        missing = _MISSING
        while heap:
            _, v = heappop(heap)
            queued.discard(v)
            if v not in members:
                continue
            p = proc[v]
            new = weight[v] / speeds[p] if p is not None else weight[v]
            sv = succ[v]
            if sv:
                best = -float("inf")
                for w, c in sv.items():
                    cand = c / beta + l[w]
                    if cand > best:
                        best = cand
                new += best
            old = l.get(v, missing)
            if new != old:
                if frame is not None:
                    frame.lold.setdefault(v, old)
                l[v] = new
                heappush(lheap, (-new, v))
                for u in pred[v]:
                    if u not in queued:
                        queued.add(u)
                        heappush(heap, (-rank.get(u, 0), u))
        self._compact_if_needed()

    def _compact_if_needed(self) -> None:
        if len(self._heap) > 64 + 4 * len(self.l):
            self._heap = [(-x, v) for v, x in self.l.items()]
            heapq.heapify(self._heap)

    # -------------------------------------------------------------- #
    # debugging / property-test hook
    # -------------------------------------------------------------- #
    def assert_consistent(self) -> None:
        """Compare every maintained value against a from-scratch sweep."""
        assert not self._pending and not self._frames
        ref = bottom_weights(self.q, self.platform)
        assert set(ref) == set(self.l), (
            f"vertex sets differ: {set(ref) ^ set(self.l)}")
        for v, x in ref.items():
            assert self.l[v] == x, (v, self.l[v], x)
