"""Makespan computation via bottom weights (paper §3.3, Eqs. (1)–(2)).

Bottom weight of a quotient vertex ν::

    l_ν = w_ν / s_ν                                    if C_ν = ∅
    l_ν = w_ν / s_ν + max_{ν'∈C_ν} ( c_{ν,ν'} / β + l_ν' )   otherwise

where ``s_ν`` is the speed of the processor assigned to ν (1 when the
vertex is still unassigned — the *estimated makespan* regime), and β the
platform bandwidth.  The makespan of Γ is the maximum bottom weight.

The critical path is the chain realizing that maximum; Step 3 of the
heuristic avoids merging into it and Step 4's idle moves walk it.
"""
from __future__ import annotations

import numpy as np

from .dag import FlatQuotient, QuotientGraph
from .platform import Platform

__all__ = [
    "bottom_weights",
    "bottom_weights_flat",
    "makespan",
    "critical_path",
]


def _speed(q: QuotientGraph, platform: Platform, v: int) -> float:
    p = q.proc[v]
    return platform.procs[p].speed if p is not None else 1.0


def bottom_weights(q: QuotientGraph, platform: Platform) -> dict[int, float]:
    """Bottom weight per quotient vertex (Eq. (1)). Γ must be acyclic."""
    order = q.topological_order()
    beta = platform.bandwidth
    l: dict[int, float] = {}
    for v in reversed(order):
        own = q.weight[v] / _speed(q, platform, v)
        if not q.succ[v]:
            l[v] = own
        else:
            l[v] = own + max(
                c / beta + l[w] for w, c in q.succ[v].items()
            )
    return l


def bottom_weights_flat(
    q: QuotientGraph,
    platform: Platform,
    flat: FlatQuotient | None = None,
) -> np.ndarray:
    """Array-driven bottom-weight sweep over a CSR snapshot.

    Returns ``l`` indexed by *position* in ``flat`` (``flat.vids[i]`` is
    the vertex at position ``i``).  Produces bit-identical values to
    :func:`bottom_weights` — ``max`` over floats is order-independent
    and the per-term arithmetic (``c / beta + l_child``) matches.  Used
    by the incremental evaluator for its full (re)builds; the dict
    version stays as the mutation-friendly reference.
    """
    if flat is None:
        flat = q.csr_arrays()
    n = flat.n
    beta = platform.bandwidth
    l = np.empty(n, dtype=np.float64)
    own = np.empty(n, dtype=np.float64)
    for i in range(n):
        own[i] = flat.weight[i] / _speed(q, platform, int(flat.vids[i]))
    indptr, indices, costs = flat.indptr, flat.indices, flat.costs
    for i in range(n - 1, -1, -1):
        s, e = indptr[i], indptr[i + 1]
        if s == e:
            l[i] = own[i]
        elif e - s < 16:
            best = -np.inf
            for k in range(s, e):
                cand = costs[k] / beta + l[indices[k]]
                if cand > best:
                    best = cand
            l[i] = own[i] + best
        else:
            l[i] = own[i] + float(np.max(costs[s:e] / beta + l[indices[s:e]]))
    return l


def makespan(q: QuotientGraph, platform: Platform) -> float:
    """Makespan of Γ (Eq. (2)) — max bottom weight over vertices."""
    if not q.members:
        return 0.0
    return max(bottom_weights(q, platform).values())


def critical_path(q: QuotientGraph, platform: Platform) -> list[int]:
    """The chain of quotient vertices realizing the makespan."""
    if not q.members:
        return []
    l = bottom_weights(q, platform)
    beta = platform.bandwidth
    v = max(l, key=lambda x: l[x])
    path = [v]
    while q.succ[v]:
        # child attaining the max in Eq. (1)
        v = max(q.succ[v], key=lambda w: q.succ[v][w] / beta + l[w])
        path.append(v)
    return path
