"""Makespan computation via bottom weights (paper §3.3, Eqs. (1)–(2)).

Bottom weight of a quotient vertex ν::

    l_ν = w_ν / s_ν                                    if C_ν = ∅
    l_ν = w_ν / s_ν + max_{ν'∈C_ν} ( c_{ν,ν'} / β + l_ν' )   otherwise

where ``s_ν`` is the speed of the processor assigned to ν (1 when the
vertex is still unassigned — the *estimated makespan* regime), and β the
platform bandwidth.  The makespan of Γ is the maximum bottom weight.

The critical path is the chain realizing that maximum; Step 3 of the
heuristic avoids merging into it and Step 4's idle moves walk it.
"""
from __future__ import annotations

from .dag import QuotientGraph
from .platform import Platform

__all__ = ["bottom_weights", "makespan", "critical_path"]


def _speed(q: QuotientGraph, platform: Platform, v: int) -> float:
    p = q.proc[v]
    return platform.procs[p].speed if p is not None else 1.0


def bottom_weights(q: QuotientGraph, platform: Platform) -> dict[int, float]:
    """Bottom weight per quotient vertex (Eq. (1)). Γ must be acyclic."""
    order = q.topological_order()
    beta = platform.bandwidth
    l: dict[int, float] = {}
    for v in reversed(order):
        own = q.weight[v] / _speed(q, platform, v)
        if not q.succ[v]:
            l[v] = own
        else:
            l[v] = own + max(
                c / beta + l[w] for w, c in q.succ[v].items()
            )
    return l


def makespan(q: QuotientGraph, platform: Platform) -> float:
    """Makespan of Γ (Eq. (2)) — max bottom weight over vertices."""
    if not q.members:
        return 0.0
    return max(bottom_weights(q, platform).values())


def critical_path(q: QuotientGraph, platform: Platform) -> list[int]:
    """The chain of quotient vertices realizing the makespan."""
    if not q.members:
        return []
    l = bottom_weights(q, platform)
    beta = platform.bandwidth
    v = max(l, key=lambda x: l[x])
    path = [v]
    while q.succ[v]:
        # child attaining the max in Eq. (1)
        v = max(q.succ[v], key=lambda w: q.succ[v][w] / beta + l[w])
        path.append(v)
    return path
