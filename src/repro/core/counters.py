"""Lightweight global perf counters for the scheduling hot paths.

The scheduler's pipeline stages are instrumented with named counters —
Step-1 partitioner dispatch and refinement work (``step1_scalar_calls``
/ ``step1_flat_calls`` / ``step1_multilevel_calls``, ``step1_moves``,
``step1_passes``, ``step1_coarsen_levels``, ``step1_cut_before`` /
``step1_cut_after``), Step-2 flat-vs-scalar dispatch and
requirement-memo reuse, the incremental evaluator's Pearce–Kelly rank
repairs vs full refreshes, Step-4 swap-probe cache hits — so every
:class:`SweepPoint` can carry
the *cache statistics* of its pipeline run (``cache_stats``) next to
its stage timings.  :func:`snapshot` / :func:`delta` bracket one
pipeline execution; under the parallel k' sweep each worker process
accumulates its own counters and ships the per-point delta back inside
the (picklable) ``SweepPoint``.  The service layer
(:mod:`repro.service`) counts through the same registry — job
lifecycle (``service_admissions`` / ``service_dispatches`` /
``service_completions`` / ``service_rejections`` /
``service_infeasible``), contention (``service_deferrals`` /
``service_displacements``), event handling (``service_replans`` /
``service_replan_cold_fallbacks``) and plan-cache traffic
(``service_cache_hits`` / ``service_cache_misses`` /
``service_cache_stores`` / ``service_seed_fallbacks``) — surfaced as
``ServiceReport.cache_stats``.

Counters only ever *count* — they never influence control flow — so
instrumentation cannot change scheduling results.

Since PR 8 this module is the **counter facet** of the typed metrics
registry (:data:`repro.obs.metrics.METRICS`): :data:`COUNTERS` *is*
``METRICS.counters``, so every ``bump()`` feeds the registry that also
holds gauges and histograms, and the registry's snapshot/delta/merge
protocol subsumes this module's.  The narrow API below is unchanged —
existing call sites and tests keep working verbatim.
"""
from __future__ import annotations

from collections import Counter

from repro.obs.metrics import METRICS

__all__ = ["COUNTERS", "bump", "snapshot", "delta", "reset"]

COUNTERS: Counter = METRICS.counters


def bump(name: str, n: int = 1) -> None:
    """Increment counter ``name`` by ``n``."""
    COUNTERS[name] += n


def snapshot() -> dict[str, int]:
    """Current counter values (a detached copy)."""
    return dict(COUNTERS)


def delta(snap: dict[str, int]) -> dict[str, int]:
    """Counters that moved since ``snap`` (name -> increment)."""
    return {
        k: v - snap.get(k, 0)
        for k, v in COUNTERS.items()
        if v != snap.get(k, 0)
    }


def reset() -> None:
    """Zero all counters (test isolation)."""
    COUNTERS.clear()
