"""DagHetPart — the four-step partitioning-based heuristic (paper §4.2).

Step 1  Partition the DAG into k' acyclic blocks (edge-cut optimizer).
Step 2  BiggestAssign/FitBlock: largest block → largest-memory free
        processor; blocks that do not fit are recursively split.
Step 3  MergeUnassignedToAssigned/FindMSOptMerge: merge leftover blocks
        into assigned ones, preferring merges off the critical path,
        resolving 2-cycles by triple merges, bounded re-queuing.
Step 4  Swaps: best-improvement block swaps + moves of critical-path
        blocks to faster idle processors.

The driver sweeps k' ≤ k and keeps the best makespan (paper Step 1).
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass

from .baseline import MappingResult
from .dag import QuotientGraph, Workflow, build_quotient
from .makespan import critical_path, makespan as compute_makespan
from .memdag import block_requirement
from .partitioner import acyclic_partition, partition_block
from .platform import Platform

__all__ = ["dag_het_part", "kprime_sweep_values"]


# ---------------------------------------------------------------------- #
# Step 2: BiggestAssign + FitBlock (Algorithms 1–2)
# ---------------------------------------------------------------------- #
@dataclass
class _Step2Result:
    assigned: list[tuple[list[int], int]]  # (tasks, processor)
    unassigned: list[list[int]]


class _BlockPQ:
    """Max-priority queue of blocks keyed by memory requirement."""

    def __init__(self, wf: Workflow, exact_limit: int) -> None:
        self.wf = wf
        self.exact_limit = exact_limit
        self._heap: list[tuple[float, int, list[int]]] = []
        self._counter = itertools.count()

    def requirement(self, nodes: list[int]) -> float:
        return block_requirement(self.wf, nodes,
                                 exact_limit=self.exact_limit)

    def push(self, nodes: list[int]) -> None:
        r = self.requirement(nodes)
        heapq.heappush(self._heap, (-r, next(self._counter), nodes))

    def pop(self) -> tuple[float, list[int]]:
        negr, _, nodes = heapq.heappop(self._heap)
        return -negr, nodes

    def __bool__(self) -> bool:
        return bool(self._heap)


_FITS, _SPLIT, _STUCK = 0, 1, 2


def _fit_block(
    nodes: list[int],
    r: float,
    queue: _BlockPQ,
    cap: float,
) -> int:
    """FitBlock (Algorithm 2) without the mapping side effect.

    ``_FITS``: block fits ``cap``.  ``_SPLIT``: did not fit, pieces
    reinserted into the queue.  ``_STUCK``: singleton exceeding ``cap``
    — cannot be split; the paper's FitBlock would loop, we hand it to
    Step 3, which may still merge it into a block on a larger-memory
    processor.
    """
    if r <= cap:
        return _FITS
    if len(nodes) > 1:
        for part in partition_block(queue.wf, nodes, 2):
            queue.push(part)
        return _SPLIT
    return _STUCK


def _biggest_assign(
    wf: Workflow,
    platform: Platform,
    blocks: list[list[int]],
    exact_limit: int,
) -> _Step2Result:
    """Algorithm 1: assign biggest blocks to biggest memories."""
    queue = _BlockPQ(wf, exact_limit)
    for b in blocks:
        queue.push(b)
    proc_ids = platform.sorted_by_memory()
    assigned: list[tuple[list[int], int]] = []
    stuck: list[list[int]] = []
    next_proc = 0
    while queue and next_proc < len(proc_ids):
        r, nodes = queue.pop()
        pj = proc_ids[next_proc]
        status = _fit_block(nodes, r, queue, platform.memory(pj))
        if status == _FITS:
            assigned.append((nodes, pj))
            next_proc += 1
        elif status == _STUCK:
            stuck.append(nodes)
    # remaining blocks: shrink them to the smallest memory (no mapping)
    unassigned: list[list[int]] = list(stuck)
    if queue:
        min_mem = platform.min_memory()
        while queue:
            r, nodes = queue.pop()
            if r <= min_mem or len(nodes) == 1:
                unassigned.append(nodes)
            else:
                for part in partition_block(wf, nodes, 2):
                    queue.push(part)
    return _Step2Result(assigned, unassigned)


# ---------------------------------------------------------------------- #
# Step 3: merging (Algorithms 3–4)
# ---------------------------------------------------------------------- #
class _Requirements:
    """Cache of r_{V} keyed by quotient vertex id."""

    def __init__(self, wf: Workflow, exact_limit: int) -> None:
        self.wf = wf
        self.exact_limit = exact_limit
        self._cache: dict[int, float] = {}

    def of(self, q: QuotientGraph, vid: int) -> float:
        r = self._cache.get(vid)
        if r is None:
            r = block_requirement(self.wf, sorted(q.members[vid]),
                                  exact_limit=self.exact_limit)
            self._cache[vid] = r
        return r

    def forget(self, *vids: int) -> None:
        for v in vids:
            self._cache.pop(v, None)


def _find_ms_opt_merge(
    v: int,
    candidates: set[int],
    q: QuotientGraph,
    platform: Platform,
    reqs: _Requirements,
) -> tuple[float, int | None, int | None]:
    """Algorithm 3: best merge of unassigned ``v`` into a candidate.

    Returns ``(best_makespan, best_partner, optional_third)``; partner
    is ``None`` when no feasible merge exists.  ``q`` is restored to its
    input state before returning.
    """
    best_ms = float("inf")
    best_partner: int | None = None
    best_third: int | None = None
    neighbours = (set(q.pred[v]) | set(q.succ[v])) & candidates
    for vp in sorted(neighbours):
        target_proc = q.proc[vp]
        vm, undo = q.merge(v, vp)
        third: int | None = None
        undo2 = None
        cycle = q.find_cycle()
        if cycle is not None:
            if len(cycle) == 2:
                other = cycle[0] if cycle[0] != vm else cycle[1]
                vm2, undo2 = q.merge(vm, other)
                if q.find_cycle() is not None:
                    q.unmerge(undo2)
                    q.unmerge(undo)
                    continue
                third = other
                vm = vm2
            else:
                q.unmerge(undo)
                continue
        # memory feasibility on the partner's processor
        r = block_requirement(reqs.wf, sorted(q.members[vm]),
                              exact_limit=reqs.exact_limit)
        if r <= platform.memory(target_proc):
            q.proc[vm] = target_proc
            ms = compute_makespan(q, platform)
            q.proc[vm] = None
            if ms < best_ms:
                best_ms, best_partner, best_third = ms, vp, third
        if undo2 is not None:
            q.unmerge(undo2)
        q.unmerge(undo)
    return best_ms, best_partner, best_third


def _merge_unassigned(
    wf: Workflow,
    platform: Platform,
    q: QuotientGraph,
    reqs: _Requirements,
) -> bool:
    """Algorithm 4.  Mutates ``q``; False when some block can't be placed.

    Beyond-paper refinement (DESIGN.md §8): when no merge is feasible,
    try placing the block on a memory-feasible *idle* processor before
    giving up — the paper only uses idle processors in Step 4, after a
    full assignment exists, which strands late-split singletons whose
    requirement exceeds every assigned block's headroom.
    """
    path = set(critical_path(q, platform))
    assigned = {v for v in q.vertices() if q.proc[v] is not None}
    queue = [v for v in sorted(q.vertices()) if q.proc[v] is None]
    seen_count: dict[int, int] = {v: 0 for v in queue}
    while queue:
        v = queue.pop(0)
        ms, partner, third = _find_ms_opt_merge(
            v, assigned - path, q, platform, reqs)
        if partner is None:
            ms, partner, third = _find_ms_opt_merge(
                v, assigned, q, platform, reqs)
        if partner is None:
            # place-on-idle fallback
            busy = {q.proc[a] for a in assigned}
            r_v = reqs.of(q, v)
            idle = [j for j in range(platform.k)
                    if j not in busy and platform.memory(j) >= r_v]
            if idle:
                q.proc[v] = max(idle, key=platform.speed)
                assigned.add(v)
                path = set(critical_path(q, platform))
                continue
        if partner is not None:
            target_proc = q.proc[partner]
            vm, _ = q.merge(v, partner)
            assigned.discard(partner)
            reqs.forget(v, partner)
            if third is not None:
                in_queue = q.proc[third] is None
                vm2, _ = q.merge(vm, third)
                assigned.discard(third)
                reqs.forget(vm, third)
                if in_queue and third in queue:
                    queue.remove(third)
                vm = vm2
            q.proc[vm] = target_proc
            assigned.add(vm)
            path = set(critical_path(q, platform))
        else:
            unresolved_nbrs = any(
                q.proc[w] is None
                for w in itertools.chain(q.pred[v], q.succ[v])
            )
            if unresolved_nbrs and seen_count.get(v, 0) <= 1:
                seen_count[v] = seen_count.get(v, 0) + 1
                queue.append(v)
            else:
                return False  # no solution for this k'
    return True


# ---------------------------------------------------------------------- #
# Step 4: swaps + idle-processor moves (Algorithm 5)
# ---------------------------------------------------------------------- #
def _swap_pass(
    wf: Workflow,
    platform: Platform,
    q: QuotientGraph,
    reqs: _Requirements,
) -> None:
    best_ms = compute_makespan(q, platform)
    while True:
        best_pair: tuple[int, int] | None = None
        verts = sorted(q.vertices())
        for i, v in enumerate(verts):
            for vp in verts[i + 1:]:
                pa, pb = q.proc[v], q.proc[vp]
                if pa == pb:
                    continue
                if reqs.of(q, v) > platform.memory(pb):
                    continue
                if reqs.of(q, vp) > platform.memory(pa):
                    continue
                q.proc[v], q.proc[vp] = pb, pa
                ms = compute_makespan(q, platform)
                q.proc[v], q.proc[vp] = pa, pb
                if ms < best_ms - 1e-12:
                    best_ms = ms
                    best_pair = (v, vp)
        if best_pair is None:
            return
        v, vp = best_pair
        q.proc[v], q.proc[vp] = q.proc[vp], q.proc[v]


def _idle_moves(
    wf: Workflow,
    platform: Platform,
    q: QuotientGraph,
    reqs: _Requirements,
) -> None:
    """Move critical-path blocks to faster idle processors."""
    busy = {q.proc[v] for v in q.vertices()}
    idle = [j for j in range(platform.k) if j not in busy]
    if not idle:
        return
    moved: set[int] = set()
    while True:
        path = critical_path(q, platform)
        cand = [v for v in path if v not in moved]
        if not cand:
            return
        ms0 = compute_makespan(q, platform)
        progressed = False
        for v in cand:
            moved.add(v)
            cur = q.proc[v]
            options = [
                j for j in idle
                if platform.speed(j) > platform.speed(cur)
                and reqs.of(q, v) <= platform.memory(j)
            ]
            if not options:
                continue
            j = max(options, key=platform.speed)
            q.proc[v] = j
            if compute_makespan(q, platform) < ms0 - 1e-12:
                idle.remove(j)
                idle.append(cur)
                progressed = True
                break  # critical path changed; recompute
            q.proc[v] = cur
        if not progressed:
            return


# ---------------------------------------------------------------------- #
# driver
# ---------------------------------------------------------------------- #
def kprime_sweep_values(wf: Workflow, platform: Platform,
                        mode: str = "auto") -> list[int]:
    """Which k' values to try (paper: all of 1..k; we default to a
    geometric subset for very large workflows — a documented knob)."""
    k = platform.k
    if mode == "full" or (mode == "auto" and wf.n <= 4000):
        return list(range(1, k + 1))
    vals = {1, 2, 3, k}
    v = 4
    while v < k:
        vals.add(v)
        v = int(v * 1.6) + 1
    return sorted(x for x in vals if 1 <= x <= k)


def dag_het_part(
    wf: Workflow,
    platform: Platform,
    *,
    kprime: str | list[int] = "auto",
    exact_limit: int = 0,
    verbose: bool = False,
) -> MappingResult | None:
    """Run the four-step heuristic, sweeping k' and keeping the best.

    ``exact_limit`` bounds the exact min-peak DP used inside block
    requirement computation (0 ⇒ heuristic traversal only, matching the
    scale of the paper's experiments).
    """
    t0 = time.perf_counter()
    if isinstance(kprime, list):
        sweep = kprime
    else:
        sweep = kprime_sweep_values(wf, platform, kprime)

    best: MappingResult | None = None
    for kp in sweep:
        res = _run_single(wf, platform, kp, exact_limit)
        if res is None:
            continue
        if best is None or res.makespan < best.makespan:
            best = res
        if verbose:
            print(f"  k'={kp}: makespan={res.makespan:.2f}")
    if best is not None:
        best.runtime_s = time.perf_counter() - t0
    return best


def _run_single(
    wf: Workflow,
    platform: Platform,
    kp: int,
    exact_limit: int,
) -> MappingResult | None:
    # ---- Step 1: initial acyclic partition -------------------------- #
    assignment = acyclic_partition(wf, kp)
    groups: dict[int, list[int]] = {}
    for u, b in enumerate(assignment):
        groups.setdefault(b, []).append(u)
    blocks = [groups[b] for b in sorted(groups)]

    # ---- Step 2: biggest-first assignment --------------------------- #
    step2 = _biggest_assign(wf, platform, blocks, exact_limit)
    if not step2.assigned:
        return None

    # ---- Step 3: merge unassigned into assigned --------------------- #
    block_of: list[int] = [-1] * wf.n
    bid = 0
    proc_of_bid: dict[int, int] = {}
    for nodes, pj in step2.assigned:
        for u in nodes:
            block_of[u] = bid
        proc_of_bid[bid] = pj
        bid += 1
    for nodes in step2.unassigned:
        for u in nodes:
            block_of[u] = bid
        bid += 1
    q = build_quotient(wf, block_of)
    for vid, members in q.members.items():
        b = block_of[next(iter(members))]
        q.proc[vid] = proc_of_bid.get(b)

    reqs = _Requirements(wf, exact_limit)
    if not _merge_unassigned(wf, platform, q, reqs):
        return None

    # ---- Step 4: swaps + idle moves ---------------------------------- #
    _swap_pass(wf, platform, q, reqs)
    _idle_moves(wf, platform, q, reqs)

    ms = compute_makespan(q, platform)
    return MappingResult(
        algo="DagHetPart",
        quotient=q,
        platform=platform,
        makespan=ms,
        runtime_s=0.0,
        k_used=q.n_vertices,
        extras={"k_prime": kp},
    )
