"""DagHetPart — the four-step partitioning-based heuristic (paper §4.2).

Step 1  Partition the DAG into k' acyclic blocks (edge-cut optimizer).
Step 2  BiggestAssign/FitBlock: largest block → largest-memory free
        processor; blocks that do not fit are recursively split.
Step 3  MergeUnassignedToAssigned/FindMSOptMerge: merge leftover blocks
        into assigned ones, preferring merges off the critical path,
        resolving 2-cycles by triple merges, bounded re-queuing.
Step 4  Swaps: best-improvement block swaps + moves of critical-path
        blocks to faster idle processors.

The driver sweeps k' ≤ k and keeps the best makespan (paper Step 1).

Migration note
--------------
The pipeline itself now lives in :mod:`repro.core.scheduler`: Steps 1–4
are registered, composable pipeline stages (``"partition"``,
``"assign"``, ``"merge"``, ``"swap"``, ``"idle_moves"``) driven by a
:class:`~repro.core.scheduler.Scheduler`, which also parallelizes the
k' sweep and returns structured :class:`ScheduleReport`\\ s.  This
module keeps the step *implementations* plus a deprecated
:func:`dag_het_part` wrapper for the old ``MappingResult | None``
contract.

Scaling design (30k-task instances)
-----------------------------------
Candidate evaluation no longer re-sweeps Γ: Steps 3–4 share one
:class:`repro.core.incremental.IncrementalEvaluator`, which maintains
bottom weights / makespan / critical path under merges, reassignments
and swaps via ancestor-only delta propagation with transactional
rollback.  Block memory requirements come from :class:`_Requirements`,
an LRU-bounded cache that *composes* merged requirements from part
witnesses (``r(A∪B) ≤ base_A + base_B + max(peak_A, peak_B) + X`` for a
one-directional merge with cross volume ``X``; ``r(A∪B) ≥ max(r_A,
r_B)``) so most merge candidates are priced O(1) instead of re-running
the min-peak traversal search.  Step 4 prunes the O(V²) swap scan to
pairs touching the critical path — a swap leaving every current
maximum chain untouched cannot lower the makespan — with an optional
exhaustive verification scan after convergence.

Three further layers close the ROADMAP's 30k hot-spot list (PR 5, see
``docs/architecture.md``): Step 2's block constants and ready-heap run
on flat numpy arrays (:mod:`repro.core.memdag`, bit-identical to the
scalar path); committed merges maintain topological ranks via
Pearce–Kelly localized reordering with a rank-window-bounded
acyclicity probe (:class:`IncrementalEvaluator`); and Step-4 rescans
reuse probe verdicts whose dependency region the applied swap did not
touch (see :func:`_swap_pass`).  Step 1 follows in PR 6: refinement
replays the scalar move sequence over the same cached CSR view behind
a vectorized prefilter, with an opt-in multilevel
coarsen→partition→uncoarsen mode for n ≥ 100k
(:mod:`repro.core.partitioner`).  All are observable through
``ScheduleReport.cache_stats``.
"""
from __future__ import annotations

import heapq
import itertools
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass

from . import counters
from .baseline import MappingResult
from .dag import QuotientGraph, Workflow
from .incremental import IncrementalEvaluator
from .memdag import block_requirement_witness, simulate_peak_members
from .partitioner import partition_block
from .platform import Platform

__all__ = ["dag_het_part", "kprime_sweep_values"]


# ---------------------------------------------------------------------- #
# Step 2: BiggestAssign + FitBlock (Algorithms 1–2)
# ---------------------------------------------------------------------- #
@dataclass
class _Step2Result:
    assigned: list[tuple[list[int], int]]  # (tasks, processor)
    unassigned: list[list[int]]


class _BlockPQ:
    """Max-priority queue of blocks keyed by memory requirement.

    ``memo`` (shared across the k' sweep) deduplicates requirement
    computations: FitBlock's recursive bisection revisits the same
    blocks for different k' — e.g. k'=1's first split of the full task
    set is exactly k'=2's initial partition — so content-keyed reuse
    cuts most of Step 2's traversal-search work after the first k'.
    """

    def __init__(self, wf: Workflow, exact_limit: int,
                 memo: dict | None = None) -> None:
        self.wf = wf
        self.exact_limit = exact_limit
        self.memo = memo if memo is not None else {}
        self._heap: list[tuple[float, int, list[int]]] = []
        self._counter = itertools.count()

    def requirement(self, nodes: list[int]) -> float:
        return _memo_witness(self.wf, nodes, self.exact_limit,
                             self.memo)[0]

    def push(self, nodes: list[int]) -> None:
        r = self.requirement(nodes)
        heapq.heappush(self._heap, (-r, next(self._counter), nodes))

    def pop(self) -> tuple[float, list[int]]:
        negr, _, nodes = heapq.heappop(self._heap)
        return -negr, nodes

    def __bool__(self) -> bool:
        return bool(self._heap)


_FITS, _SPLIT, _STUCK = 0, 1, 2


def _memo_witness(wf: Workflow, nodes: list[int], exact_limit: int,
                  memo: dict) -> tuple:
    """Content-keyed requirement witness, shared across the k' sweep.

    One computation serves every consumer that prices the same block
    content: Step 2's priority queue, Step 3's per-vertex entries
    (Step 2 hands Step 3 exactly the blocks it just priced), and the
    slow-path merged-union checks.  ``nodes`` must be ascending (all
    block lists in this module are) for keys to unify.
    """
    key = tuple(nodes)
    e = memo.get(key)
    if e is None:
        counters.bump("step2_memo_misses")
        e = block_requirement_witness(wf, nodes, exact_limit=exact_limit)
        memo[key] = e
    else:
        counters.bump("step2_memo_hits")
    return e


def _split_block(queue: _BlockPQ, nodes: list[int]) -> list[list[int]]:
    """Bisect ``nodes``; memoized by content across the k' sweep."""
    key = ("split", tuple(nodes))
    parts = queue.memo.get(key)
    if parts is None:
        parts = partition_block(queue.wf, nodes, 2)
        queue.memo[key] = parts
    return parts


def _fit_block(
    nodes: list[int],
    r: float,
    queue: _BlockPQ,
    cap: float,
) -> int:
    """FitBlock (Algorithm 2) without the mapping side effect.

    ``_FITS``: block fits ``cap``.  ``_SPLIT``: did not fit, pieces
    reinserted into the queue.  ``_STUCK``: singleton exceeding ``cap``
    — cannot be split; the paper's FitBlock would loop, we hand it to
    Step 3, which may still merge it into a block on a larger-memory
    processor.
    """
    if r <= cap:
        return _FITS
    if len(nodes) > 1:
        for part in _split_block(queue, nodes):
            queue.push(part)
        return _SPLIT
    return _STUCK


def _biggest_assign(
    wf: Workflow,
    platform: Platform,
    blocks: list[list[int]],
    exact_limit: int,
    memo: dict | None = None,
) -> _Step2Result:
    """Algorithm 1: assign biggest blocks to biggest memories."""
    queue = _BlockPQ(wf, exact_limit, memo)
    for b in blocks:
        queue.push(b)
    proc_ids = platform.sorted_by_memory()
    assigned: list[tuple[list[int], int]] = []
    stuck: list[list[int]] = []
    next_proc = 0
    while queue and next_proc < len(proc_ids):
        r, nodes = queue.pop()
        pj = proc_ids[next_proc]
        status = _fit_block(nodes, r, queue, platform.memory(pj))
        if status == _FITS:
            assigned.append((nodes, pj))
            next_proc += 1
        elif status == _STUCK:
            stuck.append(nodes)
    # remaining blocks: shrink them to the smallest memory (no mapping)
    unassigned: list[list[int]] = list(stuck)
    if queue:
        min_mem = platform.min_memory()
        while queue:
            r, nodes = queue.pop()
            if r <= min_mem or len(nodes) == 1:
                unassigned.append(nodes)
            else:
                for part in _split_block(queue, nodes):
                    queue.push(part)
    return _Step2Result(assigned, unassigned)


# ---------------------------------------------------------------------- #
# Step 3: merging (Algorithms 3–4)
# ---------------------------------------------------------------------- #
class _Requirements:
    """LRU-bounded, merge-aware cache of ``r_V`` keyed by vertex id.

    Entries are ``(r, base, peak_w, order)``: the reported requirement,
    the persistent residency base, and a concrete traversal witness
    ``order`` with simulated transient peak ``peak_w`` (see
    :func:`repro.core.memdag.block_requirement_witness`).

    Composition (the merge fast path): for a pair merge A∪B whose
    quotient edges all run A→B with total cross volume ``X``, executing
    A's witness then B's witness is a valid traversal, and every step
    carries at most ``X`` extra live bytes (the A→B files), hence::

        r(A∪B) ≤ base_A + base_B + max(peak_A, peak_B) + X      (ub)
        r(A∪B) ≥ max(r_A, r_B)                                  (lb)

    (The lb holds for true min-peaks — merging only converts streamed
    externals into held internals; on the heuristic estimates it is
    used as a pruning signal.)  When ``ub`` already fits the target
    memory, or ``lb`` already exceeds it, FindMSOptMerge prices the
    candidate without re-running the min-peak traversal search.

    Committed merges *pin* a composed entry (concatenated witness,
    re-simulated peak): pinned witnesses are not reproducible from a
    fresh greedy run, so they are exempt from LRU eviction and are
    exported into ``MappingResult.extras["orders"]`` as feasibility
    witnesses for validation.
    """

    def __init__(self, wf: Workflow, exact_limit: int,
                 max_entries: int = 8192,
                 sweep_memo: dict | None = None) -> None:
        self.wf = wf
        self.exact_limit = exact_limit
        self.max_entries = max_entries
        self.sweep_memo = sweep_memo if sweep_memo is not None else {}
        self._lru: OrderedDict[int, tuple] = OrderedDict()
        self._pinned: dict[int, tuple] = {}

    def entry(self, q: QuotientGraph, vid: int) -> tuple:
        e = self._pinned.get(vid)
        if e is not None:
            return e
        e = self._lru.get(vid)
        if e is not None:
            self._lru.move_to_end(vid)
            return e
        # content-keyed reuse: Step 2 priced this exact block already
        e = _memo_witness(self.wf, sorted(q.members[vid]),
                          self.exact_limit, self.sweep_memo)
        self._lru[vid] = e
        if len(self._lru) > self.max_entries:
            self._lru.popitem(last=False)
        return e

    def of(self, q: QuotientGraph, vid: int) -> float:
        return self.entry(q, vid)[0]

    def forget(self, *vids: int) -> None:
        for v in vids:
            self._lru.pop(v, None)
            self._pinned.pop(v, None)

    @staticmethod
    def bound_pair(e_a: tuple, e_b: tuple, cross: float) -> tuple[float, float]:
        """``(lb, ub)`` on the merged requirement (see class docstring)."""
        lb = max(e_a[0], e_b[0])
        ub = e_a[1] + e_b[1] + max(e_a[2], e_b[2]) + cross
        return lb, ub

    def commit_merged(self, q: QuotientGraph, vm: int,
                      compose: tuple | None) -> None:
        """Pin an entry for a committed merge result ``vm``.

        ``compose`` is ``(e_first, e_second)`` — part entries in
        topological order — for a pair merge, or ``None`` (triple
        merges interleave, so the witness is recomputed from scratch).
        """
        if compose is not None:
            e1, e2 = compose
            order = e1[3] + e2[3]
            base = e1[1] + e2[1]
            peak_w = simulate_peak_members(self.wf, q.members[vm], order)
            entry = (base + peak_w, base, peak_w, order)
            # a slow-path acceptance already priced this exact content
            # with the full traversal search — keep the tighter of the
            # two, else the pinned entry over-prices the block for all
            # later merge bounds and Step-4 memory checks
            known = self.sweep_memo.get(tuple(sorted(q.members[vm])))
            if known is not None and known[0] < entry[0]:
                entry = known
        else:
            entry = block_requirement_witness(
                self.wf, sorted(q.members[vm]),
                exact_limit=self.exact_limit)
        self._pinned[vm] = entry

    def snapshot(self, q: QuotientGraph) -> dict[int, float]:
        """``{vid: r}`` for all live vertices — plain-dict requirement
        lookups for Step 4, where the partition no longer changes."""
        return {vid: self.of(q, vid) for vid in q.members}

    def witness_orders(self, q: QuotientGraph) -> dict[int, list[int]]:
        """Feasibility witnesses for all live vertices with entries."""
        out: dict[int, list[int]] = {}
        for vid in q.members:
            e = self._pinned.get(vid) or self._lru.get(vid)
            if e is not None:
                out[vid] = e[3]
        return out


def _find_ms_opt_merge(
    v: int,
    neighbours: list[int],
    ev: IncrementalEvaluator,
    platform: Platform,
    reqs: _Requirements,
    pinned: frozenset[int] | set[int] = frozenset(),
) -> tuple[float, int | None, int | None]:
    """Algorithm 3: best merge of unassigned ``v`` into a candidate.

    ``neighbours`` is the pre-filtered, sorted candidate list (callers
    intersect ``v``'s adjacency with the eligible assigned set — O(deg)
    instead of O(V) set algebra per queue item).  Returns
    ``(best_makespan, best_partner, optional_third)``; partner is
    ``None`` when no feasible merge exists.  ``Γ`` is restored to its
    input state before returning.  Candidates are priced by
    delta-evaluation on ``ev`` with rollback; memory feasibility uses
    the composition bounds of :class:`_Requirements` and only falls
    back to the full min-peak traversal search when the bounds are
    inconclusive (or for triple merges, whose parts interleave).

    ``pinned`` blocks (warm-start mode: in-flight on their processor)
    may *absorb* ``v`` — the merged block keeps their processor — but a
    triple merge whose third partner is pinned is rejected: absorbing
    the third would strip it of its own processor, i.e. move it.
    """
    q = ev.q
    best_ms = float("inf")
    best_partner: int | None = None
    best_third: int | None = None
    if not neighbours:
        return best_ms, None, None
    ev.ensure_exact_ranks()  # bounded settles need the rank invariant
    e_v = reqs.entry(q, v)
    for vp in neighbours:
        target_proc = q.proc[vp]
        cap = platform.memory(target_proc)
        e_vp = reqs.entry(q, vp)
        cross = q.succ[v].get(vp, 0.0) + q.succ[vp].get(v, 0.0)
        lb, ub = reqs.bound_pair(e_v, e_vp, cross)
        if lb > cap:
            continue  # merged block cannot fit — skip the trial entirely
        # A 2-cycle after the pair merge (-> triple merge) is possible
        # only through a common out/in neighbour; knowing that up front
        # gates both cheap paths below.
        down, up = ((vp, v) if vp in q.succ[v] else (v, vp))
        two_cycle = q.succ[up].keys() & q.pred[down].keys()
        may_triple = bool(two_cycle)
        if may_triple:
            # the triple partner is known pre-merge (cycle_through
            # returns the smallest common neighbour): reject by the
            # requirement lower bound before any structural work
            other = min(two_cycle)
            if other in pinned:
                continue  # absorbing a pinned block would move it
            e_other = reqs.entry(q, other)
            if max(e_v[0], e_vp[0], e_other[0]) > cap:
                continue
        # O(1) makespan rejection before any structural work: chains
        # through the merged vertex cost at least its own time plus the
        # downstream part's child term (unchanged by a *pair* merge; a
        # triple merge may absorb that child, voiding the bound).
        if not may_triple and best_ms < float("inf"):
            own_vm = ((q.weight[v] + q.weight[vp])
                      / platform.speed(target_proc))
            child_term = ev.bottom_weight(down) - ev.own_time(down)
            if own_vm + child_term > best_ms + 1e-9 * abs(best_ms):
                continue
        if not may_triple:
            # Pair merges never need the frame machinery: feasibility
            # is decided on the member union (composition bound, then
            # concatenated-witness simulation, then the full traversal
            # search) and pricing goes through a structure-only
            # overlay probe.
            if ub > cap:  # composition bound inconclusive
                e_up = e_v if up == v else e_vp
                e_down = e_vp if e_up is e_v else e_v
                union = q.members[v] | q.members[vp]
                base = e_v[1] + e_vp[1]
                peak_sim = simulate_peak_members(
                    reqs.wf, union, e_up[3] + e_down[3])
                if base + peak_sim > cap:
                    r = _memo_witness(reqs.wf, sorted(union),
                                      reqs.exact_limit,
                                      reqs.sweep_memo)[0]
                    if r > cap:
                        continue
            ms = ev.probe_merge(v, vp, target_proc, best_ms)
            if ms is not None:
                best_ms, best_partner, best_third = ms, vp, None
            continue
        # may_triple: the pair merge is *guaranteed* cyclic (vm <-> the
        # common neighbour), so this is always a frame-managed triple
        ev.begin()
        vm, cycle = ev.merge(v, vp)
        assert cycle is not None, "pair merge with common neighbour"
        if len(cycle) != 2:
            ev.rollback()
            continue
        third = cycle[0] if cycle[0] != vm else cycle[1]
        if third in pinned:
            ev.rollback()
            continue
        vm, cycle = ev.merge(vm, third)
        if cycle is not None:
            ev.rollback()
            continue
        r = _memo_witness(reqs.wf, sorted(q.members[vm]),
                          reqs.exact_limit, reqs.sweep_memo)[0]
        if r <= cap:
            ev.set_proc(vm, target_proc)
            ms = ev.makespan()
            if ms < best_ms:
                best_ms, best_partner, best_third = ms, vp, third
        ev.rollback()
    return best_ms, best_partner, best_third


def _merge_unassigned(
    wf: Workflow,
    platform: Platform,
    q: QuotientGraph,
    reqs: _Requirements,
    ev: IncrementalEvaluator,
    pinned: set[int] | None = None,
) -> dict | None:
    """Algorithm 4.  Mutates ``q``; ``None`` on success, else a failure
    record ``{"reason", "gap", "block_size"}`` describing the block that
    could not be merged or placed (``gap`` is its requirement minus the
    largest processor memory — positive means no processor could ever
    hold it, non-positive means the capacity exists but every feasible
    merge/idle placement was exhausted).

    Beyond-paper refinement (DESIGN.md §8): when no merge is feasible,
    try placing the block on a memory-feasible *idle* processor before
    giving up — the paper only uses idle processors in Step 4, after a
    full assignment exists, which strands late-split singletons whose
    requirement exceeds every assigned block's headroom.

    The critical path comes from the maintained evaluator state, and
    committed merges pin composed requirement entries so later merges
    into the grown block stay on the O(1) bound fast path.  The
    assigned/busy/path sets are maintained incrementally — per-item
    work is O(deg), not O(V).

    ``pinned`` (warm-start mode) marks assigned blocks whose processor
    must not change: they may absorb unassigned blocks (the merged
    block keeps their processor and inherits the pin — ``pinned`` is
    updated in place), but never lose their own assignment.
    """
    if pinned is None:
        pinned = set()
    path = ev.critical_path_set()
    assigned = {v for v in q.vertices() if q.proc[v] is not None}
    busy = {q.proc[a] for a in assigned}
    queue = deque(v for v in sorted(q.vertices()) if q.proc[v] is None)
    seen_count: dict[int, int] = {v: 0 for v in queue}
    while queue:
        v = queue.popleft()
        nbrs = sorted(
            w for w in itertools.chain(q.pred[v], q.succ[v])
            if w in assigned and w not in path
        )
        ms, partner, third = _find_ms_opt_merge(
            v, nbrs, ev, platform, reqs, pinned)
        if partner is None:
            # off-path candidates are all proven infeasible at this
            # point (a feasible one would have set a partner), so the
            # fallback scan only needs the path-restricted remainder
            nbrs = sorted(
                w for w in itertools.chain(q.pred[v], q.succ[v])
                if w in assigned and w in path
            )
            ms, partner, third = _find_ms_opt_merge(
                v, nbrs, ev, platform, reqs, pinned)
        if partner is None:
            # place-on-idle fallback
            r_v = reqs.of(q, v)
            idle = [j for j in range(platform.k)
                    if j not in busy and platform.memory(j) >= r_v]
            if idle:
                pj = max(idle, key=platform.speed)
                ev.set_proc(v, pj)
                assigned.add(v)
                busy.add(pj)
                path = ev.critical_path_set()
                continue
        if partner is not None:
            target_proc = q.proc[partner]
            was_pinned = partner in pinned
            # capture part entries before the merge for witness
            # composition (quotient edges between v/partner run one way)
            first, second = ((v, partner) if partner in q.succ[v]
                             else (partner, v))
            compose = (reqs.entry(q, first), reqs.entry(q, second))
            vm, cycle = ev.merge(v, partner)
            assigned.discard(partner)
            reqs.forget(v, partner)
            if third is not None:
                third_proc = q.proc[third]
                vm2, cycle = ev.merge(vm, third)
                assert cycle is None, "triple merge no longer acyclic"
                assigned.discard(third)
                if third_proc is not None:
                    busy.discard(third_proc)  # absorbed block frees it
                reqs.forget(vm, third)
                if third_proc is None and third in queue:
                    queue.remove(third)
                vm = vm2
                compose = None  # interleaved parts: recompute witness
            ev.set_proc(vm, target_proc)
            reqs.commit_merged(q, vm, compose)
            assigned.add(vm)
            if was_pinned:
                # the merged block stays on the pinned processor; the
                # pin survives so Step 4 never moves it either
                pinned.discard(partner)
                pinned.add(vm)
            path = ev.critical_path_set()
        else:
            unresolved_nbrs = any(
                q.proc[w] is None
                for w in itertools.chain(q.pred[v], q.succ[v])
            )
            if unresolved_nbrs and seen_count.get(v, 0) <= 1:
                seen_count[v] = seen_count.get(v, 0) + 1
                queue.append(v)
            else:
                # no solution for this k'
                r_v = reqs.of(q, v)
                size = len(q.members[v])
                return {
                    "reason": (
                        f"block of {size} task(s) with requirement "
                        f"{r_v:.4g} has no feasible merge or idle "
                        f"placement"
                    ),
                    "gap": r_v - platform.max_memory(),
                    "block_size": size,
                }
    return None


# ---------------------------------------------------------------------- #
# Step 4: swaps + idle-processor moves (Algorithm 5)
# ---------------------------------------------------------------------- #
def _swap_candidates(
    q: QuotientGraph,
    platform: Platform,
    ev: IncrementalEvaluator,
):
    """Pruned best-improvement neighborhood: pairs touching the path.

    A swap that leaves every current maximum-weight chain untouched
    cannot lower the makespan (the untouched chain keeps its exact
    bottom weight), so one endpoint must lie on the maintained critical
    path.  For an off-path partner, the path endpoint must additionally
    move to a strictly *faster* processor — its own term ``w_v / s_v``
    is the only path term a swap can change.
    """
    path = ev.critical_path()
    on_path = set(path)
    verts = sorted(q.vertices())
    seen: set[tuple[int, int]] = set()
    for v in path:
        pa = q.proc[v]
        for vp in verts:
            if vp == v:
                continue
            key = (v, vp) if v < vp else (vp, v)
            if key in seen:
                continue
            seen.add(key)
            pb = q.proc[vp]
            if vp not in on_path and \
                    platform.speed(pb) <= platform.speed(pa):
                continue
            yield v, vp


def _swap_pass(
    wf: Workflow,
    platform: Platform,
    q: QuotientGraph,
    reqs: _Requirements,
    ev: IncrementalEvaluator,
    *,
    exhaustive: bool = False,
    full_scan_fallback: bool = True,
    pinned: set[int] | None = None,
    probe_cache: bool = True,
) -> None:
    """Best-improvement swaps, delta-evaluated with rollback.

    The scan is restricted to the pruned critical-path neighborhood
    (:func:`_swap_candidates`); once it is exhausted, one exhaustive
    O(V²) verification scan runs (``full_scan_fallback``) — cheap now
    that each probe is a delta evaluation instead of a full sweep.
    ``exhaustive=True`` forces full scans throughout (test oracle).
    ``pinned`` blocks (warm-start mode) never swap.

    Dependency-region probe caching (``probe_cache``): rescans after
    an applied swap re-probe mostly pairs whose verdict cannot have
    changed.  A "no improvement" probe verdict for pair ``(v, vp)``
    stays *exactly* reproducible while (a) no vertex whose bottom
    weight or processor changed lies in the pair's read closure —
    ``{v, vp}``, their ancestors, and those vertices' children — and
    (b) the probe's *head* (the untouched vertex whose maintained
    weight supplied the final max, ``ev.last_probe_head``) kept its
    value; the improvement bound only ever tightens within a pass, so
    a cached rejection can never hide a fresh improvement.  After each
    applied swap the touched region — descendant closure of the
    changed vertices, the swapped pair and their parents — is stamped,
    and cached verdicts are reused only when both endpoints (and the
    head) predate every stamp.  Cache reuse therefore replicates the
    uncached scan decision-for-decision: final mappings are
    bit-identical with the cache on or off (property-tested).
    """
    if pinned is None:
        pinned = frozenset()
    ev.ensure_exact_ranks()
    req_of = reqs.snapshot(q)  # partition is frozen during Step 4
    mem_of = [platform.memory(j) for j in range(platform.k)]
    best_ms = ev.makespan()
    full_checked = False
    verdicts: dict[tuple[int, int], tuple[int, int | None]] = {}
    inv_stamp: dict[int, int] = {}   # vid -> last scan touching its region
    l_stamp: dict[int, int] = {}     # vid -> last scan its l changed
    scan = 0
    while True:
        best_pair: tuple[int, int] | None = None
        run_full = exhaustive or full_checked
        if exhaustive:
            verts = sorted(q.vertices())
            pairs = ((v, vp) for i, v in enumerate(verts)
                     for vp in verts[i + 1:])
        elif run_full:
            # Verification scan: drop only the speed prune.  Pairs with
            # both endpoints off the critical path stay excluded — the
            # untouched path keeps its bottom weight, so those swaps
            # cannot lower the makespan (see _swap_candidates).
            on_path = set(ev.critical_path())
            verts = sorted(q.vertices())
            pairs = ((v, vp) for i, v in enumerate(verts)
                     for vp in verts[i + 1:]
                     if v in on_path or vp in on_path)
        else:
            pairs = _swap_candidates(q, platform, ev)
        for v, vp in pairs:
            if v in pinned or vp in pinned:
                continue
            pa, pb = q.proc[v], q.proc[vp]
            if pa == pb:
                continue
            # O(1) sound rejection: after the swap, vp's bottom weight
            # rises by its own-time increase, offset at most by v's
            # own-time gain (v appears at most once below vp), so
            # ms' >= l(vp) + rise(vp) - gain(v).  A small slack keeps
            # borderline cases on the exact probe path.
            sa, sb = platform.speed(pa), platform.speed(pb)
            rise_vp = q.weight[vp] / sa - q.weight[vp] / sb
            gain_v = q.weight[v] / sa - q.weight[v] / sb
            lb = ev.bottom_weight(vp) + rise_vp - max(0.0, gain_v)
            if lb > best_ms + 1e-9 * abs(best_ms):
                continue
            if req_of[v] > mem_of[pb]:
                continue
            if req_of[vp] > mem_of[pa]:
                continue
            key = (v, vp) if v < vp else (vp, v)
            if probe_cache:
                ent = verdicts.get(key)
                if ent is not None:
                    s, head = ent
                    if (inv_stamp.get(v, -1) <= s
                            and inv_stamp.get(vp, -1) <= s
                            and (head is None
                                 or l_stamp.get(head, -1) <= s)):
                        counters.bump("swap_probe_cache_hits")
                        continue
            counters.bump("swap_probes")
            ms = ev.probe_swap(v, vp, best_ms - 1e-12)
            if ms is not None:
                best_ms = ms
                best_pair = (v, vp)
                verdicts.pop(key, None)
            elif probe_cache:
                verdicts[key] = (scan, ev.last_probe_head)
        if best_pair is None:
            if run_full or not full_scan_fallback:
                return
            full_checked = True   # pruned neighborhood exhausted: verify
            continue
        changed = ev.swap_and_changes(*best_pair)
        full_checked = False
        if probe_cache:
            scan += 1
            for x in changed:
                l_stamp[x] = scan
            # invalidation region: descendants of everything whose
            # value or processor moved, plus of the parents of the
            # value-changed vertices (parents *read* a changed child)
            seeds = set(changed)
            seeds.update(best_pair)
            region = set(seeds)
            for x in changed:
                region.update(q.pred[x])
            stack = list(region)
            while stack:
                u = stack.pop()
                for w in q.succ[u]:
                    if w not in region:
                        region.add(w)
                        stack.append(w)
            for x in region:
                inv_stamp[x] = scan


def _idle_moves(
    wf: Workflow,
    platform: Platform,
    q: QuotientGraph,
    reqs: _Requirements,
    ev: IncrementalEvaluator,
    pinned: set[int] | None = None,
) -> None:
    """Move critical-path blocks to faster idle processors.

    Walks the evaluator's maintained critical path; each probe is a
    transactional reassignment, committed only on improvement.
    ``pinned`` blocks (warm-start mode) never move.
    """
    if pinned is None:
        pinned = frozenset()
    busy = {q.proc[v] for v in q.vertices()}
    idle = [j for j in range(platform.k) if j not in busy]
    if not idle:
        return
    ev.ensure_exact_ranks()
    moved: set[int] = set()
    while True:
        path = ev.critical_path()
        cand = [v for v in path if v not in moved and v not in pinned]
        if not cand:
            return
        ms0 = ev.makespan()
        progressed = False
        for v in cand:
            moved.add(v)
            cur = q.proc[v]
            options = [
                j for j in idle
                if platform.speed(j) > platform.speed(cur)
                and reqs.of(q, v) <= platform.memory(j)
            ]
            if not options:
                continue
            j = max(options, key=platform.speed)
            if ev.probe_move(v, j, ms0 - 1e-12) is not None:
                ev.set_proc(v, j)
                idle.remove(j)
                idle.append(cur)
                progressed = True
                break  # critical path changed; recompute
        if not progressed:
            return


# ---------------------------------------------------------------------- #
# driver
# ---------------------------------------------------------------------- #
def kprime_sweep_values(wf: Workflow, platform: Platform,
                        mode: str = "auto") -> list[int]:
    """Which k' values to try (paper: all of 1..k; we default to a
    geometric subset for very large workflows — a documented knob).

    The subset always contains 1, 2, 3, ``max(1, k // 2)`` and ``k``:
    half the platform is the sweep's empirically strongest anchor on
    wide workflows, and the geometric ladder can otherwise step over
    it.  Values are deduplicated before sorting, so small ``k`` (where
    the anchors collide) yields each candidate exactly once.
    """
    k = platform.k
    if mode == "full" or (mode == "auto" and wf.n <= 4000):
        return list(range(1, k + 1))
    vals = [1, 2, 3, max(1, k // 2), k]
    v = 4
    while v < k:
        vals.append(v)
        v = int(v * 1.6) + 1
    return sorted({x for x in vals if 1 <= x <= k})


def dag_het_part(
    wf: Workflow,
    platform: Platform,
    *,
    kprime: str | list[int] = "auto",
    exact_limit: int = 0,
    verbose: bool = False,
) -> MappingResult | None:
    """Run the four-step heuristic, sweeping k' and keeping the best.

    .. deprecated::
        Use :class:`repro.core.scheduler.Scheduler` (or the
        :func:`repro.core.scheduler.schedule` shorthand), which returns
        a :class:`~repro.core.scheduler.ScheduleReport` — never ``None``
        — with the k'→makespan sweep trace, per-stage timings and a
        structured infeasibility diagnosis, and can run the k' sweep on
        a process pool (``workers>1``).  This wrapper keeps the old
        ``MappingResult | None`` contract by returning ``report.best``.
    """
    warnings.warn(
        "dag_het_part() is deprecated; use repro.core.scheduler."
        "Scheduler (returns a ScheduleReport instead of "
        "MappingResult | None)",
        DeprecationWarning, stacklevel=2,
    )
    from .scheduler import schedule

    report = schedule(wf, platform, algorithm="dag_het_part",
                      kprime=kprime, exact_limit=exact_limit,
                      verbose=verbose)
    return report.best
