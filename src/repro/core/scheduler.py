"""Unified Scheduler/Plan API: the paper's heuristics as a pipeline.

The four-step heuristic (paper §4.2) and the DagHetMem baseline (§4.1)
are *pipelines* of stages, not opaque functions.  This module makes
that structure first-class:

* :class:`Stage` — protocol for one pipeline step; implementations are
  registered by name (:func:`register_stage`) and composed into
  algorithm pipelines (:data:`PIPELINES`, :func:`register_pipeline`),
* :class:`SchedulerConfig` — algorithm, k'-sweep policy, exact-DP
  limit, per-step toggles, time budget, worker count and the
  ``on_sweep_result`` reporting callback,
* :class:`Scheduler` — the facade: ``Scheduler(config).schedule(wf,
  platform)`` runs the k' sweep (serially or on a
  ``concurrent.futures`` process pool with per-worker Step-2 memos)
  and **always** returns a :class:`ScheduleReport` — never ``None``,
* :class:`ScheduleReport` — the best :class:`MappingResult` *or* a
  structured :class:`Infeasibility` (which stage failed, tightest
  memory gap, smallest k' attempted), plus per-stage timings, the full
  k'→makespan sweep trace and ``to_json()``/``from_json()`` for
  benchmark artifacts.

Paper-step ↔ stage-name map::

    Step 1  partition    acyclic k'-way partition (dagP role)
    Step 2  assign       BiggestAssign/FitBlock (Algorithms 1–2)
    Step 3  merge        MergeUnassignedToAssigned (Algorithms 3–4)
    Step 4  swap         best-improvement block swaps (Algorithm 5)
    Step 4  idle_moves   critical-path moves to faster idle processors
    §4.1    pack         DagHetMem min-peak traversal packing
    —       simulate     discrete-event replay (repro.sim), off by
                         default (``SchedulerConfig(simulate=True)``)

Determinism: every stage is deterministic, and the sweep reduction
scans results in sweep order with a strict ``<``, so ``workers=N`` and
``workers=1`` pick bit-identical best makespans (the per-worker memos
only cache deterministic pure functions).
"""
from __future__ import annotations

import json
import logging
import time
from concurrent.futures import ProcessPoolExecutor
from contextlib import contextmanager
from dataclasses import dataclass, field, replace
from typing import Callable, Protocol, Sequence, runtime_checkable

from repro.obs import JsonlSink, ObsConfig, span_events, write_chrome_trace
from repro.obs import tracer as _trc
from repro.obs.metrics import METRICS, RATIO_BOUNDARIES, Histogram
from repro.obs.tracer import trace_span

_log = logging.getLogger(__name__)

from . import counters
from .baseline import MappingResult, _pack_min_peak
from .dag import Workflow, build_quotient
from .heuristic import (
    _Requirements,
    _biggest_assign,
    _idle_moves,
    _memo_witness,
    _merge_unassigned,
    _swap_pass,
    kprime_sweep_values,
)
from .incremental import IncrementalEvaluator
from .partitioner import acyclic_partition
from .platform import Platform

__all__ = [
    "Infeasibility",
    "MappingSummary",
    "PIPELINES",
    "ResumeState",
    "ScheduleReport",
    "Scheduler",
    "SchedulerConfig",
    "Stage",
    "StageContext",
    "SweepPoint",
    "available_stages",
    "get_stage",
    "kprime_sweep_values",
    "register_pipeline",
    "register_stage",
    "schedule",
]


# ---------------------------------------------------------------------- #
# report dataclasses
# ---------------------------------------------------------------------- #
@dataclass
class SweepPoint:
    """One k' attempt of the sweep (k' is ``None`` for sweep-free
    pipelines such as the baseline's single packing run).

    ``cache_stats`` carries the pipeline run's perf-cache counters
    (:mod:`repro.core.counters` deltas: Step-2 flat/scalar dispatch and
    memo reuse, Pearce–Kelly rank repairs vs full refreshes, Step-4
    swap-probe cache hits) — collected per attempt so the parallel
    sweep's per-worker counters aggregate correctly.  ``metrics`` is
    the attempt's non-counter :data:`repro.obs.metrics.METRICS` delta
    (gauges + histogram dicts) under the same bracket, and travels the
    same picklable route from pool workers; ``spans`` holds the
    attempt's finished tracer spans when the worker traced (transient
    — spliced into the parent tracer, never serialized to JSON).
    """

    k_prime: int | None
    makespan: float | None
    feasible: bool
    time_s: float
    stage_times: dict[str, float] = field(default_factory=dict)
    failed_stage: str | None = None
    fail_reason: str | None = None
    memory_gap: float | None = None
    cache_stats: dict[str, int] = field(default_factory=dict)
    metrics: dict = field(default_factory=dict)
    spans: list = field(default_factory=list, repr=False, compare=False)

    def to_dict(self) -> dict:
        return {
            "k_prime": self.k_prime,
            "makespan": self.makespan,
            "feasible": self.feasible,
            "time_s": self.time_s,
            "stage_times": dict(self.stage_times),
            "failed_stage": self.failed_stage,
            "fail_reason": self.fail_reason,
            "memory_gap": self.memory_gap,
            "cache_stats": dict(self.cache_stats),
            "metrics": dict(self.metrics),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SweepPoint":
        return cls(**{k: v for k, v in d.items() if k != "spans"})


@dataclass
class Infeasibility:
    """Structured diagnosis of an infeasible run.

    ``stage`` is the failure of the sweep attempt that got furthest
    through the pipeline; ``tightest_gap`` is the smallest positive
    requirement-minus-capacity deficit observed across the whole sweep
    (how much more memory would have been needed, ``None`` when every
    failure was structural rather than a raw capacity shortfall);
    ``smallest_kprime`` is the smallest k' attempted (``None`` for
    sweep-free runs: the baseline's single packing attempt and
    warm-start replans).

    Warm-start replans (``algorithm="warm_start"``, produced by
    :meth:`Scheduler.resume` and the scenario policies) report through
    the same type: ``stage`` may then also be ``"warm_start"`` (an
    inherited block no longer fits its surviving processor) or
    ``"materialize"`` (blocks left unassigned, e.g. the no-replan
    policy after a failure event).  Scenario timelines surface the
    diagnosis per planning segment and in the migration log of their
    :class:`repro.scenario.TimelineReport`.
    """

    algorithm: str
    stage: str
    reason: str
    tightest_gap: float | None
    smallest_kprime: int | None
    attempts: int

    def to_dict(self) -> dict:
        return {
            "algorithm": self.algorithm,
            "stage": self.stage,
            "reason": self.reason,
            "tightest_gap": self.tightest_gap,
            "smallest_kprime": self.smallest_kprime,
            "attempts": self.attempts,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Infeasibility":
        return cls(**d)


@dataclass
class MappingSummary:
    """JSON-friendly projection of a :class:`MappingResult` (the live
    quotient graph / platform objects stay on ``ScheduleReport.best``)."""

    algo: str
    makespan: float
    k_used: int
    k_prime: int | None
    runtime_s: float
    block_of_task: list[int]
    proc_of_block: dict[int, int]

    @classmethod
    def from_result(cls, res: MappingResult) -> "MappingSummary":
        return cls(
            algo=res.algo,
            makespan=float(res.makespan),
            k_used=int(res.k_used),
            k_prime=res.extras.get("k_prime"),
            runtime_s=float(res.runtime_s),
            block_of_task=[int(b) for b in res.block_of_task()],
            proc_of_block={int(v): int(p)
                           for v, p in sorted(res.quotient.proc.items())},
        )

    def to_dict(self) -> dict:
        return {
            "algo": self.algo,
            "makespan": self.makespan,
            "k_used": self.k_used,
            "k_prime": self.k_prime,
            "runtime_s": self.runtime_s,
            "block_of_task": list(self.block_of_task),
            # JSON objects key by string; keep explicit pairs instead
            "proc_of_block": [[v, p]
                              for v, p in sorted(self.proc_of_block.items())],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MappingSummary":
        d = dict(d)
        d["proc_of_block"] = {int(v): int(p) for v, p in d["proc_of_block"]}
        return cls(**d)


@dataclass
class ScheduleReport:
    """What a :class:`Scheduler` run returns — never ``None``.

    Exactly one of ``summary`` / ``infeasibility`` is set.  ``best``
    carries the live :class:`MappingResult` on feasible runs; it is
    deliberately excluded from JSON and equality (``from_json`` yields
    a report with ``best=None`` but an otherwise identical record).

    ``stage_times`` and ``cache_stats`` aggregate over the whole sweep
    (per-attempt values live on the :class:`SweepPoint`\\ s):
    ``cache_stats`` exposes the perf-cache counters of the run —
    ``step2_flat_blocks`` / ``step2_scalar_blocks`` /
    ``step2_memo_hits`` (flat-array Step 2 and the requirement memo),
    ``rank_pk_repairs`` / ``rank_full_refreshes`` (Pearce–Kelly
    dynamic topological ranks), ``swap_probe_cache_hits`` /
    ``swap_probes`` (Step-4 dependency-region verdict reuse) — see
    docs/benchmarks.md for the full key list.

    ``metrics`` is the run's aggregated non-counter metrics block
    (``{"gauges": ..., "histograms": ...}``, merged over all sweep
    points — e.g. the ``sched_sweep_point_s`` plan-latency histogram;
    see docs/observability.md).  ``spans`` carries the run's finished
    tracer spans when tracing was on (live objects — excluded from
    JSON and equality, like ``best``).
    """

    algorithm: str
    summary: MappingSummary | None
    infeasibility: Infeasibility | None
    sweep: list[SweepPoint]
    stage_times: dict[str, float]
    total_time_s: float
    workers: int
    truncated: bool = False
    cache_stats: dict[str, int] = field(default_factory=dict)
    metrics: dict = field(default_factory=dict)
    spans: list = field(default_factory=list, repr=False, compare=False)
    best: MappingResult | None = field(
        default=None, repr=False, compare=False)

    @property
    def feasible(self) -> bool:
        return self.summary is not None

    @property
    def makespan(self) -> float | None:
        return self.summary.makespan if self.summary else None

    @property
    def sim(self):
        """The best mapping's :class:`repro.sim.SimReport` (present when
        the run included the ``simulate`` stage), else ``None``."""
        return self.best.extras.get("sim") if self.best else None

    @property
    def reliability(self):
        """The best mapping's
        :class:`repro.objectives.ReliabilityReport` (present when the
        run included the ``reliability`` stage and the platform carries
        a failure model), else ``None``."""
        return self.best.extras.get("reliability") if self.best else None

    @property
    def energy(self):
        """The best mapping's :class:`repro.objectives.EnergyReport`
        (present when the run included the ``energy`` stage and the
        platform carries a power model), else ``None``."""
        return self.best.extras.get("energy") if self.best else None

    def to_dict(self) -> dict:
        return {
            "algorithm": self.algorithm,
            "summary": self.summary.to_dict() if self.summary else None,
            "infeasibility": (self.infeasibility.to_dict()
                              if self.infeasibility else None),
            "sweep": [p.to_dict() for p in self.sweep],
            "stage_times": dict(self.stage_times),
            "total_time_s": self.total_time_s,
            "workers": self.workers,
            "truncated": self.truncated,
            "cache_stats": dict(self.cache_stats),
            "metrics": dict(self.metrics),
        }

    def to_json(self, **kw) -> str:
        """Serialize the report record to JSON.

        Covers everything except ``best`` (the live mapping does not
        round-trip; ``from_json`` restores an otherwise identical
        report with ``best=None``) — so the summary's block/processor
        maps, the sweep trace, stage timings and cache stats all
        survive.  Scenario runs embed these serialized reports
        per planning segment inside a
        :class:`repro.scenario.TimelineReport`, next to that report's
        own ``timeline`` (stitched event segments) and migration log —
        deserializing a timeline reconstructs each segment's
        ``ScheduleReport`` through :meth:`from_dict` unchanged.
        """
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "ScheduleReport":
        return cls(
            algorithm=d["algorithm"],
            summary=(MappingSummary.from_dict(d["summary"])
                     if d.get("summary") else None),
            infeasibility=(Infeasibility.from_dict(d["infeasibility"])
                           if d.get("infeasibility") else None),
            sweep=[SweepPoint.from_dict(p) for p in d.get("sweep", [])],
            stage_times=dict(d.get("stage_times", {})),
            total_time_s=d["total_time_s"],
            workers=d.get("workers", 1),
            truncated=d.get("truncated", False),
            cache_stats=dict(d.get("cache_stats", {})),
            # absent on pre-PR-8 payloads: default to empty
            metrics=dict(d.get("metrics", {})),
        )

    @classmethod
    def from_json(cls, s: str) -> "ScheduleReport":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------- #
# stages
# ---------------------------------------------------------------------- #
@dataclass
class StageFailure:
    """Why a stage declared its k' attempt infeasible."""

    stage: str
    reason: str
    gap: float | None  # requirement − capacity deficit where computable


@dataclass
class ResumeState:
    """Warm-start input for :meth:`Scheduler.resume` — a partially
    executed plan lifted onto a (possibly changed) platform.

    ``wf`` is the residual workflow (see
    :func:`repro.core.workflows.residual_workflow`), ``blocks`` its
    partition inherited from the previous plan (residual task ids,
    grouped by surviving block), ``proc_of_block[b]`` the block's
    processor on ``platform`` — ``None`` where the old processor no
    longer exists (the block re-enters Step 3 as unassigned) — and
    ``pinned`` the indices of blocks that must stay on their processor
    (in-flight at the replanning point: warm-start never migrates
    them).  :mod:`repro.scenario` constructs these from a paused
    simulation; hand-built states just need the same shape.
    """

    wf: Workflow
    platform: Platform
    blocks: list[list[int]]
    proc_of_block: list[int | None]
    pinned: set[int] = field(default_factory=set)

    def __post_init__(self) -> None:
        if len(self.blocks) != len(self.proc_of_block):
            raise ValueError("blocks / proc_of_block length mismatch")
        bad = [b for b in self.pinned
               if not 0 <= b < len(self.blocks)
               or self.proc_of_block[b] is None]
        if bad:
            raise ValueError(
                f"pinned block(s) {sorted(bad)[:5]} unassigned or out "
                "of range — a pin needs a surviving processor"
            )


@dataclass
class StageContext:
    """Mutable state threaded through one pipeline run (one k')."""

    wf: Workflow
    platform: Platform
    k_prime: int | None
    exact_limit: int
    memo: dict                      # Step-2 requirement/split memo
    blocks: list[list[int]] | None = None   # Step-1 output
    q: object | None = None                 # quotient graph (post Step 2)
    reqs: _Requirements | None = None
    ev: IncrementalEvaluator | None = None
    result: MappingResult | None = None
    failure: StageFailure | None = None
    sim_options: dict | None = None         # simulate-stage kwargs
    throughput_options: dict | None = None  # throughput-stage kwargs
    objective_options: dict | None = None   # reliability/energy kwargs
    resume: ResumeState | None = None       # warm_start-stage input
    pinned: set[int] = field(default_factory=set)  # vids frozen in place
    step1_multilevel: bool = False          # multilevel Step-1 opt-in
    seed_blocks: list[list[int]] | None = None  # seed_partition-stage input


@runtime_checkable
class Stage(Protocol):
    """One pipeline step: mutate ``ctx``; set ``ctx.failure`` to abort
    the run (structured, never an exception for infeasibility).

    ``toggle`` optionally names the :class:`SchedulerConfig` boolean
    that enables the stage (``None`` ⇒ always on).
    """

    name: str
    toggle: str | None

    def run(self, ctx: StageContext) -> None: ...


class PartitionStage:
    """Step 1: initial acyclic k'-way partition (edge-cut optimizer)."""

    name = "partition"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        assignment = acyclic_partition(ctx.wf, ctx.k_prime,
                                       multilevel=ctx.step1_multilevel)
        groups: dict[int, list[int]] = {}
        for u, b in enumerate(assignment):
            groups.setdefault(b, []).append(u)
        ctx.blocks = [groups[b] for b in sorted(groups)]


class SeedPartitionStage:
    """Step-1 replacement for plan-cache hits: adopt a previously
    computed partition instead of re-running the edge-cut optimizer.

    The seed is a *block list* over the same task ids (typically a
    cached winner's ``MappingSummary.block_of_task`` regrouped by
    :meth:`Scheduler.seeded`).  Downstream stages are unchanged —
    Step 2 re-prices and re-assigns the seeded blocks against the
    *actual* platform, Step 3 repairs anything that no longer fits and
    Step 4 refines — so a stale seed degrades gracefully into a
    slightly worse plan or a structured failure, never a wrong one.
    """

    name = "seed_partition"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        blocks = ctx.seed_blocks
        if blocks is None:
            raise ValueError(
                "seed_partition stage needs seed blocks "
                "(use Scheduler.seeded)"
            )
        seen: list[int] = [0] * ctx.wf.n
        for nodes in blocks:
            for u in nodes:
                if not 0 <= u < ctx.wf.n or seen[u]:
                    raise ValueError(
                        f"seed partition does not bijectively cover "
                        f"task ids 0..{ctx.wf.n - 1} (task {u})"
                    )
                seen[u] = 1
        if not all(seen):
            raise ValueError(
                f"seed partition leaves {seen.count(0)} task(s) "
                "uncovered"
            )
        ctx.blocks = [list(nodes) for nodes in blocks if nodes]


class AssignStage:
    """Step 2: BiggestAssign/FitBlock, then lift the result into a
    quotient graph + requirements cache + incremental evaluator."""

    name = "assign"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        wf, platform = ctx.wf, ctx.platform
        step2 = _biggest_assign(wf, platform, ctx.blocks,
                                ctx.exact_limit, ctx.memo)
        if not step2.assigned:
            # every block ended stuck: singletons exceeding even the
            # largest memory — report the tightest deficit
            gaps = [
                _memo_witness(wf, nodes, ctx.exact_limit, ctx.memo)[0]
                - platform.max_memory()
                for nodes in step2.unassigned
            ]
            ctx.failure = StageFailure(
                self.name,
                f"no block fits any processor at k'={ctx.k_prime}",
                min(gaps) if gaps else None,
            )
            return
        block_of: list[int] = [-1] * wf.n
        bid = 0
        proc_of_bid: dict[int, int] = {}
        for nodes, pj in step2.assigned:
            for u in nodes:
                block_of[u] = bid
            proc_of_bid[bid] = pj
            bid += 1
        for nodes in step2.unassigned:
            for u in nodes:
                block_of[u] = bid
            bid += 1
        q = build_quotient(wf, block_of)
        for vid, members in q.members.items():
            b = block_of[next(iter(members))]
            q.proc[vid] = proc_of_bid.get(b)
        ctx.q = q
        ctx.reqs = _Requirements(wf, ctx.exact_limit, sweep_memo=ctx.memo)
        ctx.ev = IncrementalEvaluator(q, platform)


class MergeStage:
    """Step 3: merge unassigned blocks into assigned ones (never moving
    pinned blocks in warm-start runs)."""

    name = "merge"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        fail = _merge_unassigned(ctx.wf, ctx.platform, ctx.q,
                                 ctx.reqs, ctx.ev, ctx.pinned)
        if fail is not None:
            ctx.failure = StageFailure(
                self.name,
                f"{fail['reason']} at k'={ctx.k_prime}",
                fail["gap"],
            )


class SwapStage:
    """Step 4a: best-improvement block swaps (pinned blocks excluded)."""

    name = "swap"
    toggle = "swap"

    def run(self, ctx: StageContext) -> None:
        _swap_pass(ctx.wf, ctx.platform, ctx.q, ctx.reqs, ctx.ev,
                   pinned=ctx.pinned)


class IdleMoveStage:
    """Step 4b: move critical-path blocks to faster idle processors
    (pinned blocks excluded)."""

    name = "idle_moves"
    toggle = "idle_moves"

    def run(self, ctx: StageContext) -> None:
        _idle_moves(ctx.wf, ctx.platform, ctx.q, ctx.reqs, ctx.ev,
                    ctx.pinned)


class WarmStartStage:
    """Warm start: rebuild the quotient from a :class:`ResumeState`
    instead of partitioning from scratch.

    Replaces Steps 1–2 in the ``warm_start`` pipeline: the inherited
    partition becomes the quotient, surviving assignments are kept
    (re-checked against their processor's memory), blocks whose
    processor disappeared re-enter Step 3 as unassigned, and pinned
    blocks are marked so merge/swap/idle_moves never move them.
    """

    name = "warm_start"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        state = ctx.resume
        if state is None:
            raise ValueError(
                "warm_start stage needs a ResumeState "
                "(use Scheduler.resume)"
            )
        wf, platform = ctx.wf, ctx.platform
        block_of: list[int] = [-1] * wf.n
        for b, nodes in enumerate(state.blocks):
            for u in nodes:
                block_of[u] = b
        if any(b < 0 for b in block_of):
            missing = block_of.count(-1)
            raise ValueError(
                f"{missing} residual task(s) not covered by any "
                "ResumeState block"
            )
        q = build_quotient(wf, block_of)
        procs_seen: dict[int, int] = {}
        for vid, members in q.members.items():
            b = block_of[next(iter(members))]
            pj = state.proc_of_block[b]
            if pj is not None and pj in procs_seen:
                raise ValueError(
                    f"processor {pj} assigned to blocks "
                    f"{procs_seen[pj]} and {b}"
                )
            if pj is not None:
                procs_seen[pj] = b
            q.proc[vid] = pj
            if b in state.pinned:
                ctx.pinned.add(vid)
        ctx.q = q
        ctx.reqs = _Requirements(wf, ctx.exact_limit, sweep_memo=ctx.memo)
        ctx.ev = IncrementalEvaluator(q, platform)
        # Re-certify kept assignments: platform events never shrink a
        # surviving processor's memory today, but hand-built states (or
        # future event kinds) may — fail structurally, not downstream.
        for vid in sorted(q.members):
            pj = q.proc[vid]
            if pj is None:
                continue
            r = ctx.reqs.of(q, vid)
            cap = platform.memory(pj)
            if r > cap * (1 + 1e-9):
                ctx.failure = StageFailure(
                    self.name,
                    f"inherited block {vid} (requirement {r:.4g}) no "
                    f"longer fits processor {pj} ({cap:.4g})",
                    r - cap,
                )
                return


class PackStage:
    """DagHetMem (§4.1): min-peak traversal packed memory-first."""

    name = "pack"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        res, fail = _pack_min_peak(ctx.wf, ctx.platform)
        if res is None:
            ctx.failure = StageFailure(self.name, fail["reason"],
                                       fail["gap"])
        else:
            ctx.result = res


def _materialize_result(ctx: StageContext, kp: int | None) -> None:
    """Lift a successful heuristic run's evaluator state into a
    :class:`MappingResult` (idempotent; ``pack`` sets ``ctx.result``
    itself).  A quotient with unassigned blocks — possible when a
    pipeline omits the merge stage, e.g. the no-replan baseline on a
    failure event — is a structured failure, never an invalid result."""
    if ctx.result is not None or ctx.failure is not None or ctx.ev is None:
        return
    unassigned = sum(1 for v in ctx.q.members if ctx.q.proc[v] is None)
    if unassigned:
        ctx.failure = StageFailure(
            "materialize",
            f"{unassigned} block(s) left unassigned by the pipeline",
            None,
        )
        return
    ms = ctx.ev.makespan()
    ctx.result = MappingResult(
        algo="DagHetPart-warm" if ctx.resume is not None else "DagHetPart",
        quotient=ctx.q,
        platform=ctx.platform,
        makespan=ms,
        runtime_s=0.0,
        k_used=ctx.q.n_vertices,
        # witness traversals double as feasibility certificates for
        # composed (bound-priced) blocks during validation
        extras={"k_prime": kp,
                "orders": ctx.reqs.witness_orders(ctx.q)},
    )


class SimulateStage:
    """Post-pipeline replay: attach a :class:`repro.sim.SimReport` to
    the mapping (``extras["sim"]``).  Off by default
    (``SchedulerConfig(simulate=True)`` enables it); runs once per
    sweep point, so enable it together with a narrow k' sweep or read
    ``ScheduleReport.sim`` for the winner only.  Options come from
    ``SchedulerConfig.sim_options`` (``comm``, ``jitter``, ...)."""

    name = "simulate"
    toggle = "simulate"

    def run(self, ctx: StageContext) -> None:
        _materialize_result(ctx, ctx.k_prime)
        if ctx.result is None:
            return
        from repro import sim  # deferred: core must not require sim

        ctx.result.extras["sim"] = sim.simulate(
            ctx.result, ctx.platform, **(ctx.sim_options or {}))


class ThroughputStage:
    """Post-pipeline steady-state throughput analysis
    (:mod:`repro.throughput`): replicate the mapped block groups onto
    idle processors and price the sustainable instance rate
    (``extras["throughput"]``, a
    :class:`~repro.throughput.ThroughputPlan`).

    Options come from ``SchedulerConfig.throughput_options``
    (``max_replicas``, ``include_comm``, ``latency_bound``).  A
    ``latency_bound`` the *unreplicated* plan already violates is a
    structured :class:`StageFailure` — the k' attempt is infeasible for
    sustained traffic even though a one-shot mapping exists, which is
    exactly how the sweep optimizes replication count and k' jointly.
    Each attempt's rate/replica-count/period land as single-observation
    histograms in the sweep point's ``metrics`` block (histogram deltas
    are always present, unlike unchanged gauges), so rate-maximizing
    selection (:func:`repro.throughput.plan_throughput`) can read them
    per k'.
    """

    name = "throughput"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        _materialize_result(ctx, ctx.k_prime)
        if ctx.result is None:
            return
        from repro import throughput as _tp  # deferred, like simulate

        opts = dict(ctx.throughput_options or {})
        plan = _tp.replicate_plan(ctx.result, ctx.platform, **opts)
        bound = opts.get("latency_bound")
        if bound is not None and plan.groups[0].latency > bound:
            ctx.failure = StageFailure(
                self.name,
                f"per-instance latency {plan.groups[0].latency:.6g} "
                f"exceeds bound {bound:.6g} at k'={ctx.k_prime}",
                None,
            )
            ctx.result = None
            return
        ctx.result.extras["throughput"] = plan
        METRICS.observe("throughput_rate", plan.rate)
        METRICS.observe("throughput_replicas", float(plan.n_replicas))
        METRICS.observe("throughput_period", plan.period)


class ReliabilityStage:
    """Reliability-weighted makespan pricing (:mod:`repro.objectives`):
    success probability of the mapped schedule from per-block exposure
    time × its processor's exponential failure rate
    (``extras["reliability"]``, a
    :class:`~repro.objectives.ReliabilityReport`).

    **Bit-inert** without a failure model: when
    ``platform.failure_rates`` is empty the stage returns without
    touching the result, so the makespan pipeline's output is
    unchanged.  Each attempt's weighted makespan / success probability
    land as single-observation histograms in the sweep point's
    ``metrics`` block (same contract as the throughput stage), so
    :func:`repro.objectives.plan_reliability` can pick the
    weighted-makespan winner per k'.
    """

    name = "reliability"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        _materialize_result(ctx, ctx.k_prime)
        if ctx.result is None:
            return
        if not ctx.platform.failure_rates:
            return  # no model -> bit-inert
        from repro import objectives as _obj  # deferred, like simulate

        rel = _obj.schedule_reliability(ctx.result, ctx.platform)
        ctx.result.extras["reliability"] = rel
        METRICS.counter("objective_reliability_evals")
        METRICS.observe("objective_rel_weighted_ms", rel.weighted_makespan)
        METRICS.observe("objective_success_prob", rel.success_prob,
                        boundaries=RATIO_BOUNDARIES)


class EnergyStage:
    """Energy minimization under a reliability floor
    (:mod:`repro.objectives`): per-block DVFS speed choice minimizing
    static+dynamic energy while keeping the schedule's success
    probability above ``objective_options["reliability_floor"]``
    (``extras["energy"]``, an :class:`~repro.objectives.EnergyReport`).

    Options come from ``SchedulerConfig.objective_options``
    (``reliability_floor``, ``speed_levels``).  A floor the all-nominal
    plan cannot reach is a structured :class:`StageFailure` with stage
    name ``"objective"`` — the k' attempt is infeasible under the
    reliability constraint even though a mapping exists.  **Bit-inert**
    without a power model (``platform.power`` empty).  The attempt's
    total energy lands as a single-observation histogram so
    :func:`repro.objectives.plan_energy` can pick the energy-minimizing
    attempt per k'.
    """

    name = "energy"
    toggle = None

    def run(self, ctx: StageContext) -> None:
        _materialize_result(ctx, ctx.k_prime)
        if ctx.result is None:
            return
        if not ctx.platform.power:
            return  # no model -> bit-inert
        from repro import objectives as _obj  # deferred, like simulate

        opts = dict(ctx.objective_options or {})
        floor = opts.get("reliability_floor")
        levels = opts.get("speed_levels", (1.0,))
        plan = _obj.energy_plan(ctx.result, ctx.platform,
                                reliability_floor=floor,
                                speed_levels=levels)
        if plan is None:
            METRICS.counter("objective_energy_infeasible")
            ctx.failure = StageFailure(
                "objective",
                f"reliability floor {floor:.6g} unreachable at "
                f"k'={ctx.k_prime}: even all-nominal speeds miss it",
                None,
            )
            ctx.result = None
            return
        ctx.result.extras["energy"] = plan
        METRICS.counter("objective_energy_evals")
        METRICS.observe("objective_energy_total", plan.total)
        METRICS.observe("objective_success_prob", plan.reliability,
                        boundaries=RATIO_BOUNDARIES)


_STAGES: dict[str, Stage] = {}

#: algorithm name -> pipeline (tuple of registered stage names)
PIPELINES: dict[str, tuple[str, ...]] = {}


def register_stage(stage: Stage, *, replace_existing: bool = False) -> None:
    """Register ``stage`` under ``stage.name`` for use in pipelines."""
    if stage.name in _STAGES and not replace_existing:
        raise ValueError(f"stage {stage.name!r} already registered")
    _STAGES[stage.name] = stage


def get_stage(name: str) -> Stage:
    try:
        return _STAGES[name]
    except KeyError:
        raise KeyError(
            f"unknown stage {name!r}; registered: {available_stages()}"
        ) from None


def available_stages() -> list[str]:
    return sorted(_STAGES)


def register_pipeline(algorithm: str, stage_names: Sequence[str]) -> None:
    """Register (or override) an algorithm as a stage pipeline."""
    for n in stage_names:
        get_stage(n)  # fail fast on unknown stages
    PIPELINES[algorithm] = tuple(stage_names)


for _stage in (PartitionStage(), AssignStage(), MergeStage(),
               SwapStage(), IdleMoveStage(), PackStage(),
               SimulateStage(), WarmStartStage(), SeedPartitionStage(),
               ThroughputStage(), ReliabilityStage(), EnergyStage()):
    register_stage(_stage)
register_pipeline("dag_het_part",
                  ("partition", "assign", "merge", "swap", "idle_moves",
                   "simulate"))
register_pipeline("dag_het_mem", ("pack", "simulate"))
# Scheduler.resume: inherit the partition, repair, refine.
register_pipeline("warm_start",
                  ("warm_start", "merge", "swap", "idle_moves",
                   "simulate"))
# Scheduler.seeded: adopt a cached partition, then Steps 2-4 as usual.
register_pipeline("seeded",
                  ("seed_partition", "assign", "merge", "swap",
                   "idle_moves", "simulate"))
# Sustained-traffic planning: the four-step heuristic plus steady-state
# replication/rate analysis per k' (repro.throughput reads the per-point
# rate metrics to pick the rate-maximizing attempt).
register_pipeline("throughput",
                  ("partition", "assign", "merge", "swap", "idle_moves",
                   "simulate", "throughput"))
# Plan-cache hits of the sustained path: seeded Steps 2-4, same analysis.
register_pipeline("throughput_seeded",
                  ("seed_partition", "assign", "merge", "swap",
                   "idle_moves", "simulate", "throughput"))
# Richer objectives (repro.objectives): the four-step heuristic plus
# reliability-weighted makespan pricing / DVFS energy minimization under
# a reliability floor per k' (both bit-inert on model-free platforms).
register_pipeline("reliability",
                  ("partition", "assign", "merge", "swap", "idle_moves",
                   "simulate", "reliability"))
register_pipeline("energy",
                  ("partition", "assign", "merge", "swap", "idle_moves",
                   "simulate", "energy"))


# ---------------------------------------------------------------------- #
# configuration
# ---------------------------------------------------------------------- #
@dataclass
class SchedulerConfig:
    """Everything a :class:`Scheduler` run is driven by.

    ``kprime`` is a sweep policy name (``"auto"`` / ``"full"``, see
    :func:`kprime_sweep_values`) or an explicit list of k' values.
    ``swap`` / ``idle_moves`` toggle the Step-4 refinement stages.
    ``time_budget_s`` soft-bounds the sweep: at least one k' always
    completes, later ones are skipped (serial) or cancelled (parallel)
    once the budget is exceeded, and the report is marked
    ``truncated``.  ``workers > 1`` runs independent k' values on a
    process pool with per-worker Step-2 memos — best makespans are
    bit-identical to serial.  ``on_sweep_result`` receives every
    :class:`SweepPoint` in sweep order, in the parent process, in both
    execution modes — ``verbose`` merely installs a default printer on
    the same channel.  ``stages`` overrides the algorithm's registered
    pipeline with an explicit stage-name sequence.  ``simulate``
    enables the post-pipeline discrete-event replay stage
    (:mod:`repro.sim`), configured by the ``sim_options`` keyword dict
    (``comm``, ``jitter``, ``replicas``, ``memory``, ...); it runs once
    per sweep point and attaches a :class:`repro.sim.SimReport` to
    each mapping's ``extras["sim"]`` — read ``ScheduleReport.sim`` for
    the winner's.  ``obs`` is the run's
    :class:`~repro.obs.ObsConfig`: ``enabled`` turns on span tracing
    (run → sweep point → stage, incl. pool workers), ``trace_path`` /
    ``sink`` export a Chrome trace / JSONL span log at the end of the
    run — all provably inert (bit-identical makespans on/off).
    """

    algorithm: str = "dag_het_part"
    kprime: str | Sequence[int] = "auto"
    exact_limit: int = 0
    swap: bool = True
    idle_moves: bool = True
    time_budget_s: float | None = None
    workers: int = 1
    verbose: bool = False
    on_sweep_result: Callable[[SweepPoint], None] | None = None
    stages: Sequence[str] | None = None
    simulate: bool = False
    sim_options: dict | None = None
    #: keyword dict for the ``throughput`` stage (``max_replicas``,
    #: ``include_comm``, ``latency_bound``); only algorithms whose
    #: pipeline includes the stage (``throughput`` /
    #: ``throughput_seeded``) read it
    throughput_options: dict | None = None
    #: keyword dict for the objective stages (``reliability_floor``,
    #: ``speed_levels``); only algorithms whose pipeline includes the
    #: ``reliability`` / ``energy`` stage read it
    objective_options: dict | None = None
    obs: ObsConfig | None = None
    #: opt into multilevel Step-1 partitioning (coarsen → partition →
    #: uncoarsen).  Changes cuts — hence makespans — by design, so it is
    #: never on implicitly; the bit-identical scalar/flat dispatch knob
    #: lives in :func:`repro.core.partitioner.set_step1_impl` instead.
    step1_multilevel: bool = False


@dataclass(frozen=True)
class _RunSpec:
    """The picklable subset of the config a worker needs.

    ``step2_impl`` / ``step1_impl`` snapshot the process-global
    dispatch modes (:func:`repro.core.memdag.set_step2_impl`,
    :func:`repro.core.partitioner.set_step1_impl`) at spec-creation
    time so spawn-based worker pools (no fork: the globals would reset
    to "auto" on re-import) honour a forced mode too;
    ``step1_multilevel`` carries the config's multilevel Step-1 opt-in
    into every pipeline run the same way.  ``obs_enabled`` /
    ``probe_spans`` tell spawn-pool workers to trace their sweep-point
    runs (fork workers would inherit the active tracer, but a fresh
    per-task tracer keeps the shipped span batches self-contained in
    both start methods).
    """

    stage_names: tuple[str, ...]
    exact_limit: int
    sim_options: dict | None = None
    throughput_options: dict | None = None
    objective_options: dict | None = None
    step2_impl: str = "auto"
    step1_impl: str = "auto"
    step1_multilevel: bool = False
    obs_enabled: bool = False
    probe_spans: bool = False


# ---------------------------------------------------------------------- #
# observability plumbing
# ---------------------------------------------------------------------- #
def _merge_metric_delta(acc: dict, delta: dict) -> None:
    """Fold one sweep point's sparse metrics delta (gauges + histogram
    dicts, counters excluded — they aggregate as ``cache_stats``) into
    a plain-dict accumulator of the same shape (the report's
    ``metrics`` block)."""
    for k, v in delta.get("gauges", {}).items():
        acc.setdefault("gauges", {})[k] = v
    for k, d in delta.get("histograms", {}).items():
        hists = acc.setdefault("histograms", {})
        if k not in hists:
            hists[k] = Histogram.from_dict(d).to_dict()  # detached copy
        else:
            h = Histogram.from_dict(hists[k])
            h.merge_dict(d)
            hists[k] = h.to_dict()


@contextmanager
def _obs_session(obs: ObsConfig | None):
    """One run's tracing session: yields ``(tracer, start_index)``.

    With tracing off — ``obs`` is ``None`` or disabled — yields
    ``(None, 0)`` and costs two attribute reads.  Otherwise an
    *enclosing* activation (the service loop traces across scheduler
    calls) is honoured and its tracer reused; only when this run owns
    the tracer are the exporters driven on exit: the Chrome trace to
    ``obs.trace_path``, span records to the ``obs.sink`` JSONL log.
    """
    if obs is None or not obs.enabled:
        yield None, 0
        return
    outer = _trc.current_tracer()
    tracer = outer if outer is not None else obs.make_tracer()
    own = outer is None
    with _trc.activate(tracer if own else None):
        start = len(tracer.spans)
        try:
            yield tracer, start
        finally:
            if own and (obs.trace_path or obs.sink):
                spans = tracer.spans[start:]
                if obs.trace_path:
                    write_chrome_trace(obs.trace_path,
                                       span_events(spans))
                if obs.sink:
                    with JsonlSink(obs.sink) as sink:
                        for s in spans:
                            sink.emit({"event": "span", **s.to_dict()})


# ---------------------------------------------------------------------- #
# pipeline execution (shared by the serial path and pool workers)
# ---------------------------------------------------------------------- #
def _execute_pipeline(
    wf: Workflow,
    platform: Platform,
    spec: _RunSpec,
    kp: int | None,
    memo: dict,
    resume: "ResumeState | None" = None,
    seed_blocks: list[list[int]] | None = None,
) -> tuple[MappingResult | None, SweepPoint]:
    t_run = time.perf_counter()
    snap = METRICS.snapshot()
    ctx = StageContext(wf=wf, platform=platform, k_prime=kp,
                       exact_limit=spec.exact_limit, memo=memo,
                       sim_options=spec.sim_options,
                       throughput_options=spec.throughput_options,
                       objective_options=spec.objective_options,
                       resume=resume,
                       step1_multilevel=spec.step1_multilevel,
                       seed_blocks=seed_blocks)
    stage_times: dict[str, float] = {}
    with trace_span("sweep_point", k_prime=kp, n_tasks=wf.n) as pt_span:
        for name in spec.stage_names:
            stage = get_stage(name)
            t0 = time.perf_counter()
            with trace_span(f"stage.{name}", k_prime=kp):
                stage.run(ctx)
            stage_times[name] = (stage_times.get(name, 0.0)
                                 + time.perf_counter() - t0)
            if ctx.failure is not None:
                break
        # heuristic pipelines leave the mapping in the evaluator state
        # (a trailing SimulateStage already materialized it when
        # enabled)
        _materialize_result(ctx, kp)
        dt = time.perf_counter() - t_run
        METRICS.observe("sched_sweep_point_s", dt)
        mdelta = METRICS.delta(snap)
        cache_stats = mdelta.pop("counters", {})
        # the sweep-point span carries its counter deltas + verdict
        pt_span.attrs.update(cache_stats)
        pt_span.attrs["feasible"] = ctx.result is not None
        if ctx.result is not None:
            pt_span.attrs["makespan"] = float(ctx.result.makespan)
    if ctx.result is not None:
        ctx.result.runtime_s = dt
        point = SweepPoint(k_prime=kp, makespan=float(ctx.result.makespan),
                           feasible=True, time_s=dt,
                           stage_times=stage_times,
                           cache_stats=cache_stats, metrics=mdelta)
    else:
        point = SweepPoint(k_prime=kp, makespan=None, feasible=False,
                           time_s=dt, stage_times=stage_times,
                           failed_stage=ctx.failure.stage,
                           fail_reason=ctx.failure.reason,
                           memory_gap=ctx.failure.gap,
                           cache_stats=cache_stats, metrics=mdelta)
    return ctx.result, point


# Pool workers hold the (wf, platform, spec) triple plus a *per-worker*
# Step-2 memo that persists across the k' tasks they serve — the
# parallel analogue of the serial path's single sweep-shared memo
# (ROADMAP perf follow-on #1).  Memo contents only cache deterministic
# pure functions, so sharing topology never changes results.
#
# On fork-capable platforms the triple is published to workers through
# inherited memory (set in the parent immediately before the fork):
# pickling a 10⁴-task adjacency into every worker via ``initargs``
# costs more than several whole sweep points.  Forking with JAX loaded
# in the parent draws a RuntimeWarning; it is safe *here* because
# workers execute only this pure-Python scheduling code and never call
# into JAX (or any other threaded runtime) before exiting.
_WORKER_STATE: dict = {}


def _pool_init(wf: Workflow, platform: Platform, spec: _RunSpec) -> None:
    from .memdag import set_step2_impl
    from .partitioner import set_step1_impl

    _WORKER_STATE["wf"] = wf
    _WORKER_STATE["platform"] = platform
    _WORKER_STATE["spec"] = spec
    _WORKER_STATE["memo"] = {}
    set_step2_impl(spec.step2_impl)  # no-op on fork, needed on spawn
    set_step1_impl(spec.step1_impl)


def _make_pool(wf: Workflow, platform: Platform, spec: _RunSpec,
               max_workers: int) -> ProcessPoolExecutor:
    import multiprocessing as mp

    try:
        ctx = mp.get_context("fork")
    except ValueError:  # platform without fork (e.g. Windows)
        ctx = None
    if ctx is None:
        return ProcessPoolExecutor(
            max_workers=max_workers,
            initializer=_pool_init, initargs=(wf, platform, spec))
    # fork path: children inherit _WORKER_STATE as set right now; the
    # memo dict is fresh, and each child's copy is independent (CoW).
    # (Pre-warming the memo in the parent was measured and rejected:
    # CPython refcount writes force copy-on-write of the inherited
    # pages, costing more than the workers' cold recomputation.)
    _pool_init(wf, platform, spec)
    return ProcessPoolExecutor(max_workers=max_workers, mp_context=ctx)


def _pool_run(kp: int | None) -> tuple[MappingResult | None, SweepPoint]:
    spec = _WORKER_STATE["spec"]
    # A fresh per-task tracer (never the fork-inherited parent tracer):
    # the shipped span batch is exactly this sweep point's, and its tid
    # carries the worker pid as the track name.
    tracer = (_trc.Tracer(probe_spans=spec.probe_spans)
              if spec.obs_enabled else None)
    with _trc.activate_exclusive(tracer):
        res, point = _execute_pipeline(
            _WORKER_STATE["wf"], _WORKER_STATE["platform"],
            spec, kp, _WORKER_STATE["memo"])
    if tracer is not None:
        point.spans = tracer.spans
    if res is not None:
        # Detach the workflow before the result crosses the process
        # boundary: the parent re-attaches its own (identical) copy.
        # Pickling the full adjacency once per sweep point would
        # otherwise dominate the parallel path's wall clock.
        res.quotient.wf = None
    return res, point


# ---------------------------------------------------------------------- #
# the facade
# ---------------------------------------------------------------------- #
def _default_printer(point: SweepPoint) -> None:
    # ``verbose`` narration goes through logging (silent until the
    # application installs a handler; CLI entry points call
    # ``repro.obs.setup_logging()`` for classic print-style output).
    label = f"k'={point.k_prime}" if point.k_prime is not None else "run"
    if point.feasible:
        _log.info("  %s: makespan=%.2f", label, point.makespan)
    else:
        _log.info("  %s: infeasible (%s: %s)", label,
                  point.failed_stage, point.fail_reason)


class Scheduler:
    """Facade over the stage pipelines and the k' sweep.

    >>> report = Scheduler(SchedulerConfig(kprime=[1, 4, 9])).schedule(
    ...     wf, platform)                                # doctest: +SKIP
    >>> report.feasible, report.makespan                 # doctest: +SKIP

    Construction accepts a full :class:`SchedulerConfig`, keyword
    overrides on top of it, or keywords alone.
    """

    def __init__(self, config: SchedulerConfig | None = None,
                 **overrides) -> None:
        cfg = config if config is not None else SchedulerConfig()
        if overrides:
            cfg = replace(cfg, **overrides)
        self.config = cfg

    # -------------------------------------------------------------- #
    def _filter_toggles(self, names: Sequence[str]) -> tuple[str, ...]:
        cfg = self.config
        out = []
        for n in names:
            stage = get_stage(n)
            toggle = getattr(stage, "toggle", None)
            if toggle is not None and not getattr(cfg, toggle):
                continue
            out.append(n)
        return tuple(out)

    def stage_names(self) -> tuple[str, ...]:
        """The resolved, toggle-filtered pipeline for this config."""
        cfg = self.config
        if cfg.stages is not None:
            names: Sequence[str] = tuple(cfg.stages)
        else:
            try:
                names = PIPELINES[cfg.algorithm]
            except KeyError:
                raise ValueError(
                    f"unknown algorithm {cfg.algorithm!r}; registered "
                    f"pipelines: {sorted(PIPELINES)}"
                ) from None
        return self._filter_toggles(names)

    def sweep_values(self, wf: Workflow,
                     platform: Platform) -> list[int | None]:
        """The k' values this run will attempt (``[None]`` for
        pipelines without a partition stage — nothing to sweep)."""
        if "partition" not in self.stage_names():
            return [None]
        kprime = self.config.kprime
        if isinstance(kprime, str):
            return list(kprime_sweep_values(wf, platform, kprime))
        vals = [int(x) for x in kprime]
        if not vals:
            raise ValueError("empty k' sweep")
        return vals

    # -------------------------------------------------------------- #
    def schedule(self, wf: Workflow, platform: Platform) -> ScheduleReport:
        """Run the configured pipeline; always a :class:`ScheduleReport`."""
        cfg = self.config
        return self._with_obs(
            {"algorithm": cfg.algorithm, "n_tasks": wf.n,
             "workers": cfg.workers},
            lambda: self._run_sweep(wf, platform))

    def _with_obs(self, run_attrs: dict,
                  fn: Callable[[], ScheduleReport]) -> ScheduleReport:
        """Wrap one run in the obs session + root ``run`` span and
        attach the run's span slice to the report."""
        with _obs_session(self.config.obs) as (tracer, start):
            with trace_span("run", **run_attrs):
                report = fn()
            if tracer is not None:
                report.spans = list(tracer.spans[start:])
        return report

    def _run_sweep(self, wf: Workflow,
                   platform: Platform) -> ScheduleReport:
        cfg = self.config
        t0 = time.perf_counter()
        from .memdag import step2_impl
        from .partitioner import step1_impl

        tracer = _trc.current_tracer()
        spec = _RunSpec(self.stage_names(), cfg.exact_limit,
                        cfg.sim_options, cfg.throughput_options,
                        cfg.objective_options,
                        step2_impl(), step1_impl(),
                        cfg.step1_multilevel,
                        obs_enabled=tracer is not None,
                        probe_spans=(tracer.probe_spans
                                     if tracer is not None else False))
        sweep = self.sweep_values(wf, platform)
        callbacks: list[Callable[[SweepPoint], None]] = []
        if cfg.verbose:
            callbacks.append(_default_printer)
        if cfg.on_sweep_result is not None:
            callbacks.append(cfg.on_sweep_result)

        # Best-result reduction is folded into collection: points are
        # consumed in sweep order in both modes, and strict < keeps
        # the earliest-k' winner, so at most two mappings (incumbent +
        # candidate) are ever alive — the k'-length sweep would
        # otherwise hold one full mapping per point at 30k tasks.
        best: MappingResult | None = None
        points: list[SweepPoint] = []
        truncated = False

        def reduce_best(res: MappingResult | None) -> None:
            nonlocal best
            if res is not None and (best is None
                                    or res.makespan < best.makespan):
                best = res

        def over_budget() -> bool:
            return (cfg.time_budget_s is not None
                    and time.perf_counter() - t0 > cfg.time_budget_s)

        if cfg.workers > 1 and len(sweep) > 1:
            pool = _make_pool(wf, platform, spec,
                              min(cfg.workers, len(sweep)))
            try:
                futs = [pool.submit(_pool_run, kp) for kp in sweep]
                # iterate in sweep order: callbacks and the best-result
                # reduction stay deterministic regardless of completion
                # order
                exhausted = False
                for fut in futs:
                    if points and not exhausted and over_budget():
                        exhausted = True
                    if exhausted and fut.cancel():
                        # only not-yet-started work is dropped; results
                        # already computed (or in flight) are collected
                        truncated = True
                        continue
                    res, point = fut.result()
                    if res is not None:
                        res.quotient.wf = wf  # re-attach (see _pool_run)
                    # Workers recorded into *their* registries: fold the
                    # shipped deltas into the parent's.  (Only here —
                    # the serial path records in-process directly.)
                    METRICS.merge({"counters": point.cache_stats,
                                   **point.metrics})
                    if tracer is not None and point.spans:
                        tracer.extend(point.spans)
                        point.spans = []  # spliced; avoid double export
                    reduce_best(res)
                    points.append(point)
                    for cb in callbacks:
                        cb(point)
            finally:
                pool.shutdown(wait=False, cancel_futures=True)
                _WORKER_STATE.clear()  # parent copy: drop wf references
        else:
            memo: dict = {}  # content-keyed reuse across the serial sweep
            for kp in sweep:
                if points and over_budget():
                    truncated = True
                    break
                res, point = _execute_pipeline(wf, platform, spec, kp, memo)
                reduce_best(res)
                points.append(point)
                for cb in callbacks:
                    cb(point)

        total = time.perf_counter() - t0
        stage_times: dict[str, float] = {}
        cache_stats: dict[str, int] = {}
        run_metrics: dict = {}
        for p in points:
            for name, dt in p.stage_times.items():
                stage_times[name] = stage_times.get(name, 0.0) + dt
            for name, c in p.cache_stats.items():
                cache_stats[name] = cache_stats.get(name, 0) + c
            _merge_metric_delta(run_metrics, p.metrics)

        if best is not None:
            best.runtime_s = total  # whole-sweep time, as dag_het_part did
            summary = MappingSummary.from_result(best)
            infeas = None
        else:
            summary = None
            infeas = self._diagnose(spec.stage_names, points)
        return ScheduleReport(
            algorithm=cfg.algorithm,
            summary=summary,
            infeasibility=infeas,
            sweep=points,
            stage_times=stage_times,
            total_time_s=total,
            workers=cfg.workers,
            truncated=truncated,
            cache_stats=cache_stats,
            metrics=run_metrics,
            best=best,
        )

    __call__ = schedule

    # -------------------------------------------------------------- #
    def resume(self, state: ResumeState) -> ScheduleReport:
        """Warm-start replan from a partially executed plan.

        Runs the ``warm_start`` pipeline (inherit the partition from
        ``state``, merge orphaned blocks, pin-aware Step-4 refinement;
        ``config.stages`` overrides the stage list, ``swap`` /
        ``idle_moves`` / ``simulate`` toggles apply) on the residual
        workflow.  No k' sweep: the partition already exists — that is
        what warm-starting buys over :meth:`schedule`.  Always returns
        a :class:`ScheduleReport` (``algorithm="warm_start"``); pinned
        blocks keep their processor in any feasible result.
        """
        return self._with_obs(
            {"algorithm": "warm_start", "n_tasks": state.wf.n},
            lambda: self._resume_impl(state))

    def _resume_impl(self, state: ResumeState) -> ScheduleReport:
        cfg = self.config
        t0 = time.perf_counter()
        names = self._filter_toggles(
            cfg.stages if cfg.stages is not None
            else PIPELINES["warm_start"])
        from .memdag import step2_impl
        from .partitioner import step1_impl

        spec = _RunSpec(names, cfg.exact_limit, cfg.sim_options,
                        cfg.throughput_options, cfg.objective_options,
                        step2_impl(), step1_impl(), cfg.step1_multilevel)
        res, point = _execute_pipeline(state.wf, state.platform, spec,
                                       None, {}, resume=state)
        for cb in ([_default_printer] if cfg.verbose else []) + (
                [cfg.on_sweep_result] if cfg.on_sweep_result else []):
            cb(point)
        total = time.perf_counter() - t0
        if res is not None:
            res.runtime_s = total
            summary = MappingSummary.from_result(res)
            infeas = None
        else:
            summary = None
            infeas = self._diagnose(names, [point], algorithm="warm_start")
        return ScheduleReport(
            algorithm="warm_start",
            summary=summary,
            infeasibility=infeas,
            sweep=[point],
            stage_times=dict(point.stage_times),
            total_time_s=total,
            workers=1,
            cache_stats=dict(point.cache_stats),
            metrics=dict(point.metrics),
            best=res,
        )

    # -------------------------------------------------------------- #
    def seeded(self, wf: Workflow, platform: Platform,
               block_of_task: Sequence[int],
               k_prime: int | None = None) -> ScheduleReport:
        """Plan-cache seeding hook: schedule ``wf`` starting from a
        previously computed partition instead of the k' sweep.

        ``block_of_task`` is a per-task block id (the shape stored by
        :class:`MappingSummary` — ids need not be contiguous); blocks
        are regrouped in ascending-id order and fed through the
        ``seeded`` pipeline (``seed_partition → assign → merge → swap →
        idle_moves → simulate``), so Step 2 re-prices the seed against
        the *actual* platform and Steps 3–4 repair and refine it.  No
        k' sweep — that is what a cache hit buys, exactly as
        :meth:`resume` skips it after a failure.  ``k_prime`` is
        recorded on the single :class:`SweepPoint` for diagnostics
        (conventionally the cached winner's value).  Always returns a
        :class:`ScheduleReport` (``algorithm="seeded"``); a seed that
        no longer fits is a structured infeasibility, not an error.
        """
        return self._with_obs(
            {"algorithm": "seeded", "n_tasks": wf.n},
            lambda: self._seeded_impl(wf, platform, block_of_task,
                                      k_prime))

    def _seeded_impl(self, wf: Workflow, platform: Platform,
                     block_of_task: Sequence[int],
                     k_prime: int | None) -> ScheduleReport:
        if len(block_of_task) != wf.n:
            raise ValueError(
                f"block_of_task has {len(block_of_task)} entries for "
                f"{wf.n} tasks"
            )
        groups: dict[int, list[int]] = {}
        for u, b in enumerate(block_of_task):
            groups.setdefault(int(b), []).append(u)
        seed = [groups[b] for b in sorted(groups)]
        cfg = self.config
        t0 = time.perf_counter()
        names = self._filter_toggles(
            cfg.stages if cfg.stages is not None
            else PIPELINES["seeded"])
        from .memdag import step2_impl
        from .partitioner import step1_impl

        spec = _RunSpec(names, cfg.exact_limit, cfg.sim_options,
                        cfg.throughput_options, cfg.objective_options,
                        step2_impl(), step1_impl(), cfg.step1_multilevel)
        res, point = _execute_pipeline(wf, platform, spec,
                                       k_prime, {}, seed_blocks=seed)
        for cb in ([_default_printer] if cfg.verbose else []) + (
                [cfg.on_sweep_result] if cfg.on_sweep_result else []):
            cb(point)
        total = time.perf_counter() - t0
        if res is not None:
            res.runtime_s = total
            summary = MappingSummary.from_result(res)
            infeas = None
        else:
            summary = None
            infeas = self._diagnose(names, [point], algorithm="seeded")
        return ScheduleReport(
            algorithm="seeded",
            summary=summary,
            infeasibility=infeas,
            sweep=[point],
            stage_times=dict(point.stage_times),
            total_time_s=total,
            workers=1,
            cache_stats=dict(point.cache_stats),
            metrics=dict(point.metrics),
            best=res,
        )

    # -------------------------------------------------------------- #
    def _diagnose(self, stage_names: tuple[str, ...],
                  points: list[SweepPoint],
                  algorithm: str | None = None) -> Infeasibility:
        order = {name: i for i, name in enumerate(stage_names)}
        furthest = max(points,
                       key=lambda p: order.get(p.failed_stage, -1))
        gaps = [p.memory_gap for p in points
                if p.memory_gap is not None and p.memory_gap > 0]
        kps = [p.k_prime for p in points if p.k_prime is not None]
        return Infeasibility(
            algorithm=algorithm or self.config.algorithm,
            stage=furthest.failed_stage or "?",
            reason=furthest.fail_reason or "no sweep value succeeded",
            tightest_gap=min(gaps) if gaps else None,
            smallest_kprime=min(kps) if kps else None,
            attempts=len(points),
        )


def schedule(wf: Workflow, platform: Platform,
             config: SchedulerConfig | None = None,
             **overrides) -> ScheduleReport:
    """One-call convenience: ``Scheduler(config, **kw).schedule(...)``.

    Keyword overrides are :class:`SchedulerConfig` fields — commonly
    ``algorithm=``, ``kprime=``, ``workers=``, ``simulate=`` and
    ``sim_options=`` (the keyword dict handed to the simulate stage:
    ``comm=``, ``jitter=``, ``replicas=``, ``memory=``, ...)::

        schedule(wf, platform, simulate=True,
                 sim_options={"comm": "fair-share"}).sim
    """
    return Scheduler(config, **overrides).schedule(wf, platform)
