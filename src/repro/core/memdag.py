"""Peak-memory traversal of (sub-)workflows — the paper's MemDag role.

The paper computes a block's memory requirement ``r_{V_i}`` with MemDag
(Kayaaslan et al. 2018): SP-ize the block, then find the traversal with
minimum peak memory.  Exact minimum-peak traversal of a general DAG is
NP-hard, so this module provides (DESIGN.md §3.3):

* :func:`simulate_peak` — peak memory of a *given* sequential order,
* :func:`exact_min_peak` — exact minimum over all topological orders via
  DP on downward-closed subsets (used for blocks ≤ ``EXACT_LIMIT`` tasks
  and as the oracle in property tests),
* :func:`greedy_min_peak` — best-first heuristic for larger blocks,
* :func:`block_requirement` — public entry point used by the heuristics.

Memory model (sequential execution of one block on one processor):

* an internal file ``c[u,v]`` occupies memory from the start of ``u``
  until the completion of ``v``;
* an *external input* (edge from another block) is materialized when its
  consumer starts and freed when it completes (it streams in on demand);
* an *external output* occupies memory while its producer runs and is
  freed right after (it is sent to the consuming block's processor);
* while task ``u`` runs, its own footprint ``m_u`` is added.

Hence, with ``live(S)`` = Σ internal ``c[a,b]``, ``a ∈ S``, ``b ∉ S``::

    mem_during(u, S) = live(S) + ext_in(u) + m_u + out_total(u)

which is ``live(S)`` plus a per-task constant.  (``out_total`` counts
internal and external outputs; internal inputs are already in ``live``.)
"""
from __future__ import annotations

import heapq
from typing import Sequence

from .dag import Workflow

__all__ = [
    "simulate_peak",
    "simulate_peak_members",
    "occupancy_steps",
    "exact_min_peak",
    "greedy_min_peak",
    "block_requirement",
    "block_requirement_witness",
    "EXACT_LIMIT",
]

EXACT_LIMIT = 14


def _constants(
    sub: Workflow,
    ext_in: dict[int, float],
    ext_out: dict[int, float],
) -> tuple[list[float], list[float]]:
    """Per-task ``(during_const, live_delta)``.

    ``during_const[u]``: what task ``u`` adds on top of ``live(S)`` while
    it runs.  ``live_delta[u]``: change of the internal live set after
    ``u`` completes (internal outputs appear, internal inputs freed).
    """
    during = [0.0] * sub.n
    delta = [0.0] * sub.n
    for u in range(sub.n):
        int_in = sub.in_cost(u)
        int_out = sub.out_cost(u)
        during[u] = (
            ext_in.get(u, 0.0) + sub.mem[u] + int_out + ext_out.get(u, 0.0)
        )
        delta[u] = int_out - int_in
    return during, delta


def simulate_peak(
    sub: Workflow,
    order: Sequence[int],
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
) -> float:
    """Peak memory of executing ``sub`` sequentially in ``order``."""
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    during, delta = _constants(sub, ext_in, ext_out)
    live = 0.0
    peak = 0.0
    done = [False] * sub.n
    for u in order:
        if any(not done[p] for p in sub.pred[u]):
            raise ValueError("order violates precedence constraints")
        peak = max(peak, live + during[u])
        live += delta[u]
        done[u] = True
    if not all(done):
        raise ValueError("order does not cover the block")
    return peak


def occupancy_steps(wf: Workflow, members, order: Sequence[int]):
    """Yield ``(u, during, live_after)`` along a block traversal.

    The single source of truth for the transient-occupancy
    accumulation over the original workflow (no subgraph/boundary
    materialization), with edges leaving or entering ``members``
    treated as external per the module memory model: ``during`` is the
    occupancy while ``u`` runs, ``live_after`` the internal live set
    once it completes.  Shared by the witness evaluator below and the
    simulator's time-resolved memory tracker
    (:mod:`repro.sim.memory`), which must price states bit-identically
    to :func:`block_requirement`.  ``order`` must cover ``members``
    exactly and respect precedence *within* the block (not checked —
    this is the hot path; :func:`simulate_peak` is the checked
    variant).  Excludes the persistent base (callers add Σ persistent).
    """
    members = members if isinstance(members, (set, frozenset)) \
        else set(members)
    live = 0.0
    for u in order:
        int_in = 0.0
        ext_in = 0.0
        for p, c in wf.pred[u].items():
            if p in members:
                int_in += c
            else:
                ext_in += c
        int_out = 0.0
        out_total = 0.0
        for v, c in wf.succ[u].items():
            out_total += c
            if v in members:
                int_out += c
        during = live + ext_in + wf.mem[u] + out_total
        live += int_out - int_in
        yield u, during, live


def simulate_peak_members(
    wf: Workflow,
    members,
    order: Sequence[int],
) -> float:
    """Transient peak of executing block ``members`` of ``wf`` in
    ``order`` — ``max`` over the :func:`occupancy_steps` states (see
    there for the memory model and the unchecked-precedence caveat)."""
    peak = 0.0
    for _, during, _ in occupancy_steps(wf, members, order):
        if during > peak:
            peak = during
    return peak


def exact_min_peak(
    sub: Workflow,
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
) -> float:
    """Exact minimum peak memory over all topological orders (DP).

    State: downward-closed subset ``S`` of executed tasks (bitmask).
    ``live(S)`` only depends on ``S``, so
    ``f(S) = min_{u ready into S} max(f(S \\ u), live(S \\ u) + during(u))``.
    Exponential — gate on ``sub.n <= ~20`` at call sites.
    """
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    n = sub.n
    if n == 0:
        return 0.0
    during, delta = _constants(sub, ext_in, ext_out)
    pred_mask = [0] * n
    for v in range(n):
        for p in sub.pred[v]:
            pred_mask[v] |= 1 << p
    full = (1 << n) - 1
    # frontier DP over popcount layers; store live alongside to avoid
    # recomputation (live is additive in deltas of members).
    f: dict[int, float] = {0: 0.0}
    live: dict[int, float] = {0: 0.0}
    for _ in range(n):
        nf: dict[int, float] = {}
        nlive: dict[int, float] = {}
        for S, peak in f.items():
            lS = live[S]
            for u in range(n):
                bit = 1 << u
                if S & bit or (pred_mask[u] & S) != pred_mask[u]:
                    continue
                S2 = S | bit
                cand = max(peak, lS + during[u])
                old = nf.get(S2)
                if old is None or cand < old:
                    nf[S2] = cand
                    nlive[S2] = lS + delta[u]
        f, live = nf, nlive
    return f[full]


def greedy_min_peak(
    sub: Workflow,
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
    return_order: bool = False,
):
    """Best-first heuristic traversal minimizing peak memory.

    Two ready-heaps: tasks that *shrink* the live set (scheduled first,
    by smallest transient footprint) and tasks that grow it.  Because
    ``mem_during`` is ``live + const(u)``, ordering ready tasks by
    ``const(u)`` is time-invariant, giving O(E log V).

    A final *peak-shaving* pass re-simulates with the classic
    "largest-freeing first among below-peak" tie-break and keeps the
    better of the two traversals.
    """
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    n = sub.n
    if n == 0:
        return (0.0, []) if return_order else 0.0
    during, delta = _constants(sub, ext_in, ext_out)

    def run(key) -> tuple[float, list[int]]:
        indeg = [len(sub.pred[u]) for u in range(n)]
        heap = [(key(u), u) for u in range(n) if indeg[u] == 0]
        heapq.heapify(heap)
        live = peak = 0.0
        order: list[int] = []
        while heap:
            _, u = heapq.heappop(heap)
            peak = max(peak, live + during[u])
            live += delta[u]
            order.append(u)
            for v in sub.succ[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    heapq.heappush(heap, (key(v), v))
        return peak, order

    # variant 1: memory-freeing tasks first, then smallest footprint
    p1, o1 = run(lambda u: (delta[u] >= 0, during[u], u))
    # variant 2: smallest transient footprint outright
    p2, o2 = run(lambda u: (during[u], delta[u], u))
    peak, order = (p1, o1) if p1 <= p2 else (p2, o2)
    return (peak, order) if return_order else peak


def greedy_min_peak_members(
    wf: Workflow,
    nodes: Sequence[int],
) -> tuple[float, list[int]]:
    """Subgraph-free :func:`greedy_min_peak` over block ``nodes``.

    Produces bit-identical peaks/orders to building the induced
    sub-workflow and running :func:`greedy_min_peak` on it: internal
    input volumes accumulate in the sub-``add_edge`` order (producers
    in ``nodes`` order), the ``during`` sum uses the same association,
    and heap tie-breaks use the position in ``nodes`` (the local id of
    the subgraph construction).  Avoiding the Workflow materialization
    is what keeps Step 2's recursive splitting and the requirement
    cache misses affordable at 30k tasks.
    """
    n = len(nodes)
    if n == 0:
        return 0.0, []
    local = {u: i for i, u in enumerate(nodes)}
    during = [0.0] * n
    delta = [0.0] * n
    indeg = [0] * n
    int_in = [0.0] * n
    # internal input volume, accumulated in subgraph add_edge order
    for u in nodes:
        for v, c in wf.succ[u].items():
            j = local.get(v)
            if j is not None:
                int_in[j] += c
    for i, u in enumerate(nodes):
        int_out = 0.0
        ext_out = 0.0
        for v, c in wf.succ[u].items():
            if v in local:
                int_out += c
            else:
                ext_out += c
        ext_in = 0.0
        for v, c in wf.pred[u].items():
            if v in local:
                indeg[i] += 1
            else:
                ext_in += c
        during[i] = ext_in + wf.mem[u] + int_out + ext_out
        delta[i] = int_out - int_in[i]

    succ_local: list[list[int]] = [
        [j for v in wf.succ[u] if (j := local.get(v)) is not None]
        for u in nodes
    ]

    def run(keys: list[tuple]) -> tuple[float, list[int]]:
        deg = list(indeg)
        heap = [(keys[i], i) for i in range(n) if deg[i] == 0]
        heapq.heapify(heap)
        live = peak = 0.0
        order: list[int] = []
        heappush, heappop = heapq.heappush, heapq.heappop
        while heap:
            _, i = heappop(heap)
            d = live + during[i]
            if d > peak:
                peak = d
            live += delta[i]
            order.append(i)
            for j in succ_local[i]:
                deg[j] -= 1
                if deg[j] == 0:
                    heappush(heap, (keys[j], j))
        return peak, order

    p1, o1 = run([(delta[i] >= 0, during[i], i) for i in range(n)])
    # any traversal peaks at least max(during) (live is nonnegative);
    # when variant 1 attains that bound, variant 2 cannot do better and
    # the tie-break keeps (p1, o1) anyway — skip the second run.
    if p1 > max(during):
        p2, o2 = run([(during[i], delta[i], i) for i in range(n)])
        if p2 < p1:
            return p2, [nodes[i] for i in o2]
    return p1, [nodes[i] for i in o1]


def block_requirement(
    wf: Workflow,
    nodes: Sequence[int],
    exact_limit: int = EXACT_LIMIT,
    return_order: bool = False,
):
    """Memory requirement ``r_{V_i}`` of a block of ``wf``.

    Cross-block edges contribute as external inputs/outputs per the
    module-level memory model.
    """
    nodes = list(nodes)
    # persistent residency (placement layer: weights/caches) adds a
    # traversal-independent base to the block's requirement
    base = sum(wf.persistent[u] for u in nodes)
    if len(nodes) <= exact_limit:
        sub, mapping = wf.subgraph(nodes)
        ext_in, ext_out = wf.boundary_costs(nodes)
        peak = base + exact_min_peak(sub, ext_in, ext_out)
        if not return_order:
            return peak
        # exact DP does not retain the order; fall back to the greedy
        # order (whose simulated peak may be slightly above ``peak``).
        _, order = greedy_min_peak_members(wf, nodes)
        return peak, order
    peak, order = greedy_min_peak_members(wf, nodes)
    if return_order:
        return base + peak, order
    return base + peak


def block_requirement_witness(
    wf: Workflow,
    nodes: Sequence[int],
    exact_limit: int = EXACT_LIMIT,
) -> tuple[float, float, float, list[int]]:
    """``(r, base, peak_w, order)`` — requirement plus traversal witness.

    ``r`` is :func:`block_requirement`'s value (base + min-peak
    estimate); ``base`` the persistent residency; ``order`` a concrete
    traversal of the block (original task ids) whose simulated transient
    peak is ``peak_w``.  For blocks priced by the exact DP, the greedy
    order serves as witness, so ``peak_w`` may exceed ``r - base``.  The
    witness is what makes merged requirements composable: the
    merge-aware cache (:class:`repro.core.heuristic._Requirements`)
    concatenates part witnesses and bounds the result without
    re-running the traversal search.
    """
    nodes = list(nodes)
    base = sum(wf.persistent[u] for u in nodes)
    peak_g, order = greedy_min_peak_members(wf, nodes)
    if len(nodes) <= exact_limit:
        sub, _ = wf.subgraph(nodes)
        ext_in, ext_out = wf.boundary_costs(nodes)
        peak = exact_min_peak(sub, ext_in, ext_out)
        return base + min(peak, peak_g), base, peak_g, order
    return base + peak_g, base, peak_g, order
