"""Peak-memory traversal of (sub-)workflows — the paper's MemDag role.

The paper computes a block's memory requirement ``r_{V_i}`` with MemDag
(Kayaaslan et al. 2018): SP-ize the block, then find the traversal with
minimum peak memory.  Exact minimum-peak traversal of a general DAG is
NP-hard, so this module provides (DESIGN.md §3.3):

* :func:`simulate_peak` — peak memory of a *given* sequential order,
* :func:`exact_min_peak` — exact minimum over all topological orders via
  DP on downward-closed subsets (used for blocks ≤ ``EXACT_LIMIT`` tasks
  and as the oracle in property tests),
* :func:`greedy_min_peak` — best-first heuristic for larger blocks,
* :func:`block_requirement` — public entry point used by the heuristics.

Memory model (sequential execution of one block on one processor):

* an internal file ``c[u,v]`` occupies memory from the start of ``u``
  until the completion of ``v``;
* an *external input* (edge from another block) is materialized when its
  consumer starts and freed when it completes (it streams in on demand);
* an *external output* occupies memory while its producer runs and is
  freed right after (it is sent to the consuming block's processor);
* while task ``u`` runs, its own footprint ``m_u`` is added.

Hence, with ``live(S)`` = Σ internal ``c[a,b]``, ``a ∈ S``, ``b ∉ S``::

    mem_during(u, S) = live(S) + ext_in(u) + m_u + out_total(u)

which is ``live(S)`` plus a per-task constant.  (``out_total`` counts
internal and external outputs; internal inputs are already in ``live``.)
"""
from __future__ import annotations

import heapq
from typing import Sequence

import numpy as np

from . import counters
from .dag import Workflow

__all__ = [
    "simulate_peak",
    "simulate_peak_members",
    "occupancy_steps",
    "exact_min_peak",
    "greedy_min_peak",
    "block_requirement",
    "block_requirement_witness",
    "set_step2_impl",
    "step2_impl",
    "EXACT_LIMIT",
]

EXACT_LIMIT = 14

#: Step-2 block-constant implementation: "auto" dispatches large blocks
#: to the flat-array path and small ones to the scalar path; "scalar" /
#: "flat" force one side (property tests, benchmarks).  Both paths are
#: bit-identical (see docs/architecture.md, "Flat-array Step 2").
_STEP2_IMPL = "auto"

#: blocks below this size stay on the scalar path in "auto" mode — the
#: numpy call overhead only amortizes once the block's edge volume is
#: a few cache lines wide (measured crossover ~tens of tasks).
_FLAT_CUTOVER = 48


def set_step2_impl(mode: str) -> str:
    """Select the Step-2 implementation; returns the previous mode.

    ``"auto"`` (default) uses the flat-array path for blocks of at
    least ``_FLAT_CUTOVER`` tasks and the scalar path below;
    ``"scalar"`` / ``"flat"`` force one implementation everywhere.
    Results are bit-identical in every mode (asserted by
    ``tests/test_step2_flat.py``); the knob exists for benchmarks
    (``make bench-large`` records the scalar-vs-flat Step-2 share
    under ``"step2"`` in ``BENCH_runtime.json``) and property tests.
    """
    global _STEP2_IMPL
    if mode not in ("auto", "scalar", "flat"):
        raise ValueError(f"unknown Step-2 impl {mode!r}")
    prev = _STEP2_IMPL
    _STEP2_IMPL = mode
    return prev


def step2_impl() -> str:
    """The currently selected Step-2 implementation mode."""
    return _STEP2_IMPL


def _use_flat(n: int) -> bool:
    """Shared dispatch predicate of the two Step-2 entry points."""
    if _STEP2_IMPL == "flat":
        return True
    return _STEP2_IMPL == "auto" and n >= _FLAT_CUTOVER


# ---------------------------------------------------------------------- #
# flat-array workflow view (Step-2 hot path)
# ---------------------------------------------------------------------- #
class _FlatWorkflow:
    """Immutable CSR snapshot of a workflow plus per-task scratch.

    Step 2's FitBlock recursion prices thousands of blocks of the same
    workflow; rebuilding per-task ``during``/``delta`` constants from
    the adjacency dicts per block is the remaining O(E)-per-split cost
    the ROADMAP names.  This view stores the adjacency once as flat
    arrays — successor CSR in ``(task ascending, dict insertion)``
    order, predecessor CSR in ``(task ascending, dict insertion)``
    order — and computes any block's constants with a handful of
    vectorized gathers and ``np.bincount`` accumulations.

    Bit-identity: ``np.bincount`` adds its weights sequentially in
    input order, and the edge lists are gathered in exactly the order
    the scalar loops visit the dicts, so every per-task float
    accumulates with the same association as the scalar path.

    ``stamp`` / ``local`` are global per-task vectors reused across
    blocks (token-stamped membership + local ids): switching blocks is
    O(block), never O(n) — the "maintain global per-task vectors under
    FitBlock splits" design.  The shared scratch makes the view
    single-threaded per Workflow object (like every mutable cache on
    it); the parallel k' sweep isolates by *process*, never by thread.
    """

    __slots__ = (
        "n", "s_indptr", "s_dst", "s_cost", "p_indptr", "p_src",
        "p_cost", "mem", "out_total", "stamp", "local", "_token",
    )

    def __init__(self, wf: Workflow) -> None:
        n = wf.n
        self.n = n
        m = wf.n_edges
        s_indptr = np.zeros(n + 1, dtype=np.int64)
        s_dst = np.empty(m, dtype=np.int64)
        s_cost = np.empty(m, dtype=np.float64)
        k = 0
        for u in range(n):
            for v, c in wf.succ[u].items():
                s_dst[k] = v
                s_cost[k] = c
                k += 1
            s_indptr[u + 1] = k
        p_indptr = np.zeros(n + 1, dtype=np.int64)
        p_src = np.empty(m, dtype=np.int64)
        p_cost = np.empty(m, dtype=np.float64)
        k = 0
        for v in range(n):
            for u, c in wf.pred[v].items():
                p_src[k] = u
                p_cost[k] = c
                k += 1
            p_indptr[v + 1] = k
        self.s_indptr, self.s_dst, self.s_cost = s_indptr, s_dst, s_cost
        self.p_indptr, self.p_src, self.p_cost = p_indptr, p_src, p_cost
        self.mem = np.asarray(wf.mem, dtype=np.float64)
        # total outbound volume per task, accumulated in succ-dict
        # order (bincount is sequential) — matches the scalar loops
        self.out_total = np.bincount(
            np.repeat(np.arange(n, dtype=np.int64), np.diff(s_indptr)),
            weights=s_cost, minlength=n)
        self.stamp = np.zeros(n, dtype=np.int64)
        self.local = np.zeros(n, dtype=np.int64)
        self._token = 0

    def mark(self, nodes: np.ndarray) -> int:
        """Stamp ``nodes`` as the current block; returns the token."""
        self._token += 1
        self.stamp[nodes] = self._token
        self.local[nodes] = np.arange(len(nodes), dtype=np.int64)
        return self._token


def _flat_view(wf: Workflow) -> _FlatWorkflow:
    """The workflow's cached :class:`_FlatWorkflow` (built on demand).

    Shared by Step 2 and the Step-1 flat partitioner (its CSR edge
    order *is* the scalar iteration order, which is what makes the
    replayed float accumulations bit-identical).  Cache validity is
    guarded by ``(n, n_edges)`` (both O(1)): workflows are static
    during a scheduling run, and :meth:`Workflow.add_edge` drops the
    view explicitly when it accumulates onto an existing edge (the one
    mutation this guard cannot see).  Helpers that rewrite weights of
    *existing* tasks or edges in place must do the same (the workflow
    generators do).
    """
    cached = getattr(wf, "_flat_cache", None)
    if cached is not None:
        n, m, fv = cached
        if n == wf.n and m == wf.n_edges:
            return fv
    fv = _FlatWorkflow(wf)
    wf._flat_cache = (wf.n, wf.n_edges, fv)
    return fv


def _gather_rows(indptr: np.ndarray, rows: np.ndarray):
    """``(edge_idx, row_of_edge)`` for the CSR slices of ``rows``.

    ``edge_idx`` concatenates each row's ``indptr`` range in row
    order; ``row_of_edge[j]`` is the local row index owning edge j.
    """
    counts = indptr[rows + 1] - indptr[rows]
    rep = np.repeat(np.arange(len(rows), dtype=np.int64), counts)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64), rep
    ends = np.cumsum(counts)
    idx = np.arange(total, dtype=np.int64) + np.repeat(
        indptr[rows] - (ends - counts), counts)
    return idx, rep


def _constants(
    sub: Workflow,
    ext_in: dict[int, float],
    ext_out: dict[int, float],
) -> tuple[list[float], list[float]]:
    """Per-task ``(during_const, live_delta)``.

    ``during_const[u]``: what task ``u`` adds on top of ``live(S)`` while
    it runs.  ``live_delta[u]``: change of the internal live set after
    ``u`` completes (internal outputs appear, internal inputs freed).
    """
    during = [0.0] * sub.n
    delta = [0.0] * sub.n
    for u in range(sub.n):
        int_in = sub.in_cost(u)
        int_out = sub.out_cost(u)
        during[u] = (
            ext_in.get(u, 0.0) + sub.mem[u] + int_out + ext_out.get(u, 0.0)
        )
        delta[u] = int_out - int_in
    return during, delta


def simulate_peak(
    sub: Workflow,
    order: Sequence[int],
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
) -> float:
    """Peak memory of executing ``sub`` sequentially in ``order``."""
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    during, delta = _constants(sub, ext_in, ext_out)
    live = 0.0
    peak = 0.0
    done = [False] * sub.n
    for u in order:
        if any(not done[p] for p in sub.pred[u]):
            raise ValueError("order violates precedence constraints")
        peak = max(peak, live + during[u])
        live += delta[u]
        done[u] = True
    if not all(done):
        raise ValueError("order does not cover the block")
    return peak


def occupancy_steps(wf: Workflow, members, order: Sequence[int]):
    """Yield ``(u, during, live_after)`` along a block traversal.

    The single source of truth for the transient-occupancy
    accumulation over the original workflow (no subgraph/boundary
    materialization), with edges leaving or entering ``members``
    treated as external per the module memory model: ``during`` is the
    occupancy while ``u`` runs, ``live_after`` the internal live set
    once it completes.  Shared by the witness evaluator below and the
    simulator's time-resolved memory tracker
    (:mod:`repro.sim.memory`), which must price states bit-identically
    to :func:`block_requirement`.  ``order`` must cover ``members``
    exactly and respect precedence *within* the block (not checked —
    this is the hot path; :func:`simulate_peak` is the checked
    variant).  Excludes the persistent base (callers add Σ persistent).
    """
    members = members if isinstance(members, (set, frozenset)) \
        else set(members)
    live = 0.0
    for u in order:
        int_in = 0.0
        ext_in = 0.0
        for p, c in wf.pred[u].items():
            if p in members:
                int_in += c
            else:
                ext_in += c
        int_out = 0.0
        out_total = 0.0
        for v, c in wf.succ[u].items():
            out_total += c
            if v in members:
                int_out += c
        during = live + ext_in + wf.mem[u] + out_total
        live += int_out - int_in
        yield u, during, live


def simulate_peak_members(
    wf: Workflow,
    members,
    order: Sequence[int],
) -> float:
    """Transient peak of executing block ``members`` of ``wf`` in
    ``order`` — ``max`` over the :func:`occupancy_steps` states (see
    there for the memory model and the unchecked-precedence caveat).

    ``order`` must cover ``members`` exactly (already an
    :func:`occupancy_steps` precondition); large blocks dispatch to a
    flat-array evaluation that is bit-identical to the scalar loop
    (same accumulation order — see :class:`_FlatWorkflow`).
    """
    if _use_flat(len(order)):
        return _simulate_peak_members_flat(wf, order)
    counters.bump("step2_scalar_peak_sims")
    peak = 0.0
    for _, during, _ in occupancy_steps(wf, members, order):
        if during > peak:
            peak = during
    return peak


def _simulate_peak_members_flat(wf: Workflow, order: Sequence[int]) -> float:
    """Flat-array :func:`simulate_peak_members` (identical floats).

    ``live`` is the sequential prefix sum of the per-task deltas
    (``np.cumsum`` accumulates left to right, like the scalar loop)
    and every per-task constant sums its edge contributions in the
    scalar visiting order via ``np.bincount``.
    """
    counters.bump("step2_flat_peak_sims")
    nb = len(order)
    if nb == 0:
        return 0.0
    fv = _flat_view(wf)
    order_arr = np.asarray(order, dtype=np.int64)
    token = fv.mark(order_arr)
    pidx, prep = _gather_rows(fv.p_indptr, order_arr)
    internal_p = fv.stamp[fv.p_src[pidx]] == token
    pcost = fv.p_cost[pidx]
    int_in = np.bincount(prep[internal_p], weights=pcost[internal_p],
                         minlength=nb)
    ext_in = np.bincount(prep[~internal_p], weights=pcost[~internal_p],
                         minlength=nb)
    sidx, srep = _gather_rows(fv.s_indptr, order_arr)
    internal_s = fv.stamp[fv.s_dst[sidx]] == token
    int_out = np.bincount(srep[internal_s],
                          weights=fv.s_cost[sidx][internal_s],
                          minlength=nb)
    live = np.empty(nb, dtype=np.float64)
    live[0] = 0.0
    if nb > 1:
        np.cumsum((int_out - int_in)[:-1], out=live[1:])
    during = ((live + ext_in) + fv.mem[order_arr]) + fv.out_total[order_arr]
    peak = float(during.max())
    return peak if peak > 0.0 else 0.0


def exact_min_peak(
    sub: Workflow,
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
) -> float:
    """Exact minimum peak memory over all topological orders (DP).

    State: downward-closed subset ``S`` of executed tasks (bitmask).
    ``live(S)`` only depends on ``S``, so
    ``f(S) = min_{u ready into S} max(f(S \\ u), live(S \\ u) + during(u))``.
    Exponential — gate on ``sub.n <= ~20`` at call sites.
    """
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    n = sub.n
    if n == 0:
        return 0.0
    during, delta = _constants(sub, ext_in, ext_out)
    pred_mask = [0] * n
    for v in range(n):
        for p in sub.pred[v]:
            pred_mask[v] |= 1 << p
    full = (1 << n) - 1
    # frontier DP over popcount layers; store live alongside to avoid
    # recomputation (live is additive in deltas of members).
    f: dict[int, float] = {0: 0.0}
    live: dict[int, float] = {0: 0.0}
    for _ in range(n):
        nf: dict[int, float] = {}
        nlive: dict[int, float] = {}
        for S, peak in f.items():
            lS = live[S]
            for u in range(n):
                bit = 1 << u
                if S & bit or (pred_mask[u] & S) != pred_mask[u]:
                    continue
                S2 = S | bit
                cand = max(peak, lS + during[u])
                old = nf.get(S2)
                if old is None or cand < old:
                    nf[S2] = cand
                    nlive[S2] = lS + delta[u]
        f, live = nf, nlive
    return f[full]


def greedy_min_peak(
    sub: Workflow,
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
    return_order: bool = False,
):
    """Best-first heuristic traversal minimizing peak memory.

    Two ready-heaps: tasks that *shrink* the live set (scheduled first,
    by smallest transient footprint) and tasks that grow it.  Because
    ``mem_during`` is ``live + const(u)``, ordering ready tasks by
    ``const(u)`` is time-invariant, giving O(E log V).

    A final *peak-shaving* pass re-simulates with the classic
    "largest-freeing first among below-peak" tie-break and keeps the
    better of the two traversals.
    """
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    n = sub.n
    if n == 0:
        return (0.0, []) if return_order else 0.0
    during, delta = _constants(sub, ext_in, ext_out)

    def run(key) -> tuple[float, list[int]]:
        indeg = [len(sub.pred[u]) for u in range(n)]
        heap = [(key(u), u) for u in range(n) if indeg[u] == 0]
        heapq.heapify(heap)
        live = peak = 0.0
        order: list[int] = []
        while heap:
            _, u = heapq.heappop(heap)
            peak = max(peak, live + during[u])
            live += delta[u]
            order.append(u)
            for v in sub.succ[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    heapq.heappush(heap, (key(v), v))
        return peak, order

    # variant 1: memory-freeing tasks first, then smallest footprint
    p1, o1 = run(lambda u: (delta[u] >= 0, during[u], u))
    # variant 2: smallest transient footprint outright
    p2, o2 = run(lambda u: (during[u], delta[u], u))
    peak, order = (p1, o1) if p1 <= p2 else (p2, o2)
    return (peak, order) if return_order else peak


def greedy_min_peak_members(
    wf: Workflow,
    nodes: Sequence[int],
) -> tuple[float, list[int]]:
    """Subgraph-free :func:`greedy_min_peak` over block ``nodes``.

    Produces bit-identical peaks/orders to building the induced
    sub-workflow and running :func:`greedy_min_peak` on it: internal
    input volumes accumulate in the sub-``add_edge`` order (producers
    in ``nodes`` order), the ``during`` sum uses the same association,
    and heap tie-breaks use the position in ``nodes`` (the local id of
    the subgraph construction).  Avoiding the Workflow materialization
    is what keeps Step 2's recursive splitting and the requirement
    cache misses affordable at 30k tasks.

    Dispatches by block size between two bit-identical
    implementations (see :func:`set_step2_impl`): the scalar
    dict-walking reference below and the flat-array path
    (:func:`_greedy_min_peak_members_flat`) that computes the block
    constants with vectorized gathers and runs the ready-heap on
    lexsort ranks.
    """
    n = len(nodes)
    if n == 0:
        return 0.0, []
    if _use_flat(n):
        return _greedy_min_peak_members_flat(wf, nodes)
    return _greedy_min_peak_members_scalar(wf, nodes)


def _greedy_min_peak_members_scalar(
    wf: Workflow,
    nodes: Sequence[int],
) -> tuple[float, list[int]]:
    """Scalar reference implementation of
    :func:`greedy_min_peak_members` (also the fast path for small
    blocks, where numpy call overhead dominates)."""
    counters.bump("step2_scalar_blocks")
    n = len(nodes)
    if n == 0:
        return 0.0, []
    local = {u: i for i, u in enumerate(nodes)}
    during = [0.0] * n
    delta = [0.0] * n
    indeg = [0] * n
    int_in = [0.0] * n
    # internal input volume, accumulated in subgraph add_edge order
    for u in nodes:
        for v, c in wf.succ[u].items():
            j = local.get(v)
            if j is not None:
                int_in[j] += c
    for i, u in enumerate(nodes):
        int_out = 0.0
        ext_out = 0.0
        for v, c in wf.succ[u].items():
            if v in local:
                int_out += c
            else:
                ext_out += c
        ext_in = 0.0
        for v, c in wf.pred[u].items():
            if v in local:
                indeg[i] += 1
            else:
                ext_in += c
        during[i] = ext_in + wf.mem[u] + int_out + ext_out
        delta[i] = int_out - int_in[i]

    succ_local: list[list[int]] = [
        [j for v in wf.succ[u] if (j := local.get(v)) is not None]
        for u in nodes
    ]

    def run(keys: list[tuple]) -> tuple[float, list[int]]:
        deg = list(indeg)
        heap = [(keys[i], i) for i in range(n) if deg[i] == 0]
        heapq.heapify(heap)
        live = peak = 0.0
        order: list[int] = []
        heappush, heappop = heapq.heappush, heapq.heappop
        while heap:
            _, i = heappop(heap)
            d = live + during[i]
            if d > peak:
                peak = d
            live += delta[i]
            order.append(i)
            for j in succ_local[i]:
                deg[j] -= 1
                if deg[j] == 0:
                    heappush(heap, (keys[j], j))
        return peak, order

    p1, o1 = run([(delta[i] >= 0, during[i], i) for i in range(n)])
    # any traversal peaks at least max(during) (live is nonnegative);
    # when variant 1 attains that bound, variant 2 cannot do better and
    # the tie-break keeps (p1, o1) anyway — skip the second run.
    if p1 > max(during):
        p2, o2 = run([(during[i], delta[i], i) for i in range(n)])
        if p2 < p1:
            return p2, [nodes[i] for i in o2]
    return p1, [nodes[i] for i in o1]


def _greedy_min_peak_members_flat(
    wf: Workflow,
    nodes: Sequence[int],
) -> tuple[float, list[int]]:
    """Flat-array :func:`greedy_min_peak_members` (identical results).

    Block constants come from the cached :class:`_FlatWorkflow` CSR
    view — vectorized gathers + sequential ``np.bincount``
    accumulation reproduce the scalar float associations exactly — and
    the ready-heap runs on *lexsort ranks*: each variant's key tuples
    ``(flag, during, i)`` are ranked once with ``np.lexsort`` (stable,
    so ties fall back to the local id exactly like the tuple compare)
    and the heap then holds plain ints.  Pops are strictly by minimum
    key in both versions, so the traversal — and hence every
    ``live``/``peak`` float — is bit-identical to the scalar run.
    """
    counters.bump("step2_flat_blocks")
    n = len(nodes)
    fv = _flat_view(wf)
    nodes_arr = np.asarray(nodes, dtype=np.int64)
    token = fv.mark(nodes_arr)
    # successor-side constants (edge order == scalar scan order)
    sidx, srep = _gather_rows(fv.s_indptr, nodes_arr)
    sdst = fv.s_dst[sidx]
    scost = fv.s_cost[sidx]
    internal_s = fv.stamp[sdst] == token
    int_cost = scost[internal_s]
    int_src = srep[internal_s]
    int_dst = fv.local[sdst[internal_s]]
    int_out = np.bincount(int_src, weights=int_cost, minlength=n)
    ext_out = np.bincount(srep[~internal_s], weights=scost[~internal_s],
                          minlength=n)
    # the scalar path accumulates int_in over producers in ``nodes``
    # order — exactly this (masked) edge sequence
    int_in = np.bincount(int_dst, weights=int_cost, minlength=n)
    # predecessor-side constants
    pidx, prep = _gather_rows(fv.p_indptr, nodes_arr)
    external_p = fv.stamp[fv.p_src[pidx]] != token
    ext_in = np.bincount(prep[external_p],
                         weights=fv.p_cost[pidx][external_p],
                         minlength=n)
    during = ((ext_in + fv.mem[nodes_arr]) + int_out) + ext_out
    delta = int_out - int_in
    if len(int_cost) == 0:
        # Edge-free block (common for fan families and late FitBlock
        # splits): every task is ready from the start, so the heap
        # degenerates to one sort, ``delta == 0`` everywhere keeps
        # ``live`` at 0.0, the peak is exactly ``max(during)``, and
        # the second variant can never beat it (its guard is false).
        perm = np.lexsort((during, delta >= 0))
        peak = float(during.max())
        return (peak if peak > 0.0 else 0.0,
                [nodes[i] for i in perm.tolist()])
    indeg0 = np.bincount(int_dst, minlength=n)
    # local successor CSR (int_src is nondecreasing: grouped by source)
    lptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(int_src, minlength=n), out=lptr[1:])
    lptr_l = lptr.tolist()
    ldst_l = int_dst.tolist()
    during_l = during.tolist()
    delta_l = delta.tolist()
    indeg_l = indeg0.tolist()
    ready0 = indeg0 == 0

    inf = float("inf")

    def run(perm: np.ndarray, cutoff: float = inf) -> tuple[float, list[int]]:
        order_of = perm.tolist()
        rank_of = np.empty(n, dtype=np.int64)
        rank_of[perm] = np.arange(n, dtype=np.int64)
        rank_l = rank_of.tolist()
        deg = list(indeg_l)
        heap = rank_of[ready0].tolist()
        heapq.heapify(heap)
        live = peak = 0.0
        order: list[int] = []
        heappush, heappop = heapq.heappush, heapq.heappop
        while heap:
            i = order_of[heappop(heap)]
            d = live + during_l[i]
            if d > peak:
                peak = d
                if peak >= cutoff:
                    # a traversal's peak only grows: this variant can
                    # no longer beat the incumbent — abort (the caller
                    # discards the partial order on peak >= cutoff)
                    return peak, order
            live += delta_l[i]
            order.append(i)
            for j in ldst_l[lptr_l[i]:lptr_l[i + 1]]:
                deg[j] -= 1
                if deg[j] == 0:
                    heappush(heap, rank_l[j])
        return peak, order

    # variant 1: memory-freeing tasks first, then smallest footprint
    # (np.lexsort: last key is primary; stability supplies the id tie)
    p1, o1 = run(np.lexsort((during, delta >= 0)))
    if p1 > float(during.max()):
        # variant 2: smallest transient footprint outright, aborted as
        # soon as it provably cannot beat variant 1
        p2, o2 = run(np.lexsort((delta, during)), cutoff=p1)
        if p2 < p1:
            return p2, [nodes[i] for i in o2]
    return p1, [nodes[i] for i in o1]


def block_requirement(
    wf: Workflow,
    nodes: Sequence[int],
    exact_limit: int = EXACT_LIMIT,
    return_order: bool = False,
):
    """Memory requirement ``r_{V_i}`` of a block of ``wf``.

    Cross-block edges contribute as external inputs/outputs per the
    module-level memory model.
    """
    nodes = list(nodes)
    # persistent residency (placement layer: weights/caches) adds a
    # traversal-independent base to the block's requirement
    base = sum(wf.persistent[u] for u in nodes)
    if len(nodes) <= exact_limit:
        sub, mapping = wf.subgraph(nodes)
        ext_in, ext_out = wf.boundary_costs(nodes)
        peak = base + exact_min_peak(sub, ext_in, ext_out)
        if not return_order:
            return peak
        # exact DP does not retain the order; fall back to the greedy
        # order (whose simulated peak may be slightly above ``peak``).
        _, order = greedy_min_peak_members(wf, nodes)
        return peak, order
    peak, order = greedy_min_peak_members(wf, nodes)
    if return_order:
        return base + peak, order
    return base + peak


def block_requirement_witness(
    wf: Workflow,
    nodes: Sequence[int],
    exact_limit: int = EXACT_LIMIT,
) -> tuple[float, float, float, list[int]]:
    """``(r, base, peak_w, order)`` — requirement plus traversal witness.

    ``r`` is :func:`block_requirement`'s value (base + min-peak
    estimate); ``base`` the persistent residency; ``order`` a concrete
    traversal of the block (original task ids) whose simulated transient
    peak is ``peak_w``.  For blocks priced by the exact DP, the greedy
    order serves as witness, so ``peak_w`` may exceed ``r - base``.  The
    witness is what makes merged requirements composable: the
    merge-aware cache (:class:`repro.core.heuristic._Requirements`)
    concatenates part witnesses and bounds the result without
    re-running the traversal search.
    """
    nodes = list(nodes)
    base = sum(wf.persistent[u] for u in nodes)
    peak_g, order = greedy_min_peak_members(wf, nodes)
    if len(nodes) <= exact_limit:
        sub, _ = wf.subgraph(nodes)
        ext_in, ext_out = wf.boundary_costs(nodes)
        peak = exact_min_peak(sub, ext_in, ext_out)
        return base + min(peak, peak_g), base, peak_g, order
    return base + peak_g, base, peak_g, order
