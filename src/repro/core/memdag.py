"""Peak-memory traversal of (sub-)workflows — the paper's MemDag role.

The paper computes a block's memory requirement ``r_{V_i}`` with MemDag
(Kayaaslan et al. 2018): SP-ize the block, then find the traversal with
minimum peak memory.  Exact minimum-peak traversal of a general DAG is
NP-hard, so this module provides (DESIGN.md §3.3):

* :func:`simulate_peak` — peak memory of a *given* sequential order,
* :func:`exact_min_peak` — exact minimum over all topological orders via
  DP on downward-closed subsets (used for blocks ≤ ``EXACT_LIMIT`` tasks
  and as the oracle in property tests),
* :func:`greedy_min_peak` — best-first heuristic for larger blocks,
* :func:`block_requirement` — public entry point used by the heuristics.

Memory model (sequential execution of one block on one processor):

* an internal file ``c[u,v]`` occupies memory from the start of ``u``
  until the completion of ``v``;
* an *external input* (edge from another block) is materialized when its
  consumer starts and freed when it completes (it streams in on demand);
* an *external output* occupies memory while its producer runs and is
  freed right after (it is sent to the consuming block's processor);
* while task ``u`` runs, its own footprint ``m_u`` is added.

Hence, with ``live(S)`` = Σ internal ``c[a,b]``, ``a ∈ S``, ``b ∉ S``::

    mem_during(u, S) = live(S) + ext_in(u) + m_u + out_total(u)

which is ``live(S)`` plus a per-task constant.  (``out_total`` counts
internal and external outputs; internal inputs are already in ``live``.)
"""
from __future__ import annotations

import heapq
from typing import Sequence

from .dag import Workflow

__all__ = [
    "simulate_peak",
    "exact_min_peak",
    "greedy_min_peak",
    "block_requirement",
    "EXACT_LIMIT",
]

EXACT_LIMIT = 14


def _constants(
    sub: Workflow,
    ext_in: dict[int, float],
    ext_out: dict[int, float],
) -> tuple[list[float], list[float]]:
    """Per-task ``(during_const, live_delta)``.

    ``during_const[u]``: what task ``u`` adds on top of ``live(S)`` while
    it runs.  ``live_delta[u]``: change of the internal live set after
    ``u`` completes (internal outputs appear, internal inputs freed).
    """
    during = [0.0] * sub.n
    delta = [0.0] * sub.n
    for u in range(sub.n):
        int_in = sub.in_cost(u)
        int_out = sub.out_cost(u)
        during[u] = (
            ext_in.get(u, 0.0) + sub.mem[u] + int_out + ext_out.get(u, 0.0)
        )
        delta[u] = int_out - int_in
    return during, delta


def simulate_peak(
    sub: Workflow,
    order: Sequence[int],
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
) -> float:
    """Peak memory of executing ``sub`` sequentially in ``order``."""
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    during, delta = _constants(sub, ext_in, ext_out)
    live = 0.0
    peak = 0.0
    done = [False] * sub.n
    for u in order:
        if any(not done[p] for p in sub.pred[u]):
            raise ValueError("order violates precedence constraints")
        peak = max(peak, live + during[u])
        live += delta[u]
        done[u] = True
    if not all(done):
        raise ValueError("order does not cover the block")
    return peak


def exact_min_peak(
    sub: Workflow,
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
) -> float:
    """Exact minimum peak memory over all topological orders (DP).

    State: downward-closed subset ``S`` of executed tasks (bitmask).
    ``live(S)`` only depends on ``S``, so
    ``f(S) = min_{u ready into S} max(f(S \\ u), live(S \\ u) + during(u))``.
    Exponential — gate on ``sub.n <= ~20`` at call sites.
    """
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    n = sub.n
    if n == 0:
        return 0.0
    during, delta = _constants(sub, ext_in, ext_out)
    pred_mask = [0] * n
    for v in range(n):
        for p in sub.pred[v]:
            pred_mask[v] |= 1 << p
    full = (1 << n) - 1
    # frontier DP over popcount layers; store live alongside to avoid
    # recomputation (live is additive in deltas of members).
    f: dict[int, float] = {0: 0.0}
    live: dict[int, float] = {0: 0.0}
    for _ in range(n):
        nf: dict[int, float] = {}
        nlive: dict[int, float] = {}
        for S, peak in f.items():
            lS = live[S]
            for u in range(n):
                bit = 1 << u
                if S & bit or (pred_mask[u] & S) != pred_mask[u]:
                    continue
                S2 = S | bit
                cand = max(peak, lS + during[u])
                old = nf.get(S2)
                if old is None or cand < old:
                    nf[S2] = cand
                    nlive[S2] = lS + delta[u]
        f, live = nf, nlive
    return f[full]


def greedy_min_peak(
    sub: Workflow,
    ext_in: dict[int, float] | None = None,
    ext_out: dict[int, float] | None = None,
    return_order: bool = False,
):
    """Best-first heuristic traversal minimizing peak memory.

    Two ready-heaps: tasks that *shrink* the live set (scheduled first,
    by smallest transient footprint) and tasks that grow it.  Because
    ``mem_during`` is ``live + const(u)``, ordering ready tasks by
    ``const(u)`` is time-invariant, giving O(E log V).

    A final *peak-shaving* pass re-simulates with the classic
    "largest-freeing first among below-peak" tie-break and keeps the
    better of the two traversals.
    """
    ext_in = ext_in or {}
    ext_out = ext_out or {}
    n = sub.n
    if n == 0:
        return (0.0, []) if return_order else 0.0
    during, delta = _constants(sub, ext_in, ext_out)

    def run(key) -> tuple[float, list[int]]:
        indeg = [len(sub.pred[u]) for u in range(n)]
        heap = [(key(u), u) for u in range(n) if indeg[u] == 0]
        heapq.heapify(heap)
        live = peak = 0.0
        order: list[int] = []
        while heap:
            _, u = heapq.heappop(heap)
            peak = max(peak, live + during[u])
            live += delta[u]
            order.append(u)
            for v in sub.succ[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    heapq.heappush(heap, (key(v), v))
        return peak, order

    # variant 1: memory-freeing tasks first, then smallest footprint
    p1, o1 = run(lambda u: (delta[u] >= 0, during[u], u))
    # variant 2: smallest transient footprint outright
    p2, o2 = run(lambda u: (during[u], delta[u], u))
    peak, order = (p1, o1) if p1 <= p2 else (p2, o2)
    return (peak, order) if return_order else peak


def block_requirement(
    wf: Workflow,
    nodes: Sequence[int],
    exact_limit: int = EXACT_LIMIT,
    return_order: bool = False,
):
    """Memory requirement ``r_{V_i}`` of a block of ``wf``.

    Cross-block edges contribute as external inputs/outputs per the
    module-level memory model.
    """
    nodes = list(nodes)
    sub, mapping = wf.subgraph(nodes)
    ext_in, ext_out = wf.boundary_costs(nodes)
    # persistent residency (placement layer: weights/caches) adds a
    # traversal-independent base to the block's requirement
    base = sum(wf.persistent[u] for u in nodes)
    if sub.n <= exact_limit:
        peak = base + exact_min_peak(sub, ext_in, ext_out)
        if not return_order:
            return peak
        # exact DP does not retain the order; fall back to the greedy
        # order (whose simulated peak may be slightly above ``peak``).
        _, order = greedy_min_peak(sub, ext_in, ext_out, return_order=True)
        return peak, [mapping[i] for i in order]
    result = greedy_min_peak(sub, ext_in, ext_out, return_order=return_order)
    if return_order:
        peak, order = result
        return base + peak, [mapping[i] for i in order]
    return base + result
