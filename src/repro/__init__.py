"""repro — memory-constrained workflow mapping for heterogeneous TPU
fleets (Kulagina, Meyerhenke, Benoit — ICPP'24) as a production JAX
framework.  See README.md / DESIGN.md."""

__version__ = "1.0.0"
