"""Platform events: timed changes to the execution environment.

Each event is a point-in-time transform of a
:class:`~repro.core.platform.Platform`.  ``apply(platform)`` returns
``(new_platform, proc_map)`` where ``proc_map`` maps every old
processor index to its index on the new platform (``None`` for a
processor that no longer exists) — the reindexing contract that lets
:mod:`repro.scenario` carry assignments across an event, and that the
composition property tests pin down (``without`` compacts indices,
everything else preserves them).

The transforms compose the elastic :class:`Platform` methods
(:meth:`~repro.core.platform.Platform.without`,
:meth:`~repro.core.platform.Platform.with_speed`,
:meth:`~repro.core.platform.Platform.with_link_bandwidth`,
:meth:`~repro.core.platform.Platform.with_processors`), so per-link
bandwidth overrides survive failures and arrivals.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

from repro.core.platform import Platform, Processor

__all__ = [
    "EventTimelineError",
    "LinkDegrade",
    "PlatformEvent",
    "ProcArrival",
    "ProcFailure",
    "SpeedChange",
    "canonical_event_order",
    "event_from_dict",
    "event_sort_key",
    "validate_event_timeline",
]


class EventTimelineError(ValueError):
    """Structured timeline rejection raised at *build* time.

    ``index`` is the offending position in the event list, ``code`` a
    stable kind (``"bad-type"``, ``"non-finite-time"``,
    ``"negative-time"``, ``"unsorted"``, ``"unsorted-tie"``).
    :class:`Scenario
    <repro.scenario.runner.Scenario>` construction and the
    :mod:`repro.service` event loop both enforce this invariant up
    front — an unsorted or non-finite timeline must fail loudly before
    any replanning starts, not misbehave mid-run.
    """

    def __init__(self, code: str, index: int, detail: str) -> None:
        self.code = code
        self.index = index
        self.detail = detail
        super().__init__(f"[{code}] event #{index}: {detail}")


#: canonical rank of an event kind *within* one timestamp: removals
#: first, then arrivals, then in-place parameter changes — any fixed
#: convention would do, but there must be exactly one so that a
#: fuzz-generated timeline replays identically after a JSON round-trip.
_KIND_RANK = {
    "proc_failure": 0,
    "proc_arrival": 1,
    "speed_change": 2,
    "link_degrade": 3,
}


def event_sort_key(ev: "PlatformEvent") -> tuple:
    """Total order over events: ``(time, kind rank, per-kind fields)``.

    Events at the *same* timestamp apply in list order (each sees the
    platform produced by the previous one), so two permutations of
    simultaneous events are different timelines.  This key defines the
    single canonical permutation; :func:`validate_event_timeline`
    rejects any other with code ``"unsorted-tie"`` and
    :func:`canonical_event_order` produces it.
    """
    rank = _KIND_RANK.get(ev.kind, len(_KIND_RANK))
    if isinstance(ev, ProcFailure):
        tail: tuple = (tuple(sorted(ev.procs)),)
    elif isinstance(ev, ProcArrival):
        tail = (tuple((p.name, p.speed, p.memory) for p in ev.procs),)
    elif isinstance(ev, SpeedChange):
        tail = (ev.proc, ev.factor)
    elif isinstance(ev, LinkDegrade):
        tail = (ev.src, ev.dst, ev.bandwidth, ev.symmetric)
    else:
        tail = ()
    return (ev.time, rank, ev.kind, tail)


def canonical_event_order(events: Sequence["PlatformEvent"],
                          ) -> list["PlatformEvent"]:
    """``events`` sorted into the canonical total order
    (:func:`event_sort_key`) that :func:`validate_event_timeline`
    accepts."""
    return sorted(events, key=event_sort_key)


def validate_event_timeline(events: Sequence["PlatformEvent"]) -> None:
    """Check ``events`` is a time-sorted list of finite, non-negative
    :class:`PlatformEvent` s — with simultaneous events in the
    canonical intra-timestamp order (:func:`event_sort_key`) — and
    raise :class:`EventTimelineError` if not."""
    prev = None
    prev_key = None
    for i, ev in enumerate(events):
        if not isinstance(ev, PlatformEvent):
            raise EventTimelineError(
                "bad-type", i, f"not a PlatformEvent: {ev!r}")
        if not math.isfinite(ev.time):
            raise EventTimelineError(
                "non-finite-time", i, f"time is {ev.time!r}")
        if ev.time < 0:
            raise EventTimelineError(
                "negative-time", i, f"time is {ev.time!r}")
        if prev is not None and ev.time < prev:
            raise EventTimelineError(
                "unsorted", i,
                f"time {ev.time!r} precedes event #{i - 1} "
                f"at {prev!r} — sort the timeline by time")
        key = event_sort_key(ev)
        if prev is not None and ev.time == prev and key < prev_key:
            raise EventTimelineError(
                "unsorted-tie", i,
                f"{ev.describe()!r} at t={ev.time!r} precedes "
                f"simultaneous event #{i - 1} in the canonical "
                f"intra-timestamp order — use canonical_event_order()")
        prev = ev.time
        prev_key = key


@dataclass(frozen=True)
class PlatformEvent:
    """Base: something happens to the platform at ``time``."""

    time: float

    def __post_init__(self) -> None:
        if not (self.time >= 0) or self.time == float("inf"):
            raise ValueError(
                f"event time must be finite and >= 0, got {self.time}")

    # subclasses override ------------------------------------------- #
    kind: str = field(default="event", init=False, repr=False)

    def apply(self, platform: Platform) -> tuple[Platform,
                                                 dict[int, int | None]]:
        raise NotImplementedError

    def describe(self) -> str:
        return self.kind

    def to_dict(self) -> dict:
        return {"kind": self.kind, "time": self.time,
                "detail": self.describe()}


def _identity_map(platform: Platform) -> dict[int, int | None]:
    return {j: j for j in range(platform.k)}


@dataclass(frozen=True)
class ProcFailure(PlatformEvent):
    """Processors ``procs`` disappear at ``time`` (node loss)."""

    procs: frozenset[int] = frozenset()
    kind: str = field(default="proc_failure", init=False, repr=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        object.__setattr__(self, "procs", frozenset(self.procs))
        if not self.procs:
            raise ValueError("ProcFailure needs at least one processor")

    def apply(self, platform: Platform):
        bad = [j for j in self.procs if not 0 <= j < platform.k]
        if bad:
            raise ValueError(
                f"failed processor(s) {sorted(bad)} out of range for "
                f"k={platform.k}"
            )
        if len(self.procs) >= platform.k:
            raise ValueError("cannot fail every processor")
        keep = [j for j in range(platform.k) if j not in self.procs]
        new_index = {old: i for i, old in enumerate(keep)}
        proc_map = {j: new_index.get(j) for j in range(platform.k)}
        return platform.without(set(self.procs)), proc_map

    def describe(self) -> str:
        return f"fail proc(s) {sorted(self.procs)}"

    def to_dict(self) -> dict:
        d = super().to_dict()
        d["procs"] = sorted(self.procs)
        return d


@dataclass(frozen=True)
class ProcArrival(PlatformEvent):
    """New processors join at ``time`` (elastic scale-up)."""

    procs: tuple[Processor, ...] = ()
    kind: str = field(default="proc_arrival", init=False, repr=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        object.__setattr__(self, "procs", tuple(self.procs))
        if not self.procs:
            raise ValueError("ProcArrival needs at least one processor")

    def apply(self, platform: Platform):
        return (platform.with_processors(list(self.procs)),
                _identity_map(platform))

    def describe(self) -> str:
        return f"add proc(s) {[p.name for p in self.procs]}"

    def to_dict(self) -> dict:
        d = super().to_dict()
        d["procs"] = [[p.name, p.speed, p.memory] for p in self.procs]
        return d


@dataclass(frozen=True)
class SpeedChange(PlatformEvent):
    """Processor ``proc``'s speed is scaled by ``factor`` at ``time``
    (straggler slowdown for ``factor < 1``, recovery for ``> 1``)."""

    proc: int = 0
    factor: float = 1.0
    kind: str = field(default="speed_change", init=False, repr=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        if not self.factor > 0:
            raise ValueError(
                f"speed factor must be positive, got {self.factor}")

    def apply(self, platform: Platform):
        if not 0 <= self.proc < platform.k:
            raise ValueError(
                f"processor {self.proc} out of range for k={platform.k}")
        new_speed = platform.speed(self.proc) * self.factor
        return (platform.with_speed(self.proc, new_speed),
                _identity_map(platform))

    def describe(self) -> str:
        return f"proc {self.proc} speed x{self.factor:.3g}"

    def to_dict(self) -> dict:
        d = super().to_dict()
        d["proc"] = self.proc
        d["factor"] = self.factor
        return d


@dataclass(frozen=True)
class LinkDegrade(PlatformEvent):
    """The ``src -> dst`` link (both directions when ``symmetric``)
    drops to ``bandwidth`` at ``time``."""

    src: int = 0
    dst: int = 1
    bandwidth: float = 1.0
    symmetric: bool = True
    kind: str = field(default="link_degrade", init=False, repr=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        if not self.bandwidth > 0:
            raise ValueError(
                f"link bandwidth must be positive, got {self.bandwidth}")

    def apply(self, platform: Platform):
        for j in (self.src, self.dst):
            if not 0 <= j < platform.k:
                raise ValueError(
                    f"processor {j} out of range for k={platform.k}")
        return (
            platform.with_link_bandwidth(self.src, self.dst,
                                         self.bandwidth,
                                         symmetric=self.symmetric),
            _identity_map(platform),
        )

    def describe(self) -> str:
        arrow = "<->" if self.symmetric else "->"
        return (f"link {self.src}{arrow}{self.dst} "
                f"beta={self.bandwidth:.3g}")

    def to_dict(self) -> dict:
        d = super().to_dict()
        d.update(src=self.src, dst=self.dst, bandwidth=self.bandwidth,
                 symmetric=self.symmetric)
        return d


_EVENT_KINDS = {
    "proc_failure": lambda d: ProcFailure(
        time=d["time"], procs=frozenset(d["procs"])),
    "proc_arrival": lambda d: ProcArrival(
        time=d["time"],
        procs=tuple(Processor(n, s, m) for n, s, m in d["procs"])),
    "speed_change": lambda d: SpeedChange(
        time=d["time"], proc=d["proc"], factor=d["factor"]),
    "link_degrade": lambda d: LinkDegrade(
        time=d["time"], src=d["src"], dst=d["dst"],
        bandwidth=d["bandwidth"], symmetric=d["symmetric"]),
}


def event_from_dict(d: dict) -> PlatformEvent:
    """Rebuild an event from its :meth:`PlatformEvent.to_dict` record."""
    try:
        build = _EVENT_KINDS[d["kind"]]
    except KeyError:
        raise ValueError(f"unknown event kind {d.get('kind')!r}") from None
    return build(d)
