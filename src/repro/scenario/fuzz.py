"""repro.scenario.fuzz — seeded adversarial scenario generation.

PRs 4/7/9 check the pause-replan-stitch invariants on hand-written
timelines; this module generates them.  :func:`fuzz_scenarios` draws
random valid workflows, platforms (with random failure-rate/power
models, :mod:`repro.objectives`) and event timelines — failure times
sampled from the platform's own exponential failure rates, plus
arrivals, speed changes, link degrades, and deliberate simultaneous
events in the canonical intra-timestamp order — then drives every
replanning policy and the service loop through them and checks the
*global* invariants:

* every run returns a stitched :class:`TimelineReport` or a
  *structured* infeasibility — never an uncaught exception;
* every feasible timeline validates (:func:`validate_mapping` + memory
  trace, per segment) and survives a JSON round-trip;
* conservation — the last segment's durably completed prefix plus its
  residual equals the submitted work, and the completed prefix never
  shrinks;
* an empty timeline reproduces ``schedule(simulate=True)`` bit-exactly
  (the identity anchor);
* the service loop accounts for every submission
  (completed + infeasible + rejected == submitted).

Everything is a pure function of ``(seed, case index)`` — a corpus is
reproducible from its seed (``REPRO_FUZZ_SEED`` in the test tier,
``make fuzz`` for the large corpus).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field

from repro.core import Scheduler, SchedulerConfig
from repro.core.platform import Platform, ProcPower, Processor
from repro.core.workflows import generate_workflow

from .events import (
    LinkDegrade,
    PlatformEvent,
    ProcArrival,
    ProcFailure,
    SpeedChange,
    canonical_event_order,
    event_from_dict,
    validate_event_timeline,
)
from .report import TimelineReport
from .runner import Scenario, run_scenario

__all__ = [
    "FUZZ_POLICIES",
    "FuzzCase",
    "FuzzReport",
    "FuzzViolation",
    "fuzz_scenarios",
    "generate_case",
]

FUZZ_POLICIES = ("pinned-warm-start", "full-replan", "no-replan")

_FAMILIES = ("genome", "montage", "seismology", "blast", "epigenomics")


@dataclass(frozen=True)
class FuzzViolation:
    """One broken invariant: which case/policy, which invariant, how."""

    case: int
    seed: int
    policy: str
    invariant: str
    detail: str


@dataclass
class FuzzCase:
    """One generated scenario (pure function of ``(seed, index)``)."""

    index: int
    seed: int
    family: str
    n_tasks: int
    workflow: object
    platform: Platform
    events: list[PlatformEvent]

    @property
    def scenario(self) -> Scenario:
        return Scenario(self.workflow, self.platform, self.events,
                        name=f"fuzz-{self.seed}-{self.index}")


@dataclass
class FuzzReport:
    """Corpus outcome: ``checks`` invariant evaluations across
    ``n_cases`` scenarios; ``violations`` is empty on a clean corpus.
    ``per_policy`` counts violations by policy name (``"service"`` for
    the service-loop runs)."""

    seed: int
    n_cases: int
    checks: int = 0
    violations: list[FuzzViolation] = field(default_factory=list)
    per_policy: dict[str, int] = field(default_factory=dict)

    @property
    def passed(self) -> bool:
        return not self.violations

    def record(self, v: FuzzViolation) -> None:
        self.violations.append(v)
        self.per_policy[v.policy] = self.per_policy.get(v.policy, 0) + 1

    def summary(self) -> str:
        lines = [f"fuzz corpus seed={self.seed}: {self.n_cases} cases, "
                 f"{self.checks} invariant checks, "
                 f"{len(self.violations)} violation(s)"]
        for pol in sorted(set(self.per_policy) | set(FUZZ_POLICIES)
                          | {"service"}):
            lines.append(f"  {pol:>18}: {self.per_policy.get(pol, 0)}")
        for v in self.violations[:20]:
            lines.append(f"  [{v.invariant}] case {v.case} "
                         f"({v.policy}): {v.detail}")
        return "\n".join(lines)


# ---------------------------------------------------------------------- #
# generation
# ---------------------------------------------------------------------- #
def _random_platform(rng: random.Random) -> Platform:
    k = rng.randint(3, 6)
    procs = [Processor(f"fz{j}", rng.choice([0.5, 1.0, 1.0, 2.0]),
                       rng.choice([64.0, 128.0, 256.0]))
             for j in range(k)]
    plat = Platform(procs, bandwidth=rng.choice([0.5, 1.0, 2.0]),
                    name=f"fuzz-k{k}")
    # random failure model on a subset (rates small relative to the
    # horizon so most sampled failure times land inside the run)
    if rng.random() < 0.8:
        rates = {j: rng.uniform(1e-5, 1e-3) for j in range(k)
                 if rng.random() < 0.7}
        if rates:
            plat = plat.with_failure_rates(rates)
    if rng.random() < 0.5:
        plat = plat.with_power(
            {j: ProcPower(rng.uniform(0.1, 2.0), rng.uniform(0.5, 4.0))
             for j in range(k) if rng.random() < 0.8})
    return plat


def _sample_event(rng: random.Random, t: float, plat: Platform,
                  arrivals: int) -> PlatformEvent:
    kinds = ["speed_change", "link_degrade", "proc_arrival"]
    if plat.k > 1:
        kinds += ["proc_failure", "proc_failure"]
    kind = rng.choice(kinds)
    if kind == "proc_failure":
        n_fail = 1 if plat.k <= 2 else rng.choice([1, 1, 2])
        procs = frozenset(rng.sample(range(plat.k),
                                     min(n_fail, plat.k - 1)))
        return ProcFailure(time=t, procs=procs)
    if kind == "proc_arrival":
        return ProcArrival(time=t, procs=(
            Processor(f"fznew{arrivals}", rng.choice([1.0, 2.0]),
                      rng.choice([128.0, 256.0])),))
    if kind == "speed_change":
        return SpeedChange(time=t, proc=rng.randrange(plat.k),
                           factor=rng.choice([0.25, 0.5, 2.0]))
    i = rng.randrange(plat.k)
    j = (i + 1 + rng.randrange(plat.k - 1)) % plat.k if plat.k > 1 else i
    return LinkDegrade(time=t, src=i, dst=j,
                       bandwidth=rng.uniform(0.05, 0.5))


def _sample_timeline(rng: random.Random, plat: Platform,
                     scale: float) -> list[PlatformEvent]:
    """A valid timeline against ``plat``: times from the platform's own
    failure rates where present (rescaled into the run's horizon),
    events applied sequentially so every index is in range at its
    application time, occasional canonical simultaneous pairs."""
    if rng.random() < 0.3:
        return []
    events: list[PlatformEvent] = []
    cur = plat
    arrivals = 0
    t = 0.0
    for _ in range(rng.randint(1, 3)):
        lam_total = sum(cur.failure_rates.values())
        if lam_total > 0 and rng.random() < 0.6:
            # failure-trace draw, folded into the interesting window
            dt = rng.expovariate(lam_total) % (0.4 * scale)
        else:
            dt = rng.uniform(0.05, 0.4) * scale
        t += max(dt, 1e-6)
        ev = _sample_event(rng, t, cur, arrivals)
        events.append(ev)
        if isinstance(ev, ProcArrival):
            arrivals += 1
        cur, _ = ev.apply(cur)
        if rng.random() < 0.25:
            # deliberate tie: identity-map events only, so canonical
            # reordering within the timestamp cannot invalidate indices
            tie = SpeedChange(time=t, proc=rng.randrange(cur.k),
                              factor=rng.choice([0.5, 2.0]))
            events.append(tie)
            cur, _ = tie.apply(cur)
    events = canonical_event_order(events)
    validate_event_timeline(events)
    return events


def generate_case(seed: int, index: int) -> FuzzCase:
    """Deterministically generate fuzz case ``index`` of corpus
    ``seed``: a platform-feasible workflow, a modeled platform, and a
    canonical event timeline."""
    rng = random.Random(f"fuzz:{seed}:{index}")
    plat = _random_platform(rng)
    family = rng.choice(_FAMILIES)
    n_tasks = rng.randint(20, 60)
    wf = generate_workflow(family, n_tasks, seed=rng.randrange(2**31),
                           platform=plat)
    # time scale: total work over total speed lower-bounds the makespan
    scale = wf.total_work() / sum(p.speed for p in plat.procs)
    events = _sample_timeline(rng, plat, max(scale, 1.0))
    return FuzzCase(index=index, seed=seed, family=family, n_tasks=wf.n,
                    workflow=wf, platform=plat, events=events)


# ---------------------------------------------------------------------- #
# invariant checking
# ---------------------------------------------------------------------- #
def _check_timeline(rep: FuzzReport, case: FuzzCase, policy: str,
                    tl: TimelineReport, ref) -> None:
    def bad(invariant: str, detail: str) -> None:
        rep.record(FuzzViolation(case.index, case.seed, policy,
                                 invariant, detail))

    rep.checks += 1
    if not tl.feasible and tl.infeasibility is None:
        bad("structured-infeasibility",
            "infeasible timeline without an Infeasibility record")
    if not tl.feasible:
        return

    rep.checks += 1
    errors = tl.validate(memory_trace=True)
    if errors:
        bad("validate-mapping", "; ".join(errors[:3]))

    rep.checks += 1
    segs = tl.segments
    last = segs[-1]
    if last.completed_before + last.n_tasks != case.workflow.n:
        bad("conservation",
            f"completed {last.completed_before} + residual "
            f"{last.n_tasks} != submitted {case.workflow.n}")
    if any(b.completed_before < a.completed_before
           for a, b in zip(segs, segs[1:])):
        bad("conservation", "durably completed prefix shrank")

    rep.checks += 1
    rt = TimelineReport.from_json(tl.to_json())
    if (rt.makespan != tl.makespan or len(rt.segments) != len(segs)
            or len(rt.migrations) != len(tl.migrations)):
        bad("json-roundtrip", "timeline changed across to_json/from_json")

    if not case.events and ref is not None and ref.sim is not None:
        rep.checks += 1
        if tl.makespan != ref.sim.makespan:
            bad("empty-timeline-anchor",
                f"{tl.makespan!r} != schedule(simulate=True) "
                f"{ref.sim.makespan!r}")


def _check_events_roundtrip(rep: FuzzReport, case: FuzzCase) -> None:
    rep.checks += 1
    rebuilt = [event_from_dict(e.to_dict()) for e in case.events]
    if rebuilt != list(case.events):
        rep.record(FuzzViolation(
            case.index, case.seed, "timeline", "event-roundtrip",
            "events changed across to_dict/event_from_dict"))
        return
    try:
        validate_event_timeline(rebuilt)
    except Exception as exc:  # noqa: BLE001 — fuzz records, not raises
        rep.record(FuzzViolation(
            case.index, case.seed, "timeline", "event-roundtrip",
            f"round-tripped timeline no longer validates: {exc}"))


def _check_service(rep: FuzzReport, case: FuzzCase) -> None:
    from repro.service import Submission, run_service

    rep.checks += 1
    try:
        sr = run_service([Submission(case.workflow, name="fuzz")],
                         case.platform, case.events)
    except Exception as exc:  # noqa: BLE001
        rep.record(FuzzViolation(
            case.index, case.seed, "service", "uncaught-exception",
            f"{type(exc).__name__}: {exc}"))
        return
    jobs = sr.trace.jobs
    terminal = {"completed", "infeasible", "rejected"}
    if len(jobs) != 1 or any(j.status not in terminal for j in jobs):
        rep.record(FuzzViolation(
            case.index, case.seed, "service", "service-conservation",
            f"statuses {[j.status for j in jobs]} don't account for "
            f"the submission"))


def fuzz_scenarios(seed: int = 0, n: int = 25, *,
                   policies=FUZZ_POLICIES, service: bool = True,
                   config: SchedulerConfig | None = None,
                   price_migration: bool = False) -> FuzzReport:
    """Run an ``n``-case fuzz corpus derived from ``seed`` (see module
    docstring for the invariants).  Returns a :class:`FuzzReport`;
    ``report.passed`` is the corpus verdict and ``report.summary()``
    the per-policy violation breakdown.  ``price_migration`` forwards
    to :func:`run_scenario` so the checkpoint-pricing path gets fuzzed
    too."""
    cfg = config if config is not None else SchedulerConfig(simulate=True)
    rep = FuzzReport(seed=seed, n_cases=n)
    for i in range(n):
        case = generate_case(seed, i)
        _check_events_roundtrip(rep, case)
        try:
            ref = Scheduler(cfg).schedule(case.workflow, case.platform)
        except Exception as exc:  # noqa: BLE001
            rep.record(FuzzViolation(i, seed, "initial-plan",
                                     "uncaught-exception",
                                     f"{type(exc).__name__}: {exc}"))
            continue
        for pol in policies:
            try:
                tl = run_scenario(case.scenario, policy=pol, config=cfg,
                                  initial_report=ref,
                                  price_migration=price_migration)
            except Exception as exc:  # noqa: BLE001
                rep.record(FuzzViolation(i, seed, pol,
                                         "uncaught-exception",
                                         f"{type(exc).__name__}: {exc}"))
                continue
            _check_timeline(rep, case, pol, tl, ref)
        if service:
            _check_service(rep, case)
    return rep


def main(argv=None) -> int:
    """CLI for ``make fuzz``: run a corpus, print the per-policy
    violation breakdown, exit non-zero on any violation."""
    import argparse
    import os

    ap = argparse.ArgumentParser(
        description="seeded scenario-fuzzing corpus (repro.scenario.fuzz)")
    ap.add_argument("-n", type=int, default=150,
                    help="corpus size (default 150)")
    ap.add_argument("--seed", type=int,
                    default=int(os.environ.get("REPRO_FUZZ_SEED", "0")),
                    help="corpus seed (default: $REPRO_FUZZ_SEED or 0)")
    ap.add_argument("--price-migration", action="store_true",
                    help="fuzz the checkpoint-pricing replan path too")
    args = ap.parse_args(argv)
    rep = fuzz_scenarios(seed=args.seed, n=args.n,
                         price_migration=args.price_migration)
    print(rep.summary())
    return 0 if rep.passed else 1


if __name__ == "__main__":
    raise SystemExit(main())
