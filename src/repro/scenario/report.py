"""Structured results of a scenario run (:func:`repro.scenario.run_scenario`).

A :class:`TimelineReport` stitches one :class:`SegmentReport` per
planning epoch: segment 0 runs the initial plan from ``t = 0``, each
platform event closes the current segment (freezing its executed
prefix) and opens the next with the replanned residual.  The report
carries the end-to-end makespan, the per-segment
:class:`~repro.core.scheduler.ScheduleReport` /
:class:`~repro.sim.SimReport` pairs, the migration log, ``to_json`` /
``from_json``, and a stitched ASCII Gantt with event markers.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

from repro.core.baseline import MappingResult, validate_mapping
from repro.core.platform import Platform
from repro.core.scheduler import Infeasibility, ScheduleReport
from repro.sim.report import SimReport

__all__ = ["MigrationRecord", "SegmentReport", "TimelineReport"]


@dataclass
class MigrationRecord:
    """What one replanning epoch moved.

    ``moved`` counts tasks whose block had a *surviving* processor but
    ends up elsewhere (a true migration — data would move);
    ``displaced`` counts tasks whose processor disappeared (forced to
    move); ``restarted`` counts in-flight tasks whose partial execution
    was discarded (no checkpointing — the restart semantics), with
    ``lost_work`` the operations thrown away (elapsed time × speed).
    ``moves`` lists ``[from_proc_name, to_proc_name, n_tasks]``
    triples, keyed by stable processor *names* (indices shift across
    failures).  ``checkpoint_decisions`` carries the per-in-flight-block
    restart-vs-migrate pricing verdicts from
    :func:`~repro.scenario.runner.freeze_prefix` (``decision`` /
    ``restart_cost`` / ``migrate_cost`` / ``inputs_volume`` /
    ``applied`` per block).
    """

    time: float
    policy: str
    moved_tasks: int
    moved_blocks: int
    displaced_tasks: int
    displaced_blocks: int
    restarted_tasks: int
    restarted_blocks: int
    lost_work: float
    moves: list[list] = field(default_factory=list)
    checkpoint_decisions: list[dict] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "time": self.time, "policy": self.policy,
            "moved_tasks": self.moved_tasks,
            "moved_blocks": self.moved_blocks,
            "displaced_tasks": self.displaced_tasks,
            "displaced_blocks": self.displaced_blocks,
            "restarted_tasks": self.restarted_tasks,
            "restarted_blocks": self.restarted_blocks,
            "lost_work": self.lost_work,
            "moves": [list(m) for m in self.moves],
            "checkpoint_decisions": [dict(c)
                                     for c in self.checkpoint_decisions],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MigrationRecord":
        return cls(**d)


@dataclass
class SegmentReport:
    """One planning epoch of a scenario timeline.

    ``report`` / ``sim`` describe the *plan* for this epoch and its
    as-planned execution (times relative to ``t_start``);
    ``executed_until`` is the relative time the segment actually ran
    before the next event cut it short (``None``: ran to completion).
    ``task_ids[i]`` maps the segment workflow's task ``i`` back to the
    scenario workflow's id.  The live ``mapping`` / ``platform`` /
    ``workflow`` objects ride along for validation and are excluded
    from JSON.
    """

    index: int
    t_start: float
    event: dict | None              # event that opened this segment
    platform_name: str
    n_procs: int
    n_tasks: int
    completed_before: int           # scenario tasks done before t_start
    report: ScheduleReport
    sim: SimReport | None
    executed_until: float | None
    task_ids: list[int]
    mapping: MappingResult | None = field(
        default=None, repr=False, compare=False)
    platform: Platform | None = field(
        default=None, repr=False, compare=False)

    def to_dict(self) -> dict:
        return {
            "index": self.index,
            "t_start": self.t_start,
            "event": self.event,
            "platform_name": self.platform_name,
            "n_procs": self.n_procs,
            "n_tasks": self.n_tasks,
            "completed_before": self.completed_before,
            "report": self.report.to_dict(),
            "sim": self.sim.to_dict() if self.sim else None,
            "executed_until": self.executed_until,
            "task_ids": list(self.task_ids),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SegmentReport":
        return cls(
            index=d["index"],
            t_start=d["t_start"],
            event=d.get("event"),
            platform_name=d["platform_name"],
            n_procs=d["n_procs"],
            n_tasks=d["n_tasks"],
            completed_before=d["completed_before"],
            report=ScheduleReport.from_dict(d["report"]),
            sim=SimReport.from_dict(d["sim"]) if d.get("sim") else None,
            executed_until=d.get("executed_until"),
            task_ids=list(d.get("task_ids", [])),
        )


@dataclass
class TimelineReport:
    """End-to-end record of a scenario execution — see module docstring.

    ``makespan`` is the stitched completion time (``None`` when a
    replan came back infeasible: ``feasible`` is ``False`` and
    ``infeasibility`` / ``failed_at`` say why and when).
    ``replan_times_s[i]`` is the wall-clock latency of the replan after
    event group ``i`` — the cold-vs-warm number ``make bench-scenario``
    tracks.
    """

    scenario: str
    policy: str
    segments: list[SegmentReport]
    events: list[dict]
    migrations: list[MigrationRecord]
    makespan: float | None
    feasible: bool
    infeasibility: Infeasibility | None
    failed_at: float | None
    total_time_s: float
    replan_times_s: list[float] = field(default_factory=list)

    # -------------------------------------------------------------- #
    @property
    def n_replans(self) -> int:
        return len(self.replan_times_s)

    def to_dict(self) -> dict:
        return {
            "scenario": self.scenario,
            "policy": self.policy,
            "segments": [s.to_dict() for s in self.segments],
            "events": [dict(e) for e in self.events],
            "migrations": [m.to_dict() for m in self.migrations],
            "makespan": self.makespan,
            "feasible": self.feasible,
            "infeasibility": (self.infeasibility.to_dict()
                              if self.infeasibility else None),
            "failed_at": self.failed_at,
            "total_time_s": self.total_time_s,
            "replan_times_s": list(self.replan_times_s),
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "TimelineReport":
        return cls(
            scenario=d["scenario"],
            policy=d["policy"],
            segments=[SegmentReport.from_dict(s)
                      for s in d.get("segments", [])],
            events=[dict(e) for e in d.get("events", [])],
            migrations=[MigrationRecord.from_dict(m)
                        for m in d.get("migrations", [])],
            makespan=d.get("makespan"),
            feasible=d.get("feasible", False),
            infeasibility=(Infeasibility.from_dict(d["infeasibility"])
                           if d.get("infeasibility") else None),
            failed_at=d.get("failed_at"),
            total_time_s=d.get("total_time_s", 0.0),
            replan_times_s=list(d.get("replan_times_s", [])),
        )

    @classmethod
    def from_json(cls, s: str) -> "TimelineReport":
        return cls.from_dict(json.loads(s))

    # -------------------------------------------------------------- #
    def validate(self, *, memory_trace: bool = True) -> list[str]:
        """All constraint violations across segments (empty = clean).

        Each segment's plan is checked with
        :func:`repro.core.baseline.validate_mapping` against its own
        residual workflow and platform — the acceptance gate a stitched
        timeline must pass.
        """
        errors: list[str] = []
        for seg in self.segments:
            if seg.mapping is None:
                errors.append(
                    f"segment {seg.index}: live mapping unavailable "
                    "(deserialized report?)"
                )
                continue
            wf = seg.mapping.quotient.wf
            for e in validate_mapping(wf, seg.mapping,
                                      memory_trace=memory_trace):
                errors.append(f"segment {seg.index}: {e}")
        return errors

    # -------------------------------------------------------------- #
    def gantt(self, width: int = 72) -> str:
        """Stitched ASCII Gantt: rows are processors (stable names),
        columns span ``[0, makespan]``; ``▼`` ruler marks events.

        ``█`` executed compute, ``░`` in-flight work cut off by an
        event (restarted in the next segment), ``·`` idle.
        """
        if not self.segments:
            return "(no segments)"
        horizon = self.makespan
        if horizon is None:
            last = self.segments[-1]
            horizon = last.t_start + (last.executed_until
                                      or (last.sim.horizon
                                          if last.sim else 0.0))
        h = horizon if horizon > 0 else 1.0

        def col(t: float) -> int:
            return min(int(t / h * width), width - 1)

        rows: dict[str, list[str]] = {}
        order: list[str] = []

        def row(name: str) -> list[str]:
            if name not in rows:
                rows[name] = ["·"] * width
                order.append(name)
            return rows[name]

        for seg in self.segments:
            if seg.sim is None:
                continue
            cut = seg.executed_until
            names = {p.proc: p.name for p in seg.sim.procs}
            for vid, p in seg.sim.block_proc.items():
                s = seg.sim.block_start[vid]
                f = seg.sim.block_finish[vid]
                if cut is not None and s >= cut:
                    continue  # never started in this epoch
                mark = "█"
                if cut is not None and f > cut:
                    f = cut   # in-flight at the event: lost/restarted
                    mark = "░"
                a = col(seg.t_start + s)
                b = max(a + 1, min(int(math.ceil(
                    (seg.t_start + f) / h * width)), width))
                r = row(names.get(p, f"p{p}"))
                for x in range(a, b):
                    r[x] = mark
                label = str(vid)
                if mark == "█" and b - a >= len(label) + 2:
                    r[a + 1:a + 1 + len(label)] = label

        ruler = [" "] * width
        for e in self.events:
            t = e.get("time")
            if t is not None and t <= h:
                ruler[col(t)] = "▼"
        lines = [f"{'':>14s}t=0{'':{max(width - 11, 1)}s}t={h:.6g}"]
        if any(c != " " for c in ruler):
            lines.append(f"{'events':>12.12s}  {''.join(ruler)}")
        for name in order:
            lines.append(f"{name:>12.12s} |{''.join(rows[name])}|")
        legend = [f"t={e['time']:g}: {e.get('detail', e['kind'])}"
                  for e in self.events]
        if legend:
            lines.append("  ▼ " + "; ".join(legend))
        return "\n".join(lines)
