"""Execute a workflow through a timeline of platform events.

:func:`run_scenario` drives the full loop the paper's static story
stops short of: plan → execute (:mod:`repro.sim`) → **pause** at the
next :class:`~repro.scenario.events.PlatformEvent` → freeze the
executed prefix → extract the residual DAG → replan under the chosen
policy → repeat — then stitches the epochs into a
:class:`~repro.scenario.report.TimelineReport`.

Execution semantics (the restart model):

* **output files are the unit of durability**: a block is *completed*
  once its compute interval ended **and** every outbound transfer has
  landed by the event (and, transitively, its whole quotient ancestry
  is completed) — only then do its tasks leave the workflow for good,
  never to be reassigned, and its boundary outputs count as
  materialized at their consumers (folded into task memory, not
  re-transferred);
* every other started block — mid-compute *or* with outputs still in
  transit — is *in flight*: its partial work is lost and it restarts
  in the next epoch (there is no checkpointing; pricing
  checkpoint-aware migration is a ROADMAP follow-on).  An in-flight
  transfer is never silently dropped: either its producer completes
  the durability rule or the producer re-executes and re-sends.
  :class:`~repro.scenario.policies.PinnedWarmStart` pins in-flight
  blocks to their processor, so the restart at least never pays a
  migration;
* unstarted blocks carry over; whether they keep their assignment is
  the policy's call.

Identity anchor: with an empty event timeline the single segment *is*
``Scheduler(config).schedule(wf, platform)`` — same best makespan,
same simulated makespan, bit-exactly.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence

from repro.core.dag import Workflow
from repro.core.platform import Platform
from repro.core.scheduler import ResumeState, Scheduler, SchedulerConfig
from repro.core.workflows import residual_workflow
from repro.obs.tracer import trace_span
from repro.sim import build_specs, resolve_comm, run_engine, simulate

from .events import PlatformEvent, validate_event_timeline
from .policies import resolve_policy
from .report import MigrationRecord, SegmentReport, TimelineReport

__all__ = ["FrozenPrefix", "Scenario", "apply_event_group",
           "freeze_prefix", "run_scenario"]


@dataclass
class Scenario:
    """A workflow, a platform, and what happens to the platform when.

    The event timeline must be **sorted by time** with finite,
    non-negative times — construction validates this and raises a
    structured :class:`~repro.scenario.events.EventTimelineError`
    otherwise (the :mod:`repro.service` event loop relies on the same
    invariant).  Execution pauses the simulation once per distinct
    event time (ties apply sequentially in listed order).  Processor
    indices in an event refer to the platform *as of that event's
    application*: after a ``ProcFailure``, later events (including
    same-instant ones) see the compacted indexing — compose through
    the ``proc_map`` each ``apply`` returns when building timelines
    programmatically.
    """

    workflow: Workflow
    platform: Platform
    events: Sequence[PlatformEvent] = ()
    name: str = ""

    def __post_init__(self) -> None:
        self.events = tuple(self.events)
        validate_event_timeline(self.events)
        if not self.name:
            self.name = f"{self.workflow.name}@{self.platform.name}"


def _frozen_blocks(trace, q) -> set[int]:
    """Blocks durably completed at the pause: compute finished, every
    outbound transfer landed, and (transitively) the same holds for
    the whole quotient ancestry — so the completed *task* set is
    closed under predecessors and no in-flight transfer is dropped."""
    done = {
        v for v in trace.finish
        if all((v, w) in trace.xfer_finish for w in q.succ[v])
    }
    # Fixpoint demotion: a delivered block below an undelivered
    # ancestor restarts too (rare: needs one producer transfer landed
    # and a sibling transfer still in flight).  Keeps closure exact.
    changed = True
    while changed:
        changed = False
        for v in sorted(done):
            if any(p not in done for p in q.pred[v]):
                done.discard(v)
                changed = True
    return done


def _event_groups(
    events: Sequence[PlatformEvent],
) -> list[list[PlatformEvent]]:
    """Events sorted by time, grouped per distinct time (one pause +
    one replan per group, however many events share the instant)."""
    ordered = sorted(events, key=lambda e: e.time)
    groups: list[list[PlatformEvent]] = []
    for e in ordered:
        if groups and groups[-1][0].time == e.time:
            groups[-1].append(e)
        else:
            groups.append([e])
    return groups


def apply_event_group(
    group: Sequence[PlatformEvent], platform: Platform,
) -> tuple[Platform, dict[int, int | None]]:
    """Apply same-instant events sequentially; return the new platform
    and the composed old-index → new-index map (``None`` = gone)."""
    new_platform = platform
    proc_map: dict[int, int | None] = {j: j for j in range(platform.k)}
    for ev in group:
        new_platform, m = ev.apply(new_platform)
        proc_map = {j: (m[pj] if pj is not None else None)
                    for j, pj in proc_map.items()}
    return new_platform, proc_map


@dataclass
class FrozenPrefix:
    """What :func:`freeze_prefix` extracted at a pause point.

    ``state`` is ready for :meth:`Scheduler.resume
    <repro.core.scheduler.Scheduler.resume>`; ``sub_map`` maps each
    residual task index back to the paused workflow's task id;
    ``completed_local`` are the paused workflow's durably completed
    task ids; the remaining fields are restart accounting for
    migration records.
    """

    state: ResumeState
    sub_map: list[int]
    completed_local: set[int]
    completed_vids: set[int]
    inflight_vids: set[int]
    old_names: list[str]
    restarted_tasks: int
    restarted_blocks: int
    lost_work: float
    #: checkpoint-pricing decisions for the in-flight blocks (one dict
    #: per surviving started block: restart-in-place vs migrate its
    #: materialized inputs; ``applied`` says whether the verdict
    #: changed pinning) — lands on the MigrationRecord
    checkpoint_decisions: list[dict] = field(default_factory=list)


def freeze_prefix(
    wf: Workflow,
    mapping,
    platform: Platform,
    rel: float,
    new_platform: Platform,
    proc_map: dict[int, int | None],
    *,
    comm="contention-free",
    price_migration: bool = False,
) -> FrozenPrefix:
    """Pause ``mapping``'s execution on ``platform`` at ``rel`` (time
    since this plan started), freeze the durably completed prefix, and
    build the warm-start :class:`ResumeState` on ``new_platform``.

    This is the pause-replan-stitch core shared by
    :func:`run_scenario` (one workflow, platform timeline) and the
    :mod:`repro.service` event loop (many jobs, one shared platform —
    each affected job is frozen against its own sub-platform).
    ``proc_map`` carries assignments across the event
    (old index → new index, ``None`` for a lost processor); in-flight
    blocks restart, and survive *pinned* to their processor.

    Every surviving in-flight block is also *priced*: restart-in-place
    on its (possibly slowed) processor vs. migrating — re-transferring
    its already-materialized inputs (edge volumes from completed
    producer blocks) to the best other processor and recomputing there.
    The verdicts land in ``checkpoint_decisions`` (and on the
    :class:`~repro.scenario.report.MigrationRecord`); with
    ``price_migration=True`` a migrate-wins block is left *unpinned* so
    the replan may actually move it.  The default keeps the historical
    always-pin behaviour — pricing is then advisory only.  Execution
    stays restart-based either way (no partial-block state is carried);
    the pricing models where the restart happens, not a mid-block
    checkpoint image.
    """
    with trace_span("scenario.freeze", rel=rel):
        return _freeze_prefix(wf, mapping, platform, rel, new_platform,
                              proc_map, comm=comm,
                              price_migration=price_migration)


def _freeze_prefix(
    wf: Workflow,
    mapping,
    platform: Platform,
    rel: float,
    new_platform: Platform,
    proc_map: dict[int, int | None],
    *,
    comm="contention-free",
    price_migration: bool = False,
) -> FrozenPrefix:
    q = mapping.quotient
    blocks, edges = build_specs(q, platform)
    trace = run_engine(blocks, edges, resolve_comm(comm), platform,
                       record_events=False, stop_time=rel)
    completed_vids = _frozen_blocks(trace, q)
    inflight_vids = set(trace.start) - completed_vids

    completed_local: set[int] = set()
    for vid in completed_vids:
        completed_local |= q.members[vid]
    sub, sub_map = residual_workflow(wf, completed_local)
    inv = {u: i for i, u in enumerate(sub_map)}
    res_blocks: list[list[int]] = []
    res_procs: list[int | None] = []
    old_names: list[str] = []
    pinned: set[int] = set()
    restarted_tasks = restarted_blocks = 0
    lost_work = 0.0
    decisions: list[dict] = []
    # materialized inputs per in-flight block: edge volumes whose
    # producer block durably completed — what a migration re-transfers
    inputs_vol = {vid: 0.0 for vid in inflight_vids}
    for e in edges:
        if e.dst in inputs_vol and e.src in completed_vids:
            inputs_vol[e.dst] += e.volume
    for vid in sorted(q.members):
        if vid in completed_vids:
            continue
        members = sorted(inv[u] for u in q.members[vid])
        old_pj = q.proc[vid]
        new_pj = proc_map.get(old_pj)
        b = len(res_blocks)
        res_blocks.append(members)
        res_procs.append(new_pj)
        old_names.append(platform.procs[old_pj].name)
        if vid in inflight_vids:
            restarted_blocks += 1
            restarted_tasks += len(members)
            # compute time thrown away (capped at the full duration
            # for delivered-but-undurable blocks)
            elapsed = (min(rel, trace.finish.get(vid, rel))
                       - trace.start[vid])
            lost_work += elapsed * platform.procs[old_pj].speed
            if new_pj is not None:
                # price restart-in-place vs migrate-with-inputs on the
                # post-event platform
                w = q.weight[vid]
                vol = inputs_vol[vid]
                restart_cost = w / new_platform.procs[new_pj].speed
                migrate_cost = None
                migrate_to = None
                for j in range(new_platform.k):
                    if j == new_pj:
                        continue
                    c = (w / new_platform.procs[j].speed
                         + vol / new_platform.bandwidth_between(new_pj, j))
                    if migrate_cost is None or c < migrate_cost:
                        migrate_cost, migrate_to = c, j
                verdict = ("migrate" if migrate_cost is not None
                           and migrate_cost < restart_cost
                           else "restart-in-place")
                applied = price_migration and verdict == "migrate"
                decisions.append({
                    "block": b, "tasks": len(members),
                    "proc": new_platform.procs[new_pj].name,
                    "inputs_volume": vol,
                    "restart_cost": restart_cost,
                    "migrate_cost": migrate_cost,
                    "migrate_to": (new_platform.procs[migrate_to].name
                                   if migrate_to is not None else None),
                    "decision": verdict,
                    "applied": applied,
                })
                if not applied:
                    pinned.add(b)
    state = ResumeState(wf=sub, platform=new_platform,
                        blocks=res_blocks, proc_of_block=res_procs,
                        pinned=pinned)
    return FrozenPrefix(
        state=state, sub_map=list(sub_map),
        completed_local=completed_local,
        completed_vids=completed_vids, inflight_vids=inflight_vids,
        old_names=old_names, restarted_tasks=restarted_tasks,
        restarted_blocks=restarted_blocks, lost_work=lost_work,
        checkpoint_decisions=decisions,
    )


def _group_dict(group: list[PlatformEvent]) -> dict:
    if len(group) == 1:
        return group[0].to_dict()
    return {
        "time": group[0].time,
        "kind": "+".join(e.kind for e in group),
        "detail": "; ".join(e.describe() for e in group),
        "events": [e.to_dict() for e in group],
    }


def _migration_record(
    te: float,
    policy_name: str,
    state: ResumeState,
    old_names: list[str],
    report,
    new_platform: Platform,
    restarted_tasks: int,
    restarted_blocks: int,
    lost_work: float,
    checkpoint_decisions: list[dict] | None = None,
) -> MigrationRecord:
    moved_tasks = moved_blocks = 0
    displaced_tasks = displaced_blocks = 0
    moves: dict[tuple[str, str], int] = {}
    if report.feasible:
        q2 = report.best.quotient
        new_name_of_task: dict[int, str] = {}
        for vid, members in q2.members.items():
            nm = new_platform.procs[q2.proc[vid]].name
            for u in members:
                new_name_of_task[u] = nm
        for b, members in enumerate(state.blocks):
            old_name = old_names[b]
            survived = state.proc_of_block[b] is not None
            block_moved = False
            for u in members:
                nn = new_name_of_task[u]
                if nn != old_name:
                    block_moved = True
                    moves[(old_name, nn)] = moves.get((old_name, nn),
                                                      0) + 1
                    if survived:
                        moved_tasks += 1
                    else:
                        displaced_tasks += 1
            if block_moved:
                if survived:
                    moved_blocks += 1
                else:
                    displaced_blocks += 1
    return MigrationRecord(
        time=te, policy=policy_name,
        moved_tasks=moved_tasks, moved_blocks=moved_blocks,
        displaced_tasks=displaced_tasks,
        displaced_blocks=displaced_blocks,
        restarted_tasks=restarted_tasks,
        restarted_blocks=restarted_blocks,
        lost_work=lost_work,
        moves=[[a, b, n] for (a, b), n in sorted(moves.items())],
        checkpoint_decisions=list(checkpoint_decisions or []),
    )


def run_scenario(
    scenario: Scenario,
    policy="pinned-warm-start",
    *,
    config: SchedulerConfig | None = None,
    sim_options: dict | None = None,
    initial_report=None,
    price_migration: bool = False,
) -> TimelineReport:
    """Execute ``scenario`` under ``policy``; see module docstring.

    ``config`` drives every Scheduler invocation (initial plan, cold
    replans, warm starts alike).  ``sim_options`` feed the per-segment
    simulations (``comm=...``, ``jitter=...``); when
    ``config.simulate`` is set, the scheduler's own ``sim_options``
    win and the pipeline-attached :class:`~repro.sim.SimReport` is
    reused instead of re-simulating.  The headline traces stay
    deterministic either way, so where an execution pauses never
    depends on jitter replicas.  ``initial_report`` short-circuits the
    segment-0 plan with a precomputed
    :class:`~repro.core.scheduler.ScheduleReport` for this exact
    workflow/platform (policy sweeps over one scenario replan from the
    same start without re-running the k' sweep each time).
    ``price_migration=True`` lets the checkpoint pricing in
    :func:`freeze_prefix` unpin in-flight blocks whose materialized
    inputs are cheaper to move than to recompute in place; the verdicts
    appear in the migration log either way.
    """
    t_wall = time.perf_counter()
    cfg = config if config is not None else SchedulerConfig()
    pol = resolve_policy(policy)
    # When the pipeline simulates (cfg.simulate), its sim_options — even
    # the empty default — govern the pause engine too, so the frozen
    # prefix is always classified under the same comm model as the
    # reused report.sim.
    sim_kw = dict(cfg.sim_options or {}) if cfg.simulate \
        else dict(sim_options or {})

    wf = scenario.workflow
    platform = scenario.platform
    task_ids = list(range(wf.n))
    completed_total = 0
    events = _event_groups(scenario.events)
    event_dicts = [e.to_dict()
                   for e in sorted(scenario.events, key=lambda e: e.time)]
    segments: list[SegmentReport] = []
    migrations: list[MigrationRecord] = []
    replan_times: list[float] = []
    seg_event: dict | None = None
    infeas = None
    failed_at: float | None = None
    t = 0.0

    report = (initial_report if initial_report is not None
              else Scheduler(cfg).schedule(wf, platform))
    if not report.feasible:
        return TimelineReport(
            scenario=scenario.name, policy=pol.name, segments=[],
            events=event_dicts, migrations=[], makespan=None,
            feasible=False, infeasibility=report.infeasibility,
            failed_at=0.0, total_time_s=time.perf_counter() - t_wall,
        )

    carry_sim = None
    for group in events:
        te = group[0].time
        res = report.best
        seg_sim = report.sim if report.sim is not None else simulate(
            res, platform, **sim_kw)
        rel = te - t
        if rel >= seg_sim.horizon:
            # the plan completes before the event fires: the remaining
            # timeline cannot affect this workflow
            carry_sim = seg_sim  # final segment reuses it
            break

        segments.append(SegmentReport(
            index=len(segments), t_start=t, event=seg_event,
            platform_name=platform.name, n_procs=platform.k,
            n_tasks=wf.n, completed_before=completed_total,
            report=report, sim=seg_sim, executed_until=rel,
            task_ids=task_ids, mapping=res, platform=platform,
        ))

        # -- apply the event group, pause, freeze, extract --------- #
        new_platform, proc_map = apply_event_group(group, platform)
        fz = freeze_prefix(
            wf, res, platform, rel, new_platform, proc_map,
            comm=sim_kw.get("comm", "contention-free"),
            price_migration=price_migration)
        completed_total += len(fz.completed_local)
        state = fz.state

        # -- replan ------------------------------------------------ #
        t0 = time.perf_counter()
        with trace_span("scenario.replan", policy=pol.name, t_event=te):
            report = pol.replan(state, cfg)
        replan_times.append(time.perf_counter() - t0)
        migrations.append(_migration_record(
            te, pol.name, state, fz.old_names, report, new_platform,
            fz.restarted_tasks, fz.restarted_blocks, fz.lost_work,
            fz.checkpoint_decisions))

        t = te
        wf = state.wf
        task_ids = [task_ids[u] for u in fz.sub_map]
        platform = new_platform
        seg_event = _group_dict(group)
        if not report.feasible:
            infeas = report.infeasibility
            failed_at = te
            break

    if infeas is None:
        res = report.best
        seg_sim = (report.sim if report.sim is not None
                   else carry_sim if carry_sim is not None
                   else simulate(res, platform, **sim_kw))
        segments.append(SegmentReport(
            index=len(segments), t_start=t, event=seg_event,
            platform_name=platform.name, n_procs=platform.k,
            n_tasks=wf.n, completed_before=completed_total,
            report=report, sim=seg_sim, executed_until=None,
            task_ids=task_ids, mapping=res, platform=platform,
        ))
        makespan = t + seg_sim.makespan
        feasible = True
    else:
        makespan = None
        feasible = False

    return TimelineReport(
        scenario=scenario.name, policy=pol.name, segments=segments,
        events=event_dicts, migrations=migrations, makespan=makespan,
        feasible=feasible, infeasibility=infeas, failed_at=failed_at,
        total_time_s=time.perf_counter() - t_wall,
        replan_times_s=replan_times,
    )
