"""repro.scenario — event-driven platform timelines with replanning.

The paper maps a workflow onto a *static* heterogeneous platform; this
subsystem makes the platform a timeline.  A :class:`Scenario` is a
workflow + platform + ordered :class:`PlatformEvent` list
(:class:`ProcFailure`, :class:`ProcArrival`, :class:`SpeedChange`,
:class:`LinkDegrade`), and :func:`run_scenario` executes it end to
end: simulate (:mod:`repro.sim`), pause at each event
(``run_engine(..., stop_time=t)``), freeze the completed prefix,
extract the residual DAG
(:func:`repro.core.workflows.residual_workflow`), replan under a
pluggable policy, and stitch the epochs into a
:class:`TimelineReport`::

    from repro.scenario import ProcFailure, Scenario, run_scenario
    sc = Scenario(wf, platform, [ProcFailure(time=40.0, procs={3, 7})])
    tl = run_scenario(sc, policy="pinned-warm-start")
    tl.makespan                  # stitched end-to-end completion time
    tl.migrations[0].moved_tasks # what the replan moved
    print(tl.gantt())            # event markers + restarted work

Replan policies (:mod:`repro.scenario.policies`):
``"pinned-warm-start"`` — :meth:`repro.core.scheduler.Scheduler.resume`
with the inherited partition, surviving assignments kept and in-flight
blocks pinned; ``"full-replan"`` — cold reschedule of the residual
(the quality ceiling warm-starting is measured against);
``"no-replan"`` — keep the plan verbatim (structured infeasibility
when it needed a lost processor).  ``make bench-scenario`` tracks the
replan-latency and makespan gaps between them.

An empty event timeline reproduces ``Scheduler(config).schedule(wf,
platform)`` bit-exactly — the subsystem's identity anchor.

:mod:`repro.scenario.fuzz` turns these invariants into a harness:
:func:`fuzz_scenarios` generates seeded random workflows/platforms/
timelines (failure traces drawn from ``Platform.failure_rates``,
simultaneous events in the canonical order of
:func:`event_sort_key`) and drives every policy plus the service loop
through them — ``make fuzz`` runs the large corpus.  Simultaneous
events are ordered canonically (``validate_event_timeline`` rejects
other permutations with code ``"unsorted-tie"``), so timelines replay
identically from JSON round-trips; :func:`canonical_event_order` sorts
any event list into the accepted order.
"""
from __future__ import annotations

from .events import (
    EventTimelineError,
    LinkDegrade,
    PlatformEvent,
    ProcArrival,
    ProcFailure,
    SpeedChange,
    canonical_event_order,
    event_from_dict,
    event_sort_key,
    validate_event_timeline,
)
from .fuzz import (
    FUZZ_POLICIES,
    FuzzCase,
    FuzzReport,
    FuzzViolation,
    fuzz_scenarios,
    generate_case,
)
from .policies import (
    FullReplan,
    NoReplan,
    PinnedWarmStart,
    ReplanPolicy,
    resolve_policy,
)
from .report import MigrationRecord, SegmentReport, TimelineReport
from .runner import (
    FrozenPrefix,
    Scenario,
    apply_event_group,
    freeze_prefix,
    run_scenario,
)

__all__ = [
    "EventTimelineError",
    "FUZZ_POLICIES",
    "FrozenPrefix",
    "FullReplan",
    "FuzzCase",
    "FuzzReport",
    "FuzzViolation",
    "LinkDegrade",
    "MigrationRecord",
    "NoReplan",
    "PinnedWarmStart",
    "PlatformEvent",
    "ProcArrival",
    "ProcFailure",
    "ReplanPolicy",
    "Scenario",
    "SegmentReport",
    "SpeedChange",
    "TimelineReport",
    "apply_event_group",
    "canonical_event_order",
    "event_from_dict",
    "event_sort_key",
    "freeze_prefix",
    "fuzz_scenarios",
    "generate_case",
    "resolve_policy",
    "run_scenario",
    "validate_event_timeline",
]
