"""repro.scenario — event-driven platform timelines with replanning.

The paper maps a workflow onto a *static* heterogeneous platform; this
subsystem makes the platform a timeline.  A :class:`Scenario` is a
workflow + platform + ordered :class:`PlatformEvent` list
(:class:`ProcFailure`, :class:`ProcArrival`, :class:`SpeedChange`,
:class:`LinkDegrade`), and :func:`run_scenario` executes it end to
end: simulate (:mod:`repro.sim`), pause at each event
(``run_engine(..., stop_time=t)``), freeze the completed prefix,
extract the residual DAG
(:func:`repro.core.workflows.residual_workflow`), replan under a
pluggable policy, and stitch the epochs into a
:class:`TimelineReport`::

    from repro.scenario import ProcFailure, Scenario, run_scenario
    sc = Scenario(wf, platform, [ProcFailure(time=40.0, procs={3, 7})])
    tl = run_scenario(sc, policy="pinned-warm-start")
    tl.makespan                  # stitched end-to-end completion time
    tl.migrations[0].moved_tasks # what the replan moved
    print(tl.gantt())            # event markers + restarted work

Replan policies (:mod:`repro.scenario.policies`):
``"pinned-warm-start"`` — :meth:`repro.core.scheduler.Scheduler.resume`
with the inherited partition, surviving assignments kept and in-flight
blocks pinned; ``"full-replan"`` — cold reschedule of the residual
(the quality ceiling warm-starting is measured against);
``"no-replan"`` — keep the plan verbatim (structured infeasibility
when it needed a lost processor).  ``make bench-scenario`` tracks the
replan-latency and makespan gaps between them.

An empty event timeline reproduces ``Scheduler(config).schedule(wf,
platform)`` bit-exactly — the subsystem's identity anchor.
"""
from __future__ import annotations

from .events import (
    EventTimelineError,
    LinkDegrade,
    PlatformEvent,
    ProcArrival,
    ProcFailure,
    SpeedChange,
    event_from_dict,
    validate_event_timeline,
)
from .policies import (
    FullReplan,
    NoReplan,
    PinnedWarmStart,
    ReplanPolicy,
    resolve_policy,
)
from .report import MigrationRecord, SegmentReport, TimelineReport
from .runner import (
    FrozenPrefix,
    Scenario,
    apply_event_group,
    freeze_prefix,
    run_scenario,
)

__all__ = [
    "EventTimelineError",
    "FrozenPrefix",
    "FullReplan",
    "LinkDegrade",
    "MigrationRecord",
    "NoReplan",
    "PinnedWarmStart",
    "PlatformEvent",
    "ProcArrival",
    "ProcFailure",
    "ReplanPolicy",
    "Scenario",
    "SegmentReport",
    "SpeedChange",
    "TimelineReport",
    "apply_event_group",
    "event_from_dict",
    "freeze_prefix",
    "resolve_policy",
    "run_scenario",
    "validate_event_timeline",
]
