"""Replan policies: what to do with the residual plan at an event.

A policy turns a :class:`~repro.core.scheduler.ResumeState` (residual
workflow + inherited partition + new platform) into the next segment's
:class:`~repro.core.scheduler.ScheduleReport`:

* :class:`PinnedWarmStart` — ``Scheduler.resume``: inherit the
  partition, keep surviving assignments, pin in-flight blocks, repair
  orphans via Step 3, pin-aware Step-4 refinement.  The cheap reaction.
* :class:`FullReplan` — cold ``Scheduler.schedule`` of the residual on
  the new platform (full k' sweep).  The quality ceiling; what
  warm-starting is measured against.
* :class:`NoReplan` — keep the inherited assignment verbatim (only the
  platform changed under it).  Structurally infeasible when an event
  removed a processor the plan still needs — the do-nothing baseline.

Policies are resolved by name (:func:`resolve_policy`); any object with
``name`` and ``replan(state, config)`` works.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Protocol, runtime_checkable

from repro.core.scheduler import (
    ResumeState,
    ScheduleReport,
    Scheduler,
    SchedulerConfig,
)

__all__ = [
    "FullReplan",
    "NoReplan",
    "PinnedWarmStart",
    "ReplanPolicy",
    "resolve_policy",
]


@runtime_checkable
class ReplanPolicy(Protocol):
    """Protocol: produce the next segment's plan from a resume state."""

    name: str

    def replan(self, state: ResumeState,
               config: SchedulerConfig) -> ScheduleReport: ...


class PinnedWarmStart:
    """Warm-start replan; never moves completed or in-flight work.

    A warm start inherits the old partition and cannot split blocks, so
    a displaced block may have no feasible home even when a cold replan
    would find one (splitting displaced blocks FitBlock-style is a
    ROADMAP follow-on).  ``cold_fallback=True`` escalates exactly that
    case to a :class:`FullReplan` instead of reporting infeasibility —
    pins are forfeited, but the scenario completes.
    """

    def __init__(self, cold_fallback: bool = False) -> None:
        self.cold_fallback = cold_fallback
        self.name = ("pinned-warm-start+cold-fallback" if cold_fallback
                     else "pinned-warm-start")

    def replan(self, state: ResumeState,
               config: SchedulerConfig) -> ScheduleReport:
        report = Scheduler(config).resume(state)
        if not report.feasible and self.cold_fallback:
            return Scheduler(config).schedule(state.wf, state.platform)
        return report


class FullReplan:
    """Cold replan of the residual (ignores the inherited partition)."""

    name = "full-replan"

    def replan(self, state: ResumeState,
               config: SchedulerConfig) -> ScheduleReport:
        return Scheduler(config).schedule(state.wf, state.platform)


class NoReplan:
    """Keep the inherited plan as-is, re-priced on the new platform.
    Merge/refinement stages are skipped, so any block whose processor
    disappeared surfaces as a structured infeasibility.  Like the other
    policies, the pipeline attaches a fresh :class:`~repro.sim.SimReport`
    only when ``config.simulate`` is on — :func:`~repro.scenario.run_scenario`
    simulates kept segments itself otherwise."""

    name = "no-replan"

    def replan(self, state: ResumeState,
               config: SchedulerConfig) -> ScheduleReport:
        cfg = replace(config, stages=("warm_start", "simulate"))
        return Scheduler(cfg).resume(state)


_POLICIES = {
    "pinned-warm-start": PinnedWarmStart,
    "warm-start": PinnedWarmStart,
    "warm": PinnedWarmStart,
    "pinned-warm-start+cold-fallback":
        lambda: PinnedWarmStart(cold_fallback=True),
    "warm+fallback": lambda: PinnedWarmStart(cold_fallback=True),
    "full-replan": FullReplan,
    "cold": FullReplan,
    "no-replan": NoReplan,
    "static": NoReplan,
}


def resolve_policy(policy) -> ReplanPolicy:
    """A policy instance from a name, class or ready instance."""
    if isinstance(policy, str):
        try:
            return _POLICIES[policy]()
        except KeyError:
            raise ValueError(
                f"unknown policy {policy!r}; known: "
                f"{sorted(set(_POLICIES))}"
            ) from None
    if isinstance(policy, type):
        return policy()
    return policy
