from .pipeline import DataConfig, Prefetcher, SyntheticTokens, host_slice

__all__ = ["DataConfig", "Prefetcher", "SyntheticTokens", "host_slice"]
