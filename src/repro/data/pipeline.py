"""Input pipeline: deterministic synthetic token streams with sharded
per-host feeding and background prefetch.

Production shape: each host materializes only its slice of the global
batch (``host_slice``), double-buffered by a prefetch thread.  The
synthetic source is seeded per (step, host) so restarts reproduce the
same stream — checkpoint/restart tests rely on this.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

__all__ = ["DataConfig", "SyntheticTokens", "Prefetcher", "host_slice"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    frontend_dim: int = 0


def host_slice(global_batch: int, host_id: int, n_hosts: int) -> slice:
    if global_batch % n_hosts:
        raise ValueError(
            f"global batch {global_batch} not divisible by {n_hosts} hosts")
    per = global_batch // n_hosts
    return slice(host_id * per, (host_id + 1) * per)


class SyntheticTokens:
    """Deterministic synthetic LM batches (tokens, labels[, frontend])."""

    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 n_hosts: int = 1) -> None:
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.sl = host_slice(cfg.global_batch, host_id, n_hosts)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 97 + self.host_id)
        b = self.sl.stop - self.sl.start
        # zipfian-ish marginal over the vocab, like real text
        z = rng.zipf(1.3, size=(b, cfg.seq_len + 1))
        tokens = np.minimum(z, cfg.vocab_size - 1).astype(np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if cfg.frontend_tokens:
            out["frontend"] = rng.standard_normal(
                (b, cfg.frontend_tokens, cfg.frontend_dim)
            ).astype(np.float32)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread double buffering around any batch iterator."""

    def __init__(self, it, depth: int = 2) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None
        self._stop = threading.Event()

        def worker():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001 - surfaced on get
                self._err = e
                self._q.put(None)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def get(self):
        item = self._q.get()
        if item is None and self._err is not None:
            raise self._err
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
