import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the
# device count on first init); everything else follows.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent:

* ``jax.jit(step).lower(**input_specs).compile()`` succeeds on the
  single-pod (16, 16) mesh and the 2-pod (2, 16, 16) mesh,
* ``compiled.memory_analysis()`` fits the per-chip HBM budget,
* ``compiled.cost_analysis()`` + post-SPMD collective parsing produce
  the roofline terms (compute / memory / collective).

Results are cached as JSON under ``experiments/dryrun/`` — benchmarks
and EXPERIMENTS.md §Dry-run/§Roofline read from there.

Usage::

    python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import logging
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    build_prefill_step,
    build_serve_step,
    build_train_step,
)

# explicit name: under ``python -m`` this module runs as __main__, and
# a __main__ logger would sit outside the "repro" handler subtree
_log = logging.getLogger("repro.launch.dryrun")

# --- hardware model (TPU v5e target) ---------------------------------- #
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
HBM_BYTES = 16 * 2**30       # per chip
OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# long_500k needs sub-quadratic attention: run only for SSM/hybrid.
LONG_OK_FAMILIES = ("ssm", "hybrid")


def cells(include_long: bool = True):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k":
                if cfg.family not in LONG_OK_FAMILIES:
                    continue  # skip recorded in EXPERIMENTS.md
            yield arch, shape.name


def build(arch: str, shape_name: str, mesh, **kw):
    kind = SHAPES[shape_name].kind
    if kind == "train":
        kw.pop("kv_dtype", None)   # decode-only knob
        return build_train_step(arch, shape_name, mesh, **kw)
    kw.pop("moment_dtype", None)   # train-only knobs
    kw.pop("rwkv_chunk", None)
    kw.pop("grad_accum", None)
    kw.pop("remat", None)
    if kind == "prefill":
        kw.pop("kv_dtype", None)   # decode-only knob
        return build_prefill_step(arch, shape_name, mesh, **kw)
    return build_serve_step(arch, shape_name, mesh, **kw)


def _write_hlo(save_hlo: Path, hlo_text: str) -> Path:
    """Write compressed HLO next to the cell JSON.

    ``save_hlo`` is the codec-less base path (``<cell>.hlo``); the
    codec suffix is appended here.  zstandard is optional (not part of
    the baked toolchain) — fall back to stdlib gzip so a missing
    compressor never fails the cell.  Returns the path written.
    """
    try:
        import zstandard
    except ImportError:
        import gzip
        out = save_hlo.with_name(save_hlo.name + ".gz")
        out.write_bytes(gzip.compress(hlo_text.encode(), compresslevel=6))
    else:
        out = save_hlo.with_name(save_hlo.name + ".zst")
        out.write_bytes(
            zstandard.ZstdCompressor(level=6).compress(hlo_text.encode()))
    return out


_REPO_ROOT = str(Path(__file__).resolve().parents[3])


def _sanitize_traceback(tb: str) -> str:
    """Relativize repo paths so committed artifacts stay machine-neutral."""
    return tb.replace(_REPO_ROOT + os.sep, "")


def _spec_args(bundle):
    s = bundle.input_specs
    if "batch" in s:                       # train
        return (s["params"], s["opt_state"], s["batch"])
    if "cache" in s:                       # decode
        args = [s["params"], s["cache"], s["tokens"]]
        if "memory" in s:
            args.append(s["memory"])
        return tuple(args)
    args = [s["params"], s["tokens"]]      # prefill
    if "frontend" in s:
        args.append(s["frontend"])
    return tuple(args)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             overrides: dict | None = None, verbose: bool = True,
             save_hlo: Path | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    bundle = build(arch, shape_name, mesh, **(overrides or {}))
    with mesh:
        lowered = bundle.step_fn.lower(*_spec_args(bundle))
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax < 0.5: one dict per program
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    if save_hlo is not None:
        _write_hlo(save_hlo, hlo_text)
    hlo = analyze_hlo(hlo_text)
    coll = hlo.collectives

    # trip-count-aware per-device terms (see hlo_analysis docstring;
    # XLA's own cost_analysis undercounts while bodies)
    flops = float(hlo.flops)
    bytes_accessed = float(hlo.bytes_accessed)
    compute_s = flops / PEAK_FLOPS
    # XLA:CPU float-normalization promotes bf16 compute to f32 (verified
    # on a trivial bf16 matmul) — TPU keeps bf16.  Activation-class
    # traffic is therefore inflated ~2x on this host backend; we report
    # the raw term and a bf16-corrected term and use the corrected one
    # for the roofline (documented in EXPERIMENTS.md §Dry-run).
    memory_s_raw = bytes_accessed / HBM_BW
    memory_s = 0.5 * memory_s_raw
    collective_s = coll.wire_bytes / LINK_BW

    per_dev_bytes = (
        mem.temp_size_in_bytes + mem.argument_size_in_bytes
        + mem.output_size_in_bytes - mem.alias_size_in_bytes
    )
    # TPU estimate: arguments (params/opt/caches) carry their declared
    # dtypes and are exact; temps are bf16-activations promoted to f32
    # by the CPU backend -> halve them for the TPU number.
    per_dev_bytes_tpu = (
        0.5 * mem.temp_size_in_bytes + mem.argument_size_in_bytes
        + mem.output_size_in_bytes - mem.alias_size_in_bytes
    )
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens

    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "policy": bundle.policy,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_device_bytes": int(per_dev_bytes),
        "per_device_gib": round(per_dev_bytes / 2**30, 3),
        "per_device_gib_tpu_est": round(per_dev_bytes_tpu / 2**30, 3),
        "argument_gib": round(mem.argument_size_in_bytes / 2**30, 3),
        "temp_gib": round(mem.temp_size_in_bytes / 2**30, 3),
        "fits_hbm": bool(per_dev_bytes_tpu <= HBM_BYTES),
        "fits_hbm_raw": bool(per_dev_bytes <= HBM_BYTES),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "xla_cost_flops_raw": float(cost.get("flops", 0.0)),
        "n_while_loops": hlo.n_while,
        "max_trip_count": hlo.max_trip,
        "collective_bytes_per_device": coll.total_bytes,
        "collective_wire_bytes": coll.wire_bytes,
        "collectives": {k: [coll.count_by_type[k], v]
                        for k, v in coll.bytes_by_type.items()},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_s_raw": memory_s_raw,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flop_frac": (model_flops / n_chips) / flops if flops else 0.0,
        "roofline_frac": (
            (model_flops / n_chips / PEAK_FLOPS)
            / max(compute_s, memory_s, collective_s)
            if max(compute_s, memory_s, collective_s) > 0 else 0.0),
    }
    if verbose:
        _log.info("%s", json.dumps(
            {k: result[k] for k in (
                "arch", "shape", "mesh", "policy", "compile_s",
                "per_device_gib_tpu_est", "fits_hbm", "compute_s",
                "memory_s", "collective_s", "dominant",
                "useful_flop_frac", "roofline_frac")},
            indent=None))
    return result


def _cached_ok(path: Path) -> bool:
    """True iff the cached cell JSON records a successful run.

    Error cells (and unreadable files) are treated as stale so a fixed
    environment regenerates them without needing ``--force``.
    """
    try:
        return json.loads(path.read_text()).get("status") == "ok"
    except (OSError, ValueError):
        return False


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"_{tag}" if tag else ""
    return OUT_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"


def main(argv=None) -> int:
    from repro.obs import setup_logging
    setup_logging()  # CLI entry point: bare messages on stdout
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for experiment JSONs")
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--rwkv-chunk", type=int, default=None)
    ap.add_argument("--moment-dtype", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--kv-dtype", default=None)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--remat", default=None)
    args = ap.parse_args(argv)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    todo = []
    if args.all:
        todo = list(cells())
    else:
        if not args.arch:
            ap.error("--arch required unless --all")
        shapes = [args.shape] if args.shape else [
            s for a, s in cells() if a == args.arch]
        todo = [(args.arch, s) for s in shapes]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    overrides = {}
    if args.attn_chunk:
        overrides["attn_chunk"] = args.attn_chunk
    if args.rwkv_chunk:
        overrides["rwkv_chunk"] = args.rwkv_chunk
    if args.moment_dtype:
        overrides["moment_dtype"] = args.moment_dtype
    if args.grad_accum:
        overrides["grad_accum"] = args.grad_accum
    if args.kv_dtype:
        overrides["kv_dtype"] = args.kv_dtype
    if args.policy:
        overrides["policy"] = args.policy
    if args.remat:
        overrides["remat"] = args.remat

    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            path = cell_path(arch, shape, mp, args.tag)
            if path.exists() and not args.force:
                if _cached_ok(path):
                    _log.info("cached: %s", path.name)
                    continue
                _log.info("stale error cell, re-running: %s", path.name)
            try:
                result = run_cell(arch, shape, multi_pod=mp,
                                  overrides=overrides or None,
                                  save_hlo=path.with_suffix(".hlo"))
            except Exception as e:  # noqa: BLE001 - record and continue
                failures += 1
                result = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error", "error": repr(e),
                    "traceback": _sanitize_traceback(
                        traceback.format_exc())[-2000:],
                }
                _log.error("FAIL %s %s mp=%s: %r", arch, shape, mp, e)
            path.write_text(json.dumps(result, indent=2))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
