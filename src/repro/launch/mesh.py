"""Production mesh builders.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because smoke tests
run with the single real CPU device while the dry-run requests 512
placeholder devices before its first jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "MESH_AXES"]

MESH_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16×16 = 256 chips, or 2-pod 2×16×16 = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch: ('pod', 'data') when present."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
