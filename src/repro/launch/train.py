"""Training launcher.

Two modes:

* ``--smoke`` (default on CPU): reduced config of the selected arch,
  runs real steps through the fault-tolerant Trainer.
* ``--production``: builds the full-size bundle against the production
  mesh and lowers it (the execution path used on real TPU slices; on
  this host it verifies the program end-to-end up to compilation).

Examples::

    python -m repro.launch.train --arch llama3_8b --steps 50
    python -m repro.launch.train --arch mixtral_8x7b --production \
        --shape train_4k
"""
from __future__ import annotations

import argparse
import sys

from repro.configs import get_config, get_smoke_config, shape_by_name
from repro.configs.base import ShapeConfig
from repro.runtime import FailureInjector, Trainer, TrainerConfig


def main(argv=None) -> int:
    from repro.obs import setup_logging
    _log = setup_logging()  # CLI entry point: bare messages on stdout
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--inject-fault-at", type=int, default=None)
    args = ap.parse_args(argv)

    if args.production:
        # full config, production mesh, lower + compile (no execution
        # on this CPU host; on TPU this object is what runs)
        from repro.launch.dryrun import run_cell
        result = run_cell(args.arch, args.shape, multi_pod=False)
        return 0 if result["status"] == "ok" else 1

    cfg = get_smoke_config(args.arch)
    shape = ShapeConfig("smoke_train", args.seq_len, args.batch, "train")
    injector = None
    if args.inject_fault_at is not None:
        injector = FailureInjector(fail_at_steps=(args.inject_fault_at,))
    trainer = Trainer(
        cfg, shape,
        TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir),
        attn_chunk=16,
        injector=injector,
    )
    hist = trainer.run()
    _log.info("steps: %d  first loss: %.4f  last loss: %.4f",
              len(hist["loss"]), hist["loss"][0], hist["loss"][-1])
    return 0


if __name__ == "__main__":
    sys.exit(main())
