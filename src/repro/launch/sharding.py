"""Parameter / activation / cache sharding rules.

Two policies:

* ``tp`` — tensor parallelism over the "model" axis only; parameters
  replicated across data (small models).
* ``fsdp_tp`` — 2-D sharding: "model" shards the TP dimension and
  ("pod","data") shard a second dimension FSDP-style (big models; XLA
  inserts per-layer all-gathers inside the layer scan).

Rules are name-based over the param tree paths produced by
``repro.models.LM``; any dimension not divisible by the axis size falls
back to replication (``_shard_if_divisible``), which keeps every
(arch × mesh) combination lowerable.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_sharding_rules",
    "tree_shardings",
    "batch_sharding",
    "cache_shardings",
    "make_shard_act",
    "pick_policy",
]


def pick_policy(total_params: int) -> str:
    """fsdp_tp for anything that meaningfully stresses 16 GiB chips:
    f32 optimizer state is 16 B/param, so ≥3 B params ⇒ ≥48 GB of
    optimizer state — must be sharded over data axes too (ZeRO)."""
    return "fsdp_tp" if total_params >= 3e9 else "tp"


def _axsize(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _shard_if_divisible(mesh: Mesh, shape, *axes):
    """PartitionSpec with per-dim fallback to None on non-divisibility."""
    spec = []
    for dim, ax in zip(shape, axes):
        if ax is not None and dim % _axsize(mesh, ax) == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return P(*spec)


def _rule(path: str, shape, mesh: Mesh, policy: str, fsdp):
    """PartitionSpec for one parameter. ``fsdp`` = ('pod','data') axes
    used for the second shard dim under fsdp_tp (or None under tp).

    ``policy == "fsdp"``: pure FSDP — no tensor parallelism at all; the
    "model" axis joins the data axes, every parameter is sharded over
    the combined axes on its largest divisible dim, and the batch is
    sharded over everything.  Zero activation collectives; per-layer
    weight all-gathers only.  Only valid when the global batch divides
    the full mesh (enforced by the caller).
    """
    nd = len(shape)
    if policy == "fsdp":
        allax = tuple(a for a in ("pod", "data", "model")
                      if a in mesh.axis_names)
        # shard the largest divisible dim over the combined axes
        order = sorted(range(nd), key=lambda i: -shape[i])
        for i in order:
            if shape[i] % _axsize(mesh, allax) == 0 and shape[i] > 1:
                spec = [None] * nd
                spec[i] = allax
                return P(*spec)
        return P(*([None] * nd))
    d2 = fsdp if policy == "fsdp_tp" else None

    def spec(*axes):
        # pad with None for any leading stacked dims not covered
        axes = (None,) * (nd - len(axes)) + tuple(axes)
        return _shard_if_divisible(mesh, shape, *axes)

    leaf = path.split("/")[-1]
    if leaf in ("embed", "lm_head"):                 # [V, d]
        return spec("model", d2)
    if leaf in ("wq", "wk", "wv", "w_r", "w_k", "w_v", "w_g"):
        return spec(d2, "model")                     # [d, H*hd]
    if leaf in ("wo", "w_o"):
        return spec("model", d2)                     # [H*hd, d]
    is_moe = "/moe/" in path
    if leaf in ("w_gate", "w_up"):                   # moe: [(rep,) E, d, f]
        if is_moe and shape[-3] % _axsize(mesh, "model") == 0:
            # expert parallelism: whole experts per model-rank — kills
            # the per-layer all-reduce of [G,E,C,d] partial sums that
            # f-sharding causes (see EXPERIMENTS.md §Perf, olmoe cell)
            return spec("model", d2, None)
        return spec(d2, "model")
    if leaf == "w_down":                             # moe: [(rep,) E, f, d]
        if is_moe and shape[-3] % _axsize(mesh, "model") == 0:
            return spec("model", None, d2)
        return spec("model", d2)
    if leaf == "router":                             # [d, E]
        return spec(d2, None)
    if leaf == "in_proj":                            # [d, 2*d_in]
        return spec(d2, "model")
    if leaf in ("x_proj", "out_proj"):               # [d_in, *]
        return spec("model", d2)
    if leaf == "dt_proj":                            # [r, d_in]
        return spec(d2, "model")
    if leaf in ("conv_w",):                          # [K, d_in]
        return spec(None, "model")
    if leaf in ("a_log",):                           # [d_in, N]
        return spec("model", None)
    if leaf in ("dt_bias", "d_skip", "decay_base", "ln_x"):
        return spec("model")                         # [d_in] / [dh]
    if leaf == "decay_a":                            # [d, LORA]
        return spec(d2, None)
    if leaf == "decay_b":                            # [LORA, dh]
        return spec(None, "model")
    if leaf == "bonus_u":                            # [H, hd]
        return spec(None, None)
    if leaf == "frontend_proj":                      # [F, d]
        return spec(None, "model")
    if leaf in ("bq", "bk", "bv"):
        return spec("model")
    # norms, scalars, mixes
    return P(*([None] * nd))


def _tree_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tree_paths(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _tree_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def param_sharding_rules(shapes_tree, mesh: Mesh, policy: str = "tp"):
    """Pytree of PartitionSpec matching ``shapes_tree`` (of
    ShapeDtypeStruct or arrays)."""
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp = fsdp if fsdp else None

    def one(path, leaf):
        return _rule(path, leaf.shape, mesh, policy, fsdp)

    flat = list(_tree_paths(shapes_tree))
    specs = {p: one(p, l) for p, l in flat}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            out = [rebuild(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
            return type(tree)(out)
        return specs[prefix]

    return rebuild(shapes_tree)


def tree_shardings(shapes_tree, mesh: Mesh, policy: str = "tp"):
    specs = param_sharding_rules(shapes_tree, mesh, policy)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_sharding(mesh: Mesh, batch: int | None = None,
                   policy: str = "fsdp_tp"):
    """tokens/labels [B, S] sharded over the batch axes (replicated
    when the batch doesn't divide them, e.g. long_500k's batch of 1).
    Pure-FSDP policy shards the batch over every axis."""
    candidates = [tuple(a for a in ("pod", "data")
                        if a in mesh.axis_names)]
    if policy == "fsdp":
        candidates.insert(0, tuple(
            a for a in ("pod", "data", "model") if a in mesh.axis_names))
        candidates.insert(1, tuple(
            a for a in ("data", "model") if a in mesh.axis_names))
    for axes in candidates:
        if axes and (batch is None or batch % _axsize(mesh, axes) == 0):
            return NamedSharding(mesh, P(axes, None))
    return NamedSharding(mesh, P(None, None))


def frontend_sharding(mesh: Mesh, batch: int | None = None):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch is not None and (not dp or batch % _axsize(mesh, dp) != 0):
        return NamedSharding(mesh, P(None, None, None))
    return NamedSharding(mesh, P(dp, None, None))


def cache_shardings(cache_tree, mesh: Mesh, batch: int):
    """Decode caches: batch over data axes when divisible, else the
    sequence (KV) dim over "model"."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    batch_ok = batch % dp_size == 0

    def spec(leaf):
        shape = leaf.shape
        nd = len(shape)
        axes = [None] * nd
        # layouts: attn k/v [rep, B, S, hkv, hd]; mamba conv [rep, B, K, d_in];
        # mamba ssm [rep, B, d_in, N]; rwkv last_x [rep, B, d];
        # rwkv state [rep, B, H, hd, hd]
        if batch_ok and nd >= 2:
            axes[1] = dp
        if nd == 5 and shape[2] > 1024:
            # attention KV cache: shard the long sequence over "model"
            if shape[2] % mesh.shape["model"] == 0:
                axes[2] = "model"
        elif nd == 4 and shape[2] % mesh.shape["model"] == 0:
            axes[2] = "model"          # mamba ssm d_in over model
        return _shard_if_divisible(mesh, shape, *axes)

    return jax.tree.map(
        lambda l: NamedSharding(mesh, spec(l)), cache_tree)


def make_shard_act(mesh: Mesh, policy: str = "fsdp_tp"):
    """Constraint hook injected into the model.

    * residual activations: batch over data axes, sequence over
      "model" (Megatron SP convention),
    * logits: vocabulary over "model" — the [B, S, V] tensor must never
      be replicated across the TP group,
    * pure-FSDP policy: batch over every axis, nothing else sharded.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if policy == "fsdp":
        allax = tuple(a for a in ("pod", "data", "model")
                      if a in mesh.axis_names)

        def shard_act_fsdp(x, kind="residual"):
            if x.ndim < 2:
                return x
            b = allax if x.shape[0] % _axsize(mesh, allax) == 0 else None
            spec = P(b, *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))

        return shard_act_fsdp

    def shard_act(x, kind="residual"):
        bshard = dp if (dp and x.shape[0] % _axsize(mesh, dp) == 0) else None
        if kind == "mamba_din" and x.ndim == 3:      # [B, S, d_in]
            dshard = "model" if x.shape[-1] % msize == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bshard, None, dshard)))
        if kind == "moe_tokens" and x.ndim == 4:     # [G, E, C, d]
            eshard = "model" if x.shape[1] % msize == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bshard, eshard, None, None)))
        if kind == "moe_hidden" and x.ndim == 4:     # [G, E, C, f]
            if x.shape[1] % msize == 0:              # expert parallelism
                spec = P(bshard, "model", None, None)
            else:
                fshard = "model" if x.shape[-1] % msize == 0 else None
                spec = P(bshard, None, None, fshard)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        if x.ndim != 3:
            return x
        if kind == "attn_in":
            # Megatron sequence parallelism: gather the sequence once at
            # attention entry (one [B,S,d] all-gather) so head-sharded
            # attention runs locally — instead of GSPMD gathering K/V
            # chunks per scan iteration (measured 25.8 GB vs 12.9 GB per
            # step on olmoe train)
            spec = P(bshard, None, None)
        elif kind == "logits":
            vshard = "model" if x.shape[-1] % msize == 0 else None
            spec = P(bshard, None, vshard)
        else:
            # Megatron-style sequence parallelism: residuals carried
            # between layers are sharded over "model" along the sequence
            # — without this, the layer-scan's saved carries alone
            # (n_layers × B·S·d) blow the 16 GiB HBM budget at
            # per-device batches ≥ 8·4k tokens.
            sshard = ("model" if x.shape[1] > 1
                      and x.shape[1] % msize == 0 else None)
            spec = P(bshard, sshard, None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard_act
