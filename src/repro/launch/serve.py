"""Serving launcher: batched prefill + decode with KV/state caches.

``--smoke`` serves a reduced config for real on CPU (prefill a prompt
batch, then greedy-decode); ``--production`` lowers the full-size
serve_step against the production mesh (the dry-run path).

Example::

    python -m repro.launch.serve --arch llama3_8b --tokens 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import LM


def greedy_decode(model: LM, params, prompt, new_tokens: int,
                  frontend=None):
    """Prefill via teacher-forced decode steps, then greedy generation."""
    bsz, plen = prompt.shape
    max_len = plen + new_tokens + 1
    cache = model.init_cache(bsz, max_len, dtype=jnp.float32)
    memory = model.encode_memory(params, frontend)

    step = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos, memory=memory),
        static_argnums=(3,))
    logits = None
    for t in range(plen):
        logits, cache = step(params, cache, prompt[:, t:t + 1], t)
    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for t in range(plen, plen + new_tokens):
        out.append(tok)
        logits, cache = step(params, cache, tok, t)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None) -> int:
    from repro.obs import setup_logging
    _log = setup_logging()  # CLI entry point: bare messages on stdout
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--production", action="store_true")
    args = ap.parse_args(argv)

    if args.production:
        from repro.launch.dryrun import run_cell
        result = run_cell(args.arch, args.shape, multi_pod=False)
        return 0 if result["status"] == "ok" else 1

    cfg = get_smoke_config(args.arch)
    model = LM(cfg, param_dtype=jnp.float32, attn_chunk=16,
               max_seq=args.prompt_len + args.tokens + 8)
    params = model.init(0)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    frontend = None
    if cfg.frontend_tokens:
        frontend = jnp.asarray(
            rng.normal(size=(args.batch, cfg.frontend_tokens,
                             cfg.frontend_dim)), jnp.float32)
    t0 = time.perf_counter()
    out = greedy_decode(model, params, prompt, args.tokens, frontend)
    dt = time.perf_counter() - t0
    _log.info("generated %s tokens in %.2fs (%.1f tok/s)",
              out.shape, dt, args.batch * args.tokens / dt)
    _log.info("sample: %s", np.asarray(out[0])[:16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
