"""Post-SPMD HLO text analysis: trip-count-aware FLOPs / HBM bytes /
collective traffic.

Why not ``compiled.cost_analysis()``: XLA's cost analysis visits each
``while`` body **once**, so anything under ``lax.scan`` (our layer
stacks, attention chunk loops, the chunked loss) is undercounted by the
trip count (verified: a scan of 10 matmuls reports the FLOPs of 1).
This module re-derives the roofline inputs from the optimized
(per-device) HLO text with loop weighting:

* **FLOPs** — every ``dot`` (including inside fusion bodies):
  ``2 × prod(result dims) × prod(lhs contracting dims)``.
* **HBM bytes** — operand + result sizes of top-level instructions in
  the entry/while-body computations.  Post-fusion, those boundaries are
  exactly what hits HBM (fusion internals stay in registers/VMEM).
* **Collectives** — operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, with ring wire
  factors (all-reduce 2×, others 1×).
* **Loop weighting** — a ``while`` body is weighted by its trip count,
  recovered from the largest integer literal in the loop condition
  (lax.scan lowers to a counted loop; verified against known scans).

Shapes in post-SPMD HLO are per-device, so all outputs are per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloStats", "CollectiveStats", "analyze_hlo",
           "analyze_collectives"]

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_NO_BYTES_OPS = (
    " parameter(", " constant(", " get-tuple-element(", " tuple(",
    " after-all(", " bitcast(", " iota(",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",")] if dim_str else []


@dataclass
class CollectiveStats:
    bytes_by_type: dict = field(default_factory=dict)
    wire_bytes: float = 0.0
    count_by_type: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_type.values()))

    def add(self, kind: str, nbytes: float, times: float) -> None:
        self.bytes_by_type[kind] = (
            self.bytes_by_type.get(kind, 0.0) + nbytes * times)
        self.count_by_type[kind] = (
            self.count_by_type.get(kind, 0) + times)
        self.wire_bytes += nbytes * times * _WIRE_FACTOR[kind]


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: CollectiveStats = field(default_factory=CollectiveStats)
    n_while: int = 0
    max_trip: int = 1


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if m:
                current = m.group(1)
                comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None and stripped:
            comps[current].append(stripped)
    return comps


def _entry_name(hlo: str, comps: dict) -> str | None:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    if m and m.group(1) in comps:
        return m.group(1)
    called: set[str] = set()
    call_re = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
    for lines in comps.values():
        for ln in lines:
            called.update(call_re.findall(ln))
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps), None)


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _trip_count(line: str, cond_lines: list[str]) -> int:
    """Trip count of a while: XLA's known_trip_count backend_config
    when present, else the largest literal in the loop condition."""
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    best = 1
    for ln in cond_lines:
        for c in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(c.group(1)))
    return best


def _build_def_shapes(hlo: str) -> dict[str, tuple[str, str]]:
    """instruction name -> (dtype, dims) over the whole module."""
    defs: dict[str, tuple[str, str]] = {}
    for line in hlo.splitlines():
        m = _DEF_RE.match(line.strip())
        if m:
            defs[m.group(1)] = (m.group(2), m.group(3))
    return defs


def _operand_section(ln: str) -> str:
    if "(" not in ln:
        return ""
    paren = ln[ln.index("("):]
    for stop in ("), metadata=", "), backend_config=", "), calls=",
                 "), condition=", "), to_apply=", "), kind=",
                 "), dynamic_slice_sizes=", "), channel_id=",
                 "), replica_groups=", "), dimensions="):
        idx = paren.find(stop)
        if idx >= 0:
            paren = paren[:idx + 1]
            break
    return paren


def _operand_names(ln: str) -> list[str]:
    return _OPERAND_RE.findall(_operand_section(ln))


def _operand_shapes(ln: str, defs: dict) -> list[tuple[str, str]]:
    """Resolve operand shapes of an instruction line via the def map."""
    paren = _operand_section(ln)
    inline = _SHAPE_RE.findall(paren)
    if inline:
        return inline
    out = []
    for name in _OPERAND_RE.findall(paren):
        if name in defs:
            out.append(defs[name])
    return out


def _dot_flops(ln: str, defs: dict) -> float:
    m = _DEF_RE.match(ln)
    if not m:
        return 0.0
    result = _dims(m.group(3))
    operands = _operand_shapes(ln, defs)
    if not operands:
        return 0.0
    lhs = _dims(operands[0][1])
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
    contract = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs):
                contract *= lhs[i]
    n = 1
    for d in result:
        n *= d
    return 2.0 * n * contract


def analyze_hlo(hlo: str) -> HloStats:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo, comps)
    defs = _build_def_shapes(hlo)
    stats = HloStats()
    if entry is None:
        return stats
    call_re = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")

    def line_bytes(ln: str) -> float:
        m = _DEF_RE.match(ln)
        result = _shape_bytes(m.group(2), m.group(3)) if m else 0
        # slicing ops only touch the slice-sized region, not the whole
        # operand buffer (which would massively overcount scan bodies
        # that dynamic-slice their per-iteration inputs)
        if " dynamic-slice(" in ln or " slice(" in ln:
            return 2.0 * result                       # read + write slice
        if " dynamic-update-slice(" in ln:
            ops = _operand_shapes(ln, defs)
            upd = _shape_bytes(*ops[1]) if len(ops) > 1 else result
            return 2.0 * upd                          # read + write slice
        if " gather(" in ln:
            return 2.0 * result
        return result + sum(_shape_bytes(d, s)
                            for d, s in _operand_shapes(ln, defs))

    def fusion_bytes(ln: str, callee: str) -> float:
        """Fusion boundary traffic, discounting operands that are only
        dynamic-sliced inside the fusion body (they are read
        slice-sized per invocation, not in full)."""
        naive = line_bytes(ln)
        names = _operand_names(ln)
        adjust = 0.0
        for cl in comps.get(callee, []):
            if (" dynamic-slice(" not in cl and " gather(" not in cl):
                continue
            dm = _DEF_RE.match(cl)
            if not dm:
                continue
            res = _shape_bytes(dm.group(2), dm.group(3))
            # first operand of the slice/gather; older jax prints the
            # operand type before the name ("(f32[...]{...} %param_1.1"),
            # newer jax prints "(%param_1" directly — anchor on the
            # opcode's paren so a later index operand can't match
            pm = re.search(
                r"(?:dynamic-slice|gather)\(\s*(?:\S+\s+)?%param_(\d+)", cl)
            if not pm:
                continue
            idx = int(pm.group(1))
            if idx < len(names) and names[idx] in defs:
                full = _shape_bytes(*defs[names[idx]])
                adjust += min(0.0, 2.0 * res - full)
        return max(naive + adjust, 0.0)

    def visit(name: str, times: float, count_bytes: bool,
              depth: int = 0) -> None:
        if depth > 24 or name not in comps:
            return
        for ln in comps[name]:
            # --- while loops ------------------------------------------ #
            if " while(" in ln:
                cond = re.search(r"condition=%?([\w.\-]+)", ln)
                body = re.search(r"body=%?([\w.\-]+)", ln)
                trips = _trip_count(
                    ln, comps.get(cond.group(1), []) if cond else [])
                stats.n_while += 1
                stats.max_trip = max(stats.max_trip, trips)
                if body:
                    visit(body.group(1), times * trips, count_bytes,
                          depth + 1)
                continue
            # --- collectives ------------------------------------------ #
            kind = None
            skip = False
            for c in _COLLECTIVES:
                if f" {c}-done(" in ln:
                    skip = True
                    break
                if f" {c}(" in ln or f" {c}-start(" in ln:
                    kind = c
                    break
            if skip:
                continue
            if kind is not None:
                nbytes = sum(_shape_bytes(d, s)
                             for d, s in _operand_shapes(ln, defs))
                stats.collectives.add(kind, nbytes, times)
                if count_bytes:
                    stats.bytes_accessed += nbytes * times
                continue
            # --- dots -------------------------------------------------- #
            if re.search(r"\bdot\(", ln):
                stats.flops += _dot_flops(ln, defs) * times
                if count_bytes:
                    stats.bytes_accessed += line_bytes(ln) * times
                continue
            # --- fusions / calls --------------------------------------- #
            callee = call_re.search(ln)
            if " fusion(" in ln and callee:
                # fusion internals: count dots only (they run in-core);
                # the fusion boundary shapes are the HBM traffic
                visit(callee.group(1), times, False, depth + 1)
                if count_bytes:
                    stats.bytes_accessed += fusion_bytes(
                        ln, callee.group(1)) * times
                continue
            if callee and (" call(" in ln or " conditional(" in ln
                           or " reduce(" in ln or " sort(" in ln
                           or " scatter(" in ln or " map(" in ln):
                visit(callee.group(1), times, False, depth + 1)
            # --- plain instructions ------------------------------------ #
            if count_bytes and not any(op in ln for op in _NO_BYTES_OPS):
                stats.bytes_accessed += line_bytes(ln) * times

    visit(entry, 1.0, True)
    return stats


def analyze_collectives(hlo: str) -> CollectiveStats:
    return analyze_hlo(hlo).collectives
