"""Step builders: jit-able train_step / serve_step per (arch × shape),
with input specs (ShapeDtypeStruct stand-ins) and sharding assignments.

This is the module both the real drivers (train.py / serve.py) and the
multi-pod dry-run consume; the dry-run lowers exactly what training
would run.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig, get_config, shape_by_name
from repro.models import LM
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine

from .sharding import (
    batch_sharding,
    cache_shardings,
    frontend_sharding,
    make_shard_act,
    param_sharding_rules,
    pick_policy,
    tree_shardings,
)

__all__ = ["StepBundle", "build_train_step", "build_serve_step",
           "make_model", "train_input_specs", "decode_input_specs"]


@dataclass
class StepBundle:
    """Everything needed to lower one (arch × shape × mesh) cell."""
    arch: str
    shape: ShapeConfig
    mesh: Any
    model: LM
    step_fn: Any              # jitted function
    input_specs: dict         # kwargs of ShapeDtypeStruct for .lower()
    policy: str
    notes: dict


def make_model(cfg: ModelConfig, shape: ShapeConfig, mesh=None, *,
               remat: str | None = None, attn_chunk: int = 512,
               rwkv_chunk: int = 16, kv_dtype: str = "bf16",
               policy: str = "fsdp_tp",
               param_dtype=jnp.bfloat16) -> LM:
    if remat is None:
        remat = "full" if shape.kind == "train" else "none"
    shard_act = (make_shard_act(mesh, policy)
                 if mesh is not None else None)
    return LM(
        cfg,
        param_dtype=param_dtype,
        attn_chunk=attn_chunk,
        max_seq=shape.seq_len + 8,
        remat=remat,
        shard_act=shard_act,
        rwkv_chunk=rwkv_chunk,
        kv_dtype=kv_dtype,
    )


# ---------------------------------------------------------------------- #
# input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------- #
def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      policy: str = "fsdp_tp") -> dict:
    b, s = shape.global_batch, shape.seq_len
    bsh = batch_sharding(mesh, shape.global_batch, policy)
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsh),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsh),
    }
    if cfg.frontend_tokens:
        batch["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16,
            sharding=frontend_sharding(mesh, shape.global_batch))
    return batch


def _prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    b, s = shape.global_batch, shape.seq_len
    bsh = batch_sharding(mesh, shape.global_batch)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bsh),
    }
    if cfg.frontend_tokens:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16,
            sharding=frontend_sharding(mesh, shape.global_batch))
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       model: LM) -> dict:
    """serve_step inputs: one new token + KV/state cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(b, s, dtype=jnp.bfloat16))
    cshard = cache_shardings(cache_shapes, mesh, b)
    cache = jax.tree.map(
        lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
        cache_shapes, cshard)
    bsh = batch_sharding(mesh, shape.global_batch)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=bsh),
        "cache": cache,
    }
    if cfg.frontend_tokens:
        specs["memory"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16,
            sharding=frontend_sharding(mesh, shape.global_batch))
    return specs


# ---------------------------------------------------------------------- #
# step functions
# ---------------------------------------------------------------------- #
def build_train_step(arch: str, shape_name: str, mesh, *,
                     policy: str | None = None,
                     opt: AdamWConfig | None = None,
                     cfg: ModelConfig | None = None,
                     attn_chunk: int = 512,
                     rwkv_chunk: int = 16,
                     moment_dtype: str = "float32",
                     grad_accum: int = 1,
                     remat: str | None = None) -> StepBundle:
    """jit'd (params, opt_state, batch, step) -> (params, opt_state,
    metrics), with in/out shardings bound from the rules.

    ``grad_accum`` > 1 splits the global batch into microbatches with
    gradient accumulation (scanned) — activation temps scale ~1/µ at
    the cost of a bf16 grad accumulator; the way 100B+ models train on
    16 GiB chips.
    """
    cfg = cfg or get_config(arch)
    shape = shape_by_name(shape_name)
    opt = opt or AdamWConfig(moment_dtype=moment_dtype)
    policy = policy or pick_policy(cfg.total_params())
    model = make_model(cfg, shape, mesh, remat=remat, policy=policy,
                       attn_chunk=attn_chunk, rwkv_chunk=rwkv_chunk)

    param_shapes = jax.eval_shape(lambda: model.init(0))
    pshard = tree_shardings(param_shapes, mesh, policy)
    opt_shapes = jax.eval_shape(
        lambda p: adamw_init(p, opt.moment_dtype), param_shapes)
    oshard = {
        "step": NamedSharding(mesh, P()),
        "m": pshard, "v": pshard, "master": pshard,
    }
    if shape.global_batch % grad_accum:
        raise ValueError("global batch not divisible by grad_accum")

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum,
                                     x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def acc_step(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(model.loss)(params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                    gacc, g)
                return (gacc, lacc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                              params)
            (gsum, lsum), _ = jax.lax.scan(acc_step, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
        lr_scale = warmup_cosine(opt_state["step"])
        params, opt_state, metrics = adamw_update(
            opt, params, grads, opt_state, lr_scale)
        metrics["loss"] = loss
        return params, opt_state, metrics

    batch_specs = train_input_specs(cfg, shape, mesh, policy)
    step_fn = jax.jit(
        train_step,
        in_shardings=(pshard, oshard,
                      jax.tree.map(lambda s: s.sharding, batch_specs)),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
    )
    specs = {
        "params": jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
            param_shapes, pshard),
        "opt_state": jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
            opt_shapes, oshard),
        "batch": batch_specs,
    }
    return StepBundle(arch, shape, mesh, model, step_fn, specs, policy,
                      notes={"remat": model.remat})


def build_serve_step(arch: str, shape_name: str, mesh, *,
                     policy: str | None = None,
                     cfg: ModelConfig | None = None,
                     attn_chunk: int = 512,
                     kv_dtype: str = "bf16") -> StepBundle:
    """jit'd serve_step: decode one token against the cache (decode
    shapes) — the lowered object for decode_32k / long_500k cells."""
    cfg = cfg or get_config(arch)
    shape = shape_by_name(shape_name)
    policy = policy or pick_policy(cfg.total_params())
    model = make_model(cfg, shape, mesh, remat="none",
                       attn_chunk=attn_chunk, kv_dtype=kv_dtype)

    param_shapes = jax.eval_shape(lambda: model.init(0))
    pshard = tree_shardings(param_shapes, mesh, policy)
    in_specs = decode_input_specs(cfg, shape, mesh, model)

    if cfg.frontend_tokens:
        def serve_step(params, cache, tokens, memory):
            return model.decode_step(params, cache, tokens,
                                     shape.seq_len - 1, memory=memory)
    else:
        def serve_step(params, cache, tokens):
            return model.decode_step(params, cache, tokens,
                                     shape.seq_len - 1)

    cache_sh = jax.tree.map(lambda s: s.sharding, in_specs["cache"])
    shardings = [pshard, cache_sh, in_specs["tokens"].sharding]
    if cfg.frontend_tokens:
        shardings.append(in_specs["memory"].sharding)
    step_fn = jax.jit(
        serve_step,
        in_shardings=tuple(shardings),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    specs = {
        "params": jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
            param_shapes, pshard),
        "cache": in_specs["cache"],
        "tokens": in_specs["tokens"],
    }
    if cfg.frontend_tokens:
        specs["memory"] = in_specs["memory"]
    return StepBundle(arch, shape, mesh, model, step_fn, specs, policy,
                      notes={})


def build_prefill_step(arch: str, shape_name: str, mesh, *,
                       policy: str | None = None,
                       cfg: ModelConfig | None = None,
                       attn_chunk: int = 512) -> StepBundle:
    """jit'd prefill: forward logits over the full sequence."""
    cfg = cfg or get_config(arch)
    shape = shape_by_name(shape_name)
    policy = policy or pick_policy(cfg.total_params())
    model = make_model(cfg, shape, mesh, remat="none",
                       attn_chunk=attn_chunk)
    param_shapes = jax.eval_shape(lambda: model.init(0))
    pshard = tree_shardings(param_shapes, mesh, policy)
    in_specs = _prefill_input_specs(cfg, shape, mesh)

    if cfg.frontend_tokens:
        def prefill(params, tokens, frontend):
            logits, _ = model.forward(params, tokens, frontend,
                                      last_only=True)
            return logits[:, -1]
        shardings = (pshard, in_specs["tokens"].sharding,
                     in_specs["frontend"].sharding)
    else:
        def prefill(params, tokens):
            logits, _ = model.forward(params, tokens, last_only=True)
            return logits[:, -1]
        shardings = (pshard, in_specs["tokens"].sharding)

    step_fn = jax.jit(prefill, in_shardings=shardings, out_shardings=None)
    specs = {
        "params": jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
            param_shapes, pshard),
        **in_specs,
    }
    return StepBundle(arch, shape, mesh, model, step_fn, specs, policy,
                      notes={})
