"""Launchers: mesh construction, sharding rules, step builders, the
multi-pod dry-run, and train/serve CLIs."""
from .mesh import make_local_mesh, make_production_mesh
from .sharding import pick_policy, tree_shardings
from .steps import (
    StepBundle,
    build_prefill_step,
    build_serve_step,
    build_train_step,
)

__all__ = [
    "make_production_mesh", "make_local_mesh",
    "pick_policy", "tree_shardings",
    "StepBundle", "build_train_step", "build_serve_step",
    "build_prefill_step",
]
