"""Flash attention — Pallas TPU kernel (TARGET: TPU v5e; validated in
interpret mode on CPU against ``ref.reference_attention``).

Design (TPU-native, not a CUDA port):

* grid = (batch×q_heads, S/block_q, S/block_k); the last axis is
  sequential ("arbitrary") — the online-softmax state for one q block
  lives in VMEM scratch across its k iterations.
* BlockSpec tiling: q/o tiles [block_q, head_dim] and k/v tiles
  [block_k, head_dim] in VMEM; head_dim is MXU-aligned (128 for every
  assigned architecture; rwkv uses its own kernel).
* GQA without materializing repeated KV: the k/v index_map folds the
  query-head → kv-head mapping (zero-copy head grouping).
* f32 accumulation; bf16 in/out friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params as TPUCompilerParams;
# newer releases renamed it to CompilerParams.  Support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["flash_attention_bhsd"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, seq_len: int, causal: bool,
            scale: float, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # [bq, bk]

    rows = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    cols = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = cols < seq_len                              # tail padding
    if causal:
        mask = mask & (cols <= rows)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    v = v_ref[0].astype(jnp.float32)                  # [bk, hd]
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,      # [BHq, S, hd]
    k: jax.Array,      # [BHkv, S, hd]
    v: jax.Array,      # [BHkv, S, hd]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention over flattened (batch, head) leading dim."""
    bh, s, hd = q.shape
    bh_kv = k.shape[0]
    if bh % bh_kv:
        raise ValueError(f"q heads {bh} not a multiple of kv heads {bh_kv}")
    group = bh // bh_kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    n_q = -(-s // block_q)
    n_k = -(-s // block_k)
    grid = (bh, n_q, n_k)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, seq_len=s,
        causal=causal, scale=hd ** -0.5, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, iq, ik: (b // group, ik, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, iq, ik: (b // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n_q * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)[:, :s]
