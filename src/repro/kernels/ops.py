"""Jit'd public wrappers around the Pallas kernels.

On TPU these call the Mosaic-compiled kernels; elsewhere callers pass
``interpret=True`` (tests) or use the oracles in :mod:`ref` (the model
code's chunked-jnp paths are mathematically the same algorithms).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .rwkv_wkv import wkv_bhsd

__all__ = ["flash_attention", "rwkv_wkv"]


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """Flash attention in model layout. q [B,S,H,hd]; k/v [B,S,Hkv,hd]."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    o = flash_attention_bhsd(qf, kf, vf, causal=causal, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    return o.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_wkv(r, k, v, w, u, s0=None, *, chunk: int = 128,
             interpret: bool = False):
    """WKV recurrence in model layout. r/k/v/w [B,S,H,hd]; u [H,hd]."""
    b, s, h, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    tr = lambda t: t.transpose(0, 2, 1, 3)
    out, sT = wkv_bhsd(tr(r), tr(k), tr(v), tr(w), u, s0, chunk=chunk,
                       interpret=interpret)
    return out.transpose(0, 2, 1, 3), sT
