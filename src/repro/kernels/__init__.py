"""Pallas TPU kernels for the compute hot-spots placed by the
scheduler: flash attention and the RWKV6 WKV recurrence.

Each kernel ships with a pure-jnp oracle (:mod:`ref`) and a jit'd
wrapper (:mod:`ops`); tests sweep shapes/dtypes in interpret mode."""
from .ops import flash_attention, rwkv_wkv
from .ref import reference_attention, reference_wkv

__all__ = ["flash_attention", "rwkv_wkv",
           "reference_attention", "reference_wkv"]
