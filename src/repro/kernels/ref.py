"""Pure-jnp oracles for the Pallas kernels (the ground truth the
kernels must reproduce, and the lowering used on non-TPU backends)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["reference_attention", "reference_wkv"]


def reference_attention(q, k, v, *, causal: bool = True) -> jax.Array:
    """Naive softmax attention. q [BH,S,hd]; k/v [BHkv,S,hd]."""
    bh, s, hd = q.shape
    group = bh // k.shape[0]
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def reference_wkv(r, k, v, w, u, s0):
    """Sequential WKV oracle. r/k/v/w [B,H,S,hd]; u [H,hd]; s0 [B,H,hd,hd]."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, wt = xs                        # [B,H,hd]
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        return state * wt[..., :, None] + kv, out

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (rf, kf, vf, wf))
    sT, outs = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return outs.transpose(1, 2, 0, 3).astype(r.dtype), sT
