"""RWKV6 WKV recurrence — Pallas TPU kernel (TARGET: TPU v5e; validated
in interpret mode against ``ref.reference_wkv``).

The recurrence (per batch b, head h; state S ∈ R^{hd×hd})::

    out_t = r_t · (S + (u ⊙ k_t) v_tᵀ)
    S     = diag(w_t) · S + k_t v_tᵀ

TPU adaptation: the sequence is processed in chunks; grid =
(B, H, S/chunk) with the chunk axis sequential, the f32 state carried
in VMEM scratch between chunk iterations.  Within a chunk the time loop
is a ``fori_loop`` of rank-1 updates on the VMEM-resident state — the
memory-hierarchy-aware reformulation of the CUDA kernel (which keeps S
in registers/shared memory per thread block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params as TPUCompilerParams;
# newer releases renamed it to CompilerParams.  Support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["wkv_bhsd"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
            state_scr, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                   # [hd]

    def step(t, state):
        rt = r_ref[0, 0, t].astype(jnp.float32)        # [hd]
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                 # [hd, hd]
        out = jnp.einsum("k,kv->v", rt, state + u[:, None] * kv)
        o_ref[0, 0, t] = out.astype(o_ref.dtype)
        return state * wt[:, None] + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state

    @pl.when(ic == n_chunks - 1)
    def _finalize():
        sT_ref[0, 0] = state


def wkv_bhsd(
    r: jax.Array,      # [B, H, S, hd]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,      # decay in (0, 1)
    u: jax.Array,      # [H, hd] bonus
    s0: jax.Array,     # [B, H, hd, hd] initial state (f32)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out [B, H, S, hd], final state [B, H, hd, hd])."""
    b, h, s, hd = r.shape
    if s % chunk:
        raise ValueError(f"seq len {s} must be a multiple of chunk {chunk}")
    n_chunks = s // chunk
    grid = (b, h, n_chunks)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, 1, chunk, hd),
                            lambda ib, ih, ic: (ib, ih, ic, 0))
    state_spec = pl.BlockSpec((1, 1, hd, hd),
                              lambda ib, ih, ic: (ib, ih, 0, 0))

    out, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hd), lambda ib, ih, ic: (ih, 0)),
            state_spec,
        ],
        out_specs=[seq_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, sT
