"""Pipelined multi-instance replay of a replicated plan.

:func:`build_pipelined_specs` lowers N instances of one mapped workflow
into a single engine problem: instance ``i`` occupies the disjoint vid
range ``[i*stride, (i+1)*stride)`` (``stride`` = max base vid + 1, so
instance 0 keeps the original vids — the identity anchor relies on
that), runs on its round-robin replica group's processors, and is
*released* at its arrival instant.  One :func:`repro.sim.run_engine`
pass then replays all instances together: the engine's per-processor
serialization and the communication model are the interference model —
instance ``i+1``'s sources overlap instance ``i``'s sinks wherever the
plan leaves room, and queue behind them where it does not.

:func:`simulate_pipelined` wraps the pass into a
:class:`PipelinedReport` with per-instance latencies, the achieved
rate, the canonical single-instance makespan (computed exactly as
:func:`repro.sim.simulate` computes it — the rate→0 identity anchor),
and a time-resolved memory occupancy trace summed across in-flight
instances, each transient violation pinpointed to the instance whose
task pushed the processor over.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.memdag import occupancy_steps
from repro.core.platform import Platform
from repro.sim import (
    _ReversedLinkView,
    build_specs,
    resolve_comm,
    run_engine,
    transpose_edges,
)
from repro.sim.comm import ContentionFreeComm
from repro.sim.engine import BlockSpec, EdgeSpec
from repro.sim.memory import pick_block_order
from repro.sim.report import (
    MemoryTrace,
    MemoryViolation,
    SimEvent,
    TransferRecord,
)

from .arrivals import ArrivalSpec
from .replicate import ThroughputPlan, replicate_plan

__all__ = [
    "InstanceRecord",
    "PipelinedReport",
    "build_pipelined_specs",
    "simulate_pipelined",
]

#: relative slack mirroring repro.sim.memory._TOL
_TOL = 1 + 1e-9


@dataclass(frozen=True)
class InstanceRecord:
    """One workflow instance's journey through the pipelined replay."""

    instance: int
    replica: int
    arrival: float
    start: float
    finish: float

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    def to_list(self) -> list:
        return [self.instance, self.replica, self.arrival,
                self.start, self.finish]

    @classmethod
    def from_list(cls, row: list) -> "InstanceRecord":
        return cls(*row)


def build_pipelined_specs(
    q,
    platform: Platform,
    plan: ThroughputPlan,
    arrivals,
):
    """Lower N instances into one engine problem.

    Returns ``(blocks, edges, release, stride)``.  ``arrivals`` is the
    sequence of instance arrival instants (its length sets N); instance
    ``i`` runs on replica group ``i % plan.n_replicas`` and every one
    of its blocks carries a release floor at its arrival.  Instance 0's
    vids equal the base vids and, at arrival 0 on group 0 (the identity
    group), its specs are *bit-identical* to
    :func:`repro.sim.build_specs` — the anchor below.
    """
    arrivals = [float(a) for a in arrivals]
    if not arrivals:
        raise ValueError("need at least one arrival")
    if any(a < 0 for a in arrivals):
        raise ValueError("arrival times must be >= 0")
    vids = sorted(q.members)
    stride = max(vids) + 1
    n_rep = plan.n_replicas
    blocks: list[BlockSpec] = []
    edges: list[EdgeSpec] = []
    release: dict[int, float] = {}
    for i, t_arr in enumerate(arrivals):
        g = i % n_rep
        off = i * stride
        for v in vids:
            p = plan.proc_for(g, q.proc[v])
            # same float expression as build_specs (bit-exactness)
            blocks.append(BlockSpec(
                off + v, p, q.weight[v] / platform.procs[p].speed))
            release[off + v] = t_arr
        edges.extend(EdgeSpec(off + u, off + w, c)
                     for u in vids
                     for w, c in sorted(q.succ[u].items()))
    return blocks, edges, release, stride


def _pipelined_memory_trace(
    wf, q, platform: Platform, plan: ThroughputPlan,
    start: dict[int, float], finish: dict[int, float],
    stride: int, n_instances: int,
    orders: dict[int, list[int]] | None = None,
    *, violation_limit: int = 64,
) -> MemoryTrace:
    """Occupancy summed across in-flight instances, per processor.

    Each instance's blocks contribute the same step function the
    single-instance tracker (:mod:`repro.sim.memory`) builds; here the
    steps become deltas accumulated per processor, so overlapping
    instances *sum* — and a transient violation names the instance
    whose task start pushed the occupancy over (``MemoryViolation
    .instance``).  Same memory model, same ``1e-9`` relative slack.
    """
    orders = orders or {}
    # (t, neg-before-pos, seq) -> delta, marker
    deltas: dict[int, list[tuple[float, int, int, float, tuple | None]]] = {}
    seq = 0
    for i in range(n_instances):
        g = i % plan.n_replicas
        off = i * stride
        for v in sorted(q.members):
            members = q.members[v]
            p = plan.proc_for(g, q.proc[v])
            speed = platform.procs[p].speed
            order = pick_block_order(wf, members, orders.get(v))
            base = sum(wf.persistent[u] for u in members)
            points: list[tuple[float, float, tuple | None]] = []
            t = start[off + v]
            points.append((t, base, None))
            for u, during, live_after in occupancy_steps(wf, members,
                                                         order):
                points.append((t, base + during, (i, v, u)))
                t = t + wf.work[u] / speed
                points.append((t, base + live_after, None))
            points.append((finish[off + v], 0.0, None))
            bucket = deltas.setdefault(p, [])
            prev = 0.0
            for t, val, marker in points:
                d = val - prev
                prev = val
                if d != 0.0 or marker is not None:
                    bucket.append((t, 0 if d < 0.0 else 1, seq, d, marker))
                    seq += 1

    per_proc: dict[int, list[tuple[float, float]]] = {}
    peak: dict[int, float] = {}
    violations: list[MemoryViolation] = []
    for p in sorted(deltas):
        cap = platform.memory(p)
        running = 0.0
        pts = per_proc.setdefault(p, [])
        for t, _, _, d, marker in sorted(deltas[p], key=lambda r: r[:3]):
            running += d
            pts.append((t, running))
            if running > peak.get(p, 0.0):
                peak[p] = running
            if (marker is not None and running > cap * _TOL
                    and len(violations) < violation_limit):
                inst, v, u = marker
                violations.append(MemoryViolation(
                    time=t, proc=p, vertex=v, task=u,
                    occupancy=running, capacity=cap, instance=inst))
    violations.sort(key=lambda v: (v.time, v.proc, v.task))
    return MemoryTrace(per_proc=per_proc, peak=peak, violations=violations)


@dataclass
class PipelinedReport:
    """What a pipelined N-instance replay observed.

    ``single_makespan`` is the canonical single-instance makespan
    computed exactly as :func:`repro.sim.simulate` computes it (CPM
    backward pass in the contention-free injective regime) — with one
    instance arriving at 0 it is bit-identical to
    ``simulate(...).makespan``, the subsystem's identity anchor, and
    ``exact_anchor`` records when that regime is in force.
    """

    comm: str
    n_instances: int
    n_replicas: int
    stride: int
    horizon: float
    achieved_rate: float
    single_makespan: float
    exact_anchor: bool
    instances: list[InstanceRecord]
    block_proc: dict[int, int]
    block_start: dict[int, float]
    block_finish: dict[int, float]
    transfers: list[TransferRecord] = field(default_factory=list)
    events: list[SimEvent] = field(default_factory=list)
    memory: MemoryTrace | None = None

    @property
    def latencies(self) -> list[float]:
        return [r.latency for r in self.instances]

    def percentile_latency(self, pct: float) -> float:
        """Exact percentile over the recorded instance latencies."""
        return float(np.percentile(np.asarray(self.latencies), pct))

    def to_dict(self) -> dict:
        return {
            "comm": self.comm,
            "n_instances": self.n_instances,
            "n_replicas": self.n_replicas,
            "stride": self.stride,
            "horizon": self.horizon,
            "achieved_rate": self.achieved_rate,
            "single_makespan": self.single_makespan,
            "exact_anchor": self.exact_anchor,
            "instances": [r.to_list() for r in self.instances],
            "blocks": [[v, self.block_proc[v], self.block_start[v],
                        self.block_finish[v]]
                       for v in sorted(self.block_proc)],
            "transfers": [t.to_list() for t in self.transfers],
            "events": [e.to_list() for e in self.events],
            "memory": self.memory.to_dict() if self.memory else None,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PipelinedReport":
        blocks = d.get("blocks", [])
        return cls(
            comm=d["comm"],
            n_instances=d["n_instances"],
            n_replicas=d["n_replicas"],
            stride=d["stride"],
            horizon=d["horizon"],
            achieved_rate=d["achieved_rate"],
            single_makespan=d["single_makespan"],
            exact_anchor=d.get("exact_anchor", False),
            instances=[InstanceRecord.from_list(r)
                       for r in d.get("instances", [])],
            block_proc={v: p for v, p, _, _ in blocks},
            block_start={v: s for v, _, s, _ in blocks},
            block_finish={v: f for v, _, _, f in blocks},
            transfers=[TransferRecord.from_list(t)
                       for t in d.get("transfers", [])],
            events=[SimEvent.from_list(e) for e in d.get("events", [])],
            memory=(MemoryTrace.from_dict(d["memory"])
                    if d.get("memory") else None),
        )


def simulate_pipelined(
    mapping,
    platform: Platform | None = None,
    *,
    arrivals=None,
    n_instances: int = 8,
    rate: float | None = None,
    arrival_kind: str = "poisson",
    seed: int = 0,
    plan: ThroughputPlan | None = None,
    comm="contention-free",
    memory: bool = True,
    record_events: bool = False,
    max_replicas: int | None = None,
    include_comm: bool = True,
) -> PipelinedReport:
    """Replay ``n_instances`` arrivals of one mapped plan, pipelined.

    ``arrivals`` is an :class:`~repro.throughput.arrivals.ArrivalSpec`,
    an explicit sequence of instants, or ``None`` — then ``rate`` plus
    ``arrival_kind`` build one.  ``plan`` is the replication to use
    (default: :func:`~repro.throughput.replicate.replicate_plan` of the
    mapping).  One instance arriving at 0 reproduces
    ``simulate(mapping, platform)`` bit-exactly (same specs, same
    engine, same backward pass).
    """
    res = getattr(mapping, "best", mapping)
    if res is None:
        raise ValueError("schedule report has no feasible mapping to "
                         "replay")
    q = res.quotient
    platform = platform if platform is not None else res.platform
    if plan is None:
        plan = replicate_plan(res, platform, max_replicas=max_replicas,
                              include_comm=include_comm)
    if arrivals is None:
        if rate is None:
            raise ValueError("pass arrivals= or rate=")
        arrivals = ArrivalSpec(rate, arrival_kind)
    if isinstance(arrivals, ArrivalSpec):
        arrivals = arrivals.times(n_instances, seed)
    arrivals = [float(a) for a in arrivals]
    n = len(arrivals)

    blocks, edges, release, stride = build_pipelined_specs(
        q, platform, plan, arrivals)
    comm_model = resolve_comm(comm)
    trace = run_engine(blocks, edges, comm_model, platform,
                       record_events=record_events, release=release)

    # canonical single-instance makespan, exactly as simulate() does
    base_blocks, base_edges = build_specs(q, platform)
    procs_used = {b.proc for b in base_blocks}
    injective = len(procs_used) == len(base_blocks)
    contention_free = isinstance(comm_model, ContentionFreeComm)
    if contention_free and injective:
        back = run_engine(base_blocks, transpose_edges(base_edges),
                          ContentionFreeComm(), _ReversedLinkView(platform),
                          record_events=False)
        single_ms = back.horizon
    else:
        solo = run_engine(base_blocks, base_edges, resolve_comm(comm),
                          platform, record_events=False)
        single_ms = solo.horizon
    exact_anchor = (contention_free and injective
                    and not platform.link_bandwidth)

    vids = sorted(q.members)
    instances = []
    for i, t_arr in enumerate(arrivals):
        off = i * stride
        instances.append(InstanceRecord(
            instance=i,
            replica=i % plan.n_replicas,
            arrival=t_arr,
            start=min(trace.start[off + v] for v in vids),
            finish=max(trace.finish[off + v] for v in vids),
        ))
    span = instances[-1].finish - min(r.arrival for r in instances)
    achieved = n / span if span > 0 else 0.0

    mem_trace = None
    if memory:
        mem_trace = _pipelined_memory_trace(
            q.wf, q, platform, plan, trace.start, trace.finish,
            stride, n, orders=res.extras.get("orders"))

    transfers = [
        TransferRecord(src=e.src, dst=e.dst, volume=e.volume,
                       start=trace.xfer_start[(e.src, e.dst)],
                       finish=trace.xfer_finish[(e.src, e.dst)])
        for e in edges
    ]
    return PipelinedReport(
        comm=comm_model.name,
        n_instances=n,
        n_replicas=plan.n_replicas,
        stride=stride,
        horizon=trace.horizon,
        achieved_rate=achieved,
        single_makespan=single_ms,
        exact_anchor=exact_anchor,
        instances=instances,
        block_proc={b.vid: b.proc for b in blocks},
        block_start=dict(trace.start),
        block_finish=dict(trace.finish),
        transfers=transfers,
        events=trace.events,
        memory=mem_trace,
    )
