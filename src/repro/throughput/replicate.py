"""Steady-state throughput analysis and plan replication.

Given one mapped plan (a feasible
:class:`~repro.core.baseline.MappingResult`), repeated workflow
instances can be pipelined: instance ``i+1`` starts while instance ``i``
is still draining.  In steady state every processor must fit one
instance's worth of its work — compute *and*, optionally, its share of
inter-processor transfer occupancy — into each period, so the
sustainable period is the bottleneck processor's busy time per instance
(:func:`proc_busy_times`) and the rate its reciprocal.

When the platform has idle processors, the mapped *block groups* can be
replicated onto disjoint processor groups: each replica group hosts a
full copy of the mapping (block ``v`` of group ``g`` runs on
``plan.proc_for(g, q.proc[v])``), instances are dealt round-robin to
groups, and the aggregate rate becomes ``n_groups / max_g period_g``.
Replica processors are matched by *dominance* — a free processor stands
in for a used one only when its speed and memory are both at least as
large — so every replica inherits the original plan's memory
feasibility and its latency never exceeds the original's (under the
uniform-β analytic model that prices latency).

Group 0 is always the identity mapping on the original processors; with
``max_replicas=1`` the analysis degrades to pure steady-state pricing
of the unreplicated plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.platform import Platform

__all__ = [
    "proc_busy_times",
    "ReplicaGroup",
    "ThroughputPlan",
    "replicate_plan",
]


def proc_busy_times(
    q,
    platform: Platform,
    proc_of: dict[int, int] | None = None,
    include_comm: bool = True,
) -> dict[int, float]:
    """Busy time per processor for *one* workflow instance.

    Compute time of every hosted block, plus — when ``include_comm`` —
    the occupancy of every cross-processor transfer on both its egress
    and ingress endpoint (a serial-port model: the processor is tied up
    for ``c / β`` while the edge moves, matching how the engine's
    transfer log attributes intervals).  ``proc_of`` substitutes
    processors (base → replica) before pricing, so the same function
    prices every replica group.
    """
    pm = proc_of or {}
    busy: dict[int, float] = {}
    for v in sorted(q.members):
        p = q.proc[v]
        if p is None:
            raise ValueError(
                f"block {v} is unassigned — throughput analysis needs a "
                "complete mapping"
            )
        p = pm.get(p, p)
        busy[p] = busy.get(p, 0.0) + q.weight[v] / platform.procs[p].speed
    if include_comm:
        for u in sorted(q.members):
            pu = pm.get(q.proc[u], q.proc[u])
            for w, c in sorted(q.succ[u].items()):
                pw = pm.get(q.proc[w], q.proc[w])
                if pu == pw:
                    continue
                d = c / platform.bandwidth_between(pu, pw)
                busy[pu] = busy.get(pu, 0.0) + d
                busy[pw] = busy.get(pw, 0.0) + d
    return busy


def _group_latency(
    q, platform: Platform, proc_of: dict[int, int] | None = None
) -> float:
    """Analytic per-instance latency of one replica group.

    The bottom-weight recursion of :func:`repro.core.makespan.makespan`
    with processor substitution: for the identity map the arithmetic is
    expression-for-expression identical, so the value is *bit-equal* to
    the plan's analytic makespan — the anchor the rate→0 identity test
    leans on.
    """
    pm = proc_of or {}
    beta = platform.bandwidth
    l: dict[int, float] = {}
    for v in reversed(q.topological_order()):
        p = pm.get(q.proc[v], q.proc[v])
        own = q.weight[v] / platform.procs[p].speed
        if not q.succ[v]:
            l[v] = own
        else:
            l[v] = own + max(
                c / beta + l[w] for w, c in q.succ[v].items()
            )
    return max(l.values()) if l else 0.0


@dataclass(frozen=True)
class ReplicaGroup:
    """One disjoint processor group hosting a full copy of the mapping.

    ``proc_map`` pairs every *used* base processor with its stand-in
    (identity pairs for group 0); ``period`` is the group's bottleneck
    busy time per instance, ``latency`` its analytic per-instance span.
    """

    proc_map: tuple[tuple[int, int], ...]
    period: float
    latency: float

    @property
    def procs(self) -> tuple[int, ...]:
        """The replica processors, in base-processor order."""
        return tuple(r for _, r in self.proc_map)

    def proc_for(self, base_proc: int) -> int:
        for b, r in self.proc_map:
            if b == base_proc:
                return r
        raise KeyError(f"processor {base_proc} is not used by the plan")

    def to_dict(self) -> dict:
        return {"proc_map": [list(pr) for pr in self.proc_map],
                "period": self.period, "latency": self.latency}

    @classmethod
    def from_dict(cls, d: dict) -> "ReplicaGroup":
        return cls(proc_map=tuple((int(b), int(r))
                                  for b, r in d["proc_map"]),
                   period=d["period"], latency=d["latency"])


@dataclass(frozen=True)
class ThroughputPlan:
    """Replication + steady-state pricing of one mapped plan.

    ``rate`` is the sustainable aggregate throughput in instances per
    time unit under round-robin instance→group dealing:
    ``n_replicas / max_g period_g`` (the slowest group paces the deal).
    ``latency`` is the worst group's analytic per-instance latency —
    group 0's value is bit-equal to the plan's analytic makespan.
    """

    groups: tuple[ReplicaGroup, ...]
    period: float
    rate: float
    latency: float
    include_comm: bool = True
    latency_bound: float | None = None
    extras: dict = field(default_factory=dict, compare=False)

    @property
    def n_replicas(self) -> int:
        return len(self.groups)

    @property
    def used_procs(self) -> tuple[int, ...]:
        return tuple(b for b, _ in self.groups[0].proc_map)

    def proc_for(self, group: int, base_proc: int) -> int:
        return self.groups[group].proc_for(base_proc)

    def to_dict(self) -> dict:
        return {
            "groups": [g.to_dict() for g in self.groups],
            "period": self.period,
            "rate": self.rate,
            "latency": self.latency,
            "include_comm": self.include_comm,
            "latency_bound": self.latency_bound,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ThroughputPlan":
        return cls(
            groups=tuple(ReplicaGroup.from_dict(g) for g in d["groups"]),
            period=d["period"],
            rate=d["rate"],
            latency=d["latency"],
            include_comm=d.get("include_comm", True),
            latency_bound=d.get("latency_bound"),
        )


def replicate_plan(
    result,
    platform: Platform | None = None,
    *,
    max_replicas: int | None = None,
    include_comm: bool = True,
    latency_bound: float | None = None,
) -> ThroughputPlan:
    """Price and replicate a mapped plan for sustained traffic.

    Greedy dominance matching: base processors are considered hardest
    first (descending speed, then memory) and each is matched to the
    *tightest* still-free processor that dominates it (minimal speed,
    then memory — don't burn an A1 standing in for a local).  Matching
    stops at the first base processor with no dominating stand-in, at
    ``max_replicas`` total groups, or at the first group whose analytic
    latency exceeds ``latency_bound``.

    The returned plan is always non-empty (group 0 is the identity);
    callers enforce ``latency_bound`` on group 0 themselves — the
    scheduler's ``throughput`` stage turns that into a
    :class:`~repro.core.scheduler.StageFailure`.
    """
    res = getattr(result, "best", result)
    if res is None:
        raise ValueError("schedule report has no feasible mapping to "
                         "replicate")
    q = res.quotient
    platform = platform if platform is not None else res.platform
    if max_replicas is not None and max_replicas < 1:
        raise ValueError(f"max_replicas must be >= 1, got {max_replicas}")

    busy0 = proc_busy_times(q, platform, include_comm=include_comm)
    used = sorted(busy0)
    identity = ReplicaGroup(
        proc_map=tuple((p, p) for p in used),
        period=max(busy0.values()),
        latency=_group_latency(q, platform),
    )
    groups = [identity]

    free = sorted(set(range(platform.k)) - set(used))
    # hardest-to-substitute base processors first
    order = sorted(
        used,
        key=lambda p: (-platform.procs[p].speed, -platform.procs[p].memory,
                       p),
    )
    while max_replicas is None or len(groups) < max_replicas:
        pm: dict[int, int] = {}
        taken: list[int] = []
        for b in order:
            sb, mb = platform.procs[b].speed, platform.procs[b].memory
            candidates = [
                j for j in free
                if j not in pm.values()
                and platform.procs[j].speed >= sb
                and platform.procs[j].memory >= mb
            ]
            if not candidates:
                pm = {}
                break
            j = min(candidates,
                    key=lambda j: (platform.procs[j].speed,
                                   platform.procs[j].memory, j))
            pm[b] = j
            taken.append(j)
        if not pm:
            break
        lat = _group_latency(q, platform, pm)
        if latency_bound is not None and lat > latency_bound:
            break
        busy = proc_busy_times(q, platform, pm, include_comm=include_comm)
        groups.append(ReplicaGroup(
            proc_map=tuple((b, pm[b]) for b in used),
            period=max(busy.values()),
            latency=lat,
        ))
        free = [j for j in free if j not in taken]

    worst_period = max(g.period for g in groups)
    return ThroughputPlan(
        groups=tuple(groups),
        period=worst_period,
        rate=len(groups) / worst_period if worst_period > 0 else 0.0,
        latency=max(g.latency for g in groups),
        include_comm=include_comm,
        latency_bound=latency_bound,
    )
