"""repro.throughput — steady-state pipelined scheduling of repeated
workflow instances.

One mapped plan answers "how fast can *one* instance finish"; this
subsystem answers "how many instances per second can the platform
*sustain*".  Three layers:

* **steady state** (:mod:`~repro.throughput.replicate`) — the
  sustainable period of a mapped plan is its bottleneck processor's
  busy time per instance (compute + transfer occupancy), and idle
  processors can host *replica groups* of the whole mapping (matched
  by speed/memory dominance, so feasibility and the latency bound are
  inherited).  Instances deal round-robin to groups:
  ``rate = n_groups / max_g period_g``.
* **pipelined replay** (:mod:`~repro.throughput.pipeline`) — N
  instances lowered into one :mod:`repro.sim` engine pass, released at
  seeded arrival instants (:mod:`~repro.throughput.arrivals`), with a
  memory-occupancy trace summed across in-flight instances.  One
  instance at rate→0 reproduces ``sim.simulate`` bit-exactly.
* **planning** (:mod:`~repro.throughput.plan`) — the scheduler's
  ``throughput`` pipeline prices every k' attempt's replicated rate;
  :func:`plan_throughput` picks the rate maximizer (k' and replication
  count jointly), :func:`saturation_sweep` maps the latency/throughput
  curve.

Entry points::

    from repro.throughput import plan_throughput, simulate_pipelined
    tr = plan_throughput(wf, platform, latency_bound=2.0)
    tr.rate, tr.plan.n_replicas
    rep = simulate_pipelined(tr.best, platform, rate=0.8 * tr.rate,
                             n_instances=64)
    rep.achieved_rate, rep.percentile_latency(99), rep.memory.feasible

Service-level sustained admission (arrival stream → ``ServiceReport``
with p50/p99 and the saturation point) lives in
:func:`repro.service.run_sustained`.
"""
from __future__ import annotations

from .arrivals import ArrivalSpec
from .pipeline import (
    InstanceRecord,
    PipelinedReport,
    build_pipelined_specs,
    simulate_pipelined,
)
from .plan import ThroughputResult, plan_throughput, saturation_sweep
from .replicate import (
    ReplicaGroup,
    ThroughputPlan,
    proc_busy_times,
    replicate_plan,
)

__all__ = [
    "ArrivalSpec",
    "InstanceRecord",
    "PipelinedReport",
    "ReplicaGroup",
    "ThroughputPlan",
    "ThroughputResult",
    "build_pipelined_specs",
    "plan_throughput",
    "proc_busy_times",
    "replicate_plan",
    "saturation_sweep",
    "simulate_pipelined",
]
