"""Rate-maximizing planning over the scheduler's k' sweep.

The ``throughput`` pipeline attaches a
:class:`~repro.throughput.replicate.ThroughputPlan` to every feasible
k' attempt and lands each attempt's sustainable rate as a
single-observation histogram in that sweep point's ``metrics`` block.
The scheduler's own best-result reduction still minimizes *makespan*
(one instance as fast as possible) — :func:`plan_throughput` instead
reads the per-point rate observations and selects the k' whose
replicated plan sustains the **highest instance rate**, re-running the
single winning k' when it differs from the makespan winner.  That is
the "replication count and k' sweep jointly" objective: a finer
partition may lose on latency yet free enough processors for an extra
replica group to win on throughput.

:func:`saturation_sweep` replays one plan against a ladder of offered
arrival rates (:func:`~repro.throughput.pipeline.simulate_pipelined`)
and reports achieved rate + latency percentiles per rung — the curve
whose knee is the saturation point the benchmarks and
``repro.service.run_sustained`` report.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.dag import Workflow
from repro.core.platform import Platform

from .arrivals import ArrivalSpec
from .pipeline import simulate_pipelined
from .replicate import ThroughputPlan

__all__ = ["ThroughputResult", "plan_throughput", "saturation_sweep"]


@dataclass
class ThroughputResult:
    """What :func:`plan_throughput` returns — never ``None``.

    ``report`` is the full k'-sweep :class:`ScheduleReport` (makespans,
    per-point rates in ``sweep[i].metrics``); ``best`` / ``plan`` the
    rate-maximizing mapping and its replication (``None`` when no
    attempt was feasible — the report's ``infeasibility`` says why).
    """

    report: object
    best: object | None
    plan: ThroughputPlan | None
    k_prime: int | None

    @property
    def feasible(self) -> bool:
        return self.plan is not None

    @property
    def rate(self) -> float | None:
        return self.plan.rate if self.plan is not None else None

    @property
    def latency(self) -> float | None:
        return self.plan.latency if self.plan is not None else None


def _point_rate(point) -> float | None:
    """The attempt's observed sustainable rate, from its metrics block.

    Histogram deltas are always present for the bracket that observed
    them (unchanged gauges are elided from deltas), and the throughput
    stage observes exactly once per attempt — so the single
    observation's value is the histogram's ``sum``.
    """
    h = point.metrics.get("histograms", {}).get("throughput_rate")
    if not h or not h.get("count"):
        return None
    return float(h["sum"])


def plan_throughput(
    wf: Workflow,
    platform: Platform,
    *,
    latency_bound: float | None = None,
    max_replicas: int | None = None,
    include_comm: bool = True,
    config=None,
    **overrides,
) -> ThroughputResult:
    """Plan ``wf`` for sustained traffic: maximize instances/s.

    Runs the registered ``throughput`` pipeline across the k' sweep
    (``config`` / ``overrides`` are
    :class:`~repro.core.scheduler.SchedulerConfig` material — ``kprime``,
    ``workers``, ``obs``, ...), then picks the attempt with the highest
    sustainable rate; ties prefer the smaller makespan, then the
    earlier sweep position.  ``latency_bound`` makes attempts whose
    *unreplicated* latency exceeds the bound structurally infeasible
    and stops replication at groups that would violate it.
    """
    from repro.core.scheduler import Scheduler, SchedulerConfig

    cfg = config if config is not None else SchedulerConfig()
    opts = dict(cfg.throughput_options or {})
    opts.setdefault("include_comm", include_comm)
    if latency_bound is not None:
        opts["latency_bound"] = latency_bound
    if max_replicas is not None:
        opts["max_replicas"] = max_replicas
    run_overrides = {"algorithm": "throughput",
                     "throughput_options": opts, **overrides}
    report = Scheduler(cfg, **run_overrides).schedule(wf, platform)
    if report.best is None:
        return ThroughputResult(report=report, best=None, plan=None,
                                k_prime=None)

    best_kp: int | None = None
    best_rate = -math.inf
    best_ms = math.inf
    for p in report.sweep:
        if not p.feasible:
            continue
        r = _point_rate(p)
        if r is None:
            continue
        if r > best_rate or (r == best_rate and p.makespan < best_ms):
            best_kp, best_rate, best_ms = p.k_prime, r, p.makespan
    best = report.best
    if best_kp is not None and best_kp != best.extras.get("k_prime"):
        # the rate winner lost the makespan reduction: re-materialize
        # it with a single-point sweep (stages are deterministic, so
        # this reproduces the attempt exactly)
        rerun = Scheduler(cfg, **{**run_overrides, "kprime": [best_kp],
                                  "workers": 1}).schedule(wf, platform)
        if rerun.best is not None:
            best = rerun.best
    plan = best.extras.get("throughput")
    return ThroughputResult(report=report, best=best, plan=plan,
                            k_prime=best.extras.get("k_prime"))


def saturation_sweep(
    mapping,
    platform: Platform | None = None,
    *,
    rates,
    plan: ThroughputPlan | None = None,
    n_instances: int = 32,
    arrival_kind: str = "poisson",
    seed: int = 0,
    comm="contention-free",
) -> list[dict]:
    """Offered-rate ladder: one pipelined replay per rate.

    Returns one row per offered rate — ``{"offered", "achieved",
    "p50", "p99", "saturated"}`` — where ``saturated`` flags rungs
    whose achieved rate fell more than 5% short of the offer (the
    pipeline can no longer keep up; latencies grow without bound past
    this knee).  Memory tracking and event recording are off: this is
    the bulk path behind ``make bench-throughput``.
    """
    rows = []
    for r in rates:
        rep = simulate_pipelined(
            mapping, platform,
            arrivals=ArrivalSpec(float(r), arrival_kind),
            n_instances=n_instances, seed=seed, plan=plan, comm=comm,
            memory=False, record_events=False)
        rows.append({
            "offered": float(r),
            "achieved": rep.achieved_rate,
            "p50": rep.percentile_latency(50),
            "p99": rep.percentile_latency(99),
            "saturated": rep.achieved_rate < 0.95 * float(r),
        })
    return rows
