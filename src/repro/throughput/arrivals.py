"""Seeded arrival processes for sustained-traffic replays.

An :class:`ArrivalSpec` turns ``(rate, kind, seed)`` into the arrival
instants of N workflow instances, in the same style as
:class:`repro.sim.perturb.JitterSpec`: a frozen spec whose draws are a
pure function of ``(seed, stream)`` through the shared
:func:`repro.sim.rng.stream_rng` helper — identical seeds give
identical traces across subsystems, processes and platforms.

Kinds:

* ``poisson`` — exponential inter-arrival gaps of mean ``1/rate`` (the
  classic open-loop traffic model; bursts stress the pipelined
  schedule beyond its steady-state period);
* ``deterministic`` — exact spacing ``1/rate`` (the periodic regime the
  steady-state analysis in :mod:`repro.throughput.replicate` prices).

``rate`` is in instances per virtual time unit, the same clock the
simulation engine runs on.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.sim.rng import stream_rng

__all__ = ["ArrivalSpec"]

# SeedSequence namespace for arrival draws (jitter uses 0x51D0)
_ARRIVAL_TAG = 0xA221

_KINDS = ("poisson", "deterministic")


@dataclass(frozen=True)
class ArrivalSpec:
    """How instances arrive: ``kind`` ∈ {poisson, deterministic}."""

    rate: float
    kind: str = "poisson"
    start: float = 0.0

    def __post_init__(self) -> None:
        if not self.rate > 0:
            raise ValueError(f"arrival rate must be > 0, got {self.rate}")
        if self.kind not in _KINDS:
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        if self.start < 0:
            raise ValueError("arrival start must be >= 0")

    def times(self, n: int, seed: int = 0, stream: int = 0) -> np.ndarray:
        """Arrival instants of instances ``0..n-1`` (non-decreasing).

        ``deterministic`` arrivals begin *at* ``start`` (instance 0
        arrives exactly then — the rate→0 limit reproduces a solo
        run released at ``start``); ``poisson`` arrivals begin one
        exponential gap after it, as a Poisson process does.
        """
        if n < 1:
            raise ValueError(f"need at least one instance, got {n}")
        if self.kind == "deterministic":
            return self.start + np.arange(n, dtype=np.float64) / self.rate
        rng = stream_rng(_ARRIVAL_TAG, seed, stream)
        gaps = rng.exponential(1.0 / self.rate, size=n)
        return self.start + np.cumsum(gaps)
