"""Fault-tolerant checkpointing.

* msgpack-framed tensor store (no external deps), one file per step,
* atomic writes (tmp + rename) so a crash mid-save never corrupts the
  latest checkpoint,
* async mode: saves happen on a background thread from a snapshotted
  host copy, overlapping with the next train steps,
* retention of the last ``keep`` checkpoints,
* restore-to-a-different-mesh: arrays are saved unsharded (gathered);
  the loader re-shards onto whatever mesh/sharding the caller passes —
  this is what elastic rescale (repro.runtime.elastic) builds on.
"""
from __future__ import annotations

import io
import json
import os
import threading
from pathlib import Path

import jax
import numpy as np

__all__ = ["Checkpointer", "save_pytree", "load_pytree"]

_SENTINEL = "__leaf__"


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _unflatten_into(skeleton, flat, prefix=""):
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(skeleton[k], flat, f"{prefix}/{k}")
                for k in skeleton}
    if isinstance(skeleton, (list, tuple)):
        out = [_unflatten_into(v, flat, f"{prefix}/{i}")
               for i, v in enumerate(skeleton)]
        return type(skeleton)(out)
    return flat[prefix]


def save_pytree(path: Path, tree, extra_meta: dict | None = None) -> None:
    """Atomically write a pytree of arrays to ``path``."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    entries = []
    with open(tmp, "wb") as f:
        header_items = []
        blobs = []
        offset = 0
        for key, leaf in _flatten(tree):
            arr = np.asarray(jax.device_get(leaf))
            # bfloat16 has no numpy wire format -> view as uint16
            wire_dtype = str(arr.dtype)
            if wire_dtype == "bfloat16":
                arr = arr.view(np.uint16)
            blob = arr.tobytes()
            header_items.append({
                "key": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "orig_dtype": wire_dtype,
                "offset": offset,
                "nbytes": len(blob),
            })
            blobs.append(blob)
            offset += len(blob)
        header = json.dumps({
            "leaves": header_items,
            "meta": extra_meta or {},
        }).encode()
        f.write(len(header).to_bytes(8, "little"))
        f.write(header)
        for blob in blobs:
            f.write(blob)
    os.replace(tmp, path)


def load_pytree(path: Path, skeleton, shardings=None):
    """Load a pytree saved by :func:`save_pytree`.

    ``skeleton`` supplies the structure; ``shardings`` (same structure,
    of jax.sharding.Sharding) re-shards each leaf on load — pass the
    *new* mesh's shardings to restore elastically.
    """
    path = Path(path)
    with open(path, "rb") as f:
        hlen = int.from_bytes(f.read(8), "little")
        header = json.loads(f.read(hlen))
        base = f.tell()
        flat = {}
        for item in header["leaves"]:
            f.seek(base + item["offset"])
            buf = f.read(item["nbytes"])
            arr = np.frombuffer(buf, dtype=item["dtype"]).reshape(
                item["shape"])
            if item["orig_dtype"] == "bfloat16":
                import jax.numpy as jnp
                arr = arr.view(jnp.bfloat16.dtype)
            flat[item["key"]] = arr
    tree = _unflatten_into(skeleton, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def checkpoint_meta(path: Path) -> dict:
    with open(path, "rb") as f:
        hlen = int.from_bytes(f.read(8), "little")
        return json.loads(f.read(hlen))["meta"]


class Checkpointer:
    """Step-indexed checkpoint directory manager with async saves."""

    def __init__(self, directory, keep: int = 3,
                 async_save: bool = True) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    def _path(self, step: int) -> Path:
        return self.dir / f"ckpt_{step:09d}.msgpack"

    def steps(self) -> list[int]:
        return sorted(
            int(p.stem.split("_")[1]) for p in self.dir.glob("ckpt_*.msgpack"))

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, tree, extra_meta: dict | None = None) -> None:
        self.wait()
        # snapshot to host immediately; write possibly in background
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        meta = dict(extra_meta or {}, step=step)

        def write():
            save_pytree(self._path(step), host_tree, meta)
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def restore(self, skeleton, step: int | None = None, shardings=None):
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        tree = load_pytree(self._path(step), skeleton, shardings)
        meta = checkpoint_meta(self._path(step))
        return tree, meta

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            try:
                self._path(s).unlink()
            except FileNotFoundError:
                pass
