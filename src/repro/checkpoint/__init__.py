from .checkpointer import Checkpointer, load_pytree, save_pytree

__all__ = ["Checkpointer", "load_pytree", "save_pytree"]
