"""``repro.obs`` — spans, typed metrics, Chrome-trace/JSONL export.

The observability layer the scheduler (:mod:`repro.core.scheduler`),
simulator (:mod:`repro.sim`), scenario runner and service loop
(:mod:`repro.service`) are instrumented with:

* :mod:`repro.obs.tracer` — hierarchical wall-clock spans
  (``run → sweep_point → stage.* → probe.*`` on the scheduler side,
  ``service.admit / service.dispatch / service.plan / service.replan /
  service.complete`` on the service side) behind a no-op fast path;
* :mod:`repro.obs.metrics` — the :data:`~repro.obs.metrics.METRICS`
  registry of counters + gauges + fixed-bucket histograms
  (``repro.core.counters`` is its counter facet), with the
  snapshot/delta/merge protocol that ships per-worker metrics back
  through ``SweepPoint`` picklably;
* :mod:`repro.obs.export` — Chrome trace-event JSON
  (``chrome://tracing`` / Perfetto) with wall and virtual clock
  domains on separate ``pid``\\ s, and the :class:`JsonlSink` event
  log.

Everything is driven by an :class:`ObsConfig` threaded through
``SchedulerConfig(obs=...)`` and ``ServiceConfig(obs=...)``.  The
contract: instrumentation is **inert** (bit-identical makespans and
service traces on/off) and near-free when disabled.  See
``docs/observability.md`` for the span taxonomy and metric names.
"""
from __future__ import annotations

import logging
import sys
from dataclasses import dataclass

from .export import (
    JsonlSink,
    service_virtual_events,
    sim_proc_events,
    span_events,
    write_chrome_trace,
)
from .metrics import (
    DEFAULT_BOUNDARIES,
    METRICS,
    Histogram,
    MetricsRegistry,
    RATIO_BOUNDARIES,
    percentile,
    percentiles,
)
from .tracer import (
    Span,
    Tracer,
    activate,
    current_tracer,
    span_attr,
    trace_span,
    tracing_active,
)

__all__ = [
    "DEFAULT_BOUNDARIES",
    "Histogram",
    "JsonlSink",
    "METRICS",
    "MetricsRegistry",
    "ObsConfig",
    "RATIO_BOUNDARIES",
    "Span",
    "Tracer",
    "activate",
    "current_tracer",
    "percentile",
    "percentiles",
    "service_virtual_events",
    "setup_logging",
    "sim_proc_events",
    "span_attr",
    "span_events",
    "trace_span",
    "tracing_active",
    "write_chrome_trace",
]


@dataclass(frozen=True)
class ObsConfig:
    """One switchboard for a run's observability (picklable).

    ``enabled`` turns span tracing on (metrics/counters always record:
    they are cheap, and reports carry their deltas regardless).
    ``sink`` names a JSONL event-log path — service narration and span
    records stream there as they happen.  ``trace_path`` writes the
    Chrome trace at the end of the run.  ``probe_spans`` opts into
    per-probe spans in the incremental engine (off by default; see
    :class:`~repro.obs.tracer.Tracer`).
    """

    enabled: bool = False
    sink: str | None = None
    trace_path: str | None = None
    probe_spans: bool = False

    def make_tracer(self) -> Tracer | None:
        """A fresh tracer when ``enabled``, else ``None`` (feed to
        :func:`activate`, which treats ``None`` as a passthrough)."""
        if not self.enabled:
            return None
        return Tracer(probe_spans=self.probe_spans)


def setup_logging(level: int = logging.INFO, *,
                  stream=None) -> logging.Logger:
    """Attach a plain-message handler to the ``repro`` logger.

    The library logs through module-level ``logging`` loggers and, per
    library convention, never installs handlers on import — narration
    is silent until the application configures logging.  CLI entry
    points (``repro.launch.*``, benchmarks) call this to restore the
    classic ``print()`` behaviour: bare messages, no timestamps, to
    ``stdout``.  Idempotent.
    """
    logger = logging.getLogger("repro")
    if not any(getattr(h, "_repro_default", False)
               for h in logger.handlers):
        h = logging.StreamHandler(stream if stream is not None
                                  else sys.stdout)
        h.setFormatter(logging.Formatter("%(message)s"))
        h._repro_default = True
        logger.addHandler(h)
    logger.setLevel(level)
    return logger
