"""Exporters: Chrome trace-event JSON (Perfetto-loadable) and JSONL.

Two output shapes, one file format each:

* :func:`write_chrome_trace` — the Trace Event Format consumed by
  ``chrome://tracing`` and https://ui.perfetto.dev.  Wall-clock spans
  become matched ``B``/``E`` duration events (nesting renders the span
  hierarchy), virtual-time service activity becomes ``X`` complete
  events on per-tenant/per-job tracks plus a busy-processor counter
  track, and simulated executions become per-processor ``X`` tracks —
  each group under its own ``pid`` so wall-time and virtual-time
  clock domains never interleave on one track.
* :class:`JsonlSink` — line-oriented JSON event log (one dict per
  line): service narration, span records, anything ``emit()``-ed.

Builders are composable: :func:`span_events`,
:func:`service_virtual_events` and :func:`sim_proc_events` each return
plain event dicts; :func:`write_chrome_trace` sorts and wraps them.
"""
from __future__ import annotations

import json
from pathlib import Path

__all__ = [
    "JsonlSink",
    "service_virtual_events",
    "sim_proc_events",
    "span_events",
    "write_chrome_trace",
]

_US = 1e6  # seconds -> Chrome trace microseconds


def span_events(spans, *, pid: str = "wall", t0: float | None = None,
                cat: str = "span") -> list[dict]:
    """Matched ``B``/``E`` event pairs from finished :class:`Span`s.

    ``t0`` rebases timestamps (defaults to the earliest span start, so
    the trace begins at 0).  Within one track, ties are broken so that
    ends precede begins (back-to-back siblings), outer spans open
    before inner ones and inner spans close before outer ones —
    Perfetto's stack discipline holds even for zero-duration spans.
    """
    if not spans:
        return []
    if t0 is None:
        t0 = min(s.ts for s in spans)
    raw: list[tuple[float, int, int, dict]] = []
    for s in spans:
        ts = (s.ts - t0) * _US
        te = ts + s.dur * _US
        args = {k: v for k, v in s.attrs.items()}
        raw.append((ts, 1, s.depth, {
            "name": s.name, "ph": "B", "ts": ts, "pid": pid,
            "tid": s.tid, "cat": cat, "args": args,
        }))
        # zero-duration spans must still close after they open: their
        # E ties their own B, so it sorts *after* begins (order 2),
        # while ordinary ends keep preceding same-ts begins (order 0)
        raw.append((te, 0 if s.dur > 0 else 2, -s.depth, {
            "name": s.name, "ph": "E", "ts": te, "pid": pid,
            "tid": s.tid, "cat": cat,
        }))
    raw.sort(key=lambda r: (r[3]["tid"], r[0], r[1], r[2]))
    return [r[3] for r in raw]


def service_virtual_events(trace, *, pid: str = "virtual",
                           unit_s: float = 1.0) -> list[dict]:
    """Virtual-time tracks from a :class:`ServiceTrace`.

    One track per tenant (jobs stack as ``X`` slices: a ``queued``
    slice from arrival to dispatch, a ``run`` slice from dispatch to
    finish), one instant marker per platform event, and a ``busy
    procs`` counter track from the utilization change points.  Virtual
    time maps to trace microseconds at ``unit_s`` seconds per unit.
    """
    scale = unit_s * _US
    ev: list[dict] = []
    for j in trace.jobs:
        if j.status == "rejected":
            continue
        tid = f"tenant:{j.tenant}"
        end = j.finish_t if j.finish_t is not None else trace.horizon
        disp = j.dispatch_t if j.dispatch_t is not None else end
        if disp > j.arrival_t:
            ev.append({
                "name": f"{j.name}#{j.job_id} queued", "ph": "X",
                "ts": j.arrival_t * scale,
                "dur": (disp - j.arrival_t) * scale,
                "pid": pid, "tid": tid, "cat": "job",
                "args": {"status": j.status, "tenant": j.tenant},
            })
        if end > disp or j.status == "completed":
            ev.append({
                "name": f"{j.name}#{j.job_id}", "ph": "X",
                "ts": disp * scale, "dur": (end - disp) * scale,
                "pid": pid, "tid": tid, "cat": "job",
                "args": {
                    "status": j.status,
                    "planning_path": j.planning_path,
                    "k_prime": j.k_prime,
                    "n_replans": j.n_replans,
                    "procs": list(j.allocation),
                },
            })
    for e in trace.events:
        ev.append({
            "name": e.get("kind", "event"), "ph": "i",
            "ts": float(e["time"]) * scale, "pid": pid,
            "tid": "platform", "cat": "event", "s": "p",
            "args": {"detail": e.get("detail", "")},
        })
    for t, busy, k in trace.utilization:
        ev.append({
            "name": "busy procs", "ph": "C", "ts": t * scale,
            "pid": pid, "tid": "platform", "cat": "util",
            "args": {"busy": busy, "total": k},
        })
    return ev


def sim_proc_events(sim, *, pid: str = "sim", unit_s: float = 1.0,
                    t_offset: float = 0.0,
                    stride: int | None = None) -> list[dict]:
    """Per-processor ``X`` tracks from a :class:`repro.sim.SimReport`
    (or anything exposing ``.events`` of ``SimEvent``'s shape).
    ``t_offset`` shifts the segment onto a service/scenario timeline.

    ``stride`` decodes pipelined multi-instance replays
    (:func:`repro.throughput.simulate_pipelined` lowers instance ``i``'s
    block ``v`` to vertex ``i*stride + v``, and its own report exposes
    the stride): slices are named ``i{instance}:b{block}`` and carry
    ``instance`` in their args, so per-instance overlap on one
    processor track is readable — and ``tools/trace_view.py
    --per-instance`` can split tracks per instance.
    """
    scale = unit_s * _US

    def decode(v: int) -> tuple[int | None, int]:
        if stride is None:
            return None, v
        return v // stride, v % stride

    open_at: dict[tuple, float] = {}
    ev: list[dict] = []
    for e in sim.events:
        if e.kind == "task_start":
            open_at[("t", e.vertex)] = e.time
        elif e.kind == "task_finish":
            t0 = open_at.pop(("t", e.vertex), None)
            if t0 is not None:
                inst, base = decode(e.vertex)
                args = {"vertex": base}
                name = f"block {base}"
                if inst is not None:
                    args["instance"] = inst
                    name = f"i{inst}:b{base}"
                ev.append({
                    "name": name, "ph": "X",
                    "ts": (t0 + t_offset) * scale,
                    "dur": (e.time - t0) * scale,
                    "pid": pid, "tid": f"proc:{e.proc}", "cat": "task",
                    "args": args,
                })
        elif e.kind == "transfer_start":
            open_at[("x", e.edge)] = e.time
        elif e.kind == "transfer_finish":
            t0 = open_at.pop(("x", e.edge), None)
            if t0 is not None:
                inst, src = decode(e.edge[0])
                _, dst = decode(e.edge[1])
                args = {"edge": [src, dst]}
                name = f"xfer {src}→{dst}"
                if inst is not None:
                    args["instance"] = inst
                    name = f"i{inst}:xfer {src}→{dst}"
                ev.append({
                    "name": name, "ph": "X",
                    "ts": (t0 + t_offset) * scale,
                    "dur": (e.time - t0) * scale,
                    "pid": pid, "tid": "transfers", "cat": "transfer",
                    "args": args,
                })
    return ev


def write_chrome_trace(path, events, *, meta: dict | None = None) -> Path:
    """Sort ``events`` by timestamp and write the Trace Event JSON.

    The global sort keeps ``ts`` monotone across the whole file (the
    schema property ``tools/trace_view.py`` and the tests check);
    per-track B/E ordering from :func:`span_events` is preserved for
    equal timestamps because ``sort`` is stable.
    """
    path = Path(path)
    doc = {
        "traceEvents": sorted(events, key=lambda e: e["ts"]),
        "displayTimeUnit": "ms",
    }
    if meta:
        doc["otherData"] = meta
    path.write_text(json.dumps(doc))
    return path


class JsonlSink:
    """Append-mode line-oriented JSON event log.

    ``emit(dict)`` writes one compact JSON line immediately (narration
    streams out even if the run dies); ``close()`` flushes.  Usable as
    a context manager.  A ``None`` path builds a disabled sink whose
    ``emit`` is a no-op — call sites never need to branch.
    """

    def __init__(self, path=None) -> None:
        self.path = Path(path) if path is not None else None
        self._fh = self.path.open("a") if self.path is not None else None

    @property
    def enabled(self) -> bool:
        return self._fh is not None

    def emit(self, record: dict) -> None:
        if self._fh is not None:
            self._fh.write(json.dumps(record, separators=(",", ":"))
                           + "\n")

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
