"""Typed metrics registry: counters + gauges + fixed-bucket histograms.

This generalizes the flat :mod:`repro.core.counters` ``Counter`` to
three metric kinds behind one global :data:`METRICS` registry with the
same *snapshot/delta* protocol the scheduler already uses to bracket a
pipeline run:

* **counters** — monotonically increasing integers (``counter(name)``).
  :data:`METRICS.counters` *is* the ``collections.Counter`` that
  ``repro.core.counters.COUNTERS`` aliases, so every existing
  ``bump()`` call site feeds this registry unchanged.
* **gauges** — last-write-wins floats (``gauge(name, value)``): queue
  depths, cache sizes, horizons.
* **histograms** — fixed-boundary bucket counts plus ``sum`` / ``count``
  / ``min`` / ``max`` (``observe(name, value)``): plan latencies, queue
  waits, makespan premia.  Quantiles (p50/p95/p99) are estimated from
  the buckets by :func:`percentile` — log-spaced default boundaries
  keep the estimate within a bucket's relative width.

Everything snapshots to plain dicts (:meth:`MetricsRegistry.snapshot`),
deltas against a snapshot (:meth:`MetricsRegistry.delta`), merges a
delta back in (:meth:`MetricsRegistry.merge`) and pickles — that is
how per-worker metrics ship back through ``SweepPoint`` under the
fork/spawn process-pool k' sweep and aggregate in the parent.

Metrics only ever *record* — they never influence control flow — so
instrumentation cannot change scheduling results (the same contract
:mod:`repro.core.counters` documents).
"""
from __future__ import annotations

import math
from collections import Counter

__all__ = [
    "DEFAULT_BOUNDARIES",
    "Histogram",
    "METRICS",
    "MetricsRegistry",
    "RATIO_BOUNDARIES",
    "percentile",
    "percentiles",
]

#: log-spaced (2 buckets/decade) boundaries for duration-like values —
#: wall-clock seconds and virtual time units alike span 1e-4 .. 1e5.
DEFAULT_BOUNDARIES: tuple[float, ...] = tuple(
    round(10 ** (e / 2), 6) for e in range(-8, 11)
)

#: boundaries for ratios hovering around 1.0 (e.g. the makespan premium
#: a seeded plan pays over its cached winner).
RATIO_BOUNDARIES: tuple[float, ...] = (
    0.5, 0.9, 0.99, 1.0, 1.01, 1.02, 1.05, 1.1, 1.25, 1.5, 2.0, 4.0)


class Histogram:
    """Fixed-boundary bucket histogram (cumulative stats, not samples).

    ``boundaries`` are the *upper* bucket edges; values above the last
    edge land in an overflow bucket, so ``counts`` has
    ``len(boundaries) + 1`` entries.  The exact ``sum`` / ``count`` /
    ``min`` / ``max`` ride along, so means are exact and quantile
    estimates are clamped to the observed range.
    """

    __slots__ = ("boundaries", "counts", "sum", "count", "min", "max")

    def __init__(self, boundaries=DEFAULT_BOUNDARIES) -> None:
        self.boundaries = tuple(float(b) for b in boundaries)
        if list(self.boundaries) != sorted(set(self.boundaries)):
            raise ValueError("histogram boundaries must be strictly "
                             "increasing")
        self.counts = [0] * (len(self.boundaries) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        lo, hi = 0, len(self.boundaries)
        while lo < hi:                      # first boundary >= value
            mid = (lo + hi) // 2
            if self.boundaries[mid] < value:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1
        self.sum += value
        self.count += 1
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    @property
    def mean(self) -> float | None:
        """Exact mean of every observation (``None`` when empty) —
        ``sum``/``count`` ride along precisely for this."""
        return self.sum / self.count if self.count else None

    # ------------------------------------------------------------ #
    def to_dict(self) -> dict:
        """Plain-dict form (JSON- and pickle-friendly)."""
        return {
            "boundaries": list(self.boundaries),
            "counts": list(self.counts),
            "sum": self.sum,
            "count": self.count,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls(d["boundaries"])
        h.counts = [int(c) for c in d["counts"]]
        h.sum = float(d["sum"])
        h.count = int(d["count"])
        h.min = math.inf if d.get("min") is None else float(d["min"])
        h.max = -math.inf if d.get("max") is None else float(d["max"])
        return h

    def merge_dict(self, d: dict) -> None:
        """Fold a compatible histogram dict into this histogram."""
        if tuple(d["boundaries"]) != self.boundaries:
            raise ValueError("histogram boundary mismatch on merge")
        for i, c in enumerate(d["counts"]):
            self.counts[i] += int(c)
        self.sum += float(d["sum"])
        self.count += int(d["count"])
        if d.get("min") is not None:
            self.min = min(self.min, float(d["min"]))
        if d.get("max") is not None:
            self.max = max(self.max, float(d["max"]))


def _delta_hist(cur: dict, old: dict | None) -> dict | None:
    """``cur - old`` for two histogram dicts (None when nothing moved).

    min/max are not subtractable; the delta keeps the *current* values
    (exact when the snapshot was empty — the per-run bracket case)."""
    if old is None:
        return cur if cur["count"] else None
    if cur["count"] == old["count"]:
        return None
    return {
        "boundaries": list(cur["boundaries"]),
        "counts": [a - b for a, b in zip(cur["counts"], old["counts"])],
        "sum": cur["sum"] - old["sum"],
        "count": cur["count"] - old["count"],
        "min": cur["min"],
        "max": cur["max"],
    }


def percentile(hist: dict, q: float) -> float | None:
    """Estimate the ``q``-th percentile (0..100) from a histogram dict.

    Linear interpolation inside the containing bucket, clamped to the
    observed ``[min, max]`` range; ``None`` on an empty histogram.
    """
    count = hist["count"]
    if not count:
        return None
    lo_clamp = hist.get("min")
    hi_clamp = hist.get("max")
    rank = q / 100.0 * count
    cum = 0
    bounds = hist["boundaries"]
    for i, c in enumerate(hist["counts"]):
        if c == 0:
            continue
        if cum + c >= rank:
            lo = bounds[i - 1] if i > 0 else (
                lo_clamp if lo_clamp is not None else 0.0)
            hi = bounds[i] if i < len(bounds) else (
                hi_clamp if hi_clamp is not None else lo)
            frac = (rank - cum) / c
            est = lo + (hi - lo) * max(0.0, min(1.0, frac))
            if lo_clamp is not None:
                est = max(est, lo_clamp)
            if hi_clamp is not None:
                est = min(est, hi_clamp)
            return est
        cum += c
    return hi_clamp


def percentiles(hist: dict, qs=(50, 95, 99)) -> dict[str, float] | None:
    """``{"p50": ..., "p95": ..., "p99": ...}`` or ``None`` if empty."""
    if not hist or not hist.get("count"):
        return None
    return {f"p{g:g}": percentile(hist, g) for g in qs}


class MetricsRegistry:
    """The process-global home of counters, gauges and histograms."""

    def __init__(self) -> None:
        self.counters: Counter = Counter()
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}

    # recording -------------------------------------------------- #
    def counter(self, name: str, n: int = 1) -> None:
        self.counters[name] += n

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float,
                boundaries=None) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(
                boundaries if boundaries is not None
                else DEFAULT_BOUNDARIES)
        h.observe(value)

    # snapshot / delta / merge ----------------------------------- #
    def snapshot(self) -> dict:
        """Detached copy of everything (the delta bracket's opening)."""
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.to_dict()
                           for k, h in self.histograms.items()},
        }

    def delta(self, snap: dict) -> dict:
        """What moved since ``snap`` — same shape as :meth:`snapshot`,
        sparse (untouched metrics are omitted).  Picklable and
        JSON-serializable: this is what crosses process boundaries."""
        counters = {
            k: v - snap["counters"].get(k, 0)
            for k, v in self.counters.items()
            if v != snap["counters"].get(k, 0)
        }
        gauges = {k: v for k, v in self.gauges.items()
                  if snap["gauges"].get(k) != v}
        hists = {}
        for k, h in self.histograms.items():
            d = _delta_hist(h.to_dict(), snap["histograms"].get(k))
            if d is not None:
                hists[k] = d
        out: dict = {}
        if counters:
            out["counters"] = counters
        if gauges:
            out["gauges"] = gauges
        if hists:
            out["histograms"] = hists
        return out

    def merge(self, delta: dict) -> None:
        """Fold a :meth:`delta` (e.g. shipped from a worker process)
        into this registry — the parent-side half of the per-worker
        metrics protocol."""
        for k, v in delta.get("counters", {}).items():
            self.counters[k] += v
        for k, v in delta.get("gauges", {}).items():
            self.gauges[k] = v
        for k, d in delta.get("histograms", {}).items():
            h = self.histograms.get(k)
            if h is None:
                self.histograms[k] = Histogram.from_dict(d)
            else:
                h.merge_dict(d)

    def restore(self, snap: dict) -> None:
        """Reset the registry to a prior :meth:`snapshot` (test
        isolation: the autouse fixture brackets every test)."""
        self.counters.clear()
        self.counters.update(snap["counters"])
        self.gauges.clear()
        self.gauges.update(snap["gauges"])
        self.histograms = {k: Histogram.from_dict(d)
                           for k, d in snap["histograms"].items()}

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()


#: the process-global registry; ``repro.core.counters.COUNTERS`` is an
#: alias of ``METRICS.counters``.
METRICS = MetricsRegistry()
